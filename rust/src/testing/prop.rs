//! Mini property-based testing harness.
//!
//! `forall(cases, f)` runs `f` against `cases` deterministic seeds; on
//! failure it reports the seed so the case replays exactly. `Gen` wraps
//! the crate PRNG with the generators our invariants need (random graphs,
//! partitions, k values). No shrinking — cases are small enough to debug
//! at face value, and the seed pins them.

use crate::graph::{Graph, GraphBuilder};
use crate::util::rng::Rng;

/// Generator context for one property case.
pub struct Gen {
    /// The case's deterministic PRNG — draw named seeds from it.
    pub rng: Rng,
    /// The case seed (printed on failure for exact replay).
    pub seed: u64,
}

impl Gen {
    /// Uniform usize in [lo, hi] (inclusive).
    pub fn int(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range(lo, hi + 1)
    }

    /// Uniform f64 in [lo, hi).
    pub fn float(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.f64() * (hi - lo)
    }

    /// A connected random graph with `n in [n_lo, n_hi]` vertices and
    /// average degree in [2, 6].
    pub fn graph(&mut self, n_lo: usize, n_hi: usize) -> Graph {
        let n = self.int(n_lo.max(4), n_hi);
        let avg = self.float(2.0, 6.0);
        let m = ((n as f64 * avg / 2.0) as usize).max(n - 1);
        let seed = self.rng.next_u64();
        crate::graph::generators::GraphKind::ErdosRenyi { n, m }
            .generate(seed)
    }

    /// An arbitrary (possibly disconnected, clustered) graph.
    pub fn any_graph(&mut self, n_lo: usize, n_hi: usize) -> Graph {
        use crate::graph::generators::GraphKind;
        let n = self.int(n_lo.max(6), n_hi);
        let seed = self.rng.next_u64();
        match self.int(0, 3) {
            0 => GraphKind::ErdosRenyi { n, m: n * 2 }.generate(seed),
            1 => {
                GraphKind::PowerlawCluster { n, m: 3, p: 0.4 }.generate(seed)
            }
            2 => GraphKind::WattsStrogatz {
                n,
                k: 4,
                beta: 0.1,
            }
            .generate(seed),
            _ => {
                // union of two ER components (disconnected)
                let half = n / 2;
                let a = GraphKind::ErdosRenyi { n: half, m: half * 2 }
                    .generate(seed);
                let b = GraphKind::ErdosRenyi {
                    n: n - half,
                    m: (n - half) * 2,
                }
                .generate(seed ^ 1);
                let mut builder = GraphBuilder::new();
                for (_, u, v) in a.edge_iter() {
                    builder.push_edge(u, v);
                }
                let off = a.vertex_count() as u32;
                for (_, u, v) in b.edge_iter() {
                    builder.push_edge(u + off, v + off);
                }
                builder.build()
            }
        }
    }
}

/// Run a property over `cases` deterministic cases. Panics with the seed
/// on the first failure.
pub fn forall(cases: usize, mut prop: impl FnMut(&mut Gen)) {
    for case in 0..cases {
        let seed = 0xD1CE_0000u64 + case as u64;
        let mut gen = Gen { rng: Rng::new(seed), seed };
        let result = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| prop(&mut gen)),
        );
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| err.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!("property failed on case {case} (seed {seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_runs_all_cases() {
        let mut count = 0;
        forall(25, |_| count += 1);
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "property failed on case 3")]
    fn forall_reports_seed() {
        let mut i = 0;
        forall(10, |_| {
            assert!(i < 3, "boom");
            i += 1;
        });
    }

    #[test]
    fn generated_graphs_are_valid() {
        forall(10, |g| {
            let graph = g.any_graph(10, 60);
            assert!(graph.edge_count() > 0);
            for (_, u, v) in graph.edge_iter() {
                assert!(u < v);
                assert!((v as usize) < graph.vertex_count());
            }
        });
    }
}
