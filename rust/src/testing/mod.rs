//! Test substrate: a small property-based testing harness (the vendored
//! crate set has no `proptest`).

pub mod prop;
