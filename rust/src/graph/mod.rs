//! Graph substrate: CSR storage, builders, IO, generators, statistics,
//! out-of-core edge streaming and the Fig-6 rewiring protocol.
//!
//! Graphs are simple undirected graphs with contiguous `u32` vertex ids and
//! explicit edge ids (`0..m`) — DFEP partitions *edges*, so edge identity
//! is first-class throughout the crate. When the graph is too large to
//! materialize, [`stream::EdgeStream`] delivers the edge sequence in
//! bounded-memory chunks for the ingest-time partitioners.

pub mod builder;
pub mod datasets;
pub mod generators;
pub mod io;
pub mod rewire;
pub mod stats;
pub mod stream;

pub use builder::GraphBuilder;

/// Iterator over a vertex's `(neighbor, edge_id)` pairs — a zip over the
/// two SoA adjacency arrays, yielding pairs by value. Implements
/// `ExactSizeIterator` and `DoubleEndedIterator` like the slice iterator
/// it replaced.
pub type NeighborIter<'a> = std::iter::Zip<
    std::iter::Copied<std::slice::Iter<'a, u32>>,
    std::iter::Copied<std::slice::Iter<'a, u32>>,
>;

/// Immutable simple undirected graph in CSR form with edge ids.
///
/// Adjacency is stored struct-of-arrays: neighbor ids and edge ids live
/// in two parallel `Vec<u32>`s sharing one CSR offset table. Scans that
/// only need neighbors (degree work, multiplicity counting, label
/// spreading, HDRF scoring) touch half the bytes an AoS
/// `Vec<(u32, u32)>` would stream through cache.
#[derive(Clone, Debug)]
pub struct Graph {
    n: usize,
    /// Canonical edge list; `edges[e] = (u, v)` with `u < v`.
    edges: Vec<(u32, u32)>,
    /// CSR offsets, length `n + 1` (shared by both adjacency arrays).
    offsets: Vec<u32>,
    /// Flattened neighbor ids (sorted per vertex).
    adj_nbr: Vec<u32>,
    /// Edge id of each adjacency slot: `adj_eid[i]` is the edge behind
    /// `adj_nbr[i]`.
    adj_eid: Vec<u32>,
}

impl Graph {
    /// Number of vertices.
    #[inline]
    pub fn vertex_count(&self) -> usize {
        self.n
    }

    /// Number of (undirected) edges.
    #[inline]
    pub fn edge_count(&self) -> usize {
        self.edges.len()
    }

    /// Endpoints of edge `e`, canonical order (`u < v`).
    #[inline]
    pub fn endpoints(&self, e: u32) -> (u32, u32) {
        self.edges[e as usize]
    }

    /// Degree of vertex `v`.
    #[inline]
    pub fn degree(&self, v: u32) -> usize {
        (self.offsets[v as usize + 1] - self.offsets[v as usize]) as usize
    }

    /// `(neighbor, edge_id)` pairs incident on `v`, sorted by neighbor id.
    #[inline]
    pub fn neighbors(&self, v: u32) -> NeighborIter<'_> {
        let (lo, hi) = self.adj_range(v);
        self.adj_nbr[lo..hi]
            .iter()
            .copied()
            .zip(self.adj_eid[lo..hi].iter().copied())
    }

    /// Neighbor ids of `v` as a slice, sorted ascending — the half the
    /// neighbor-only scans (and binary-searchable lookups) want.
    #[inline]
    pub fn neighbor_vertices(&self, v: u32) -> &[u32] {
        let (lo, hi) = self.adj_range(v);
        &self.adj_nbr[lo..hi]
    }

    /// Edge ids incident on `v` as a slice, parallel to
    /// [`neighbor_vertices`](Self::neighbor_vertices).
    #[inline]
    pub fn neighbor_edges(&self, v: u32) -> &[u32] {
        let (lo, hi) = self.adj_range(v);
        &self.adj_eid[lo..hi]
    }

    #[inline]
    fn adj_range(&self, v: u32) -> (usize, usize) {
        (
            self.offsets[v as usize] as usize,
            self.offsets[v as usize + 1] as usize,
        )
    }

    /// Iterator over `(edge_id, u, v)`.
    pub fn edge_iter(&self) -> impl Iterator<Item = (u32, u32, u32)> + '_ {
        self.edges
            .iter()
            .enumerate()
            .map(|(e, &(u, v))| (e as u32, u, v))
    }

    /// The canonical edge slice.
    #[inline]
    pub fn edges(&self) -> &[(u32, u32)] {
        &self.edges
    }

    /// Given one endpoint of edge `e`, return the other.
    #[inline]
    pub fn other_endpoint(&self, e: u32, v: u32) -> u32 {
        let (a, b) = self.edges[e as usize];
        if a == v {
            b
        } else {
            debug_assert_eq!(b, v);
            a
        }
    }

    /// Construct from parts — used by [`GraphBuilder`]; keeps invariants
    /// (canonical edges, sorted adjacency, parallel SoA arrays) by
    /// construction.
    pub(crate) fn from_parts(
        n: usize,
        edges: Vec<(u32, u32)>,
        offsets: Vec<u32>,
        adj_nbr: Vec<u32>,
        adj_eid: Vec<u32>,
    ) -> Self {
        debug_assert_eq!(adj_nbr.len(), adj_eid.len());
        Graph { n, edges, offsets, adj_nbr, adj_eid }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn triangle_plus_tail() -> Graph {
        // 0-1, 1-2, 0-2, 2-3
        GraphBuilder::new()
            .add_edge(0, 1)
            .add_edge(1, 2)
            .add_edge(0, 2)
            .add_edge(2, 3)
            .build()
    }

    #[test]
    fn counts() {
        let g = triangle_plus_tail();
        assert_eq!(g.vertex_count(), 4);
        assert_eq!(g.edge_count(), 4);
        assert_eq!(g.degree(2), 3);
        assert_eq!(g.degree(3), 1);
    }

    #[test]
    fn neighbors_sorted_with_edge_ids() {
        let g = triangle_plus_tail();
        let nbrs: Vec<u32> = g.neighbors(2).map(|(w, _)| w).collect();
        assert_eq!(nbrs, vec![0, 1, 3]);
        assert_eq!(g.neighbor_vertices(2), &[0, 1, 3]);
        for (w, e) in g.neighbors(2) {
            let (a, b) = g.endpoints(e);
            assert!(a == 2 || b == 2);
            assert_eq!(g.other_endpoint(e, 2), w);
        }
    }

    #[test]
    fn soa_slices_are_parallel() {
        let g = triangle_plus_tail();
        for v in 0..g.vertex_count() as u32 {
            let vs = g.neighbor_vertices(v);
            let es = g.neighbor_edges(v);
            assert_eq!(vs.len(), es.len());
            assert_eq!(vs.len(), g.degree(v));
            let zipped: Vec<(u32, u32)> = g.neighbors(v).collect();
            assert_eq!(zipped.len(), g.neighbors(v).len());
            for (i, &(w, e)) in zipped.iter().enumerate() {
                assert_eq!(vs[i], w);
                assert_eq!(es[i], e);
            }
        }
    }

    #[test]
    fn edge_iter_is_canonical() {
        let g = triangle_plus_tail();
        for (_, u, v) in g.edge_iter() {
            assert!(u < v);
        }
    }
}
