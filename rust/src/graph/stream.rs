//! Out-of-core edge-stream ingestion: replayable sources of cleaned
//! edges, delivered in fixed-size chunks with bounded memory.
//!
//! Every partitioner that existed before this module — including the
//! "streaming" [`crate::partition::fennel::StreamingGreedy`] — needs the
//! fully materialized CSR [`Graph`] before it can place a single edge.
//! [`EdgeStream`] inverts that: a source yields edge chunks and the
//! ingest-time partitioners in [`crate::partition::streaming`] place each
//! edge as it arrives, so the graph itself never has to fit in memory.
//!
//! ## Contract
//!
//! - **Cleaned, stable sequence.** A stream yields `(u, v)` pairs with
//!   canonical orientation (`u < v`) and no self-loops, and the sequence
//!   is identical on every replay ([`EdgeStream::reset`]) — stream
//!   position is the edge's identity. Duplicate suppression is the
//!   *source's* responsibility: [`MemoryEdgeStream`] is deduplicated by
//!   construction (it replays a built graph's canonical edge list);
//!   [`FileEdgeStream`] is faithful to the file minus comments and
//!   self-loops, so a canonical file (as written by
//!   [`super::io::write_edge_list`]) streams exactly its graph's edge
//!   ids, while a raw SNAP file with both directions of each edge would
//!   stream duplicates.
//! - **Bounded memory.** [`FileEdgeStream`] holds one line buffer and the
//!   caller's chunk buffer — O(chunk), independent of |E|. The synthetic
//!   sources are materialized by nature (the generators need their own
//!   working state), so [`MemoryEdgeStream`] holds the edge list — it
//!   exists to make in-memory and from-disk ingestion byte-comparable,
//!   which the streaming property tests pin.
//! - **Chunk size is presentation only.** Chunk boundaries carry no
//!   meaning; consumers must produce identical results for every chunk
//!   size (the streaming partitioners re-buffer into fixed scoring
//!   groups internally — see `partition::streaming`).
//!
//! File parsing goes through the exact same line parser as the
//! materializing reader ([`super::io::parse_edge_line`]), so the two
//! ingestion paths cannot drift.

use std::io::{BufRead, Seek, SeekFrom};
use std::path::{Path, PathBuf};

use crate::util::error::{Context, Result};

use super::generators::GraphKind;
use super::io::parse_edge_line;
use super::Graph;

/// A replayable source of cleaned edges, delivered in chunks.
///
/// See the [module docs](self) for the sequence/memory contract.
pub trait EdgeStream {
    /// Rewind to the first edge; the subsequent sequence is identical to
    /// every earlier replay.
    fn reset(&mut self) -> Result<()>;

    /// Clear `buf` and refill it with up to `chunk` edges (`chunk >= 1`);
    /// returns the number delivered, `0` once the stream is exhausted.
    fn fill(
        &mut self,
        chunk: usize,
        buf: &mut Vec<(u32, u32)>,
    ) -> Result<usize>;
}

/// An in-memory edge sequence (canonical edge-id order when built from a
/// [`Graph`]), used to make chunked and materialized ingestion
/// byte-comparable.
#[derive(Clone, Debug)]
pub struct MemoryEdgeStream {
    edges: Vec<(u32, u32)>,
    pos: usize,
}

impl MemoryEdgeStream {
    /// Stream a built graph's canonical edge list: stream position ==
    /// edge id, so a streaming partitioner's owner vector lines up with
    /// the graph's edge ids directly.
    pub fn from_graph(g: &Graph) -> MemoryEdgeStream {
        MemoryEdgeStream { edges: g.edges().to_vec(), pos: 0 }
    }

    /// Stream an explicit edge list (callers guarantee the cleaning
    /// contract: `u < v`, no self-loops, no duplicates).
    pub fn from_edges(edges: Vec<(u32, u32)>) -> MemoryEdgeStream {
        debug_assert!(edges.iter().all(|&(u, v)| u < v));
        MemoryEdgeStream { edges, pos: 0 }
    }

    /// Stream a synthetic generator's output (the generator runs once;
    /// only the canonical edge list is kept, not the CSR).
    pub fn from_kind(kind: &GraphKind, seed: u64) -> MemoryEdgeStream {
        MemoryEdgeStream::from_graph(&kind.generate(seed))
    }

    /// Total number of edges in the stream.
    pub fn len(&self) -> usize {
        self.edges.len()
    }

    /// True when the stream holds no edges at all.
    pub fn is_empty(&self) -> bool {
        self.edges.is_empty()
    }
}

impl EdgeStream for MemoryEdgeStream {
    fn reset(&mut self) -> Result<()> {
        self.pos = 0;
        Ok(())
    }

    fn fill(
        &mut self,
        chunk: usize,
        buf: &mut Vec<(u32, u32)>,
    ) -> Result<usize> {
        assert!(chunk >= 1, "chunk size must be >= 1");
        buf.clear();
        let end = (self.pos + chunk).min(self.edges.len());
        buf.extend_from_slice(&self.edges[self.pos..end]);
        let got = end - self.pos;
        self.pos = end;
        Ok(got)
    }
}

/// Bounded-memory SNAP edge-list reader: one reused line buffer, the
/// shared [`parse_edge_line`] grammar, orientation normalized to `u < v`,
/// self-loops dropped. Replayable via a seek back to the start.
pub struct FileEdgeStream {
    path: PathBuf,
    reader: std::io::BufReader<std::fs::File>,
    line: String,
    lineno: usize,
}

impl FileEdgeStream {
    /// Open an edge-list file for streaming.
    pub fn open(path: &Path) -> Result<FileEdgeStream> {
        let file = std::fs::File::open(path)
            .with_context(|| format!("open {}", path.display()))?;
        Ok(FileEdgeStream {
            path: path.to_path_buf(),
            reader: std::io::BufReader::new(file),
            line: String::new(),
            lineno: 0,
        })
    }

    /// The path this stream reads from.
    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl EdgeStream for FileEdgeStream {
    fn reset(&mut self) -> Result<()> {
        self.reader
            .seek(SeekFrom::Start(0))
            .with_context(|| format!("rewind {}", self.path.display()))?;
        self.lineno = 0;
        Ok(())
    }

    fn fill(
        &mut self,
        chunk: usize,
        buf: &mut Vec<(u32, u32)>,
    ) -> Result<usize> {
        assert!(chunk >= 1, "chunk size must be >= 1");
        buf.clear();
        while buf.len() < chunk {
            self.line.clear();
            if self
                .reader
                .read_line(&mut self.line)
                .with_context(|| format!("read {}", self.path.display()))?
                == 0
            {
                break;
            }
            self.lineno += 1;
            match parse_edge_line(&self.line) {
                Ok(None) => {}
                Ok(Some((u, v))) => {
                    if u != v {
                        buf.push((u.min(v), u.max(v)));
                    }
                }
                Err(what) => {
                    return Err(crate::anyhow!(
                        "{}:{}: {what}",
                        self.path.display(),
                        self.lineno
                    ))
                }
            }
        }
        Ok(buf.len())
    }
}

/// Drain a stream into a single vector (tests / small inputs only — this
/// forfeits the bounded-memory property).
pub fn collect(stream: &mut dyn EdgeStream) -> Result<Vec<(u32, u32)>> {
    let mut all = Vec::new();
    let mut buf = Vec::new();
    loop {
        if stream.fill(1024, &mut buf)? == 0 {
            break;
        }
        all.extend_from_slice(&buf);
    }
    Ok(all)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{io, GraphBuilder};

    fn g() -> Graph {
        GraphBuilder::new()
            .add_edge(0, 1)
            .add_edge(1, 2)
            .add_edge(0, 3)
            .add_edge(2, 3)
            .build()
    }

    #[test]
    fn memory_stream_yields_canonical_edges_in_any_chunking() {
        let g = g();
        for chunk in [1usize, 2, 3, 100] {
            let mut s = MemoryEdgeStream::from_graph(&g);
            let mut buf = Vec::new();
            let mut all = Vec::new();
            loop {
                let got = s.fill(chunk, &mut buf).unwrap();
                if got == 0 {
                    break;
                }
                assert!(got <= chunk);
                all.extend_from_slice(&buf);
            }
            assert_eq!(all, g.edges(), "chunk {chunk}");
            // replay gives the identical sequence
            s.reset().unwrap();
            assert_eq!(collect(&mut s).unwrap(), g.edges());
        }
    }

    #[test]
    fn file_stream_matches_memory_stream_and_reader() {
        let g = g();
        let dir = std::env::temp_dir().join("dfep_stream_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("g.txt");
        io::write_edge_list(&g, &path).unwrap();

        let mut fs = FileEdgeStream::open(&path).unwrap();
        assert_eq!(collect(&mut fs).unwrap(), g.edges());
        // replay after reset
        fs.reset().unwrap();
        assert_eq!(collect(&mut fs).unwrap(), g.edges());
        // and the materializing reader sees the same edge ids
        let g2 = io::read_edge_list(&path, false).unwrap();
        assert_eq!(g2.edges(), g.edges());
    }

    #[test]
    fn file_stream_cleans_comments_orientation_and_self_loops() {
        let dir = std::env::temp_dir().join("dfep_stream_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("raw.txt");
        std::fs::write(&path, "# hdr\n5 2\n% c\n3 3\n1 4\n").unwrap();
        let mut fs = FileEdgeStream::open(&path).unwrap();
        // orientation normalized, self-loop dropped, comments skipped
        assert_eq!(collect(&mut fs).unwrap(), vec![(2, 5), (1, 4)]);
    }

    #[test]
    fn file_stream_reports_bad_lines_with_position() {
        let dir = std::env::temp_dir().join("dfep_stream_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.txt");
        std::fs::write(&path, "0 1\nnope\n").unwrap();
        let mut fs = FileEdgeStream::open(&path).unwrap();
        let mut buf = Vec::new();
        let err = fs.fill(16, &mut buf).unwrap_err().to_string();
        assert!(err.contains(":2:"), "{err}");
    }
}
