//! SNAP-style edge-list IO (`# comment` lines, whitespace-separated pairs).
//!
//! The paper's datasets come from the SNAP library in this format; this
//! module reads/writes it so real SNAP files drop in unchanged when
//! available (this environment has no network, so `graph::datasets`
//! generates calibrated synthetic analogues instead).
//!
//! [`parse_edge_line`] is the single line parser: [`read_edge_list`]
//! (materializing) and the chunked [`super::stream::FileEdgeStream`]
//! (bounded-memory) both go through it, so the two ingestion paths
//! cannot drift. Both readers reuse one `read_line` buffer instead of
//! allocating a fresh `String` per line.

use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

use crate::util::error::{Context, Result};

use super::{Graph, GraphBuilder};

/// Parse one edge-list line: `Ok(None)` for blank / `#` / `%` comment
/// lines, `Ok(Some((u, v)))` for a whitespace-separated vertex pair
/// (orientation as written — callers normalize), `Err(what)` with a short
/// description for malformed lines (callers attach file:line context).
///
/// The one copy of the SNAP line grammar, shared by [`read_edge_list`]
/// and [`super::stream::FileEdgeStream`].
pub fn parse_edge_line(
    line: &str,
) -> Result<Option<(u32, u32)>, &'static str> {
    let t = line.trim();
    if t.is_empty() || t.starts_with('#') || t.starts_with('%') {
        return Ok(None);
    }
    let mut it = t.split_whitespace();
    let u: u32 = it
        .next()
        .and_then(|s| s.parse().ok())
        .ok_or("bad source vertex")?;
    let v: u32 = it
        .next()
        .and_then(|s| s.parse().ok())
        .ok_or("bad target vertex")?;
    Ok(Some((u, v)))
}

/// Read a SNAP edge list. Applies the paper's cleaning: undirect, dedup,
/// drop self-loops; `largest_component` additionally removes disconnected
/// components and compacts ids.
pub fn read_edge_list(path: &Path, largest_component: bool) -> Result<Graph> {
    let file = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    let mut reader = std::io::BufReader::new(file);
    let mut b = GraphBuilder::new();
    // one reused line buffer — no per-line String allocation
    let mut line = String::new();
    let mut lineno = 0usize;
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            break;
        }
        lineno += 1;
        match parse_edge_line(&line) {
            Ok(None) => {}
            Ok(Some((u, v))) => b.push_edge(u, v),
            Err(what) => {
                return Err(crate::anyhow!(
                    "{}:{lineno}: {what}",
                    path.display()
                ))
            }
        }
    }
    Ok(if largest_component { b.build_largest_component() } else { b.build() })
}

/// Write a graph as a SNAP edge list (canonical orientation).
pub fn write_edge_list(g: &Graph, path: &Path) -> Result<()> {
    let file = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(file);
    writeln!(w, "# Undirected graph: {} vertices, {} edges", g.vertex_count(), g.edge_count())?;
    for (_, u, v) in g.edge_iter() {
        writeln!(w, "{u}\t{v}")?;
    }
    Ok(())
}

/// Write an edge partitioning next to the graph: `edge_id \t partition`.
pub fn write_partition(owner: &[u32], path: &Path) -> Result<()> {
    let file = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(file);
    for (e, &p) in owner.iter().enumerate() {
        writeln!(w, "{e}\t{p}")?;
    }
    Ok(())
}

/// Atomically persist an opaque binary blob (cluster checkpoints): write
/// to `<path>.tmp`, then rename over `path`, so a crash mid-write never
/// leaves a truncated checkpoint where a valid one stood.
pub fn write_blob(path: &Path, blob: &[u8]) -> Result<()> {
    let tmp = path.with_extension("tmp");
    {
        let file = std::fs::File::create(&tmp)
            .with_context(|| format!("create {}", tmp.display()))?;
        let mut w = BufWriter::new(file);
        w.write_all(blob)?;
        w.flush()?;
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("rename into {}", path.display()))?;
    Ok(())
}

/// Read back a blob written by [`write_blob`].
pub fn read_blob(path: &Path) -> Result<Vec<u8>> {
    std::fs::read(path).with_context(|| format!("read {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blob_roundtrip_is_atomic() {
        let dir = std::env::temp_dir().join("dfep_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ckpt.bin");
        let blob: Vec<u8> = (0..=255u8).cycle().take(10_000).collect();
        write_blob(&path, &blob).unwrap();
        assert_eq!(read_blob(&path).unwrap(), blob);
        // overwrite leaves no tmp residue
        write_blob(&path, b"second").unwrap();
        assert_eq!(read_blob(&path).unwrap(), b"second");
        assert!(!path.with_extension("tmp").exists());
    }

    #[test]
    fn roundtrip() {
        let g = GraphBuilder::new()
            .add_edge(0, 1)
            .add_edge(1, 2)
            .add_edge(2, 0)
            .build();
        let dir = std::env::temp_dir().join("dfep_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("g.txt");
        write_edge_list(&g, &path).unwrap();
        let g2 = read_edge_list(&path, false).unwrap();
        assert_eq!(g2.vertex_count(), 3);
        assert_eq!(g2.edges(), g.edges());
    }

    #[test]
    fn skips_comments_and_directed_duplicates() {
        let dir = std::env::temp_dir().join("dfep_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("snap.txt");
        std::fs::write(&path, "# SNAP header\n0 1\n1 0\n% other\n1 2\n").unwrap();
        let g = read_edge_list(&path, false).unwrap();
        assert_eq!(g.edge_count(), 2);
    }

    #[test]
    fn parse_edge_line_grammar() {
        assert_eq!(parse_edge_line(""), Ok(None));
        assert_eq!(parse_edge_line("  # comment\n"), Ok(None));
        assert_eq!(parse_edge_line("% comment"), Ok(None));
        assert_eq!(parse_edge_line("3\t7\n"), Ok(Some((3, 7))));
        assert_eq!(parse_edge_line("  9 2 extra"), Ok(Some((9, 2))));
        assert!(parse_edge_line("x 1").is_err());
        assert!(parse_edge_line("1").is_err());
        assert!(parse_edge_line("1 y").is_err());
    }

    #[test]
    fn bad_line_errors() {
        let dir = std::env::temp_dir().join("dfep_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.txt");
        std::fs::write(&path, "0 x\n").unwrap();
        assert!(read_edge_list(&path, false).is_err());
    }
}
