//! SNAP-style edge-list IO (`# comment` lines, whitespace-separated pairs).
//!
//! The paper's datasets come from the SNAP library in this format; this
//! module reads/writes it so real SNAP files drop in unchanged when
//! available (this environment has no network, so `graph::datasets`
//! generates calibrated synthetic analogues instead).
//!
//! [`parse_edge_line`] is the single line parser: [`read_edge_list`]
//! (materializing) and the chunked [`super::stream::FileEdgeStream`]
//! (bounded-memory) both go through it, so the two ingestion paths
//! cannot drift. Both readers reuse one `read_line` buffer instead of
//! allocating a fresh `String` per line.

use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

use crate::util::error::{Context, ErrorKind, Result};
use crate::util::fault::{FaultArm, WriteFault};
use crate::util::frame::fnv1a64;

use super::{Graph, GraphBuilder};

/// Parse one edge-list line: `Ok(None)` for blank / `#` / `%` comment
/// lines, `Ok(Some((u, v)))` for a whitespace-separated vertex pair
/// (orientation as written — callers normalize), `Err(what)` with a short
/// description for malformed lines (callers attach file:line context).
///
/// The one copy of the SNAP line grammar, shared by [`read_edge_list`]
/// and [`super::stream::FileEdgeStream`].
pub fn parse_edge_line(
    line: &str,
) -> Result<Option<(u32, u32)>, &'static str> {
    let t = line.trim();
    if t.is_empty() || t.starts_with('#') || t.starts_with('%') {
        return Ok(None);
    }
    let mut it = t.split_whitespace();
    let u: u32 = it
        .next()
        .and_then(|s| s.parse().ok())
        .ok_or("bad source vertex")?;
    let v: u32 = it
        .next()
        .and_then(|s| s.parse().ok())
        .ok_or("bad target vertex")?;
    Ok(Some((u, v)))
}

/// Read a SNAP edge list. Applies the paper's cleaning: undirect, dedup,
/// drop self-loops; `largest_component` additionally removes disconnected
/// components and compacts ids.
pub fn read_edge_list(path: &Path, largest_component: bool) -> Result<Graph> {
    let file = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    let mut reader = std::io::BufReader::new(file);
    let mut b = GraphBuilder::new();
    // one reused line buffer — no per-line String allocation
    let mut line = String::new();
    let mut lineno = 0usize;
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            break;
        }
        lineno += 1;
        match parse_edge_line(&line) {
            Ok(None) => {}
            Ok(Some((u, v))) => b.push_edge(u, v),
            Err(what) => {
                return Err(crate::anyhow!(
                    "{}:{lineno}: {what}",
                    path.display()
                ))
            }
        }
    }
    Ok(if largest_component { b.build_largest_component() } else { b.build() })
}

/// Write a graph as a SNAP edge list (canonical orientation).
pub fn write_edge_list(g: &Graph, path: &Path) -> Result<()> {
    let file = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(file);
    writeln!(w, "# Undirected graph: {} vertices, {} edges", g.vertex_count(), g.edge_count())?;
    for (_, u, v) in g.edge_iter() {
        writeln!(w, "{u}\t{v}")?;
    }
    Ok(())
}

/// Write an edge partitioning next to the graph: `edge_id \t partition`.
pub fn write_partition(owner: &[u32], path: &Path) -> Result<()> {
    let file = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(file);
    for (e, &p) in owner.iter().enumerate() {
        writeln!(w, "{e}\t{p}")?;
    }
    Ok(())
}

/// Atomically persist an opaque binary blob (cluster checkpoints): write
/// to `<path>.tmp`, fsync, then rename over `path`, so a crash mid-write
/// never leaves a truncated checkpoint where a valid one stood.
pub fn write_blob(path: &Path, blob: &[u8]) -> Result<()> {
    let tmp = path.with_extension("tmp");
    {
        let file = std::fs::File::create(&tmp)
            .with_context(|| format!("create {}", tmp.display()))?;
        let mut w = BufWriter::new(file);
        w.write_all(blob)?;
        w.flush()?;
        w.get_ref()
            .sync_all()
            .with_context(|| format!("fsync {}", tmp.display()))?;
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("rename into {}", path.display()))?;
    Ok(())
}

/// Read back a blob written by [`write_blob`].
pub fn read_blob(path: &Path) -> Result<Vec<u8>> {
    std::fs::read(path).with_context(|| format!("read {}", path.display()))
}

/// Magic tag leading every checked blob: ASCII `"BLB1"`, little-endian.
pub const BLOB_MAGIC: u32 = u32::from_le_bytes(*b"BLB1");

/// Header bytes of a checked blob (magic `u32` + length `u64` +
/// fnv1a64 checksum `u64`, all little-endian).
pub const BLOB_HEADER_BYTES: usize = 20;

/// Persist a payload wrapped in a checked header ([`BLOB_MAGIC`],
/// length, fnv1a64) via the atomic [`write_blob`] protocol, so
/// [`read_blob_checked`] can tell an intact checkpoint from a torn or
/// bit-rotted one.
pub fn write_blob_checked(path: &Path, payload: &[u8]) -> Result<()> {
    write_blob_checked_with(path, payload, None)
}

/// [`write_blob_checked`] with an optional fault-injection arm.
///
/// A firing `drop` fails the write (typed [`ErrorKind::Io`]) with the
/// previous file, if any, left untouched. A firing `torn_write` models
/// a *lying fsync*: a prefix of the framed blob lands at the final
/// path and the call still reports success — exactly the
/// crash-consistency hole the checked header exists to catch on
/// restore.
pub fn write_blob_checked_with(
    path: &Path,
    payload: &[u8],
    arm: Option<&mut FaultArm>,
) -> Result<()> {
    let mut framed = Vec::with_capacity(payload.len() + BLOB_HEADER_BYTES);
    framed.extend_from_slice(&BLOB_MAGIC.to_le_bytes());
    framed.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    framed.extend_from_slice(&fnv1a64(payload).to_le_bytes());
    framed.extend_from_slice(payload);
    if let Some(arm) = arm {
        match arm.on_write() {
            WriteFault::Pass => {}
            WriteFault::Drop => {
                return Err(crate::anyhow!(
                    "injected blob write failure: {}",
                    path.display()
                )
                .with_kind(ErrorKind::Io));
            }
            WriteFault::Torn => {
                let cut = framed.len() / 2;
                std::fs::write(path, &framed[..cut]).with_context(|| {
                    format!("torn write {}", path.display())
                })?;
                return Ok(());
            }
        }
    }
    write_blob(path, &framed)
}

/// Read and verify a blob written by [`write_blob_checked`], returning
/// the payload. Short files, wrong magic, length mismatches and
/// checksum failures are all errors — the caller (checkpoint restore)
/// skips such a file and falls back to an older intact one.
pub fn read_blob_checked(path: &Path) -> Result<Vec<u8>> {
    let framed = read_blob(path)?;
    if framed.len() < BLOB_HEADER_BYTES {
        return Err(crate::anyhow!(
            "checked blob {}: {} bytes is shorter than the header",
            path.display(),
            framed.len()
        ));
    }
    let magic = u32::from_le_bytes(framed[0..4].try_into().unwrap());
    if magic != BLOB_MAGIC {
        return Err(crate::anyhow!(
            "checked blob {}: bad magic {magic:#010x}",
            path.display()
        ));
    }
    let len = u64::from_le_bytes(framed[4..12].try_into().unwrap()) as usize;
    let body = &framed[BLOB_HEADER_BYTES..];
    if body.len() != len {
        return Err(crate::anyhow!(
            "checked blob {}: header claims {len} bytes, file carries {}",
            path.display(),
            body.len()
        ));
    }
    let crc = u64::from_le_bytes(framed[12..20].try_into().unwrap());
    let actual = fnv1a64(body);
    if actual != crc {
        return Err(crate::anyhow!(
            "checked blob {}: checksum mismatch (header {crc:#018x}, \
             payload {actual:#018x})",
            path.display()
        ));
    }
    Ok(body.to_vec())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blob_roundtrip_is_atomic() {
        let dir = std::env::temp_dir().join("dfep_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ckpt.bin");
        let blob: Vec<u8> = (0..=255u8).cycle().take(10_000).collect();
        write_blob(&path, &blob).unwrap();
        assert_eq!(read_blob(&path).unwrap(), blob);
        // overwrite leaves no tmp residue
        write_blob(&path, b"second").unwrap();
        assert_eq!(read_blob(&path).unwrap(), b"second");
        assert!(!path.with_extension("tmp").exists());
    }

    #[test]
    fn checked_blob_detects_every_corruption_mode() {
        let dir = std::env::temp_dir().join("dfep_io_checked_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ckpt.bin");
        let payload: Vec<u8> = (0..=255u8).cycle().take(5_000).collect();
        write_blob_checked(&path, &payload).unwrap();
        assert_eq!(read_blob_checked(&path).unwrap(), payload);
        // flip one payload byte on disk
        let mut raw = read_blob(&path).unwrap();
        raw[BLOB_HEADER_BYTES + 100] ^= 0x01;
        std::fs::write(&path, &raw).unwrap();
        let err = read_blob_checked(&path).unwrap_err();
        assert!(err.to_string().contains("checksum"), "{err}");
        // truncate the file (a torn write)
        write_blob_checked(&path, &payload).unwrap();
        let raw = read_blob(&path).unwrap();
        std::fs::write(&path, &raw[..raw.len() / 2]).unwrap();
        assert!(read_blob_checked(&path).is_err());
        // an unchecked blob has no magic
        write_blob(&path, b"just bytes, no header").unwrap();
        let err = read_blob_checked(&path).unwrap_err();
        assert!(err.to_string().contains("magic"), "{err}");
        // shorter than the header
        std::fs::write(&path, b"tiny").unwrap();
        assert!(read_blob_checked(&path).is_err());
    }

    #[test]
    fn torn_write_fault_persists_a_detectable_wreck() {
        use crate::util::fault::{FaultCounters, FaultPlan};
        let dir = std::env::temp_dir().join("dfep_io_torn_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ckpt.bin");
        let payload = vec![0xABu8; 4_000];
        // a torn write "succeeds" but restore must reject the file
        let plan = FaultPlan { torn_write: 1.0, ..FaultPlan::default() };
        let mut arm = plan.arm(0, FaultCounters::shared());
        write_blob_checked_with(&path, &payload, Some(&mut arm)).unwrap();
        assert!(path.exists());
        assert!(read_blob_checked(&path).is_err());
        // a dropped write fails typed and leaves the file untouched
        write_blob_checked(&path, &payload).unwrap();
        let plan = FaultPlan { drop: 1.0, ..FaultPlan::default() };
        let mut arm = plan.arm(0, FaultCounters::shared());
        let err = write_blob_checked_with(&path, b"new", Some(&mut arm))
            .unwrap_err();
        assert_eq!(err.kind(), ErrorKind::Io);
        assert_eq!(read_blob_checked(&path).unwrap(), payload);
    }

    #[test]
    fn roundtrip() {
        let g = GraphBuilder::new()
            .add_edge(0, 1)
            .add_edge(1, 2)
            .add_edge(2, 0)
            .build();
        let dir = std::env::temp_dir().join("dfep_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("g.txt");
        write_edge_list(&g, &path).unwrap();
        let g2 = read_edge_list(&path, false).unwrap();
        assert_eq!(g2.vertex_count(), 3);
        assert_eq!(g2.edges(), g.edges());
    }

    #[test]
    fn skips_comments_and_directed_duplicates() {
        let dir = std::env::temp_dir().join("dfep_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("snap.txt");
        std::fs::write(&path, "# SNAP header\n0 1\n1 0\n% other\n1 2\n").unwrap();
        let g = read_edge_list(&path, false).unwrap();
        assert_eq!(g.edge_count(), 2);
    }

    #[test]
    fn parse_edge_line_grammar() {
        assert_eq!(parse_edge_line(""), Ok(None));
        assert_eq!(parse_edge_line("  # comment\n"), Ok(None));
        assert_eq!(parse_edge_line("% comment"), Ok(None));
        assert_eq!(parse_edge_line("3\t7\n"), Ok(Some((3, 7))));
        assert_eq!(parse_edge_line("  9 2 extra"), Ok(Some((9, 2))));
        assert!(parse_edge_line("x 1").is_err());
        assert!(parse_edge_line("1").is_err());
        assert!(parse_edge_line("1 y").is_err());
    }

    #[test]
    fn bad_line_errors() {
        let dir = std::env::temp_dir().join("dfep_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.txt");
        std::fs::write(&path, "0 x\n").unwrap();
        assert!(read_edge_list(&path, false).is_err());
    }
}
