//! Edge-list to CSR builder with the cleaning pipeline the paper applies to
//! the SNAP datasets: make directed edges undirected, drop self-loops and
//! duplicates, optionally keep only the largest connected component and
//! re-compact vertex ids.

use super::Graph;

/// Accumulates edges, then builds a [`Graph`].
#[derive(Default, Clone, Debug)]
pub struct GraphBuilder {
    edges: Vec<(u32, u32)>,
    max_vertex: u32,
    has_edges: bool,
}

impl GraphBuilder {
    /// New empty builder.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add one (possibly directed / duplicated / self-loop) edge; cleaning
    /// happens in [`build`](Self::build).
    pub fn add_edge(mut self, u: u32, v: u32) -> Self {
        self.push_edge(u, v);
        self
    }

    /// Non-consuming variant for loops.
    pub fn push_edge(&mut self, u: u32, v: u32) {
        self.max_vertex = self.max_vertex.max(u).max(v);
        self.has_edges = true;
        self.edges.push((u.min(v), u.max(v)));
    }

    /// Declare a vertex id even if isolated (extends the vertex range).
    pub fn touch_vertex(&mut self, v: u32) {
        self.max_vertex = self.max_vertex.max(v);
        self.has_edges = true;
    }

    /// Number of raw edges accumulated so far (pre-dedup).
    pub fn raw_edge_count(&self) -> usize {
        self.edges.len()
    }

    /// Build: dedup, drop self-loops, CSR-ify.
    pub fn build(self) -> Graph {
        let n = if self.has_edges { self.max_vertex as usize + 1 } else { 0 };
        let mut edges = self.edges;
        edges.retain(|&(u, v)| u != v);
        edges.sort_unstable();
        edges.dedup();
        build_csr(n, edges)
    }

    /// Build, then keep only the largest connected component with vertex
    /// ids re-compacted to `0..n'` (what the paper's "cleaned" datasets do).
    pub fn build_largest_component(self) -> Graph {
        largest_component(&self.build())
    }
}

pub(crate) fn build_csr(n: usize, edges: Vec<(u32, u32)>) -> Graph {
    let mut deg = vec![0u32; n + 1];
    for &(u, v) in &edges {
        deg[u as usize + 1] += 1;
        deg[v as usize + 1] += 1;
    }
    let mut offsets = deg;
    for i in 1..offsets.len() {
        offsets[i] += offsets[i - 1];
    }
    let slots = offsets[n] as usize;
    let mut adj_nbr = vec![0u32; slots];
    let mut adj_eid = vec![0u32; slots];
    let mut cursor = offsets.clone();
    for (e, &(u, v)) in edges.iter().enumerate() {
        let cu = cursor[u as usize] as usize;
        adj_nbr[cu] = v;
        adj_eid[cu] = e as u32;
        cursor[u as usize] += 1;
        let cv = cursor[v as usize] as usize;
        adj_nbr[cv] = u;
        adj_eid[cv] = e as u32;
        cursor[v as usize] += 1;
    }
    // each adjacency run must be sorted by neighbor id for
    // binary-searchable lookups; the canonical (sorted) edge order already
    // yields sorted runs, so this pass verifies and only sorts on the rare
    // out-of-order run
    let mut perm: Vec<u32> = Vec::new();
    for v in 0..n {
        let (lo, hi) = (offsets[v] as usize, offsets[v + 1] as usize);
        if adj_nbr[lo..hi].windows(2).all(|w| w[0] <= w[1]) {
            continue;
        }
        perm.clear();
        perm.extend(lo as u32..hi as u32);
        perm.sort_unstable_by_key(|&i| {
            (adj_nbr[i as usize], adj_eid[i as usize])
        });
        let nbr: Vec<u32> = perm.iter().map(|&i| adj_nbr[i as usize]).collect();
        let eid: Vec<u32> = perm.iter().map(|&i| adj_eid[i as usize]).collect();
        adj_nbr[lo..hi].copy_from_slice(&nbr);
        adj_eid[lo..hi].copy_from_slice(&eid);
    }
    Graph::from_parts(n, edges, offsets, adj_nbr, adj_eid)
}

/// Extract the largest connected component, re-compacting vertex ids.
pub fn largest_component(g: &Graph) -> Graph {
    let n = g.vertex_count();
    if n == 0 {
        return g.clone();
    }
    let mut comp = vec![u32::MAX; n];
    let mut sizes: Vec<usize> = Vec::new();
    let mut stack = Vec::new();
    for s in 0..n as u32 {
        if comp[s as usize] != u32::MAX {
            continue;
        }
        let c = sizes.len() as u32;
        let mut size = 0usize;
        comp[s as usize] = c;
        stack.push(s);
        while let Some(u) = stack.pop() {
            size += 1;
            for &w in g.neighbor_vertices(u) {
                if comp[w as usize] == u32::MAX {
                    comp[w as usize] = c;
                    stack.push(w);
                }
            }
        }
        sizes.push(size);
    }
    let best = sizes
        .iter()
        .enumerate()
        .max_by_key(|&(_, s)| *s)
        .map(|(i, _)| i as u32)
        .unwrap();
    // re-compact ids
    let mut remap = vec![u32::MAX; n];
    let mut next = 0u32;
    for v in 0..n {
        if comp[v] == best {
            remap[v] = next;
            next += 1;
        }
    }
    let mut b = GraphBuilder::new();
    for (_, u, v) in g.edge_iter() {
        if comp[u as usize] == best && comp[v as usize] == best {
            b.push_edge(remap[u as usize], remap[v as usize]);
        }
    }
    if next > 0 {
        b.touch_vertex(next - 1);
    }
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dedup_and_selfloops() {
        let g = GraphBuilder::new()
            .add_edge(0, 1)
            .add_edge(1, 0) // duplicate in other direction
            .add_edge(0, 1) // exact duplicate
            .add_edge(2, 2) // self-loop
            .add_edge(1, 2)
            .build();
        assert_eq!(g.edge_count(), 2);
        assert_eq!(g.vertex_count(), 3);
    }

    #[test]
    fn largest_component_kept_and_compacted() {
        // component A: 0-1-2 (3 vertices), component B: 10-11 (2 vertices)
        let g = GraphBuilder::new()
            .add_edge(0, 1)
            .add_edge(1, 2)
            .add_edge(10, 11)
            .build_largest_component();
        assert_eq!(g.vertex_count(), 3);
        assert_eq!(g.edge_count(), 2);
    }

    #[test]
    fn empty_builder_is_empty_graph() {
        let g = GraphBuilder::new().build();
        assert_eq!(g.vertex_count(), 0);
        assert_eq!(g.edge_count(), 0);
    }

    #[test]
    fn csr_roundtrip_consistency() {
        let g = GraphBuilder::new()
            .add_edge(0, 3)
            .add_edge(3, 1)
            .add_edge(1, 0)
            .add_edge(2, 3)
            .build();
        // every edge appears exactly twice across adjacency lists
        let mut seen = vec![0u32; g.edge_count()];
        for v in 0..g.vertex_count() as u32 {
            for (w, e) in g.neighbors(v) {
                assert_eq!(g.other_endpoint(e, v), w);
                seen[e as usize] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 2));
    }
}
