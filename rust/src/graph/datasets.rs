//! Named synthetic analogues of the paper's datasets (Tables II and III).
//!
//! No network in this environment, so each SNAP dataset is replaced by a
//! generator calibrated to its published statistics — the properties DFEP
//! is sensitive to (size, diameter, clustering, degree distribution). The
//! `tables` bench prints paper-vs-generated side by side.
//!
//! Sizes are matched at full scale for the simulation-engine datasets
//! (Table II) and for the EC2 datasets (Table III); `scaled(frac)` gives
//! proportionally smaller instances for quick tests and examples.

use super::generators::GraphKind;
use super::Graph;

/// Paper-reported reference row (for the tables bench).
#[derive(Clone, Copy, Debug)]
pub struct PaperRow {
    /// Vertex count |V|.
    pub v: usize,
    /// Edge count |E|.
    pub e: usize,
    /// Diameter.
    pub d: u32,
    /// Global clustering coefficient.
    pub cc: f64,
    /// Clustering coefficient of a same-density random graph.
    pub rcc: f64,
}

/// One named dataset: its paper stats and the calibrated generator.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Upper-case dataset name (lookup key of [`by_name`]).
    pub name: &'static str,
    /// The paper-reported statistics row.
    pub paper: PaperRow,
    /// The calibrated generator standing in for the SNAP download.
    pub kind: GraphKind,
    /// true = Table II (simulation engine), false = Table III (EC2)
    pub simulation: bool,
}

impl Dataset {
    /// Generate the full-scale calibrated instance.
    pub fn generate(&self, seed: u64) -> Graph {
        self.kind.generate(seed)
    }

    /// A proportionally scaled-down instance (for tests/examples); `frac`
    /// in (0, 1].
    pub fn scaled(&self, frac: f64, seed: u64) -> Graph {
        assert!(frac > 0.0 && frac <= 1.0);
        let s = |x: usize| ((x as f64 * frac).round() as usize).max(8);
        let kind = match self.kind {
            GraphKind::ErdosRenyi { n, m } => {
                GraphKind::ErdosRenyi { n: s(n), m: s(m) }
            }
            GraphKind::BarabasiAlbert { n, m } => {
                GraphKind::BarabasiAlbert { n: s(n), m }
            }
            GraphKind::PowerlawCluster { n, m, p } => {
                GraphKind::PowerlawCluster { n: s(n), m, p }
            }
            GraphKind::WattsStrogatz { n, k, beta } => {
                GraphKind::WattsStrogatz { n: s(n), k, beta }
            }
            GraphKind::RoadNetwork { rows, cols, drop, subdiv, shortcuts } => {
                let f = frac.sqrt();
                GraphKind::RoadNetwork {
                    rows: ((rows as f64 * f).round() as usize).max(4),
                    cols: ((cols as f64 * f).round() as usize).max(4),
                    drop,
                    subdiv,
                    shortcuts: (shortcuts as f64 * frac).round() as usize,
                }
            }
        };
        kind.generate(seed)
    }
}

/// ASTROPH: astrophysics collaboration net — small world, high clustering.
pub fn astroph() -> Dataset {
    Dataset {
        name: "ASTROPH",
        paper: PaperRow { v: 17903, e: 196972, d: 14, cc: 1.34e-1, rcc: 1.23e-3 },
        kind: GraphKind::PowerlawCluster { n: 17903, m: 11, p: 0.64 },
        simulation: true,
    }
}

/// EMAIL-ENRON: email communication network — small world, lower clustering.
pub fn email_enron() -> Dataset {
    Dataset {
        name: "EMAIL-ENRON",
        paper: PaperRow { v: 33696, e: 180811, d: 13, cc: 3.01e-2, rcc: 3.19e-4 },
        kind: GraphKind::PowerlawCluster { n: 33696, m: 5, p: 0.18 },
        simulation: true,
    }
}

/// USROADS: US road network — huge diameter, near-zero clustering.
pub fn usroads() -> Dataset {
    Dataset {
        name: "USROADS",
        paper: PaperRow { v: 126146, e: 161950, d: 617, cc: 1.45e-2, rcc: 2.03e-5 },
        // 165x165 grid, 20% edges dropped, each segment subdivided in 3:
        // V ≈ 27k + 43k*2 ≈ 114k, E ≈ 130k, diameter ~ 600-900
        kind: GraphKind::RoadNetwork {
            rows: 165,
            cols: 165,
            drop: 0.20,
            subdiv: 3,
            shortcuts: 40,
        },
        simulation: true,
    }
}

/// WORDNET: synonym network — small diameter, very high clustering.
pub fn wordnet() -> Dataset {
    Dataset {
        name: "WORDNET",
        paper: PaperRow { v: 75606, e: 231622, d: 14, cc: 7.12e-2, rcc: 8.10e-5 },
        kind: GraphKind::PowerlawCluster { n: 75606, m: 3, p: 0.55 },
        simulation: true,
    }
}

/// DBLP: co-authorship network (Table III).
pub fn dblp() -> Dataset {
    Dataset {
        name: "DBLP",
        paper: PaperRow { v: 317080, e: 1049866, d: 21, cc: 1.28e-1, rcc: 2.09e-5 },
        kind: GraphKind::PowerlawCluster { n: 317080, m: 3, p: 0.62 },
        simulation: false,
    }
}

/// YOUTUBE: friendship graph (Table III) — power-law, low clustering.
pub fn youtube() -> Dataset {
    Dataset {
        name: "YOUTUBE",
        paper: PaperRow { v: 1134890, e: 2987624, d: 20, cc: 2.08e-3, rcc: 4.64e-6 },
        kind: GraphKind::BarabasiAlbert { n: 1134890, m: 3 },
        simulation: false,
    }
}

/// AMAZON: co-purchasing network (Table III).
pub fn amazon() -> Dataset {
    Dataset {
        name: "AMAZON",
        paper: PaperRow { v: 400727, e: 2349869, d: 18, cc: 5.99e-2, rcc: 2.93e-5 },
        kind: GraphKind::PowerlawCluster { n: 400727, m: 6, p: 0.35 },
        simulation: false,
    }
}

/// The four Table II datasets (simulation engine experiments).
pub fn simulation_datasets() -> Vec<Dataset> {
    vec![astroph(), email_enron(), usroads(), wordnet()]
}

/// The three Table III datasets (EC2/Hadoop experiments).
pub fn ec2_datasets() -> Vec<Dataset> {
    vec![dblp(), youtube(), amazon()]
}

/// Look a dataset up by (case-insensitive) name.
pub fn by_name(name: &str) -> Option<Dataset> {
    let up = name.to_uppercase();
    simulation_datasets()
        .into_iter()
        .chain(ec2_datasets())
        .find(|d| d.name == up)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::stats;

    #[test]
    fn lookup_by_name() {
        assert!(by_name("astroph").is_some());
        assert!(by_name("AstroPh").is_some());
        assert!(by_name("nope").is_none());
        assert_eq!(simulation_datasets().len(), 4);
        assert_eq!(ec2_datasets().len(), 3);
    }

    #[test]
    fn scaled_astroph_matches_character() {
        // 10% scale: still small-world with real clustering
        let g = astroph().scaled(0.10, 1);
        let s = stats::graph_stats(&g, 1);
        assert!(s.vertices > 1000, "{s:?}");
        assert!(s.clustering > 0.05, "{s:?}");
        assert!(s.diameter <= 14, "{s:?}");
        assert_eq!(s.components, 1);
    }

    #[test]
    fn scaled_usroads_has_large_diameter() {
        let g = usroads().scaled(0.05, 2);
        let s = stats::graph_stats(&g, 2);
        // at 5% scale of a ~617-diameter graph, expect > 100
        assert!(s.diameter > 100, "{s:?}");
        assert!(s.clustering < 0.05, "{s:?}");
    }

    #[test]
    fn full_scale_astroph_close_to_paper() {
        let d = astroph();
        let g = d.generate(7);
        let v_err = (g.vertex_count() as f64 / d.paper.v as f64 - 1.0).abs();
        let e_err = (g.edge_count() as f64 / d.paper.e as f64 - 1.0).abs();
        assert!(v_err < 0.05, "V off by {v_err}");
        assert!(e_err < 0.15, "E off by {e_err}");
    }
}
