//! The Fig-6 rewiring protocol: "starting from the USROADS dataset we
//! remapped random edges, thus decreasing the diameter. The remapping has
//! been performed in such a way to keep the number of triangles as close
//! as possible to the original graph."
//!
//! We remap a fraction of edges to uniformly random endpoint pairs,
//! rejecting replacements that would create a triangle (road networks have
//! almost none, so this keeps the triangle count essentially unchanged
//! while each remapped edge acts as a diameter-cutting shortcut).

use super::{Graph, GraphBuilder};
use crate::util::rng::Rng;

/// Remap `fraction` of the edges to random endpoint pairs, triangle-free.
/// Returns the largest component of the result (remapping can in principle
/// disconnect fringe vertices).
pub fn rewire_fraction(g: &Graph, fraction: f64, seed: u64) -> Graph {
    assert!((0.0..=1.0).contains(&fraction));
    let mut rng = Rng::new(seed);
    let n = g.vertex_count();
    let m = g.edge_count();
    let k = (fraction * m as f64).round() as usize;

    let mut edges: Vec<(u32, u32)> = g.edges().to_vec();
    let mut edge_set: std::collections::HashSet<(u32, u32)> =
        edges.iter().cloned().collect();
    let picks = rng.sample_indices(m, k);
    for &e in &picks {
        let old = edges[e];
        let mut accepted = None;
        for _ in 0..32 {
            let u = rng.below(n) as u32;
            let v = rng.below(n) as u32;
            if u == v {
                continue;
            }
            let cand = (u.min(v), u.max(v));
            if edge_set.contains(&cand) {
                continue;
            }
            if creates_triangle(g, cand.0, cand.1) {
                continue;
            }
            accepted = Some(cand);
            break;
        }
        if let Some(cand) = accepted {
            edge_set.remove(&old);
            edge_set.insert(cand);
            edges[e] = cand;
        }
    }
    let mut b = GraphBuilder::new();
    b.touch_vertex(n as u32 - 1);
    for (u, v) in edges {
        b.push_edge(u, v);
    }
    b.build_largest_component()
}

fn creates_triangle(g: &Graph, u: u32, v: u32) -> bool {
    // common neighbor in the *original* adjacency is a good proxy; exact
    // tracking would need incremental adjacency updates and the original
    // road graph has ~no triangles anyway.
    let nu = g.neighbor_vertices(u);
    let nv = g.neighbor_vertices(v);
    let (mut i, mut j) = (0usize, 0usize);
    while i < nu.len() && j < nv.len() {
        use std::cmp::Ordering::*;
        match nu[i].cmp(&nv[j]) {
            Less => i += 1,
            Greater => j += 1,
            Equal => return true,
        }
    }
    false
}

/// Produce the Fig-6 ladder: graphs of (approximately) the same size whose
/// diameters descend as the remap fraction grows. Returns
/// `(fraction, graph)` pairs ordered by decreasing diameter.
pub fn diameter_ladder(
    g: &Graph,
    fractions: &[f64],
    seed: u64,
) -> Vec<(f64, Graph)> {
    fractions
        .iter()
        .map(|&f| (f, rewire_fraction(g, f, seed ^ (f * 1e6) as u64)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::GraphKind;
    use crate::graph::stats;

    fn road() -> Graph {
        GraphKind::RoadNetwork {
            rows: 14,
            cols: 14,
            drop: 0.2,
            subdiv: 3,
            shortcuts: 0,
        }
        .generate(11)
    }

    #[test]
    fn rewiring_reduces_diameter() {
        let g = road();
        let d0 = stats::diameter_estimate(&g, 4, 1);
        let g2 = rewire_fraction(&g, 0.2, 7);
        let d2 = stats::diameter_estimate(&g2, 4, 1);
        assert!(d2 < d0, "expected shrink: {d0} -> {d2}");
    }

    #[test]
    fn rewiring_keeps_size_roughly() {
        let g = road();
        let g2 = rewire_fraction(&g, 0.3, 7);
        let keep = g2.edge_count() as f64 / g.edge_count() as f64;
        assert!(keep > 0.9, "kept only {keep}");
    }

    #[test]
    fn rewiring_keeps_triangles_low() {
        let g = road();
        let t0 = stats::triangle_count(&g);
        let g2 = rewire_fraction(&g, 0.3, 7);
        let t2 = stats::triangle_count(&g2);
        assert!(
            t2 <= t0 + (g.edge_count() as u64) / 100 + 2,
            "triangles grew {t0} -> {t2}"
        );
    }

    #[test]
    fn zero_fraction_is_identity_modulo_components() {
        let g = road();
        let g2 = rewire_fraction(&g, 0.0, 7);
        assert_eq!(g.edge_count(), g2.edge_count());
    }

    #[test]
    fn ladder_is_monotone_in_practice() {
        let g = road();
        let ladder = diameter_ladder(&g, &[0.0, 0.1, 0.4], 3);
        let ds: Vec<u32> = ladder
            .iter()
            .map(|(_, g)| stats::diameter_estimate(g, 3, 1))
            .collect();
        assert!(ds[0] >= ds[1] && ds[1] >= ds[2], "{ds:?}");
    }
}
