//! Graph statistics: components, BFS, diameter estimation, clustering
//! coefficients — everything Tables II/III report.

use super::Graph;
use crate::util::rng::Rng;

/// Hop distances from `source` (`u32::MAX` = unreachable).
pub fn bfs_distances(g: &Graph, source: u32) -> Vec<u32> {
    let mut dist = vec![u32::MAX; g.vertex_count()];
    let mut queue = std::collections::VecDeque::new();
    dist[source as usize] = 0;
    queue.push_back(source);
    while let Some(u) = queue.pop_front() {
        let du = dist[u as usize];
        for &w in g.neighbor_vertices(u) {
            if dist[w as usize] == u32::MAX {
                dist[w as usize] = du + 1;
                queue.push_back(w);
            }
        }
    }
    dist
}

/// Eccentricity of `v` within its component (max finite BFS distance).
pub fn eccentricity(g: &Graph, v: u32) -> u32 {
    bfs_distances(g, v)
        .into_iter()
        .filter(|&d| d != u32::MAX)
        .max()
        .unwrap_or(0)
}

/// Number of connected components.
pub fn component_count(g: &Graph) -> usize {
    components(g).1
}

/// Per-vertex component labels and the component count.
pub fn components(g: &Graph) -> (Vec<u32>, usize) {
    let n = g.vertex_count();
    let mut comp = vec![u32::MAX; n];
    let mut next = 0u32;
    let mut stack = Vec::new();
    for s in 0..n as u32 {
        if comp[s as usize] != u32::MAX {
            continue;
        }
        comp[s as usize] = next;
        stack.push(s);
        while let Some(u) = stack.pop() {
            for &w in g.neighbor_vertices(u) {
                if comp[w as usize] == u32::MAX {
                    comp[w as usize] = next;
                    stack.push(w);
                }
            }
        }
        next += 1;
    }
    (comp, next as usize)
}

/// Diameter lower-bound estimate by repeated double sweeps: from each of
/// `starts` random vertices, BFS to the farthest vertex, BFS again from it.
/// Exact on trees; within a small factor on real graphs — this is the
/// standard estimator for graphs too large for all-pairs BFS.
pub fn diameter_estimate(g: &Graph, starts: usize, seed: u64) -> u32 {
    if g.vertex_count() == 0 {
        return 0;
    }
    let mut rng = Rng::new(seed);
    let mut best = 0;
    for _ in 0..starts {
        let s = rng.below(g.vertex_count()) as u32;
        let d1 = bfs_distances(g, s);
        let (far, _) = d1
            .iter()
            .enumerate()
            .filter(|&(_, &d)| d != u32::MAX)
            .max_by_key(|&(_, &d)| d)
            .unwrap();
        let d2 = bfs_distances(g, far as u32);
        let ecc = d2.into_iter().filter(|&d| d != u32::MAX).max().unwrap_or(0);
        best = best.max(ecc);
    }
    best
}

/// Exact diameter (all-pairs BFS) — only for small graphs/tests.
pub fn diameter_exact(g: &Graph) -> u32 {
    (0..g.vertex_count() as u32)
        .map(|v| eccentricity(g, v))
        .max()
        .unwrap_or(0)
}

/// Count of triangles incident on each vertex plus total wedges; uses
/// sorted-adjacency intersection, O(sum_deg^2 / n) in practice.
fn triangles_and_wedges(g: &Graph) -> (u64, u64, Vec<u64>) {
    let n = g.vertex_count();
    let mut tri_per_vertex = vec![0u64; n];
    let mut triangles = 0u64;
    let mut wedges = 0u64;
    for v in 0..n as u32 {
        let d = g.degree(v) as u64;
        wedges += d * (d.saturating_sub(1)) / 2;
    }
    for (_, u, v) in g.edge_iter() {
        // count common neighbors of u, v via sorted merge
        let (mut i, mut j) = (0usize, 0usize);
        let nu = g.neighbor_vertices(u);
        let nv = g.neighbor_vertices(v);
        while i < nu.len() && j < nv.len() {
            use std::cmp::Ordering::*;
            match nu[i].cmp(&nv[j]) {
                Less => i += 1,
                Greater => j += 1,
                Equal => {
                    let w = nu[i];
                    // each triangle (u,v,w) is counted once per edge, i.e.
                    // 3 times in total across the edge loop
                    triangles += 1;
                    tri_per_vertex[w as usize] += 1;
                    i += 1;
                    j += 1;
                }
            }
        }
    }
    // `triangles` now holds 3 * #triangles (once per edge of the triangle)
    (triangles / 3, wedges, tri_per_vertex)
}

/// Global clustering coefficient (transitivity): 3·triangles / wedges.
pub fn global_clustering(g: &Graph) -> f64 {
    let (tri, wedges, _) = triangles_and_wedges(g);
    if wedges == 0 {
        0.0
    } else {
        3.0 * tri as f64 / wedges as f64
    }
}

/// Total triangle count.
pub fn triangle_count(g: &Graph) -> u64 {
    triangles_and_wedges(g).0
}

/// Expected clustering coefficient of a G(n,m) random graph of the same
/// size — the paper's "RCC" column: for ER, CC ≈ p = 2m / (n(n-1)).
pub fn random_graph_cc(g: &Graph) -> f64 {
    let n = g.vertex_count() as f64;
    let m = g.edge_count() as f64;
    if n < 2.0 {
        0.0
    } else {
        2.0 * m / (n * (n - 1.0))
    }
}

/// The stats row the paper tabulates per dataset.
#[derive(Clone, Debug)]
pub struct GraphStats {
    /// Vertex count |V|.
    pub vertices: usize,
    /// Edge count |E|.
    pub edges: usize,
    /// Diameter (double-sweep estimate).
    pub diameter: u32,
    /// Global clustering coefficient.
    pub clustering: f64,
    /// Expected clustering of a same-density random graph.
    pub random_cc: f64,
    /// Mean degree `2|E|/|V|`.
    pub avg_degree: f64,
    /// Maximum degree.
    pub max_degree: usize,
    /// Connected component count.
    pub components: usize,
}

/// Compute the Table II/III row (diameter via double-sweep estimate).
pub fn graph_stats(g: &Graph, seed: u64) -> GraphStats {
    let max_degree =
        (0..g.vertex_count() as u32).map(|v| g.degree(v)).max().unwrap_or(0);
    GraphStats {
        vertices: g.vertex_count(),
        edges: g.edge_count(),
        diameter: diameter_estimate(g, 8, seed),
        clustering: global_clustering(g),
        random_cc: random_graph_cc(g),
        avg_degree: 2.0 * g.edge_count() as f64 / g.vertex_count().max(1) as f64,
        max_degree,
        components: component_count(g),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    fn path(n: u32) -> Graph {
        let mut b = GraphBuilder::new();
        for i in 0..n - 1 {
            b.push_edge(i, i + 1);
        }
        b.build()
    }

    #[test]
    fn bfs_on_path() {
        let g = path(5);
        assert_eq!(bfs_distances(&g, 0), vec![0, 1, 2, 3, 4]);
        assert_eq!(bfs_distances(&g, 2), vec![2, 1, 0, 1, 2]);
    }

    #[test]
    fn diameter_path_exact_and_estimate() {
        let g = path(10);
        assert_eq!(diameter_exact(&g), 9);
        // double sweep is exact on trees
        assert_eq!(diameter_estimate(&g, 1, 0), 9);
    }

    #[test]
    fn clustering_triangle_vs_star() {
        let tri = GraphBuilder::new()
            .add_edge(0, 1)
            .add_edge(1, 2)
            .add_edge(0, 2)
            .build();
        assert!((global_clustering(&tri) - 1.0).abs() < 1e-12);
        assert_eq!(triangle_count(&tri), 1);
        let star = GraphBuilder::new()
            .add_edge(0, 1)
            .add_edge(0, 2)
            .add_edge(0, 3)
            .build();
        assert_eq!(global_clustering(&star), 0.0);
        assert_eq!(triangle_count(&star), 0);
    }

    #[test]
    fn components_counted() {
        let g = GraphBuilder::new()
            .add_edge(0, 1)
            .add_edge(2, 3)
            .add_edge(3, 4)
            .build();
        let (labels, count) = components(&g);
        assert_eq!(count, 2);
        assert_eq!(labels[0], labels[1]);
        assert_eq!(labels[2], labels[3]);
        assert_ne!(labels[0], labels[2]);
    }

    #[test]
    fn stats_row_consistent() {
        let g = path(6);
        let s = graph_stats(&g, 0);
        assert_eq!(s.vertices, 6);
        assert_eq!(s.edges, 5);
        assert_eq!(s.diameter, 5);
        assert_eq!(s.components, 1);
        assert_eq!(s.max_degree, 2);
    }
}
