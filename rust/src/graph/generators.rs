//! Synthetic graph generators.
//!
//! These stand in for the SNAP datasets (no network in this environment —
//! see DESIGN.md "Substitutions"): each model is chosen so the properties
//! DFEP is sensitive to (degree distribution, clustering, diameter) can be
//! matched to the paper's Tables II/III.

use super::{Graph, GraphBuilder};
use crate::util::rng::Rng;

/// A parameterized generator; `generate(seed)` is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum GraphKind {
    /// G(n, m): `m` uniform random edges. Low clustering, low diameter.
    ErdosRenyi { n: usize, m: usize },
    /// Barabási–Albert preferential attachment, `m` edges per new vertex.
    /// Power-law degrees, low clustering (YOUTUBE-like).
    BarabasiAlbert { n: usize, m: usize },
    /// Holme–Kim power-law cluster model: BA plus triad formation with
    /// probability `p` per edge. Power-law + high clustering
    /// (ASTROPH / DBLP / WORDNET-like).
    PowerlawCluster { n: usize, m: usize, p: f64 },
    /// Watts–Strogatz ring (k nearest neighbors, rewire prob `beta`).
    WattsStrogatz { n: usize, k: usize, beta: f64 },
    /// Road-network model: a `rows x cols` grid with `drop` fraction of
    /// grid edges removed (keeping it connected), every surviving edge
    /// subdivided into `subdiv` segments, plus `shortcuts` long-range
    /// chords. Very large diameter, near-zero clustering (USROADS-like).
    RoadNetwork {
        rows: usize,
        cols: usize,
        drop: f64,
        subdiv: usize,
        shortcuts: usize,
    },
}

impl GraphKind {
    /// Generate the graph (always connected: falls back to the largest
    /// component for models that may fragment).
    pub fn generate(&self, seed: u64) -> Graph {
        let mut rng = Rng::new(seed);
        match *self {
            GraphKind::ErdosRenyi { n, m } => erdos_renyi(n, m, &mut rng),
            GraphKind::BarabasiAlbert { n, m } => {
                powerlaw_cluster(n, m, 0.0, &mut rng)
            }
            GraphKind::PowerlawCluster { n, m, p } => {
                powerlaw_cluster(n, m, p, &mut rng)
            }
            GraphKind::WattsStrogatz { n, k, beta } => {
                watts_strogatz(n, k, beta, &mut rng)
            }
            GraphKind::RoadNetwork { rows, cols, drop, subdiv, shortcuts } => {
                road_network(rows, cols, drop, subdiv, shortcuts, &mut rng)
            }
        }
    }
}

fn erdos_renyi(n: usize, m: usize, rng: &mut Rng) -> Graph {
    assert!(n >= 2);
    let mut seen = std::collections::HashSet::with_capacity(m * 2);
    let mut b = GraphBuilder::new();
    b.touch_vertex(n as u32 - 1);
    while seen.len() < m {
        let u = rng.below(n) as u32;
        let v = rng.below(n) as u32;
        if u == v {
            continue;
        }
        if seen.insert((u.min(v), u.max(v))) {
            b.push_edge(u, v);
        }
    }
    b.build_largest_component()
}

/// Holme–Kim: preferential attachment with triad steps. `p = 0` is plain BA.
fn powerlaw_cluster(n: usize, m: usize, p: f64, rng: &mut Rng) -> Graph {
    assert!(n > m && m >= 1);
    // repeated-endpoint list gives preferential attachment in O(1);
    // a live adjacency list makes the triad step exact (attach to a
    // uniform neighbor of the previous target, closing a triangle)
    let mut targets: Vec<u32> = Vec::with_capacity(2 * n * m);
    let mut adj: Vec<Vec<u32>> = vec![Vec::new(); n];
    let mut b = GraphBuilder::new();
    let connect = |b: &mut GraphBuilder,
                       adj: &mut Vec<Vec<u32>>,
                       targets: &mut Vec<u32>,
                       u: u32,
                       v: u32| {
        b.push_edge(u, v);
        adj[u as usize].push(v);
        adj[v as usize].push(u);
        targets.push(u);
        targets.push(v);
    };
    // seed clique over m+1 vertices
    for u in 0..=(m as u32) {
        for v in (u + 1)..=(m as u32) {
            connect(&mut b, &mut adj, &mut targets, u, v);
        }
    }
    for v in (m as u32 + 1)..(n as u32) {
        let mut attached: Vec<u32> = Vec::with_capacity(m);
        let mut last: Option<u32> = None;
        let mut tries = 0usize;
        while attached.len() < m {
            tries += 1;
            let w = if let (Some(anchor), true, true) =
                (last, rng.chance(p), tries < 64)
            {
                // triad step: uniform neighbor of the previous target
                let nbrs = &adj[anchor as usize];
                nbrs[rng.below(nbrs.len())]
            } else {
                targets[rng.below(targets.len())]
            };
            if w != v && !attached.contains(&w) {
                attached.push(w);
                last = Some(w);
            }
        }
        for &w in &attached {
            connect(&mut b, &mut adj, &mut targets, v, w);
        }
    }
    b.build_largest_component()
}

fn watts_strogatz(n: usize, k: usize, beta: f64, rng: &mut Rng) -> Graph {
    assert!(k % 2 == 0 && k < n);
    let mut edges = std::collections::HashSet::new();
    for u in 0..n {
        for j in 1..=(k / 2) {
            let v = (u + j) % n;
            edges.insert((u.min(v) as u32, u.max(v) as u32));
        }
    }
    // rewire
    let orig: Vec<(u32, u32)> = edges.iter().cloned().collect();
    for (u, v) in orig {
        if rng.chance(beta) {
            edges.remove(&(u, v));
            let mut tries = 0;
            loop {
                let w = rng.below(n) as u32;
                let cand = (u.min(w), u.max(w));
                if w != u && !edges.contains(&cand) {
                    edges.insert(cand);
                    break;
                }
                tries += 1;
                if tries > 64 {
                    edges.insert((u, v)); // give up, restore
                    break;
                }
            }
        }
    }
    let mut b = GraphBuilder::new();
    b.touch_vertex(n as u32 - 1);
    for (u, v) in edges {
        b.push_edge(u, v);
    }
    b.build_largest_component()
}

fn road_network(
    rows: usize,
    cols: usize,
    drop: f64,
    subdiv: usize,
    shortcuts: usize,
    rng: &mut Rng,
) -> Graph {
    assert!(rows >= 2 && cols >= 2 && subdiv >= 1);
    let id = |r: usize, c: usize| (r * cols + c) as u32;
    // all grid edges
    let mut grid_edges: Vec<(u32, u32)> = Vec::new();
    for r in 0..rows {
        for c in 0..cols {
            if c + 1 < cols {
                grid_edges.push((id(r, c), id(r, c + 1)));
            }
            if r + 1 < rows {
                grid_edges.push((id(r, c), id(r + 1, c)));
            }
        }
    }
    // drop a fraction, then keep the largest component at the end
    rng.shuffle(&mut grid_edges);
    let keep = ((1.0 - drop) * grid_edges.len() as f64).round() as usize;
    grid_edges.truncate(keep.max(rows * cols - 1));

    // subdivide: each kept edge becomes a path of `subdiv` segments
    let mut next_vertex = (rows * cols) as u32;
    let mut b = GraphBuilder::new();
    for &(u, v) in &grid_edges {
        let mut prev = u;
        for _ in 1..subdiv {
            b.push_edge(prev, next_vertex);
            prev = next_vertex;
            next_vertex += 1;
        }
        b.push_edge(prev, v);
    }
    // a few long-range chords (highways) to trim the worst-case diameter
    for _ in 0..shortcuts {
        let u = rng.below(rows * cols) as u32;
        let v = rng.below(rows * cols) as u32;
        if u != v {
            b.push_edge(u, v);
        }
    }
    b.build_largest_component()
}

/// Convenience: a connected ER graph of given average degree.
pub fn random_connected(n: usize, avg_degree: f64, seed: u64) -> Graph {
    let m = ((n as f64) * avg_degree / 2.0).round() as usize;
    GraphKind::ErdosRenyi { n, m }.generate(seed)
}

/// Dense CSR -> padded tropical adjacency for the XLA runtime path.
/// Returns row-major `size x size` with `inf` off-edges, `w` on edges and
/// 0 diagonal (so relaxation keeps current labels).
pub fn dense_tropical(g: &Graph, size: usize, w: f32, inf: f32) -> Vec<f32> {
    assert!(g.vertex_count() <= size);
    let mut a = vec![inf; size * size];
    for i in 0..size {
        a[i * size + i] = 0.0;
    }
    for (_, u, v) in g.edge_iter() {
        a[u as usize * size + v as usize] = w;
        a[v as usize * size + u as usize] = w;
    }
    a
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::stats;

    #[test]
    fn erdos_renyi_counts() {
        let g = GraphKind::ErdosRenyi { n: 500, m: 1500 }.generate(1);
        assert!(g.vertex_count() <= 500);
        assert!(g.edge_count() <= 1500);
        assert!(g.edge_count() > 1300); // largest component keeps most
        assert_eq!(stats::component_count(&g), 1);
    }

    #[test]
    fn generators_are_deterministic() {
        let k = GraphKind::PowerlawCluster { n: 300, m: 3, p: 0.4 };
        let a = k.generate(9);
        let b = k.generate(9);
        assert_eq!(a.edges(), b.edges());
        let c = k.generate(10);
        assert_ne!(a.edges(), c.edges());
    }

    #[test]
    fn powerlaw_cluster_has_clustering() {
        let flat = GraphKind::BarabasiAlbert { n: 2000, m: 4 }.generate(2);
        let clustered =
            GraphKind::PowerlawCluster { n: 2000, m: 4, p: 0.8 }.generate(2);
        let cc_flat = stats::global_clustering(&flat);
        let cc_clus = stats::global_clustering(&clustered);
        assert!(cc_clus > cc_flat * 1.5, "{cc_clus} vs {cc_flat}");
    }

    #[test]
    fn powerlaw_has_heavy_tail() {
        let g = GraphKind::BarabasiAlbert { n: 3000, m: 3 }.generate(3);
        let dmax = (0..g.vertex_count() as u32)
            .map(|v| g.degree(v))
            .max()
            .unwrap();
        // ER with same density would have max degree ~ 6 + small; BA grows
        // like sqrt(n)
        assert!(dmax > 40, "max degree {dmax}");
    }

    #[test]
    fn road_network_has_large_diameter() {
        let road = GraphKind::RoadNetwork {
            rows: 12,
            cols: 12,
            drop: 0.25,
            subdiv: 3,
            shortcuts: 0,
        }
        .generate(4);
        let small = GraphKind::ErdosRenyi {
            n: road.vertex_count(),
            m: road.edge_count(),
        }
        .generate(4);
        let d_road = stats::diameter_estimate(&road, 4, 4);
        let d_small = stats::diameter_estimate(&small, 4, 4);
        assert!(
            d_road > 3 * d_small,
            "road {d_road} vs er {d_small}"
        );
        assert_eq!(stats::component_count(&road), 1);
    }

    #[test]
    fn watts_strogatz_ring_structure() {
        let g = GraphKind::WattsStrogatz { n: 200, k: 4, beta: 0.05 }
            .generate(5);
        assert!(g.edge_count() >= 395 && g.edge_count() <= 400);
        let avg_deg = 2.0 * g.edge_count() as f64 / g.vertex_count() as f64;
        assert!((3.5..=4.2).contains(&avg_deg));
    }

    #[test]
    fn dense_tropical_layout() {
        let g = GraphBuilder::new().add_edge(0, 1).add_edge(1, 2).build();
        let inf = f32::MAX / 4.0;
        let a = dense_tropical(&g, 4, 1.0, inf);
        assert_eq!(a[0 * 4 + 1], 1.0);
        assert_eq!(a[1 * 4 + 0], 1.0);
        assert_eq!(a[0 * 4 + 2], inf);
        assert_eq!(a[2 * 4 + 2], 0.0);
        assert_eq!(a[3 * 4 + 3], 0.0);
    }
}
