//! `repro` — the leader binary: partition graphs, run ETSCH workloads,
//! simulate the EC2 cluster experiments, print dataset stats.
//!
//! Every command is a thin client of the coordinator facade
//! (`PartitionRequest -> RunReport`); partitioners are named by spec
//! (`--algo hdrf:lambda=1.5`) and resolved through the registry. All
//! failures — malformed specs, missing files, bad `k` — print a one-line
//! error and exit non-zero; no panics, no backtraces for user errors.
//!
//! Examples:
//!   repro partition --graph astroph --algo dfep --k 20 --seed 1
//!   repro partition --graph astroph --algo hdrf:lambda=1.5 --k 32
//!   repro batch --graph astroph@0.05 --algos dfep,random --ks 16,32 --seeds 1,2
//!   repro sssp --graph usroads@0.05 --k 8 --source 0
//!   repro cluster --graph dblp@0.1 --k 16 --workers 3 --verify
//!   repro cluster --simulate --graph dblp@0.1 --nodes 2,4,8,16
//!   repro stats --graph wordnet@0.1
//!   repro serve --addr 127.0.0.1:7411 --workers 4
//!   repro xla-info
//!   repro xla-partition --graph er:n=500,m=1500 --k 8

use dfep::anyhow;
use dfep::util::error::Result;

use dfep::cluster::cost::CostModel;
use dfep::cluster::dfep_mr::{resimulate, run_cluster_dfep};
use dfep::cluster::etsch_mr::{run_baseline_sssp, run_etsch_sssp};
use dfep::coordinator::cli::Args;
use dfep::coordinator::runs::{
    resolve_graph, PartitionRequest, RunReport, Workload,
};
use dfep::graph::{io, stats};
use dfep::partition::spec::PartitionerSpec;
use dfep::partition::{registry, PartitionInput, Partitioner, StreamInput};
use dfep::runtime::Runtime;

const HELP: &str = "\
repro — DFEP + ETSCH reproduction (Guerrieri & Montresor, 2014)

USAGE: repro <command> [--key value]...

COMMANDS
  partition   partition a graph and print the paper's metrics
              --graph SPEC --algo ALGOSPEC --k N --seed S
              [--threads N] [--gain-samples N] [--out FILE] [--json FILE]
  stream-partition  out-of-core: partition a SNAP edge-list file without
              materializing the graph (bounded-memory ingestion for the
              streaming-native algos; others materialize)
              --input FILE --algo ALGOSPEC --k N --seed S
              [--chunk N] [--out FILE] [--evaluate]
  sssp        run ETSCH single-source shortest paths on a partitioning
              --graph SPEC [--algo ALGOSPEC] --k N --source V --seed S
  etsch       run any ETSCH algorithm on a partitioning
              --graph SPEC [--algo ALGOSPEC]
              --alg sssp|cc|mis|pagerank|kcore|labelprop|betweenness
              --k N [--core-k N] [--samples N] --seed S
  batch       run a (algo, k, seed) sweep against one graph through the
              batched engine: one graph resolve + one shared profile,
              variants fanned out over pool lanes, reports in variant
              order bit-identical to sequential runs
              --graph SPEC [--algos A,B,...] [--ks 2,8] [--seeds 1,2]
              [--threads N] [--gain-samples N] [--json FILE]
  algos       list every registered partitioner spec and its parameters
  faults      re-simulate the Fig-8 DFEP job under failure injection
              --graph SPEC --k N --nodes N --fail-rate P --seed S
  cluster     real distributed partitioning: a coordinator drives W
              worker processes of this binary over localhost TCP, with
              periodic checkpoints, optional failure injection, and
              measured-vs-predicted wire bytes (see DESIGN.md
              \"Distributed runtime\")
              --graph SPEC [--algo ALGOSPEC] --k N --seed S
              [--workers W] [--in-process] [--checkpoint-every N]
              [--checkpoint-dir DIR] [--resume] [--sssp-source V]
              [--verify]
              [--fail-rank R --fail-round N [--fail-stall-ms MS]]
              [--fault FAULTSPEC] [--timeout-ms MS] [--max-recoveries N]
              --quick: canned 3-worker smoke run, verified against the
              single-process facade
              --simulate: legacy analytic Hadoop/EC2 model (Figs 8-9)
              --graph SPEC --k N --nodes 2,4,8,16 --seed S
  worker      internal: one cluster worker (spawned by `repro cluster`)
              --connect HOST:PORT
  stats       print the Table II/III row for a graph
              --graph SPEC [--seed S]
  serve       partitioning-as-a-service: long-running HTTP/1.1 server
              answering PartitionRequest JSON on POST /partition and
              BatchRequest JSON on POST /batch, with a single-flight
              result cache and bounded-load shedding
              (see DESIGN.md \"Serving layer\")
              [--addr HOST:PORT] [--workers N] [--max-body BYTES]
              [--max-queue N] [--max-compute N] [--timeout SECS]
              [--cache N] [--graphs N] [--fault FAULTSPEC]
  xla-info    show the PJRT platform and the AOT artifact manifest
  xla-partition  run DFEP with XLA-offloaded funding rounds
              --graph SPEC --k N --seed S [--artifacts DIR]
  help        this text

ALGO SPECS (see `repro algos` for parameters and defaults)
  name[:key=val,...]   e.g. dfep | hdrf:lambda=1.5 | jabeja:temp=2,rounds=50
  refine:base=SPEC     local-search post-pass over any base spec; the
                       nested spec writes its commas as '+', e.g.
                       refine:base=hdrf:lambda=1.5+group=512,rounds=4

GRAPH SPECS
  astroph | email-enron | usroads | wordnet | dblp | youtube | amazon
  name@FRAC     scaled instance, e.g. usroads@0.05
  er:n=..,m=..  plc:n=..,m=..,p=..  ba:n=..,m=..  road:n=..

FAULT SPECS (deterministic chaos; see DESIGN.md \"Fault plane\")
  fault:seed=S[,drop=P][,delay_ms=LO..HI][,corrupt=P]
        [,short_read=P][,torn_write=P]
  `--fault` on cluster/serve, or the DFEP_FAULT env var when the flag
  is absent; same seed replays the same fault sequence
";

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn dispatch(argv: Vec<String>) -> Result<()> {
    let args = Args::parse(argv)?;
    match args.command.as_str() {
        "partition" => cmd_partition(&args),
        "batch" => cmd_batch(&args),
        "stream-partition" => cmd_stream_partition(&args),
        "sssp" => cmd_sssp(&args),
        "etsch" => cmd_etsch(&args),
        "algos" => cmd_algos(),
        "faults" => cmd_faults(&args),
        "cluster" => cmd_cluster(&args),
        "worker" => cmd_worker(&args),
        "stats" => cmd_stats(&args),
        "serve" => cmd_serve(&args),
        "xla-info" => cmd_xla_info(&args),
        "xla-partition" => cmd_xla_partition(&args),
        "help" | "-h" | "--help" => {
            print!("{HELP}");
            Ok(())
        }
        other => Err(anyhow!("unknown command '{other}' (try `repro help`)")),
    }
}

/// `--fault SPEC`, falling back to the `DFEP_FAULT` env var (so CI can
/// turn chaos on without rewriting command lines). `None` when neither
/// is present; a malformed spec is a hard error either way.
fn fault_arg(args: &Args) -> Result<Option<dfep::util::fault::FaultPlan>> {
    let spec = match args.get("fault") {
        Some(s) => Some(s.to_string()),
        None => std::env::var("DFEP_FAULT").ok().filter(|s| !s.is_empty()),
    };
    spec.map(|s| dfep::util::fault::FaultPlan::parse(&s)).transpose()
}

fn graph_arg(args: &Args) -> Result<dfep::graph::Graph> {
    let spec = args
        .get("graph")
        .ok_or_else(|| anyhow!("--graph is required"))?;
    resolve_graph(spec, args.get_u64("graph-seed", 42)?)
}

/// Build the facade request shared by `partition` / `sssp` / `etsch`.
fn request_arg(args: &Args, default_k: usize) -> Result<PartitionRequest> {
    let mut req = PartitionRequest::new(args.get_or("algo", "dfep"))?
        .dataset(
            args.get("graph")
                .ok_or_else(|| anyhow!("--graph is required"))?,
        )
        .k(args.get_usize("k", default_k)?)
        .seed(args.get_u64("seed", 1)?)
        .graph_seed(args.get_u64("graph-seed", 42)?)
        .gain_samples(args.get_usize("gain-samples", 0)?);
    if args.get("threads").is_some() {
        req = req.threads(args.get_usize("threads", 1)?);
    }
    Ok(req)
}

fn print_report(r: &RunReport) {
    println!(
        "partitioner: {}  k={}  seed={}",
        r.spec, r.k, r.seed
    );
    println!("  time        {:.3}s", r.timings.partition_secs);
    println!("  rounds      {}", r.metrics.rounds);
    println!("  largest     {:.4} (normalized)", r.metrics.largest);
    println!("  nstdev      {:.4}", r.metrics.nstdev);
    println!("  messages    {}", r.metrics.messages);
    println!("  disconnected {:.2}%", r.metrics.disconnected * 100.0);
    if let Some(gain) = r.gain {
        println!("  gain        {gain:.4}");
    }
}

fn cmd_partition(args: &Args) -> Result<()> {
    let req = request_arg(args, 20)?;
    let res = req.execute()?;
    println!(
        "graph: {} |V|={} |E|={} (resolved in {:.3}s)",
        res.dataset, res.vertices, res.edges, res.timings.resolve_secs
    );
    print_report(&res);
    if let Some(out) = args.get("out") {
        io::write_partition(&res.partition.owner, std::path::Path::new(out))?;
        println!("  wrote {out}");
    }
    if let Some(out) = args.get("json") {
        std::fs::write(out, res.to_json())
            .map_err(|e| anyhow!("writing {out}: {e}"))?;
        println!("  wrote {out}");
    }
    Ok(())
}

fn cmd_batch(args: &Args) -> Result<()> {
    use dfep::coordinator::batch::{grid, BatchRequest};
    let graph = args
        .get("graph")
        .ok_or_else(|| anyhow!("--graph is required"))?;
    let algos: Vec<&str> = args.get_or("algos", "dfep").split(',').collect();
    let ks: Vec<usize> = args
        .get_or("ks", "20")
        .split(',')
        .map(|s| s.parse().map_err(|_| anyhow!("bad k '{s}' in --ks")))
        .collect::<Result<_>>()?;
    let seeds: Vec<u64> = args
        .get_or("seeds", "1")
        .split(',')
        .map(|s| s.parse().map_err(|_| anyhow!("bad seed '{s}' in --seeds")))
        .collect::<Result<_>>()?;
    let mut req = BatchRequest::new(graph)
        .graph_seed(args.get_u64("graph-seed", 42)?)
        .gain_samples(args.get_usize("gain-samples", 0)?);
    for v in grid(&algos, &ks, &seeds)? {
        req = req.variant(v);
    }
    if args.get("threads").is_some() {
        req = req.threads(args.get_usize("threads", 1)?);
    }
    let rep = req.execute()?;
    println!(
        "graph: {} |V|={} |E|={} max-deg {} avg-deg {:.2} \
         (resolved {:.3}s, profiled {:.3}s)",
        rep.dataset,
        rep.vertices,
        rep.edges,
        rep.shared.max_degree,
        rep.shared.avg_degree,
        rep.resolve_secs,
        rep.shared_secs
    );
    println!(
        "{} variant(s) over {} lane(s) in {:.3}s ({:.1} variants/s, \
         scratch peak {} B)",
        rep.reports.len(),
        rep.lanes,
        rep.exec_secs,
        rep.reports.len() as f64 / rep.exec_secs.max(1e-9),
        rep.scratch_peak_bytes
    );
    println!(
        "{:<18} {:>4} {:>6} {:>7} {:>8} {:>8} {:>9} {:>8}",
        "spec", "k", "seed", "rounds", "largest", "nstdev", "messages",
        "secs"
    );
    for r in &rep.reports {
        println!(
            "{:<18} {:>4} {:>6} {:>7} {:>8.4} {:>8.4} {:>9} {:>8.3}",
            r.spec,
            r.k,
            r.seed,
            r.metrics.rounds,
            r.metrics.largest,
            r.metrics.nstdev,
            r.metrics.messages,
            r.timings.partition_secs
        );
    }
    if let Some(out) = args.get("json") {
        std::fs::write(out, rep.to_json())
            .map_err(|e| anyhow!("writing {out}: {e}"))?;
        println!("  wrote {out}");
    }
    Ok(())
}

fn cmd_algos() -> Result<()> {
    println!("registered partitioners (spec grammar: name[:key=val,...]):");
    for e in registry::all() {
        let native = if e.streaming_native { "  [streaming-native]" } else { "" };
        println!("\n  {}{native} — {}", e.name, e.summary);
        if !e.aliases.is_empty() {
            println!("    aliases: {}", e.aliases.join(", "));
        }
        for p in e.params {
            println!(
                "    {}={}  {}",
                p.key, p.default, p.doc
            );
        }
    }
    Ok(())
}

fn cmd_stream_partition(args: &Args) -> Result<()> {
    use dfep::graph::stream::FileEdgeStream;
    use dfep::partition::streaming;
    let input = args
        .get("input")
        .ok_or_else(|| anyhow!("--input FILE is required"))?;
    let path = std::path::Path::new(input);
    let k = args.get_usize("k", 8)?;
    let seed = args.get_u64("seed", 1)?;
    let chunk = args.get_usize("chunk", 4096)?.max(1);
    // the one `--algo` grammar: any registered spec; `--chunk` is sugar
    // for the spec's chunk parameter where the algo has one
    let mut spec = PartitionerSpec::parse(args.get_or("algo", "hdrf"))?;
    if spec.algo().param("chunk").is_some()
        && !spec.overrides().iter().any(|(key, _)| key == "chunk")
    {
        let sep = if spec.overrides().is_empty() { ':' } else { ',' };
        spec = PartitionerSpec::parse(&format!("{spec}{sep}chunk={chunk}"))?;
    }
    let p = spec.build();
    if !p.streaming_native() {
        println!(
            "note: '{spec}' is not streaming-native; the graph will be \
             materialized in memory"
        );
    }
    let mut stream = FileEdgeStream::open(path)?;
    let (part, secs) = dfep::util::timer::time(|| {
        p.partition(
            PartitionInput::Stream(StreamInput::new(&mut stream)),
            k,
            seed,
        )
    });
    let part = part?;
    // streaming-native quality: one more bounded-memory replay, no Graph
    let stats = streaming::stream_stats(&mut stream, &part.owner, k, chunk)?;
    println!(
        "stream: {} edges, {} vertices ({} chunk)",
        stats.edges, stats.vertices, chunk
    );
    println!(
        "{spec} k={k} seed={seed}: {:.3}s ({:.2} Medges/s, {} pass(es))",
        secs,
        stats.edges as f64 / secs.max(1e-9) / 1e6,
        part.rounds
    );
    println!("  replication factor {:.4}", stats.replication_factor());
    println!("  largest            {:.4} (normalized)", stats.largest_normalized());
    if args.flag("evaluate") {
        // optional in-memory check: materialize enforces that the file is
        // canonical (stream position == edge id, e.g. written by
        // write_edge_list), so owners cannot silently pair with the
        // wrong edges
        let g = StreamInput::new(&mut stream).materialize("--evaluate")?;
        let r = dfep::partition::metrics::evaluate(&g, &part);
        println!(
            "  evaluate: largest {:.4}  nstdev {:.4}  messages {}  disconnected {:.2}%",
            r.largest,
            r.nstdev,
            r.messages,
            r.disconnected * 100.0
        );
    }
    if let Some(out) = args.get("out") {
        io::write_partition(&part.owner, std::path::Path::new(out))?;
        println!("  wrote {out}");
    }
    Ok(())
}

fn cmd_sssp(args: &Args) -> Result<()> {
    let mut req = request_arg(args, 8)?;
    let source = args.get_usize("source", 0)? as u32;
    req.workload = Some(Workload::Sssp { source });
    // resolve once; the facade's execute_on and the baseline share it
    let g = resolve_graph(&req.dataset, req.graph_seed)?;
    let res = req.execute_on(&g)?;
    let w = res
        .workload
        .as_ref()
        .ok_or_else(|| anyhow!("workload produced no report"))?;
    let base = dfep::etsch::vertex_baseline::bsp_sssp(&g, source);
    println!("graph: |V|={} |E|={}", res.vertices, res.edges);
    println!(
        "ETSCH sssp ({} k={}): rounds={} messages={} reached={}",
        res.spec, res.k, w.rounds, w.messages, w.reached
    );
    println!(
        "baseline:   supersteps={} messages={}",
        base.supersteps, base.messages
    );
    println!(
        "gain: {:.4}",
        (1.0 - w.rounds as f64 / base.supersteps.max(1) as f64).max(0.0)
    );
    Ok(())
}

fn cmd_etsch(args: &Args) -> Result<()> {
    use dfep::etsch::{
        betweenness, cc::ConnectedComponents, kcore::KCore,
        labelprop::LabelPropagation, mis, pagerank::PageRank, sssp::Sssp,
    };
    let g = graph_arg(args)?;
    let k = args.get_usize("k", 8)?;
    let seed = args.get_u64("seed", 1)?;
    let spec = PartitionerSpec::parse(args.get_or("algo", "dfep"))?;
    let p = spec.build().partition_graph(&g, k, seed)?;
    // one derived-state build serves the frontier stats and the engine
    let view = dfep::partition::view::PartitionView::build(&g, &p);
    let mut engine = dfep::etsch::Etsch::from_view(&g, &view);
    let alg = args.get_or("alg", "sssp");
    println!(
        "graph |V|={} |E|={}  {spec} k={k} ({} rounds, {} frontier replicas)",
        g.vertex_count(),
        g.edge_count(),
        p.rounds,
        view.messages()
    );
    match alg {
        "sssp" => {
            let source = args.get_usize("source", 0)? as u32;
            let d = engine.run(&mut Sssp::new(source));
            let reached = d.iter().filter(|&&x| x != u32::MAX).count();
            println!(
                "sssp: {} rounds, {reached} reached",
                engine.rounds_executed()
            );
        }
        "cc" => {
            let labels = engine.run(&mut ConnectedComponents::new(seed));
            let n = labels
                .iter()
                .collect::<std::collections::HashSet<_>>()
                .len();
            println!(
                "cc: {} rounds, {n} component(s)",
                engine.rounds_executed()
            );
        }
        "mis" => {
            let st = engine.run(&mut mis::LubyMis::new(seed));
            let in_set: Vec<bool> = st
                .iter()
                .map(|s| s.status == mis::Status::InSet)
                .collect();
            mis::validate_mis(&g, &in_set)
                .map_err(|e| anyhow!(e))?;
            println!(
                "mis: {} rounds, |S| = {} (validated)",
                engine.rounds_executed(),
                in_set.iter().filter(|&&b| b).count()
            );
        }
        "pagerank" => {
            let iters = args.get_usize("iters", 20)?;
            let pr = engine.run(&mut PageRank::new(&g, iters));
            let top = pr
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.rank.total_cmp(&b.1.rank))
                .ok_or_else(|| anyhow!("pagerank on an empty graph"))?;
            println!(
                "pagerank: {iters} rounds, top vertex {} rank {:.6}",
                top.0, top.1.rank
            );
        }
        "kcore" => {
            let ck = args.get_usize("core-k", 3)? as u32;
            let st = engine.run(&mut KCore::new(ck));
            println!(
                "{ck}-core: {} rounds, {} vertices",
                engine.rounds_executed(),
                st.iter().filter(|s| s.alive).count()
            );
        }
        "labelprop" => {
            let st = engine.run(&mut LabelPropagation::default());
            let n = st
                .iter()
                .map(|s| s.label)
                .collect::<std::collections::HashSet<_>>()
                .len();
            println!(
                "labelprop: {} rounds, {n} communities",
                engine.rounds_executed()
            );
        }
        "betweenness" => {
            let samples = args.get_usize("samples", 32)?;
            let bc = betweenness::etsch_betweenness(&g, &p, samples, seed);
            let mut top: Vec<(usize, f64)> =
                bc.iter().cloned().enumerate().collect();
            top.sort_by(|a, b| b.1.total_cmp(&a.1));
            println!("betweenness ({samples} sources), top 5:");
            for (v, c) in top.iter().take(5) {
                println!("  vertex {v:>8}  {c:.1}");
            }
        }
        other => return Err(anyhow!("unknown algorithm '{other}'")),
    }
    Ok(())
}

fn cmd_faults(args: &Args) -> Result<()> {
    use dfep::cluster::failures::{simulate_with_faults, FaultModel};
    let g = graph_arg(args)?;
    let k = args.get_usize("k", 20)?;
    let nodes = args.get_usize("nodes", 8)?;
    let seed = args.get_u64("seed", 1)?;
    let rate = args.get_f64("fail-rate", 0.005)?;
    let cost = CostModel::default();
    let run = run_cluster_dfep(&g, k, nodes, seed, &cost, 2000);
    let clean: f64 =
        run.work.iter().map(|&w| cost.round_time(nodes, w)).sum();
    let fm = FaultModel {
        node_failure_per_round: rate,
        ..Default::default()
    };
    let f = simulate_with_faults(&cost, &fm, nodes, &run.work, seed);
    println!(
        "DFEP job: {} rounds on {nodes} nodes (fail-rate {rate}/node-round)",
        run.work.len()
    );
    println!("  clean   {clean:.1}s");
    println!(
        "  faulty  {:.1}s  (+{:.1}%, {} failures, {} straggled rounds)",
        f.total_time,
        (f.total_time / clean - 1.0) * 100.0,
        f.failures,
        f.straggled_rounds
    );
    Ok(())
}

fn cmd_worker(args: &Args) -> Result<()> {
    let connect = args
        .get("connect")
        .ok_or_else(|| anyhow!("--connect HOST:PORT is required"))?;
    dfep::cluster::runtime::worker_main(connect)
}

fn cmd_cluster(args: &Args) -> Result<()> {
    use dfep::cluster::runtime::{
        run_cluster, ClusterConfig, FailMode, FailureInjection,
    };
    if args.flag("simulate") {
        return cmd_cluster_simulate(args);
    }
    let d = ClusterConfig::default();
    let quick = args.flag("quick");
    let dataset = match args.get("graph") {
        Some(s) => s.to_string(),
        None if quick => d.dataset.clone(),
        None => return Err(anyhow!("--graph is required (or --quick)")),
    };
    let fail = if args.get("fail-rank").is_some() {
        Some(FailureInjection {
            rank: args.get_usize("fail-rank", 0)?,
            round: args.get_u64("fail-round", 2)?,
            mode: match args.get_u64("fail-stall-ms", 0)? {
                0 => FailMode::Kill,
                ms => FailMode::Stall(ms),
            },
        })
    } else {
        None
    };
    let cfg = ClusterConfig {
        workers: args.get_usize("workers", d.workers)?,
        k: args.get_usize("k", d.k)?,
        seed: args.get_u64("seed", d.seed)?,
        spec: args.get_or("algo", "dfep").to_string(),
        dataset,
        graph_seed: args.get_u64("graph-seed", 42)?,
        checkpoint_every: args.get_u64(
            "checkpoint-every",
            if quick { 4 } else { d.checkpoint_every },
        )?,
        checkpoint_dir: args.get("checkpoint-dir").map(std::path::PathBuf::from),
        sssp_source: if args.get("sssp-source").is_some() {
            Some(args.get_usize("sssp-source", 0)? as u32)
        } else if quick {
            Some(0)
        } else {
            None
        },
        fail,
        fault: fault_arg(args)?,
        resume: args.flag("resume"),
        worker_timeout_ms: args.get_u64("timeout-ms", d.worker_timeout_ms)?,
        in_process: args.flag("in-process"),
        max_recoveries: args.get_usize("max-recoveries", d.max_recoveries)?,
    };
    if let Some(plan) = &cfg.fault {
        println!("fault plane: {plan}");
    }
    let (rep, secs) = dfep::util::timer::time(|| run_cluster(&cfg));
    let rep = rep?;
    println!(
        "cluster: {} worker(s), |V|={} |E|={} k={} ({})",
        rep.workers, rep.shape.n, rep.shape.m, rep.partition.k, cfg.dataset
    );
    let avg_round = if rep.round_ms.is_empty() {
        0.0
    } else {
        rep.round_ms.iter().sum::<f64>() / rep.round_ms.len() as f64
    };
    println!(
        "  rounds      {} ({:.2} ms/round avg, {:.3}s total)",
        rep.partition.rounds, avg_round, secs
    );
    if rep.recoveries > 0 {
        let t: f64 = rep.recovery_ms.iter().sum();
        println!(
            "  recoveries  {} ({:.1} ms respawn+rollback total)",
            rep.recoveries, t
        );
    }
    if let Some(round) = rep.resumed_round {
        println!(
            "  resumed     from on-disk checkpoint r{round} \
             ({} corrupt round(s) skipped)",
            rep.skipped_checkpoints
        );
    }
    if rep.faults.total() > 0 {
        let f = &rep.faults;
        println!(
            "  faults      {} injected ({} drops, {} delays, {} corruptions, \
             {} short reads, {} torn writes)",
            f.total(),
            f.drops,
            f.delays,
            f.corruptions,
            f.short_reads,
            f.torn_writes
        );
    }
    if let Some(dist) = &rep.sssp_dist {
        let reached = dist.iter().filter(|&&x| x != u32::MAX).count();
        println!("  sssp        {reached} vertices reached");
    }
    println!("  wire bytes       measured    predicted");
    let rows = [
        ("load", rep.measured.load, rep.predicted.load),
        ("control", rep.measured.control, rep.predicted.control),
        ("bids_up", rep.measured.bids_up, rep.predicted.bids_up),
        ("bids_down", rep.measured.bids_down, rep.predicted.bids_down),
        ("checkpoint", rep.measured.checkpoint, rep.predicted.checkpoint),
        ("merge", rep.measured.merge, rep.predicted.merge),
        ("sssp", rep.measured.sssp, rep.predicted.sssp),
    ];
    for (name, m, p) in rows {
        println!("    {name:<12} {m:>10} {p:>12.0}");
    }
    println!(
        "    {:<12} {:>10}   (unmodeled)",
        "recovery", rep.measured.recovery
    );
    println!(
        "    {:<12} {:>10} {:>12.0}",
        "total",
        rep.measured.total(),
        rep.predicted.total()
    );
    if quick || args.flag("verify") {
        let facade = PartitionRequest::new(&cfg.spec)?
            .dataset(&cfg.dataset)
            .k(cfg.k)
            .seed(cfg.seed)
            .graph_seed(cfg.graph_seed)
            .execute()?;
        if facade.partition.owner != rep.partition.owner {
            return Err(anyhow!(
                "cluster owners diverge from the single-process facade"
            ));
        }
        println!(
            "  verify      owners bit-identical to the single-process \
             facade"
        );
    }
    Ok(())
}

fn cmd_cluster_simulate(args: &Args) -> Result<()> {
    let g = graph_arg(args)?;
    let k = args.get_usize("k", 20)?;
    let seed = args.get_u64("seed", 1)?;
    let spec = PartitionerSpec::parse(args.get_or("algo", "dfep"))?;
    let nodes: Vec<usize> = args
        .get_or("nodes", "2,4,8,16")
        .split(',')
        .map(|s| s.parse().map_err(|_| anyhow!("bad node count '{s}'")))
        .collect::<Result<_>>()?;
    if nodes.is_empty() {
        return Err(anyhow!("--nodes needs at least one node count"));
    }
    let cost = CostModel::default();
    println!("graph: |V|={} |E|={}", g.vertex_count(), g.edge_count());
    println!("-- DFEP partitioning job (Fig 8) --");
    let base_run = run_cluster_dfep(&g, k, nodes[0], seed, &cost, 2000);
    let t0 = base_run.total_time;
    for &n in &nodes {
        let t = resimulate(&base_run, n, &cost);
        println!(
            "  nodes={n:<3} time={t:>8.1}s  speedup vs {} nodes: {:.2}x",
            nodes[0],
            t0 / t
        );
    }
    println!("-- SSSP: ETSCH vs vertex-centric baseline (Fig 9) --");
    let partitioner = spec.build();
    for &n in &nodes {
        let p = partitioner.partition_graph(&g, n, seed)?;
        let e = run_etsch_sssp(&g, &p, 0, n, &cost);
        let b = run_baseline_sssp(&g, 0, n, &cost);
        println!(
            "  nodes={n:<3} etsch={:>8.1}s ({} rounds)   baseline={:>8.1}s ({} supersteps)",
            e.total_time, e.rounds, b.total_time, b.rounds
        );
    }
    Ok(())
}

fn cmd_stats(args: &Args) -> Result<()> {
    let g = graph_arg(args)?;
    let s = stats::graph_stats(&g, args.get_u64("seed", 1)?);
    println!("V           {}", s.vertices);
    println!("E           {}", s.edges);
    println!("D (est)     {}", s.diameter);
    println!("CC          {:.4e}", s.clustering);
    println!("RCC         {:.4e}", s.random_cc);
    println!("avg degree  {:.2}", s.avg_degree);
    println!("max degree  {}", s.max_degree);
    println!("components  {}", s.components);
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    use dfep::coordinator::serve::{ServeConfig, Server};
    let d = ServeConfig::default();
    let cfg = ServeConfig {
        addr: args.get_or("addr", &d.addr).to_string(),
        workers: args.get_usize("workers", d.workers)?.max(1),
        max_body_bytes: args.get_usize("max-body", d.max_body_bytes)?,
        max_queue: args.get_usize("max-queue", d.max_queue)?,
        max_compute: args.get_usize("max-compute", d.max_compute)?,
        request_timeout_s: args.get_f64("timeout", d.request_timeout_s)?,
        cache_capacity: args.get_usize("cache", d.cache_capacity)?,
        graph_capacity: args.get_usize("graphs", d.graph_capacity)?,
        fault: fault_arg(args)?,
    };
    if let Some(plan) = &cfg.fault {
        println!("fault plane: {plan}");
    }
    let server = Server::bind(cfg)?;
    println!("repro serve listening on http://{}", server.addr());
    println!(
        "  POST /partition  POST /batch  GET /healthz  GET /stats  \
         (ctrl-c stops)"
    );
    server.serve();
    Ok(())
}

fn artifacts_dir(args: &Args) -> String {
    args.get("artifacts")
        .map(str::to_string)
        .or_else(|| std::env::var("DFEP_ARTIFACTS").ok())
        .unwrap_or_else(|| "artifacts".to_string())
}

fn cmd_xla_info(args: &Args) -> Result<()> {
    let rt = Runtime::open(std::path::Path::new(&artifacts_dir(args)))?;
    println!("platform: {}", rt.platform());
    for (name, spec) in &rt.manifest().artifacts {
        let ins: Vec<String> = spec
            .inputs
            .iter()
            .map(|t| format!("{:?}{:?}", t.dtype, t.shape))
            .collect();
        println!("  {name}: {} -> {} outputs", ins.join(", "), spec.outputs.len());
    }
    Ok(())
}

fn cmd_xla_partition(args: &Args) -> Result<()> {
    let g = graph_arg(args)?;
    let k = args.get_usize("k", 8)?;
    let seed = args.get_u64("seed", 1)?;
    let rt = Runtime::open(std::path::Path::new(&artifacts_dir(args)))?;
    let engine = dfep::runtime::xla_engine::XlaDfep::default();
    let (p, secs) =
        dfep::util::timer::time(|| engine.partition(&rt, &g, k, seed));
    let p = p?;
    let r = dfep::partition::metrics::evaluate(&g, &p);
    println!("XLA DFEP on {} ({} edges): {:.3}s", rt.platform(), g.edge_count(), secs);
    println!("  rounds={} nstdev={:.4} messages={}", r.rounds, r.nstdev, r.messages);
    Ok(())
}
