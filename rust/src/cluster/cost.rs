//! Cost model of the simulated Hadoop cluster (the EC2 substitute).
//!
//! The paper's §V-D runs Hadoop 1.2.1 on EC2 *m1.medium* instances (1
//! vCPU, ~4 GB, moderate network). We cannot measure 16 machines inside
//! this environment, so Figs 8-9 are regenerated on a per-round cost
//! model whose constants are calibrated to that era:
//!
//!   round_time(nodes) = overhead
//!                     + map_records   / (map_rate    * nodes)
//!                     + shuffle_bytes / (shuffle_bw  * nodes) * sort_f
//!                     + reduce_records/ (reduce_rate * nodes)
//!
//! The *relative* shapes the paper reports (speedup curve, ETSCH vs
//! baseline crossover behavior) depend on the computation/communication/
//! overhead ratio, which this preserves; absolute seconds are indicative
//! only. All real algorithmic quantities (records, messages, rounds) come
//! from actually running DFEP/ETSCH — only the clock is modeled.

/// Per-node, per-phase rates (see module docs).
#[derive(Clone, Debug)]
pub struct CostModel {
    /// Fixed per-MapReduce-round cost: JVM spawn, scheduling, HDFS
    /// round-trip (Hadoop 1.x jobs pay this every iteration).
    pub round_overhead_s: f64,
    /// Map-side record processing rate per node (records/s).
    pub map_rate: f64,
    /// Reduce-side record processing rate per node (records/s).
    pub reduce_rate: f64,
    /// Shuffle bandwidth per node (bytes/s).
    pub shuffle_bw: f64,
    /// Sort/merge multiplier on shuffle volume.
    pub sort_factor: f64,
    /// Straggler inflation: the slowest of `n` tasks runs this much
    /// slower than average per doubling of n (Hadoop-era tail behavior).
    pub straggler_per_doubling: f64,
    /// In-memory graph traversal rate per node (edge ops/s) for work done
    /// inside a single task without touching the record machinery.
    pub in_memory_rate: f64,
}

impl Default for CostModel {
    /// Hadoop 1.2.1 on m1.medium calibration. Hadoop 1.x pays heavy
    /// per-record overhead (java serialization, spill/merge, HDFS
    /// round-trips): effective map throughput was single-digit
    /// thousands of records/s per m1.medium core, job startup 10-15 s.
    /// These constants put the computation/overhead ratio where the
    /// paper's Fig 8 speedup curve (>5x from 2 to 16 nodes on the Table
    /// III datasets) lives.
    fn default() -> Self {
        CostModel {
            round_overhead_s: 12.0,
            map_rate: 8_000.0,
            reduce_rate: 6_000.0,
            shuffle_bw: 10e6,
            sort_factor: 1.3,
            straggler_per_doubling: 0.06,
            in_memory_rate: 1.0e6,
        }
    }
}

/// Work volume of one MapReduce round.
#[derive(Clone, Copy, Debug, Default)]
pub struct RoundWork {
    /// Records processed by the Map phase.
    pub map_records: f64,
    /// Bytes moved through the shuffle.
    pub shuffle_bytes: f64,
    /// Records processed by the Reduce phase.
    pub reduce_records: f64,
    /// Raw in-memory edge operations executed *inside* a task (e.g.
    /// ETSCH's local Dijkstra) — these bypass the MapReduce record
    /// machinery and run at memory speed, not at `map_rate`.
    pub cpu_edge_ops: f64,
}

impl CostModel {
    /// Simulated wall-clock of one round on `nodes` workers.
    pub fn round_time(&self, nodes: usize, w: RoundWork) -> f64 {
        assert!(nodes >= 1);
        let n = nodes as f64;
        let parallel = w.map_records / (self.map_rate * n)
            + w.shuffle_bytes * self.sort_factor / (self.shuffle_bw * n)
            + w.reduce_records / (self.reduce_rate * n)
            + w.cpu_edge_ops / (self.in_memory_rate * n);
        let straggle =
            1.0 + self.straggler_per_doubling * (n.log2().max(0.0));
        self.round_overhead_s + parallel * straggle
    }

    /// Sum over a job's rounds.
    pub fn job_time(&self, nodes: usize, rounds: &[RoundWork]) -> f64 {
        rounds.iter().map(|&w| self.round_time(nodes, w)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn more_nodes_is_faster_until_overhead() {
        let m = CostModel::default();
        let w = RoundWork {
            map_records: 3e6,
            shuffle_bytes: 50e6,
            reduce_records: 3e6,
            cpu_edge_ops: 0.0,
        };
        let t2 = m.round_time(2, w);
        let t8 = m.round_time(8, w);
        let t16 = m.round_time(16, w);
        assert!(t2 > t8 && t8 > t16, "{t2} {t8} {t16}");
        // overhead floors the curve
        assert!(t16 > m.round_overhead_s);
    }

    #[test]
    fn speedup_shape_matches_fig8_band() {
        // DBLP-scale work volume must yield >4x speedup from 2 to 16 nodes
        let m = CostModel::default();
        let w = RoundWork {
            map_records: 3.2e5,            // |V| records
            shuffle_bytes: 2.1e6 * 16.0,   // funding messages
            reduce_records: 1.4e6,         // |V| + messages,
            cpu_edge_ops: 0.0,
        };
        let rounds = vec![w; 15];
        let speedup = m.job_time(2, &rounds) / m.job_time(16, &rounds);
        assert!(
            (4.0..8.0).contains(&speedup),
            "speedup {speedup} out of the paper's band"
        );
    }

    #[test]
    fn tiny_jobs_do_not_scale() {
        // overhead-dominated jobs stay flat — the Fig 9 small-dataset story
        let m = CostModel::default();
        let w = RoundWork {
            map_records: 1e4,
            shuffle_bytes: 1e5,
            reduce_records: 1e4,
            cpu_edge_ops: 0.0,
        };
        let r = vec![w; 5];
        let speedup = m.job_time(2, &r) / m.job_time(16, &r);
        assert!(speedup < 1.5, "speedup {speedup}");
    }
}
