//! Cost model of the simulated Hadoop cluster (the EC2 substitute).
//!
//! The paper's §V-D runs Hadoop 1.2.1 on EC2 *m1.medium* instances (1
//! vCPU, ~4 GB, moderate network). We cannot measure 16 machines inside
//! this environment, so Figs 8-9 are regenerated on a per-round cost
//! model whose constants are calibrated to that era:
//!
//!   round_time(nodes) = overhead
//!                     + map_records   / (map_rate    * nodes)
//!                     + shuffle_bytes / (shuffle_bw  * nodes) * sort_f
//!                     + reduce_records/ (reduce_rate * nodes)
//!
//! The *relative* shapes the paper reports (speedup curve, ETSCH vs
//! baseline crossover behavior) depend on the computation/communication/
//! overhead ratio, which this preserves; absolute seconds are indicative
//! only. All real algorithmic quantities (records, messages, rounds) come
//! from actually running DFEP/ETSCH — only the clock is modeled.

/// Per-node, per-phase rates (see module docs).
#[derive(Clone, Debug)]
pub struct CostModel {
    /// Fixed per-MapReduce-round cost: JVM spawn, scheduling, HDFS
    /// round-trip (Hadoop 1.x jobs pay this every iteration).
    pub round_overhead_s: f64,
    /// Map-side record processing rate per node (records/s).
    pub map_rate: f64,
    /// Reduce-side record processing rate per node (records/s).
    pub reduce_rate: f64,
    /// Shuffle bandwidth per node (bytes/s).
    pub shuffle_bw: f64,
    /// Sort/merge multiplier on shuffle volume.
    pub sort_factor: f64,
    /// Straggler inflation: the slowest of `n` tasks runs this much
    /// slower than average per doubling of n (Hadoop-era tail behavior).
    pub straggler_per_doubling: f64,
    /// In-memory graph traversal rate per node (edge ops/s) for work done
    /// inside a single task without touching the record machinery.
    pub in_memory_rate: f64,
}

impl Default for CostModel {
    /// Hadoop 1.2.1 on m1.medium calibration. Hadoop 1.x pays heavy
    /// per-record overhead (java serialization, spill/merge, HDFS
    /// round-trips): effective map throughput was single-digit
    /// thousands of records/s per m1.medium core, job startup 10-15 s.
    /// These constants put the computation/overhead ratio where the
    /// paper's Fig 8 speedup curve (>5x from 2 to 16 nodes on the Table
    /// III datasets) lives.
    fn default() -> Self {
        CostModel {
            round_overhead_s: 12.0,
            map_rate: 8_000.0,
            reduce_rate: 6_000.0,
            shuffle_bw: 10e6,
            sort_factor: 1.3,
            straggler_per_doubling: 0.06,
            in_memory_rate: 1.0e6,
        }
    }
}

/// Work volume of one MapReduce round.
#[derive(Clone, Copy, Debug, Default)]
pub struct RoundWork {
    /// Records processed by the Map phase.
    pub map_records: f64,
    /// Bytes moved through the shuffle.
    pub shuffle_bytes: f64,
    /// Records processed by the Reduce phase.
    pub reduce_records: f64,
    /// Raw in-memory edge operations executed *inside* a task (e.g.
    /// ETSCH's local Dijkstra) — these bypass the MapReduce record
    /// machinery and run at memory speed, not at `map_rate`.
    pub cpu_edge_ops: f64,
}

impl CostModel {
    /// Simulated wall-clock of one round on `nodes` workers.
    pub fn round_time(&self, nodes: usize, w: RoundWork) -> f64 {
        assert!(nodes >= 1);
        let n = nodes as f64;
        let parallel = w.map_records / (self.map_rate * n)
            + w.shuffle_bytes * self.sort_factor / (self.shuffle_bw * n)
            + w.reduce_records / (self.reduce_rate * n)
            + w.cpu_edge_ops / (self.in_memory_rate * n);
        let straggle =
            1.0 + self.straggler_per_doubling * (n.log2().max(0.0));
        self.round_overhead_s + parallel * straggle
    }

    /// Sum over a job's rounds.
    pub fn job_time(&self, nodes: usize, rounds: &[RoundWork]) -> f64 {
        rounds.iter().map(|&w| self.round_time(nodes, w)).sum()
    }
}

/// Measured wire traffic of a `cluster::runtime` run, by protocol phase,
/// in bytes as framed on the wire (payload + the 16-byte v2 frame
/// header: magic, length, checksum).
/// The coordinator sits at the center of the star topology, so counting
/// its sends and receives captures every byte the cluster moves.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WireBytes {
    /// `Init` bootstrap messages (dataset shipping).
    pub load: u64,
    /// `Ready`, `StartRound`, `RoundDone` and `Shutdown` round-control
    /// messages.
    pub control: u64,
    /// Worker → coordinator bid lists.
    pub bids_up: u64,
    /// Coordinator → worker stitched global bid broadcasts.
    pub bids_down: u64,
    /// `Snapshot` requests and checkpoint blobs.
    pub checkpoint: u64,
    /// `FetchOwners` / `Owners` result collection.
    pub merge: u64,
    /// ETSCH SSSP phase (`SsspStart`/`SsspStep`/`SsspDelta`).
    pub sssp: u64,
    /// Failure recovery (`Restore`, `Barrier`/`BarrierAck`, respawn
    /// `Init`s). Zero on a clean run; not predicted by [`WireModel`].
    pub recovery: u64,
}

impl WireBytes {
    /// Sum over every phase.
    pub fn total(&self) -> u64 {
        self.load
            + self.control
            + self.bids_up
            + self.bids_down
            + self.checkpoint
            + self.merge
            + self.sssp
            + self.recovery
    }
}

/// Protocol event counts of one cluster run — the workload statistics
/// [`WireModel::predict`] turns into byte predictions. Recorded by the
/// coordinator as the run executes.
#[derive(Clone, Copy, Debug, Default)]
pub struct ClusterShape {
    /// Worker count.
    pub workers: usize,
    /// Graph vertices.
    pub n: usize,
    /// Graph edges.
    pub m: usize,
    /// Partitions.
    pub k: usize,
    /// `StartRound` broadcasts (equals DFEP rounds on a clean run;
    /// includes replayed rounds after a recovery).
    pub rounds: u64,
    /// Total stitched global bids over all rounds (each bid travels up
    /// exactly once and down `workers` times).
    pub total_bids: u64,
    /// Partition-phase checkpoint barriers completed.
    pub checkpoints: u64,
    /// SSSP-phase checkpoints (0 or 1: one at phase entry).
    pub sssp_checkpoints: u64,
    /// SSSP supersteps executed.
    pub sssp_steps: u64,
    /// Total `(vertex, dist)` pairs broadcast down over all supersteps.
    pub sssp_updates: u64,
    /// Total `(vertex, dist)` pairs received up over all supersteps.
    pub sssp_deltas: u64,
}

/// Predicted wire bytes per phase (same phase meanings as [`WireBytes`];
/// `recovery` is intentionally absent — failures are not a modeled cost).
#[derive(Clone, Copy, Debug, Default)]
pub struct WirePrediction {
    /// Predicted [`WireBytes::load`].
    pub load: f64,
    /// Predicted [`WireBytes::control`].
    pub control: f64,
    /// Predicted [`WireBytes::bids_up`].
    pub bids_up: f64,
    /// Predicted [`WireBytes::bids_down`].
    pub bids_down: f64,
    /// Predicted [`WireBytes::checkpoint`] (structural part only — the
    /// sparse ledger section of a blob is state-dependent, see
    /// [`WireModel`]).
    pub checkpoint: f64,
    /// Predicted [`WireBytes::merge`].
    pub merge: f64,
    /// Predicted [`WireBytes::sssp`].
    pub sssp: f64,
}

impl WirePrediction {
    /// Sum over every phase.
    pub fn total(&self) -> f64 {
        self.load
            + self.control
            + self.bids_up
            + self.bids_down
            + self.checkpoint
            + self.merge
            + self.sssp
    }
}

/// Per-message byte constants of the `cluster::proto` schema — the wire
/// cost model validated against measured [`WireBytes`] by
/// `tests/cluster.rs`.
///
/// Constants mirror the documented encoding (DESIGN.md "Distributed
/// runtime"): every message costs `frame_overhead` (16-byte v2 frame
/// header + 2-byte version + 1-byte tag) plus its fixed fields plus its
/// variable-length payload. All phases except `checkpoint` are exact by
/// construction; a checkpoint blob additionally carries the sparse
/// ledger section (holder lists + money cells, `4 + 12` bytes per
/// holding vertex), which depends on run state and is deliberately *not*
/// modeled — the validation test brackets it with an asymmetric
/// tolerance instead (measured ≥ structural prediction, and within the
/// documented factor of it).
#[derive(Clone, Debug)]
pub struct WireModel {
    /// Frame header (magic + length + checksum) + version + tag, paid
    /// by every message.
    pub frame_overhead: f64,
    /// One encoded bid (`u32` edge, `u32` partition, 2 × `f64`).
    pub bid_bytes: f64,
    /// One edge in the `Init` edge list (2 × `u32`).
    pub edge_bytes: f64,
    /// One owner entry (`u32`).
    pub owner_bytes: f64,
    /// One SSSP `(vertex, dist)` pair (2 × `u32`).
    pub update_bytes: f64,
    /// `Init` fixed fields (rank/workers/k/seed/tunables/failure
    /// plan/n/edge count).
    pub init_fixed: f64,
    /// `Ready` fixed fields.
    pub ready_fixed: f64,
    /// `StartRound` fixed fields.
    pub start_round_fixed: f64,
    /// `RoundDone` fixed fields.
    pub round_done_fixed: f64,
    /// `Bids` fixed fields (round + count), either direction.
    pub bids_fixed: f64,
    /// `Snapshot` request fixed fields.
    pub snapshot_req_fixed: f64,
    /// `Snapshot` reply fixed fields (round + blob length).
    pub snapshot_reply_fixed: f64,
    /// Partition-phase blob structural header (version, phase, round,
    /// free edges, rng state, k/n/m, owned-partition count).
    pub snap_fixed: f64,
    /// Per-partition *replicated* blob bytes (`u64` size + `u64` anchor),
    /// carried by every worker's blob.
    pub snap_replicated_bytes: f64,
    /// Per-partition owned-section header (id + holder count + cell
    /// count), carried once per partition cluster-wide.
    pub snap_part_bytes: f64,
    /// SSSP-phase blob fixed bytes (version, phase, source, owner count).
    pub sssp_snap_fixed: f64,
}

impl Default for WireModel {
    fn default() -> Self {
        WireModel {
            frame_overhead: 19.0,
            bid_bytes: 24.0,
            edge_bytes: 8.0,
            owner_bytes: 4.0,
            update_bytes: 8.0,
            init_fixed: 61.0,
            ready_fixed: 4.0,
            start_round_fixed: 9.0,
            round_done_fixed: 24.0,
            bids_fixed: 12.0,
            snapshot_req_fixed: 8.0,
            snapshot_reply_fixed: 12.0,
            snap_fixed: 51.0,
            snap_replicated_bytes: 16.0,
            snap_part_bytes: 12.0,
            sssp_snap_fixed: 11.0,
        }
    }
}

impl WireModel {
    /// Predict per-phase wire bytes for a run of the given shape.
    pub fn predict(&self, s: &ClusterShape) -> WirePrediction {
        let w = s.workers as f64;
        let (n, m, k) = (s.n as f64, s.m as f64, s.k as f64);
        let rounds = s.rounds as f64;
        let bids = s.total_bids as f64;
        let fo = self.frame_overhead;
        let load = w * (fo + self.init_fixed + self.edge_bytes * m);
        let control = w * (fo + self.ready_fixed)
            + rounds
                * w
                * (2.0 * fo
                    + self.start_round_fixed
                    + self.round_done_fixed)
            + w * fo; // one Shutdown per worker
        let bids_up =
            rounds * w * (fo + self.bids_fixed) + self.bid_bytes * bids;
        let bids_down = rounds * w * (fo + self.bids_fixed)
            + self.bid_bytes * bids * w;
        // one checkpoint barrier = W snapshot requests + W blob replies;
        // a blob's structural part: fixed header + the replicated
        // owner/free_deg/sizes/anchor vectors on every worker + one
        // owned-section header per partition (each partition appears in
        // exactly one worker's owned section)
        let per_ckpt = w
            * (2.0 * fo
                + self.snapshot_req_fixed
                + self.snapshot_reply_fixed
                + self.snap_fixed
                + self.owner_bytes * m
                + self.owner_bytes * n
                + self.snap_replicated_bytes * k)
            + k * self.snap_part_bytes;
        let sssp_ckpt = s.sssp_checkpoints as f64
            * w
            * (2.0 * fo
                + self.snapshot_req_fixed
                + self.snapshot_reply_fixed
                + self.sssp_snap_fixed
                + self.owner_bytes * m);
        let checkpoint = s.checkpoints as f64 * per_ckpt + sssp_ckpt;
        let merge = fo + (fo + 4.0 + self.owner_bytes * m);
        let steps = s.sssp_steps as f64;
        let sssp = if steps > 0.0 || s.sssp_updates > 0 {
            w * (fo + 4.0 + 4.0 + self.owner_bytes * m) // SsspStart
                + steps * w * 2.0 * (fo + self.bids_fixed)
                + self.update_bytes * s.sssp_updates as f64 * w
                + self.update_bytes * s.sssp_deltas as f64
        } else {
            0.0
        };
        WirePrediction {
            load,
            control,
            bids_up,
            bids_down,
            checkpoint,
            merge,
            sssp,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_model_hand_computed_shape() {
        // 2 workers, 3 rounds, 10 global bids, no checkpoints/sssp
        let s = ClusterShape {
            workers: 2,
            n: 5,
            m: 6,
            k: 4,
            rounds: 3,
            total_bids: 10,
            ..ClusterShape::default()
        };
        let p = WireModel::default().predict(&s);
        // load: 2 * (19 + 61 + 8*6) = 256
        assert_eq!(p.load, 256.0);
        // control: 2*(19+4) + 3*2*(38 + 9 + 24) + 2*19 = 46 + 426 + 38
        assert_eq!(p.control, 510.0);
        // bids_up: 3*2*(19+12) + 24*10 = 186 + 240 = 426
        assert_eq!(p.bids_up, 426.0);
        // bids_down: 186 + 240*2 = 666
        assert_eq!(p.bids_down, 666.0);
        assert_eq!(p.checkpoint, 0.0);
        // merge: 19 + (19 + 4 + 4*6) = 66
        assert_eq!(p.merge, 66.0);
        assert_eq!(p.sssp, 0.0);
        assert!((p.total() - (256.0 + 510.0 + 426.0 + 666.0 + 66.0)).abs()
            < 1e-9);
        // one checkpoint barrier on the same shape:
        // 2*(38 + 8 + 12 + 51 + 4*6 + 4*5 + 16*4) + 4*12 = 2*217 + 48
        let s2 = ClusterShape { checkpoints: 1, ..s };
        let p2 = WireModel::default().predict(&s2);
        assert_eq!(p2.checkpoint, 482.0);
    }

    #[test]
    fn wire_bytes_total_sums_phases() {
        let b = WireBytes {
            load: 1,
            control: 2,
            bids_up: 3,
            bids_down: 4,
            checkpoint: 5,
            merge: 6,
            sssp: 7,
            recovery: 8,
        };
        assert_eq!(b.total(), 36);
    }

    #[test]
    fn more_nodes_is_faster_until_overhead() {
        let m = CostModel::default();
        let w = RoundWork {
            map_records: 3e6,
            shuffle_bytes: 50e6,
            reduce_records: 3e6,
            cpu_edge_ops: 0.0,
        };
        let t2 = m.round_time(2, w);
        let t8 = m.round_time(8, w);
        let t16 = m.round_time(16, w);
        assert!(t2 > t8 && t8 > t16, "{t2} {t8} {t16}");
        // overhead floors the curve
        assert!(t16 > m.round_overhead_s);
    }

    #[test]
    fn speedup_shape_matches_fig8_band() {
        // DBLP-scale work volume must yield >4x speedup from 2 to 16 nodes
        let m = CostModel::default();
        let w = RoundWork {
            map_records: 3.2e5,            // |V| records
            shuffle_bytes: 2.1e6 * 16.0,   // funding messages
            reduce_records: 1.4e6,         // |V| + messages,
            cpu_edge_ops: 0.0,
        };
        let rounds = vec![w; 15];
        let speedup = m.job_time(2, &rounds) / m.job_time(16, &rounds);
        assert!(
            (4.0..8.0).contains(&speedup),
            "speedup {speedup} out of the paper's band"
        );
    }

    #[test]
    fn tiny_jobs_do_not_scale() {
        // overhead-dominated jobs stay flat — the Fig 9 small-dataset story
        let m = CostModel::default();
        let w = RoundWork {
            map_records: 1e4,
            shuffle_bytes: 1e5,
            reduce_records: 1e4,
            cpu_edge_ops: 0.0,
        };
        let r = vec![w; 5];
        let speedup = m.job_time(2, &r) / m.job_time(16, &r);
        assert!(speedup < 1.5, "speedup {speedup}");
    }
}
