//! Cluster execution: the real distributed runtime plus the simulated
//! Hadoop/EC2 model (the Section V-D substitute).
//!
//! [`runtime`] runs actual multi-worker partitioning over localhost
//! sockets (`repro cluster` / `repro worker`), with checkpoints,
//! failure injection, and measured wire bytes validated against the
//! [`cost`] model. The remaining modules simulate a MapReduce cluster
//! analytically for the paper's Figures 8–9 (`repro cluster
//! --simulate`).

pub mod cost;
pub mod dfep_mr;
pub mod etsch_mr;
pub mod failures;
pub mod mapreduce;
pub(crate) mod proto;
pub mod runtime;
