//! Simulated Hadoop/EC2 cluster — the Section V-D substitute.

pub mod cost;
pub mod dfep_mr;
pub mod etsch_mr;
pub mod failures;
pub mod mapreduce;
