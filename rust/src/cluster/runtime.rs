//! Real multi-worker distributed partitioning over localhost sockets.
//!
//! This is the subsystem the rest of `cluster/` simulates: a `repro
//! cluster` coordinator drives `W` workers (child processes of the same
//! binary, or in-process threads for tests — both speak real TCP through
//! the same [`util::frame`](crate::util::frame) codec) through the DFEP
//! funding-round loop, then optionally through an ETSCH SSSP phase on
//! the finalized partition.
//!
//! # Decomposition and determinism
//!
//! Partition `i` is *owned* by worker `i % W`. Every worker holds the
//! full graph plus a full [`DfepState`] replica: the replicated fields
//! (`owner`, `sizes`, `free_edges`, `free_deg`, `anchor`, the rng) are
//! advanced identically everywhere by redundantly applying the same
//! deterministic auction, while each ledger row is authoritative on
//! exactly one worker (the masked phases of
//! [`partition::dfep`](crate::partition::dfep)). One round:
//!
//! 1. coordinator broadcasts `StartRound` (with the pending stall
//!    reseed flag);
//! 2. each worker runs step 1 on its owned partitions and sends its
//!    bids up (canonical partition-major order);
//! 3. the coordinator stitches the global bid list — partition `i`'s
//!    contiguous run taken from worker `i % W` — and broadcasts it;
//! 4. each worker runs the auction + coordinator step on the stitched
//!    list and replies `RoundDone` with `free_edges` and an FNV-1a hash
//!    of its ownership vector (replica-divergence tripwire).
//!
//! The stitched list reproduces the single-process bid order bit-for-bit
//! (bids travel as raw IEEE-754 bits), so the final owners are
//! bit-identical to the [`PartitionRequest`](crate::coordinator::runs)
//! facade at any worker count.
//!
//! # Checkpoints and recovery
//!
//! The coordinator snapshots every worker's state at round 0, every
//! [`ClusterConfig::checkpoint_every`] rounds, and once at SSSP entry.
//! A blob is replayable state: round counter, rng stream position,
//! replicated vectors, and the owned sparse ledger (holder lists +
//! cells). Blobs are held in coordinator memory (and optionally
//! persisted as checksummed
//! [checked blobs](crate::graph::io::write_blob_checked) plus a
//! `ckpt_r<N>_meta.bin` metadata file, written last); a checkpoint
//! replaces the previous one only after every blob has arrived, so a
//! failure mid-checkpoint cannot corrupt the floor. Persistence is
//! best-effort — a failed disk write is logged and the run continues
//! on the in-memory floor. With [`ClusterConfig::resume`], boot scans
//! the checkpoint directory and rolls back to the newest round whose
//! metadata *and* every rank blob verify, skipping torn or bit-rotted
//! rounds instead of failing on them.
//!
//! On a worker failure — dropped connection, read timeout (a stall),
//! or a corrupt frame (checksum/magic mismatch) — the coordinator
//! respawns the rank, re-runs `Init` with the failure plan disabled,
//! restores *all* workers from the last checkpoint (global rollback),
//! and flushes stale in-flight frames with a `Barrier` token
//! round-trip. A further failure mid-recovery restarts recovery
//! against the same [`ClusterConfig::max_recoveries`] budget.
//! Deterministic replay from the checkpoint then reproduces the exact
//! same run, so a recovered run's owners are bit-identical to an
//! undisturbed one.
//!
//! # Fault plane
//!
//! [`ClusterConfig::fault`] arms a seeded
//! [`FaultPlan`](crate::util::fault::FaultPlan) at the coordinator's
//! two I/O chokepoints: every connection's frame reads/writes (tagged
//! `rank | incarnation << 32`, so a respawned rank draws a fresh but
//! still seed-determined fault stream) and the checkpoint disk sink.
//! Arms attach only *after* the round-0 checkpoint lands, so every
//! injected failure has a rollback floor; the same seed replays the
//! same fault sequence, and the final owners remain bit-identical to a
//! fault-free run (or the run ends in a typed error — never a wrong
//! answer).
//!
//! # Measured wire bytes
//!
//! The coordinator sits at the center of the star topology, so counting
//! its sends and receives captures every byte the cluster moves. Each
//! message is classified into a [`WireBytes`] phase and compared against
//! the [`WireModel`] prediction in the final [`ClusterReport`]. On a
//! clean run every phase except `checkpoint` is exact by construction
//! (the blob's sparse ledger section is state-dependent and deliberately
//! unmodeled); `recovery` bytes are measured but never predicted.

use std::io::{BufReader, BufWriter};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::cluster::cost::{
    ClusterShape, WireBytes, WireModel, WirePrediction,
};
use crate::cluster::proto::{CoordMsg, Dec, Enc, InitMsg, WorkerMsg};
use crate::coordinator::runs::resolve_graph;
use crate::graph::io::{read_blob_checked, write_blob_checked_with};
use crate::graph::{Graph, GraphBuilder};
use crate::partition::dfep::{self, Bid, Dfep, DfepState};
use crate::partition::registry::Resolved;
use crate::partition::spec::PartitionerSpec;
use crate::partition::{check_k, EdgePartition};
use crate::util::error::{Error, ErrorKind, Result};
use crate::util::fault::{
    FaultArm, FaultCounters, FaultPlan, FaultSnapshot, RetryPolicy,
};
use crate::util::frame;
use crate::util::rng::Rng;
use crate::{anyhow, bail};

/// Checkpoint blob schema version (independent of the message schema).
const SNAP_VERSION: u16 = 1;
/// Blob phase tag: mid-partitioning state.
const SNAP_PHASE_PARTITION: u8 = 0;
/// Blob phase tag: SSSP phase entered (partition finalized).
const SNAP_PHASE_SSSP: u8 = 1;
/// Fault-arm tag for the checkpoint disk sink (connection arms are
/// tagged `rank | incarnation << 32`, which never collides with this).
const DISK_ARM_TAG: u64 = u64::MAX;
/// Stale-frame drain cap per worker during a barrier (protocol-bug
/// tripwire, not a real limit — one failure strands at most a few
/// frames per worker).
const DRAIN_LIMIT: usize = 10_000;

fn terr(msg: String) -> Error {
    Error::msg(msg).with_kind(ErrorKind::Transport)
}

fn invalid(msg: String) -> Error {
    Error::msg(msg).with_kind(ErrorKind::InvalidRequest)
}

/// FNV-1a over the little-endian bytes of an ownership vector — the
/// per-round replica-divergence tripwire carried by `RoundDone`.
pub(crate) fn fnv1a64(owner: &[u32]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &x in owner {
        for b in x.to_le_bytes() {
            h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// How an injected failure manifests.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FailMode {
    /// Drop the connection at the start of the round (process death —
    /// the coordinator sees EOF).
    Kill,
    /// Go silent for this many milliseconds, then die (hung worker —
    /// the coordinator's read timeout is the failure detector, the real
    /// analogue of `failures::FaultModel::detection_latency_s`).
    Stall(u64),
}

/// One scripted worker failure, injected inside the worker's
/// `StartRound` handler — mid-round, after the round has begun on the
/// other workers.
#[derive(Clone, Copy, Debug)]
pub struct FailureInjection {
    /// Which worker dies.
    pub rank: usize,
    /// The round at whose start it dies.
    pub round: u64,
    /// How it dies.
    pub mode: FailMode,
}

/// Configuration of one distributed run.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// Worker count (`>= 1`; `1` degenerates to a remote single
    /// process).
    pub workers: usize,
    /// Partition count.
    pub k: usize,
    /// Partitioning seed (same meaning as the facade's `seed`).
    pub seed: u64,
    /// Partitioner spec string — must resolve to the `dfep` algorithm
    /// (overrides like `dfep:cap=5` are honored).
    pub spec: String,
    /// Graph source, any [`resolve_graph`] spec (named dataset or
    /// generator).
    pub dataset: String,
    /// Seed for graph generation / scaling.
    pub graph_seed: u64,
    /// Snapshot every N completed rounds (`0` = only the mandatory
    /// round-0 and SSSP-entry checkpoints).
    pub checkpoint_every: u64,
    /// Also persist each checkpoint's blobs to this directory
    /// (`ckpt_r<round>_w<rank>.bin`, written atomically).
    pub checkpoint_dir: Option<PathBuf>,
    /// Run the distributed ETSCH SSSP phase from this source vertex
    /// after partitioning.
    pub sssp_source: Option<u32>,
    /// Scripted failure, if any.
    pub fail: Option<FailureInjection>,
    /// Seeded fault plan injected coordinator-side at the frame and
    /// checkpoint-disk chokepoints (`None` = zero-overhead clean run).
    /// Arms attach only after the round-0 checkpoint, so every injected
    /// failure has a rollback floor.
    pub fault: Option<FaultPlan>,
    /// Scan [`checkpoint_dir`](Self::checkpoint_dir) at boot and resume
    /// from the newest intact persisted checkpoint, skipping corrupt or
    /// torn rounds.
    pub resume: bool,
    /// Coordinator read timeout per worker reply — the stall detector;
    /// every other deadline derives from it (must be `>= 1`).
    pub worker_timeout_ms: u64,
    /// Run workers as in-process threads over real loopback sockets
    /// instead of spawned child processes (required inside test
    /// binaries, where respawning `current_exe` would re-run the test
    /// harness).
    pub in_process: bool,
    /// Abort after this many recoveries (guards against a failure the
    /// rollback cannot clear).
    pub max_recoveries: usize,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            workers: 3,
            k: 8,
            seed: 1,
            spec: "dfep".into(),
            dataset: "plc:n=600,m=4,p=0.3".into(),
            graph_seed: 1,
            checkpoint_every: 8,
            checkpoint_dir: None,
            sssp_source: None,
            fail: None,
            fault: None,
            resume: false,
            worker_timeout_ms: 10_000,
            in_process: false,
            max_recoveries: 2,
        }
    }
}

impl ClusterConfig {
    /// The stall detector as a [`Duration`]: the coordinator's read
    /// timeout per worker reply.
    pub fn worker_timeout(&self) -> Duration {
        Duration::from_millis(self.worker_timeout_ms.max(1))
    }

    /// How long a (re)spawned worker gets to dial back: 3x the stall
    /// detector, floored at one second (spawning a process is slower
    /// than answering a frame).
    pub fn boot_timeout(&self) -> Duration {
        (self.worker_timeout() * 3).max(Duration::from_secs(1))
    }

    /// Grace period for children to exit after `Shutdown` before they
    /// are killed: half the stall detector, floored at 100ms.
    pub fn shutdown_grace(&self) -> Duration {
        (self.worker_timeout() / 2).max(Duration::from_millis(100))
    }

    /// Validate everything checkable without resolving the graph:
    /// partition count, worker count, the timeout every deadline
    /// derives from, the failure script, and the partitioner spec.
    pub fn validate(&self) -> Result<()> {
        check_k(self.k)?;
        if self.workers == 0 {
            return Err(invalid("cluster needs at least one worker".into()));
        }
        if self.worker_timeout_ms == 0 {
            return Err(invalid(
                "worker_timeout_ms must be >= 1: it is the failure \
                 detector, and the boot and shutdown deadlines derive \
                 from it"
                    .into(),
            ));
        }
        if let Some(f) = &self.fail {
            if f.rank >= self.workers {
                return Err(invalid(format!(
                    "failure rank {} out of range (workers {})",
                    f.rank, self.workers
                )));
            }
        }
        let spec = PartitionerSpec::parse(&self.spec)?;
        if spec.name() != "dfep" {
            return Err(Error::msg(format!(
                "the cluster runtime drives the dfep algorithm only \
                 (got '{}')",
                spec.name()
            ))
            .with_kind(ErrorKind::InvalidSpec));
        }
        Ok(())
    }
}

/// Everything a finished distributed run reports.
pub struct ClusterReport {
    /// The finalized partition — bit-identical to the single-process
    /// facade for the same `(dataset, spec, k, seed)`.
    pub partition: EdgePartition,
    /// Worker count the run used.
    pub workers: usize,
    /// Failures recovered from (0 on a clean run).
    pub recoveries: usize,
    /// Measured wire traffic by protocol phase.
    pub measured: WireBytes,
    /// [`WireModel`] prediction for the run's [`ClusterShape`].
    pub predicted: WirePrediction,
    /// Protocol event counts the prediction was computed from.
    pub shape: ClusterShape,
    /// SSSP distances, when [`ClusterConfig::sssp_source`] was set —
    /// equal to single-process `Etsch` on the same partition.
    pub sssp_dist: Option<Vec<u32>>,
    /// Wall-clock per completed round, milliseconds.
    pub round_ms: Vec<f64>,
    /// Wall-clock per recovery (respawn + rollback + drain),
    /// milliseconds.
    pub recovery_ms: Vec<f64>,
    /// Injected faults that actually fired, by kind (all zero when
    /// [`ClusterConfig::fault`] is `None`).
    pub faults: FaultSnapshot,
    /// Round the run rolled back to when [`ClusterConfig::resume`]
    /// found an intact persisted checkpoint.
    pub resumed_round: Option<u64>,
    /// Persisted checkpoint rounds the resume scan rejected as corrupt,
    /// torn, or shape-incompatible.
    pub skipped_checkpoints: usize,
}

// ---------------------------------------------------------------------
// worker side
// ---------------------------------------------------------------------

/// Entry point of `repro worker --connect HOST:PORT`: dial the
/// coordinator — with bounded, deterministically-jittered retries,
/// since a respawned worker can race the coordinator's accept loop —
/// then serve its messages until `Shutdown` or EOF.
pub fn worker_main(connect: &str) -> Result<()> {
    let policy = RetryPolicy::default();
    let mut rng = Rng::new(
        frame::fnv1a64(connect.as_bytes()) ^ std::process::id() as u64,
    );
    let mut last = String::from("no attempt made");
    for attempt in 0..policy.attempts {
        match TcpStream::connect(connect) {
            Ok(stream) => return serve_worker(stream),
            Err(e) => last = e.to_string(),
        }
        if attempt + 1 < policy.attempts {
            std::thread::sleep(policy.delay(attempt, &mut rng));
        }
    }
    Err(terr(format!(
        "connect to coordinator {connect}: {last} (gave up after {} \
         attempts)",
        policy.attempts
    )))
}

/// SSSP-phase replica: the finalized owner vector plus this worker's
/// view of the distance array (edges with `owner % W == rank` are
/// relaxed here).
struct SsspReplica {
    source: u32,
    owner: Vec<u32>,
    dist: Vec<u32>,
}

/// What a handled message asks the serve loop to do.
enum Action {
    Reply(WorkerMsg),
    Silent,
    Die { stall_ms: u64 },
}

struct WorkerState {
    rank: usize,
    workers: usize,
    k: usize,
    cap: f64,
    g: Graph,
    st: DfepState,
    rng: Rng,
    owned: Vec<bool>,
    fail_round: i64,
    fail_stall_ms: u64,
    sssp: Option<SsspReplica>,
}

/// Serve one coordinator connection. EOF is a clean exit (the
/// coordinator is gone); anything else unexpected is an error that
/// drops the connection, which the coordinator treats as a failure.
fn serve_worker(stream: TcpStream) -> Result<()> {
    stream
        .set_nodelay(true)
        .map_err(|e| terr(format!("set_nodelay: {e}")))?;
    let mut reader = BufReader::new(
        stream.try_clone().map_err(|e| terr(format!("clone stream: {e}")))?,
    );
    let mut writer = BufWriter::new(stream);
    let mut wk: Option<WorkerState> = None;
    loop {
        let payload = match frame::read_frame(&mut reader) {
            Ok(p) => p,
            Err(e) if e.is_eof() => return Ok(()),
            Err(e) => return Err(terr(format!("read from coordinator: {e}"))),
        };
        match CoordMsg::decode(&payload)? {
            CoordMsg::Init(init) => {
                let ready = WorkerMsg::Ready { rank: init.rank };
                wk = Some(WorkerState::boot(init)?);
                send_to_coord(&mut writer, &ready)?;
            }
            CoordMsg::Shutdown => return Ok(()),
            other => {
                let Some(w) = wk.as_mut() else {
                    return Err(terr("message before Init".into()));
                };
                match w.handle(other)? {
                    Action::Reply(msg) => send_to_coord(&mut writer, &msg)?,
                    Action::Silent => {}
                    Action::Die { stall_ms } => {
                        if stall_ms > 0 {
                            std::thread::sleep(Duration::from_millis(
                                stall_ms,
                            ));
                        }
                        return Ok(()); // drop the connection mid-round
                    }
                }
            }
        }
    }
}

fn send_to_coord(
    w: &mut BufWriter<TcpStream>,
    msg: &WorkerMsg,
) -> Result<()> {
    frame::write_frame(w, &msg.encode())
        .map_err(|e| terr(format!("reply to coordinator: {e}")))
}

impl WorkerState {
    /// Rebuild the graph from the shipped canonical edge list and
    /// initialize a state replica exactly as the single-process
    /// `run_inner` does (same rng stream, same initial funding), so the
    /// replicated fields start bit-identical on every worker.
    fn boot(init: InitMsg) -> Result<WorkerState> {
        let mut b = GraphBuilder::new();
        if init.n > 0 {
            b.touch_vertex(init.n - 1);
        }
        for &(u, v) in &init.edges {
            b.push_edge(u, v);
        }
        let g = b.build();
        if g.vertex_count() != init.n as usize
            || g.edge_count() != init.edges.len()
        {
            return Err(terr(format!(
                "graph reconstruction mismatch: got |V|={} |E|={}, \
                 want |V|={} |E|={}",
                g.vertex_count(),
                g.edge_count(),
                init.n,
                init.edges.len()
            )));
        }
        let k = init.k as usize;
        let workers = init.workers as usize;
        if workers == 0 || k == 0 || init.rank as usize >= workers {
            return Err(terr(format!(
                "bad init: rank {} of {workers} workers, k={k}",
                init.rank
            )));
        }
        let mut rng = Rng::new(init.seed);
        let initial =
            init.init_frac * g.edge_count() as f64 / k as f64;
        let mut st = DfepState::new(&g, k, initial.max(1.0), &mut rng);
        st.frontier_first = init.frontier_first;
        let rank = init.rank as usize;
        let owned = (0..k).map(|i| i % workers == rank).collect();
        Ok(WorkerState {
            rank,
            workers,
            k,
            cap: init.cap,
            g,
            st,
            rng,
            owned,
            fail_round: init.fail_round,
            fail_stall_ms: init.fail_stall_ms,
            sssp: None,
        })
    }

    fn handle(&mut self, msg: CoordMsg) -> Result<Action> {
        match msg {
            CoordMsg::StartRound { round, reseed } => {
                if self.fail_round >= 0 && round == self.fail_round as u64 {
                    self.fail_round = -1;
                    return Ok(Action::Die {
                        stall_ms: self.fail_stall_ms,
                    });
                }
                if self.sssp.is_some() {
                    return Err(terr("StartRound in SSSP phase".into()));
                }
                if self.st.rounds as u64 != round {
                    return Err(terr(format!(
                        "round desync: replica at {}, coordinator at {round}",
                        self.st.rounds
                    )));
                }
                if reseed {
                    dfep::reseed_on_free_edge_masked(
                        &self.g,
                        &mut self.st,
                        &mut self.rng,
                        Some(&self.owned),
                    );
                }
                self.st.round_bids(&self.g, None, None, Some(&self.owned));
                Ok(Action::Reply(WorkerMsg::Bids {
                    round,
                    bids: self.st.pending_bids().to_vec(),
                }))
            }
            CoordMsg::Bids { round, bids } => {
                if self.st.rounds as u64 != round {
                    return Err(terr(format!(
                        "auction desync: replica at {}, coordinator at \
                         {round}",
                        self.st.rounds
                    )));
                }
                self.st.set_pending_bids(&bids);
                self.st.round_auction(&self.g, None, None, Some(&self.owned));
                self.st.coordinator_step_masked(self.cap, Some(&self.owned));
                Ok(Action::Reply(WorkerMsg::RoundDone {
                    round,
                    free_edges: self.st.free_edges as u64,
                    owner_hash: fnv1a64(&self.st.owner),
                }))
            }
            CoordMsg::Snapshot { round } => Ok(Action::Reply(
                WorkerMsg::Snapshot { round, blob: self.snapshot() },
            )),
            CoordMsg::Restore { blob } => {
                self.restore(&blob)?;
                Ok(Action::Silent)
            }
            CoordMsg::Barrier { token } => {
                Ok(Action::Reply(WorkerMsg::BarrierAck { token }))
            }
            CoordMsg::FetchOwners => Ok(Action::Reply(WorkerMsg::Owners {
                owner: self.st.owner.clone(),
            })),
            CoordMsg::SsspStart { source, owner } => {
                if owner.len() != self.g.edge_count() {
                    return Err(terr("SsspStart: bad owner length".into()));
                }
                self.sssp = Some(SsspReplica {
                    source,
                    owner,
                    dist: vec![u32::MAX; self.g.vertex_count()],
                });
                Ok(Action::Silent)
            }
            CoordMsg::SsspStep { step, updates } => {
                let g = &self.g;
                let (workers, rank) = (self.workers, self.rank);
                let Some(s) = self.sssp.as_mut() else {
                    return Err(terr("SsspStep before SsspStart".into()));
                };
                let n = s.dist.len();
                // apply the globally-improved distances, then relax the
                // edges this worker owns around each improved vertex
                let mut changed: Vec<u32> = Vec::new();
                for &(v, d) in &updates {
                    if (v as usize) >= n {
                        return Err(terr("SsspStep: vertex out of range"
                            .into()));
                    }
                    if d < s.dist[v as usize] {
                        s.dist[v as usize] = d;
                        changed.push(v);
                    }
                }
                let mut out: Vec<(u32, u32)> = Vec::new();
                for &v in &changed {
                    let nd = s.dist[v as usize] + 1;
                    for &e in g.neighbor_edges(v) {
                        if s.owner[e as usize] as usize % workers != rank {
                            continue;
                        }
                        let u = g.other_endpoint(e, v);
                        if nd < s.dist[u as usize] {
                            s.dist[u as usize] = nd;
                            out.push((u, nd));
                        }
                    }
                }
                Ok(Action::Reply(WorkerMsg::SsspDelta {
                    step,
                    updates: out,
                }))
            }
            CoordMsg::Init(_) | CoordMsg::Shutdown => {
                Err(terr("unexpected control message".into()))
            }
        }
    }

    /// Serialize replayable state. Partition phase: round/rng position,
    /// the replicated vectors, and — for owned partitions only — the
    /// holder lists plus one `(vertex, value)` ledger cell per holder
    /// entry (every positive cell's vertex is in its holder list, an
    /// invariant of `credit` and the frontier pool, so this is lossless;
    /// duplicate holder entries re-assign the same value, which is
    /// idempotent). SSSP phase: source + finalized owners (distances are
    /// recomputed from superstep 0 on restore).
    fn snapshot(&self) -> Vec<u8> {
        let mut e = Enc::default();
        e.u16(SNAP_VERSION);
        if let Some(s) = &self.sssp {
            e.u8(SNAP_PHASE_SSSP);
            e.u32(s.source);
            e.vec_u32(&s.owner);
            return e.buf;
        }
        e.u8(SNAP_PHASE_PARTITION);
        e.u64(self.st.rounds as u64);
        e.u64(self.st.free_edges as u64);
        let (rs, ri) = self.rng.state();
        e.u64(rs);
        e.u64(ri);
        e.u32(self.k as u32);
        e.u32(self.g.vertex_count() as u32);
        e.u32(self.g.edge_count() as u32);
        for &s in &self.st.sizes {
            e.u64(s as u64);
        }
        for &a in &self.st.anchor {
            e.u64(a as u64);
        }
        for &o in &self.st.owner {
            e.u32(o);
        }
        for &d in &self.st.free_deg {
            e.u32(d);
        }
        let owned: Vec<usize> =
            (0..self.k).filter(|&i| self.owned[i]).collect();
        e.u32(owned.len() as u32);
        for &i in &owned {
            e.u32(i as u32);
            e.vec_u32(&self.st.holders[i]);
            let row = self.st.money.part(i);
            e.u32(self.st.holders[i].len() as u32);
            for &v in &self.st.holders[i] {
                e.u32(v);
                e.f64(row[v as usize]);
            }
        }
        e.buf
    }

    /// Overwrite state from a checkpoint blob (the exact inverse of
    /// [`snapshot`](Self::snapshot)).
    fn restore(&mut self, blob: &[u8]) -> Result<()> {
        let n = self.g.vertex_count();
        let m = self.g.edge_count();
        let mut d = Dec::new(blob);
        let ver = d.u16()?;
        if ver != SNAP_VERSION {
            return Err(terr(format!("checkpoint version {ver}")));
        }
        match d.u8()? {
            SNAP_PHASE_SSSP => {
                let source = d.u32()?;
                let owner = d.vec_u32()?;
                d.done()?;
                if owner.len() != m {
                    return Err(terr("restore: bad owner length".into()));
                }
                self.sssp = Some(SsspReplica {
                    source,
                    owner,
                    dist: vec![u32::MAX; n],
                });
                Ok(())
            }
            SNAP_PHASE_PARTITION => {
                let rounds = d.u64()? as usize;
                let free_edges = d.u64()? as usize;
                let (rs, ri) = (d.u64()?, d.u64()?);
                let (bk, bn, bm) =
                    (d.u32()? as usize, d.u32()? as usize, d.u32()? as usize);
                if bk != self.k || bn != n || bm != m {
                    return Err(terr(format!(
                        "restore shape mismatch: blob k/n/m = \
                         {bk}/{bn}/{bm}, replica {}/{n}/{m}",
                        self.k
                    )));
                }
                for s in self.st.sizes.iter_mut() {
                    *s = d.u64()? as usize;
                }
                for a in self.st.anchor.iter_mut() {
                    *a = d.u64()? as usize;
                }
                for o in self.st.owner.iter_mut() {
                    *o = d.u32()?;
                }
                for f in self.st.free_deg.iter_mut() {
                    *f = d.u32()?;
                }
                let parts = d.u32()? as usize;
                // the blob's sparse section fully replaces the owned
                // ledger rows: zero them first, cells only cover holders
                for i in 0..self.k {
                    if self.owned[i] {
                        for c in self.st.money.part_mut(i) {
                            *c = 0.0;
                        }
                        self.st.holders[i].clear();
                    }
                }
                for _ in 0..parts {
                    let i = d.u32()? as usize;
                    if i >= self.k || !self.owned[i] {
                        return Err(terr(format!(
                            "restore: partition {i} not owned here"
                        )));
                    }
                    let holders = d.vec_u32()?;
                    let cells = d.u32()? as usize;
                    if cells != holders.len() {
                        return Err(terr(
                            "restore: cell/holder count mismatch".into(),
                        ));
                    }
                    for _ in 0..cells {
                        let v = d.u32()? as usize;
                        let val = d.f64()?;
                        if v >= n {
                            return Err(terr(
                                "restore: holder out of range".into(),
                            ));
                        }
                        *self.st.money.cell_mut(i, v) = val;
                    }
                    self.st.holders[i] = holders;
                }
                d.done()?;
                self.st.rounds = rounds;
                self.st.free_edges = free_edges;
                self.rng = Rng::from_state(rs, ri);
                self.st.rebuild_live();
                self.sssp = None;
                Ok(())
            }
            p => Err(terr(format!("unknown checkpoint phase {p}"))),
        }
    }
}

// ---------------------------------------------------------------------
// coordinator side
// ---------------------------------------------------------------------

/// Which [`WireBytes`] phase a message is accounted under (classified
/// by protocol context, not message type: a respawn `Init` is recovery
/// traffic, the boot `Init`s are load).
#[derive(Clone, Copy)]
enum Phase {
    Load,
    Control,
    BidsUp,
    BidsDown,
    Checkpoint,
    Merge,
    Sssp,
    Recovery,
}

/// Coordinator-internal error split: a worker failure names the rank
/// (recoverable by rollback), everything else is fatal.
enum RunErr {
    Worker { rank: usize, err: Error },
    Fatal(Error),
}

fn fatal<T>(e: Error) -> Result<T, RunErr> {
    Err(RunErr::Fatal(e))
}

/// Collapse a [`RunErr`] where recovery is not applicable (boot,
/// inside recovery itself).
fn plain<T>(r: Result<T, RunErr>) -> Result<T> {
    r.map_err(|e| match e {
        RunErr::Worker { err, .. } => err,
        RunErr::Fatal(err) => err,
    })
}

/// One worker connection (+ the child process handle in spawn mode,
/// + this connection's fault-injection arm when a plan is active).
struct Conn {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    child: Option<Child>,
    arm: Option<FaultArm>,
}

/// Launch a worker: an in-process thread dialing back over loopback,
/// or a `repro worker --connect` child of the current executable.
fn spawn_worker(addr: SocketAddr, in_process: bool) -> Result<Option<Child>> {
    if in_process {
        std::thread::spawn(move || {
            if let Ok(stream) = TcpStream::connect(addr) {
                let _ = serve_worker(stream);
            }
        });
        return Ok(None);
    }
    let exe = std::env::current_exe()
        .map_err(|e| terr(format!("locate worker executable: {e}")))?;
    let child = Command::new(exe)
        .arg("worker")
        .arg("--connect")
        .arg(addr.to_string())
        .stdin(Stdio::null())
        .spawn()
        .map_err(|e| terr(format!("spawn worker process: {e}")))?;
    Ok(Some(child))
}

/// Accept the next worker connection, polling so a worker that never
/// dials (failed spawn) times out instead of hanging the coordinator.
/// Every error names the rank and the protocol phase (`"boot"` /
/// `"recovery"`) so a failed accept is attributable.
fn accept_worker(
    listener: &TcpListener,
    read_timeout: Duration,
    boot_timeout: Duration,
    child: Option<Child>,
    rank: usize,
    phase: &str,
) -> Result<Conn> {
    listener
        .set_nonblocking(true)
        .map_err(|e| terr(format!("listener nonblocking: {e}")))?;
    let deadline = Instant::now() + boot_timeout;
    let stream = loop {
        match listener.accept() {
            Ok((s, _)) => break s,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if Instant::now() > deadline {
                    return Err(terr(format!(
                        "worker {rank} did not connect within the boot \
                         timeout ({phase}, {:.1}s)",
                        boot_timeout.as_secs_f64()
                    )));
                }
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(e) => {
                return Err(terr(format!(
                    "accept worker {rank} ({phase}): {e}"
                )))
            }
        }
    };
    let _ = listener.set_nonblocking(false);
    stream
        .set_nonblocking(false)
        .map_err(|e| terr(format!("worker {rank} stream blocking: {e}")))?;
    stream
        .set_nodelay(true)
        .map_err(|e| terr(format!("worker {rank} set_nodelay: {e}")))?;
    stream
        .set_read_timeout(Some(read_timeout))
        .map_err(|e| terr(format!("worker {rank} set_read_timeout: {e}")))?;
    let reader = BufReader::new(stream.try_clone().map_err(|e| {
        terr(format!("worker {rank} clone stream: {e}"))
    })?);
    Ok(Conn { reader, writer: BufWriter::new(stream), child, arm: None })
}

/// Recovery floor metadata, mirrored coordinator-side alongside the
/// blobs so the round loop can resume its control variables.
#[derive(Clone, Copy)]
enum CkptMeta {
    Partition { round: u64, free_edges: u64, stall: u32, reseed_next: bool },
    Sssp,
}

/// Persisted-checkpoint metadata codec version.
const META_VERSION: u16 = 1;

/// Encode coordinator-side checkpoint metadata for persistence,
/// alongside the run shape a resume must match. SSSP checkpoints
/// return `None`: resume targets the partition phase only (re-running
/// SSSP from the finalized owners is cheaper than a meta schema for
/// it).
fn encode_meta(
    meta: &CkptMeta,
    workers: usize,
    k: usize,
    n: usize,
    m: usize,
) -> Option<Vec<u8>> {
    let CkptMeta::Partition { round, free_edges, stall, reseed_next } = meta
    else {
        return None;
    };
    let mut e = Enc::default();
    e.u16(META_VERSION);
    e.u64(*round);
    e.u64(*free_edges);
    e.u32(*stall);
    e.u8(*reseed_next as u8);
    e.u32(workers as u32);
    e.u32(k as u32);
    e.u32(n as u32);
    e.u32(m as u32);
    Some(e.buf)
}

/// Decoded persisted-checkpoint metadata plus the shape it was taken
/// under.
struct DiskMeta {
    meta: CkptMeta,
    workers: usize,
    k: usize,
    n: usize,
    m: usize,
}

/// Inverse of [`encode_meta`]; truncation, trailing bytes, and version
/// skew are all errors (the resume scan skips the round).
fn decode_meta(buf: &[u8]) -> Result<DiskMeta> {
    let mut d = Dec::new(buf);
    let ver = d.u16()?;
    if ver != META_VERSION {
        bail!("checkpoint meta version {ver} (want {META_VERSION})");
    }
    let round = d.u64()?;
    let free_edges = d.u64()?;
    let stall = d.u32()?;
    let reseed_next = d.u8()? != 0;
    let workers = d.u32()? as usize;
    let k = d.u32()? as usize;
    let n = d.u32()? as usize;
    let m = d.u32()? as usize;
    d.done()?;
    Ok(DiskMeta {
        meta: CkptMeta::Partition { round, free_edges, stall, reseed_next },
        workers,
        k,
        n,
        m,
    })
}

struct Coordinator<'a> {
    cfg: &'a ClusterConfig,
    tune: Dfep,
    g: &'a Graph,
    listener: TcpListener,
    addr: SocketAddr,
    conns: Vec<Conn>,
    /// Per-rank respawn count; fault-arm tags mix it in so a respawned
    /// connection draws a fresh (still seed-determined) fault stream.
    incarnations: Vec<u64>,
    fault_counters: Arc<FaultCounters>,
    /// Fault arm over the checkpoint disk sink.
    disk_arm: Option<FaultArm>,
    bytes: WireBytes,
    shape: ClusterShape,
    ckpt_blobs: Vec<Vec<u8>>,
    ckpt_meta: CkptMeta,
    recoveries: usize,
    barrier_token: u64,
    round_ms: Vec<f64>,
    recovery_ms: Vec<f64>,
    resumed_round: Option<u64>,
    skipped_checkpoints: usize,
}

impl<'a> Coordinator<'a> {
    fn account(&mut self, phase: Phase, bytes: usize) {
        let n = bytes as u64;
        let b = &mut self.bytes;
        match phase {
            Phase::Load => b.load += n,
            Phase::Control => b.control += n,
            Phase::BidsUp => b.bids_up += n,
            Phase::BidsDown => b.bids_down += n,
            Phase::Checkpoint => b.checkpoint += n,
            Phase::Merge => b.merge += n,
            Phase::Sssp => b.sssp += n,
            Phase::Recovery => b.recovery += n,
        }
    }

    /// (Re)arm a connection's deterministic fault stream and bump its
    /// incarnation. No-op (beyond the bump) when no plan is configured.
    fn arm_conn(&mut self, rank: usize) {
        let inc = self.incarnations[rank];
        self.incarnations[rank] += 1;
        if let Some(plan) = &self.cfg.fault {
            let tag = (inc << 32) | rank as u64;
            self.conns[rank].arm =
                Some(plan.arm(tag, Arc::clone(&self.fault_counters)));
        }
    }

    fn send(
        &mut self,
        rank: usize,
        msg: &CoordMsg,
        phase: Phase,
    ) -> Result<(), RunErr> {
        let payload = msg.encode();
        self.account(phase, frame::wire_len(payload.len()));
        let conn = &mut self.conns[rank];
        frame::write_frame_with(&mut conn.writer, &payload, conn.arm.as_mut())
            .map_err(|e| RunErr::Worker {
                rank,
                err: terr(format!("send to worker {rank}: {e}")),
            })
    }

    fn recv(&mut self, rank: usize, phase: Phase) -> Result<WorkerMsg, RunErr> {
        let conn = &mut self.conns[rank];
        let payload =
            frame::read_frame_with(&mut conn.reader, conn.arm.as_mut())
                .map_err(|e| {
                    let what = if e.is_timeout() {
                        "timed out waiting for"
                    } else if e.is_eof() {
                        "lost connection to"
                    } else if e.is_corrupt() {
                        "corrupt frame from"
                    } else {
                        "read error from"
                    };
                    RunErr::Worker {
                        rank,
                        err: terr(format!("{what} worker {rank}: {e}")),
                    }
                })?;
        self.account(phase, frame::wire_len(payload.len()));
        WorkerMsg::decode(&payload)
            .map_err(|err| RunErr::Worker { rank, err })
    }

    fn init_msg(&self, rank: usize, allow_fail: bool) -> InitMsg {
        let (fail_round, fail_stall_ms) = match &self.cfg.fail {
            Some(f) if allow_fail && f.rank == rank => (
                f.round as i64,
                match f.mode {
                    FailMode::Kill => 0,
                    FailMode::Stall(ms) => ms.max(1),
                },
            ),
            _ => (-1, 0),
        };
        InitMsg {
            rank: rank as u32,
            workers: self.cfg.workers as u32,
            k: self.cfg.k as u32,
            seed: self.cfg.seed,
            cap: self.tune.funding_cap,
            init_frac: self.tune.initial_fraction,
            frontier_first: self.tune.frontier_first,
            fail_round,
            fail_stall_ms,
            n: self.g.vertex_count() as u32,
            edges: self.g.edges().to_vec(),
        }
    }

    /// Spawn + init every worker, then take the round-0 checkpoint —
    /// the recovery floor, so even a first-round failure has a rollback
    /// target.
    fn boot(&mut self) -> Result<()> {
        for rank in 0..self.cfg.workers {
            let child = spawn_worker(self.addr, self.cfg.in_process)?;
            let conn = accept_worker(
                &self.listener,
                self.cfg.worker_timeout(),
                self.cfg.boot_timeout(),
                child,
                rank,
                "boot",
            )?;
            self.conns.push(conn);
        }
        for rank in 0..self.cfg.workers {
            let init = CoordMsg::Init(self.init_msg(rank, true));
            plain(self.send(rank, &init, Phase::Load))?;
        }
        for rank in 0..self.cfg.workers {
            match plain(self.recv(rank, Phase::Control))? {
                WorkerMsg::Ready { rank: r } if r as usize == rank => {}
                other => bail!("worker {rank}: expected Ready, got {other:?}"),
            }
        }
        plain(self.checkpoint(CkptMeta::Partition {
            round: 0,
            free_edges: self.g.edge_count() as u64,
            stall: 0,
            reseed_next: false,
        }))?;
        // arm the fault plan only now: everything injected from here on
        // has (at least) the round-0 floor to roll back to
        for rank in 0..self.cfg.workers {
            self.arm_conn(rank);
        }
        Ok(())
    }

    /// One checkpoint barrier: collect a blob from every worker, then
    /// atomically replace the in-memory floor (and optionally persist).
    fn checkpoint(&mut self, meta: CkptMeta) -> Result<(), RunErr> {
        let round = match meta {
            CkptMeta::Partition { round, .. } => round,
            CkptMeta::Sssp => u64::MAX,
        };
        let w = self.conns.len();
        let req = CoordMsg::Snapshot { round };
        for rank in 0..w {
            self.send(rank, &req, Phase::Checkpoint)?;
        }
        let mut blobs = vec![Vec::new(); w];
        for (rank, slot) in blobs.iter_mut().enumerate() {
            match self.recv(rank, Phase::Checkpoint)? {
                WorkerMsg::Snapshot { round: r, blob } if r == round => {
                    *slot = blob;
                }
                other => {
                    return fatal(anyhow!(
                        "worker {rank}: expected Snapshot, got {other:?}"
                    ))
                }
            }
        }
        if let Some(dir) = self.cfg.checkpoint_dir.clone() {
            if let Err(e) =
                self.persist_checkpoint(&dir, round, &blobs, &meta)
            {
                // best-effort: recovery runs off the in-memory floor;
                // losing the on-disk copy only narrows what a later
                // --resume can find
                eprintln!(
                    "checkpoint r{round}: persist to {} failed \
                     (continuing on the in-memory floor): {e}",
                    dir.display()
                );
            }
        }
        self.ckpt_blobs = blobs;
        self.ckpt_meta = meta;
        match meta {
            CkptMeta::Partition { .. } => self.shape.checkpoints += 1,
            CkptMeta::Sssp => self.shape.sssp_checkpoints += 1,
        }
        Ok(())
    }

    /// Write one checkpoint round to disk: a checksummed framed blob
    /// per rank, then the metadata file *last* — a resume trusts a
    /// round only when the meta and every rank blob verify.
    fn persist_checkpoint(
        &mut self,
        dir: &Path,
        round: u64,
        blobs: &[Vec<u8>],
        meta: &CkptMeta,
    ) -> Result<()> {
        std::fs::create_dir_all(dir).map_err(|e| {
            Error::msg(format!(
                "create checkpoint dir {}: {e}",
                dir.display()
            ))
            .with_kind(ErrorKind::Io)
        })?;
        for (rank, blob) in blobs.iter().enumerate() {
            let path = dir.join(format!("ckpt_r{round}_w{rank}.bin"));
            write_blob_checked_with(&path, blob, self.disk_arm.as_mut())?;
        }
        if let Some(bytes) = encode_meta(
            meta,
            self.cfg.workers,
            self.cfg.k,
            self.g.vertex_count(),
            self.g.edge_count(),
        ) {
            let path = dir.join(format!("ckpt_r{round}_meta.bin"));
            write_blob_checked_with(&path, &bytes, self.disk_arm.as_mut())?;
        }
        Ok(())
    }

    /// Respawn a failed rank, restore every worker from the last
    /// checkpoint (global rollback), and drain stale in-flight frames
    /// with a barrier token. A *further* worker failure mid-recovery —
    /// another dead rank, or an injected fault on the restore traffic —
    /// restarts recovery against the same budget instead of aborting
    /// the run. After this, deterministic replay continues from the
    /// checkpoint's control state.
    fn recover(&mut self, dead: usize, err: Error) -> Result<()> {
        let (mut dead, mut err) = (dead, err);
        loop {
            self.recoveries += 1;
            if self.recoveries > self.cfg.max_recoveries {
                return Err(terr(format!(
                    "recovery budget exhausted ({} failures, budget \
                     {}): {err}",
                    self.recoveries, self.cfg.max_recoveries
                )));
            }
            let t0 = Instant::now();
            match self.recover_once(dead) {
                Ok(()) => {
                    self.recovery_ms
                        .push(t0.elapsed().as_secs_f64() * 1e3);
                    return Ok(());
                }
                Err(RunErr::Worker { rank, err: e }) => {
                    dead = rank;
                    err = e;
                }
                Err(RunErr::Fatal(e)) => return Err(e),
            }
        }
    }

    /// One recovery attempt: kill + respawn `dead`, re-init it, then
    /// roll every worker back to the floor. Worker failures along the
    /// way surface as `RunErr::Worker` so [`recover`](Self::recover)
    /// can retry.
    fn recover_once(&mut self, dead: usize) -> Result<(), RunErr> {
        if let Some(child) = self.conns[dead].child.as_mut() {
            let _ = child.kill();
            let _ = child.wait();
        }
        let child = spawn_worker(self.addr, self.cfg.in_process)
            .map_err(RunErr::Fatal)?;
        // replacing the Conn drops the dead streams; a stalled-but-alive
        // worker hits a broken pipe when it wakes and exits on its own
        self.conns[dead] = accept_worker(
            &self.listener,
            self.cfg.worker_timeout(),
            self.cfg.boot_timeout(),
            child,
            dead,
            "recovery",
        )
        .map_err(|err| RunErr::Worker { rank: dead, err })?;
        self.arm_conn(dead);
        let init = CoordMsg::Init(self.init_msg(dead, false));
        self.send(dead, &init, Phase::Recovery)?;
        match self.recv(dead, Phase::Recovery)? {
            WorkerMsg::Ready { rank } if rank as usize == dead => {}
            other => {
                return fatal(anyhow!(
                    "respawned worker {dead}: expected Ready, got {other:?}"
                ))
            }
        }
        self.rollback_all(Phase::Recovery)
    }

    /// Restore every worker from the in-memory floor, then flush stale
    /// in-flight frames with a fresh barrier token round-trip.
    fn rollback_all(&mut self, phase: Phase) -> Result<(), RunErr> {
        self.barrier_token += 1;
        let token = self.barrier_token;
        for rank in 0..self.conns.len() {
            let restore =
                CoordMsg::Restore { blob: self.ckpt_blobs[rank].clone() };
            self.send(rank, &restore, phase)?;
            self.send(rank, &CoordMsg::Barrier { token }, phase)?;
        }
        for rank in 0..self.conns.len() {
            let mut drained = 0usize;
            loop {
                match self.recv(rank, phase)? {
                    WorkerMsg::BarrierAck { token: t } if t == token => break,
                    _stale => {
                        drained += 1;
                        if drained > DRAIN_LIMIT {
                            return fatal(anyhow!(
                                "worker {rank}: barrier {token} never \
                                 acknowledged"
                            ));
                        }
                    }
                }
            }
        }
        Ok(())
    }
}

impl<'a> Coordinator<'a> {
    /// Drive funding rounds from the last checkpoint's control state to
    /// completion, then fetch the pre-finalize owners. Re-entrant: a
    /// worker failure unwinds to the caller, which recovers and calls
    /// again; the control variables always resume from the checkpoint.
    fn partition_phase(&mut self) -> Result<(u64, Vec<u32>), RunErr> {
        let CkptMeta::Partition { round, free_edges, stall, reseed_next } =
            self.ckpt_meta
        else {
            return fatal(anyhow!("partition phase re-entered after SSSP"));
        };
        let (mut round, mut free, mut stall, mut reseed_next) =
            (round, free_edges, stall, reseed_next);
        let max_rounds = self.tune.max_rounds as u64;
        // the exact run_inner control flow: stall counting on unchanged
        // free_edges, reseed applied at the start of the *next* round
        // (deferred-reseed equivalence: the rng draw order is identical)
        while free > 0 && round < max_rounds {
            let t0 = Instant::now();
            let reseed = reseed_next;
            reseed_next = false;
            let new_free = self.one_round(round, reseed)?;
            round += 1;
            if new_free == free {
                stall += 1;
                if stall >= 3 {
                    reseed_next = true;
                    stall = 0;
                }
            } else {
                stall = 0;
            }
            free = new_free;
            self.round_ms.push(t0.elapsed().as_secs_f64() * 1e3);
            if self.cfg.checkpoint_every > 0
                && round % self.cfg.checkpoint_every == 0
                && free > 0
            {
                self.checkpoint(CkptMeta::Partition {
                    round,
                    free_edges: free,
                    stall,
                    reseed_next,
                })?;
            }
        }
        let owner = self.collect_owners()?;
        Ok((round, owner))
    }

    /// One funding round: bids up, stitch, bids down, RoundDone barrier
    /// with the replica-divergence check.
    fn one_round(&mut self, round: u64, reseed: bool) -> Result<u64, RunErr> {
        let w = self.conns.len();
        let start = CoordMsg::StartRound { round, reseed };
        for rank in 0..w {
            self.send(rank, &start, Phase::Control)?;
        }
        let mut per_worker: Vec<Vec<Bid>> = Vec::with_capacity(w);
        for rank in 0..w {
            match self.recv(rank, Phase::BidsUp)? {
                WorkerMsg::Bids { round: r, bids } if r == round => {
                    per_worker.push(bids);
                }
                other => {
                    return fatal(anyhow!(
                        "worker {rank}: expected Bids for round {round}, \
                         got {other:?}"
                    ))
                }
            }
        }
        let merged = stitch_bids(self.cfg.k, w, &per_worker)
            .map_err(RunErr::Fatal)?;
        self.shape.rounds += 1;
        self.shape.total_bids += merged.len() as u64;
        let down = CoordMsg::Bids { round, bids: merged };
        for rank in 0..w {
            self.send(rank, &down, Phase::BidsDown)?;
        }
        let (mut free, mut hash) = (None, None);
        for rank in 0..w {
            match self.recv(rank, Phase::Control)? {
                WorkerMsg::RoundDone { round: r, free_edges, owner_hash }
                    if r == round =>
                {
                    if *hash.get_or_insert(owner_hash) != owner_hash
                        || *free.get_or_insert(free_edges) != free_edges
                    {
                        return fatal(anyhow!(
                            "replica divergence at round {round}: worker \
                             {rank} disagrees on owner hash or free edges"
                        ));
                    }
                }
                other => {
                    return fatal(anyhow!(
                        "worker {rank}: expected RoundDone for round \
                         {round}, got {other:?}"
                    ))
                }
            }
        }
        Ok(free.expect("at least one worker"))
    }

    /// Fetch the pre-finalize owners from rank 0 only — the per-round
    /// hash checks already proved every replica identical.
    fn collect_owners(&mut self) -> Result<Vec<u32>, RunErr> {
        self.send(0, &CoordMsg::FetchOwners, Phase::Merge)?;
        match self.recv(0, Phase::Merge)? {
            WorkerMsg::Owners { owner }
                if owner.len() == self.g.edge_count() =>
            {
                Ok(owner)
            }
            other => fatal(anyhow!(
                "worker 0: expected Owners of length {}, got {other:?}",
                self.g.edge_count()
            )),
        }
    }

    /// Distributed ETSCH SSSP on the finalized partition, with the same
    /// recover-and-replay loop as partitioning (the phase-entry
    /// checkpoint is the rollback floor; supersteps restart from 0).
    fn run_sssp(&mut self, source: u32, owner: &[u32]) -> Result<Vec<u32>> {
        loop {
            match self.sssp_phase(source, owner) {
                Ok(dist) => return Ok(dist),
                Err(RunErr::Worker { rank, err }) => self.recover(rank, err)?,
                Err(RunErr::Fatal(e)) => return Err(e),
            }
        }
    }

    fn sssp_phase(
        &mut self,
        source: u32,
        owner: &[u32],
    ) -> Result<Vec<u32>, RunErr> {
        let w = self.conns.len();
        if !matches!(self.ckpt_meta, CkptMeta::Sssp) {
            // first entry (or retry of a failure before the SSSP
            // checkpoint landed): broadcast the finalized owners and
            // take the phase-entry checkpoint
            let start = CoordMsg::SsspStart {
                source,
                owner: owner.to_vec(),
            };
            for rank in 0..w {
                self.send(rank, &start, Phase::Sssp)?;
            }
            self.checkpoint(CkptMeta::Sssp)?;
        }
        // replicated frontier relaxation: the coordinator min-merges
        // worker deltas (order-independent), so the result equals the
        // single-process Etsch run — unit-weight BFS distances
        let n = self.g.vertex_count();
        let mut dist = vec![u32::MAX; n];
        dist[source as usize] = 0;
        let mut pending = vec![(source, 0u32)];
        let mut step = 0u64;
        while !pending.is_empty() {
            self.shape.sssp_steps += 1;
            self.shape.sssp_updates += pending.len() as u64;
            let msg = CoordMsg::SsspStep { step, updates: pending };
            for rank in 0..w {
                self.send(rank, &msg, Phase::Sssp)?;
            }
            let mut next: Vec<(u32, u32)> = Vec::new();
            for rank in 0..w {
                match self.recv(rank, Phase::Sssp)? {
                    WorkerMsg::SsspDelta { step: s, updates } if s == step => {
                        self.shape.sssp_deltas += updates.len() as u64;
                        for (v, d) in updates {
                            if (v as usize) < n && d < dist[v as usize] {
                                dist[v as usize] = d;
                                next.push((v, d));
                            }
                        }
                    }
                    other => {
                        return fatal(anyhow!(
                            "worker {rank}: expected SsspDelta for step \
                             {step}, got {other:?}"
                        ))
                    }
                }
            }
            pending = next;
            step += 1;
        }
        Ok(dist)
    }

    /// Scan the checkpoint directory for the newest intact persisted
    /// checkpoint — meta and *every* rank blob verifying — and roll the
    /// booted cluster back to it. Corrupt, torn, or shape-incompatible
    /// rounds are skipped with a note; if nothing survives, the run
    /// simply starts fresh from round 0.
    fn resume_from_disk(&mut self, dir: &Path) -> Result<()> {
        let entries = match std::fs::read_dir(dir) {
            Ok(e) => e,
            Err(_) => return Ok(()), // no directory: nothing to resume
        };
        let mut rounds: Vec<u64> = Vec::new();
        for entry in entries.flatten() {
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            if let Some(r) = name
                .strip_prefix("ckpt_r")
                .and_then(|s| s.strip_suffix("_meta.bin"))
                .and_then(|s| s.parse::<u64>().ok())
            {
                rounds.push(r);
            }
        }
        rounds.sort_unstable();
        rounds.dedup();
        for &round in rounds.iter().rev() {
            match self.load_checkpoint(dir, round) {
                Ok((meta, blobs)) => {
                    self.ckpt_meta = meta;
                    self.ckpt_blobs = blobs;
                    match self.rollback_all(Phase::Recovery) {
                        Ok(()) => {}
                        Err(RunErr::Worker { rank, err }) => {
                            self.recover(rank, err)?;
                        }
                        Err(RunErr::Fatal(e)) => return Err(e),
                    }
                    self.resumed_round = Some(round);
                    return Ok(());
                }
                Err(e) => {
                    self.skipped_checkpoints += 1;
                    eprintln!(
                        "resume: skipping persisted checkpoint \
                         r{round}: {e}"
                    );
                }
            }
        }
        Ok(())
    }

    /// Read and verify one persisted checkpoint round end-to-end:
    /// checksummed meta, shape match against this run, then every rank
    /// blob's checksum.
    fn load_checkpoint(
        &self,
        dir: &Path,
        round: u64,
    ) -> Result<(CkptMeta, Vec<Vec<u8>>)> {
        let meta_bytes = read_blob_checked(
            &dir.join(format!("ckpt_r{round}_meta.bin")),
        )?;
        let dm = decode_meta(&meta_bytes)?;
        let CkptMeta::Partition { round: meta_round, .. } = dm.meta else {
            bail!("meta is not a partition-phase checkpoint");
        };
        if meta_round != round {
            bail!("meta says round {meta_round}, filename says {round}");
        }
        let (n, m) = (self.g.vertex_count(), self.g.edge_count());
        if dm.workers != self.cfg.workers
            || dm.k != self.cfg.k
            || dm.n != n
            || dm.m != m
        {
            bail!(
                "shape mismatch: checkpoint has workers/k/n/m = \
                 {}/{}/{}/{}, this run has {}/{}/{n}/{m}",
                dm.workers,
                dm.k,
                dm.n,
                dm.m,
                self.cfg.workers,
                self.cfg.k
            );
        }
        let mut blobs = Vec::with_capacity(dm.workers);
        for rank in 0..dm.workers {
            blobs.push(read_blob_checked(
                &dir.join(format!("ckpt_r{round}_w{rank}.bin")),
            )?);
        }
        Ok((dm.meta, blobs))
    }

    /// Full run: boot, optional resume-from-disk, partition (with
    /// recovery), finalize, optional SSSP (with recovery).
    fn execute(&mut self) -> Result<(EdgePartition, Option<Vec<u32>>)> {
        self.boot()?;
        if self.cfg.resume {
            if let Some(dir) = self.cfg.checkpoint_dir.clone() {
                self.resume_from_disk(&dir)?;
            }
        }
        let (rounds, owner_raw) = loop {
            match self.partition_phase() {
                Ok(out) => break out,
                Err(RunErr::Worker { rank, err }) => self.recover(rank, err)?,
                Err(RunErr::Fatal(e)) => return Err(e),
            }
        };
        let owner = dfep::finalize(self.g, owner_raw, self.cfg.k);
        let partition = EdgePartition {
            k: self.cfg.k,
            owner,
            rounds: rounds as usize,
        };
        let sssp_dist = match self.cfg.sssp_source {
            Some(src) => Some(self.run_sssp(src, &partition.owner)?),
            None => None,
        };
        Ok((partition, sssp_dist))
    }

    /// Best-effort clean teardown: `Shutdown` to every worker, then
    /// reap children (kill stragglers after a grace period).
    fn shutdown(&mut self) {
        for rank in 0..self.conns.len() {
            let _ = self.send(rank, &CoordMsg::Shutdown, Phase::Control);
        }
        let grace = self.cfg.shutdown_grace();
        for conn in &mut self.conns {
            if let Some(child) = conn.child.as_mut() {
                let deadline = Instant::now() + grace;
                loop {
                    match child.try_wait() {
                        Ok(Some(_)) => break,
                        Ok(None) if Instant::now() < deadline => {
                            std::thread::sleep(Duration::from_millis(10));
                        }
                        _ => {
                            let _ = child.kill();
                            let _ = child.wait();
                            break;
                        }
                    }
                }
            }
        }
    }
}

/// Stitch per-worker bid lists into the canonical global order:
/// partition `i`'s contiguous run, taken from worker `i % workers`, in
/// ascending partition order — exactly the order the single-process
/// `round_bids` emits. Validates that every bid sits in its owner's
/// list and that runs are contiguous (each a transport-corruption
/// tripwire).
fn stitch_bids(
    k: usize,
    workers: usize,
    per_worker: &[Vec<Bid>],
) -> Result<Vec<Bid>> {
    let mut runs: Vec<(u32, u32)> = vec![(0, 0); k];
    let mut have = vec![false; k];
    let mut total = 0usize;
    for (w, bids) in per_worker.iter().enumerate() {
        total += bids.len();
        let mut lo = 0usize;
        while lo < bids.len() {
            let p = bids[lo].1 as usize;
            if p >= k || p % workers != w {
                return Err(terr(format!(
                    "worker {w} sent a bid for foreign partition {p}"
                )));
            }
            if have[p] {
                return Err(terr(format!(
                    "worker {w}: partition {p} bids split across runs"
                )));
            }
            let mut hi = lo + 1;
            while hi < bids.len() && bids[hi].1 as usize == p {
                hi += 1;
            }
            have[p] = true;
            runs[p] = (lo as u32, hi as u32);
            lo = hi;
        }
    }
    let mut merged = Vec::with_capacity(total);
    for (p, &(lo, hi)) in runs.iter().enumerate() {
        if have[p] {
            merged.extend_from_slice(
                &per_worker[p % workers][lo as usize..hi as usize],
            );
        }
    }
    Ok(merged)
}

/// Run a full distributed partitioning (and optional SSSP) according to
/// `cfg`, returning the partition plus the measured-vs-predicted wire
/// cost report. The coordinator binds an ephemeral loopback port,
/// spawns the workers itself, and tears everything down before
/// returning.
pub fn run_cluster(cfg: &ClusterConfig) -> Result<ClusterReport> {
    cfg.validate()?;
    let spec = PartitionerSpec::parse(&cfg.spec)?;
    let r = Resolved::of(&spec);
    let tune = Dfep {
        funding_cap: r.f64("cap"),
        initial_fraction: r.f64("init"),
        max_rounds: r.usize("max_rounds"),
        frontier_first: r.bool("frontier_first"),
    };
    let g = resolve_graph(&cfg.dataset, cfg.graph_seed)?;
    if g.edge_count() == 0 {
        return Err(invalid(format!(
            "graph '{}' has no edges",
            cfg.dataset
        )));
    }
    if let Some(src) = cfg.sssp_source {
        if src as usize >= g.vertex_count() {
            return Err(invalid(format!(
                "sssp source {src} out of range (|V| = {})",
                g.vertex_count()
            )));
        }
    }
    let listener = TcpListener::bind(("127.0.0.1", 0)).map_err(|e| {
        Error::msg(format!("bind coordinator listener: {e}"))
            .with_kind(ErrorKind::Io)
    })?;
    let addr = listener.local_addr().map_err(|e| {
        Error::msg(format!("coordinator address: {e}"))
            .with_kind(ErrorKind::Io)
    })?;
    let m = g.edge_count();
    let fault_counters = FaultCounters::shared();
    let disk_arm = cfg
        .fault
        .as_ref()
        .map(|p| p.arm(DISK_ARM_TAG, Arc::clone(&fault_counters)));
    let mut co = Coordinator {
        cfg,
        tune,
        g: &g,
        listener,
        addr,
        conns: Vec::new(),
        incarnations: vec![0; cfg.workers],
        fault_counters,
        disk_arm,
        bytes: WireBytes::default(),
        shape: ClusterShape {
            workers: cfg.workers,
            n: g.vertex_count(),
            m,
            k: cfg.k,
            ..ClusterShape::default()
        },
        ckpt_blobs: Vec::new(),
        ckpt_meta: CkptMeta::Partition {
            round: 0,
            free_edges: m as u64,
            stall: 0,
            reseed_next: false,
        },
        recoveries: 0,
        barrier_token: 0,
        round_ms: Vec::new(),
        recovery_ms: Vec::new(),
        resumed_round: None,
        skipped_checkpoints: 0,
    };
    let result = co.execute();
    co.shutdown();
    let (partition, sssp_dist) = result?;
    let predicted = WireModel::default().predict(&co.shape);
    Ok(ClusterReport {
        partition,
        workers: cfg.workers,
        recoveries: co.recoveries,
        measured: co.bytes,
        predicted,
        shape: co.shape,
        sssp_dist,
        round_ms: co.round_ms,
        recovery_ms: co.recovery_ms,
        faults: co.fault_counters.snapshot(),
        resumed_round: co.resumed_round,
        skipped_checkpoints: co.skipped_checkpoints,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv1a64_is_stable_and_distinguishes() {
        let a = fnv1a64(&[0, 1, 2, 3]);
        assert_eq!(a, fnv1a64(&[0, 1, 2, 3]));
        assert_ne!(a, fnv1a64(&[0, 1, 3, 2]));
        assert_ne!(a, fnv1a64(&[0, 1, 2]));
        assert_ne!(fnv1a64(&[]), fnv1a64(&[0]));
    }

    #[test]
    fn stitch_bids_reassembles_partition_major_order() {
        // k=4, 2 workers: worker 0 owns partitions 0 and 2, worker 1
        // owns 1 and 3; each list holds contiguous per-partition runs
        let b = |e: u32, p: u32| (e, p, 1.0, 2.0);
        let per_worker = vec![
            vec![b(10, 0), b(11, 0), b(12, 2)],
            vec![b(20, 1), b(21, 3), b(22, 3)],
        ];
        let merged = stitch_bids(4, 2, &per_worker).unwrap();
        assert_eq!(
            merged,
            vec![
                b(10, 0),
                b(11, 0),
                b(20, 1),
                b(12, 2),
                b(21, 3),
                b(22, 3),
            ]
        );
        // a partition with no bids this round is simply absent
        let sparse = vec![vec![b(12, 2)], vec![]];
        assert_eq!(stitch_bids(4, 2, &sparse).unwrap(), vec![b(12, 2)]);
    }

    #[test]
    fn stitch_bids_rejects_foreign_and_split_runs() {
        let b = |e: u32, p: u32| (e, p, 1.0, 2.0);
        // worker 0 must not bid for partition 1 (owned by worker 1)
        let foreign = vec![vec![b(10, 1)], vec![]];
        assert!(stitch_bids(4, 2, &foreign).is_err());
        // out-of-range partition id
        let oob = vec![vec![b(10, 4)], vec![]];
        assert!(stitch_bids(4, 2, &oob).is_err());
        // non-contiguous run for one partition
        let split = vec![vec![b(10, 0), b(12, 2), b(11, 0)], vec![]];
        assert!(stitch_bids(4, 2, &split).is_err());
    }

    fn test_init(rank: u32, workers: u32) -> InitMsg {
        // a 3x3 grid-ish graph: enough structure for non-trivial state
        let edges = vec![
            (0, 1),
            (0, 3),
            (1, 2),
            (1, 4),
            (2, 5),
            (3, 4),
            (4, 5),
            (5, 6),
            (6, 7),
            (7, 8),
        ];
        InitMsg {
            rank,
            workers,
            k: 4,
            seed: 7,
            cap: 10.0,
            init_frac: 1.0,
            frontier_first: true,
            fail_round: -1,
            fail_stall_ms: 0,
            n: 9,
            edges,
        }
    }

    #[test]
    fn snapshot_restore_roundtrip_is_lossless() {
        let mut wk = WorkerState::boot(test_init(1, 2)).unwrap();
        // advance a few rounds through the real masked phases so the
        // ledger/holders are in an organic mid-run shape
        for round in 0..3u64 {
            wk.handle(CoordMsg::StartRound { round, reseed: false })
                .unwrap();
            let bids = wk.st.pending_bids().to_vec();
            wk.handle(CoordMsg::Bids { round, bids }).unwrap();
        }
        let blob = wk.snapshot();
        // corrupt every restorable field, then restore
        wk.st.owner[0] = 99;
        wk.st.sizes[0] += 17;
        wk.st.free_edges = 0;
        wk.st.rounds = 1000;
        wk.st.free_deg[0] = 42;
        wk.st.anchor[1] = 3;
        let _ = wk.rng.next_u64();
        for i in 0..wk.k {
            if wk.owned[i] {
                wk.st.holders[i].clear();
            }
        }
        wk.restore(&blob).unwrap();
        assert_eq!(wk.snapshot(), blob);
        assert_eq!(wk.st.rounds, 3);
    }

    #[test]
    fn restore_rejects_garbage() {
        let mut wk = WorkerState::boot(test_init(0, 1)).unwrap();
        assert!(wk.restore(b"").is_err());
        assert!(wk.restore(&[0xff; 40]).is_err());
        let mut blob = wk.snapshot();
        blob.truncate(blob.len() - 1);
        assert!(wk.restore(&blob).is_err());
    }

    #[test]
    fn config_validation_and_derived_deadlines() {
        let ok = ClusterConfig { in_process: true, ..Default::default() };
        ok.validate().unwrap();
        // the stall detector is the root of every deadline; zero is out
        let bad = ClusterConfig { worker_timeout_ms: 0, ..ok.clone() };
        assert_eq!(
            bad.validate().unwrap_err().kind(),
            ErrorKind::InvalidRequest
        );
        // derived deadlines scale with it, with sane floors
        let fast = ClusterConfig { worker_timeout_ms: 100, ..ok.clone() };
        fast.validate().unwrap();
        assert_eq!(fast.boot_timeout(), Duration::from_secs(1));
        assert_eq!(fast.shutdown_grace(), Duration::from_millis(100));
        let slow = ClusterConfig { worker_timeout_ms: 60_000, ..ok };
        assert_eq!(slow.boot_timeout(), Duration::from_secs(180));
        assert_eq!(slow.shutdown_grace(), Duration::from_secs(30));
        // the defaults reproduce the previously hard-coded constants
        let d = ClusterConfig { in_process: true, ..Default::default() };
        assert_eq!(d.boot_timeout(), Duration::from_secs(30));
        assert_eq!(d.shutdown_grace(), Duration::from_secs(5));
    }

    #[test]
    fn disk_meta_roundtrips_and_rejects_corruption() {
        let meta = CkptMeta::Partition {
            round: 12,
            free_edges: 345,
            stall: 2,
            reseed_next: true,
        };
        let bytes = encode_meta(&meta, 3, 8, 400, 1600).unwrap();
        let dm = decode_meta(&bytes).unwrap();
        assert_eq!((dm.workers, dm.k, dm.n, dm.m), (3, 8, 400, 1600));
        let CkptMeta::Partition { round, free_edges, stall, reseed_next } =
            dm.meta
        else {
            panic!("partition meta expected");
        };
        assert_eq!(
            (round, free_edges, stall, reseed_next),
            (12, 345, 2, true)
        );
        // SSSP checkpoints are deliberately not resumable
        assert!(encode_meta(&CkptMeta::Sssp, 3, 8, 400, 1600).is_none());
        // truncation, trailing bytes and version skew all fail loudly
        assert!(decode_meta(&bytes[..bytes.len() - 1]).is_err());
        let mut longer = bytes.clone();
        longer.push(0);
        assert!(decode_meta(&longer).is_err());
        let mut wrong_ver = bytes.clone();
        wrong_ver[0] = 99;
        assert!(decode_meta(&wrong_ver).is_err());
    }

    #[test]
    fn accept_timeout_error_names_rank_phase_and_kind() {
        // nobody ever dials: the accept must time out with a typed
        // Transport error attributing the rank and the protocol phase
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let err = accept_worker(
            &listener,
            Duration::from_millis(50),
            Duration::from_millis(50),
            None,
            3,
            "recovery",
        )
        .unwrap_err();
        assert_eq!(err.kind(), ErrorKind::Transport);
        let msg = err.to_string();
        assert!(msg.contains("worker 3"), "{msg}");
        assert!(msg.contains("recovery"), "{msg}");
    }

    #[test]
    fn sssp_snapshot_roundtrip() {
        let mut wk = WorkerState::boot(test_init(0, 2)).unwrap();
        let owner: Vec<u32> = (0..10).map(|e| e % 4).collect();
        wk.handle(CoordMsg::SsspStart { source: 0, owner }).unwrap();
        let blob = wk.snapshot();
        wk.sssp = None;
        wk.restore(&blob).unwrap();
        assert_eq!(wk.snapshot(), blob);
        let s = wk.sssp.as_ref().unwrap();
        assert_eq!(s.source, 0);
        assert_eq!(s.owner.len(), 10);
    }
}
