//! Failure & straggler injection for the simulated cluster.
//!
//! Hadoop's fault model re-executes failed tasks: a node failure during a
//! round costs a redo of that node's share (plus detection latency), and a
//! straggler stretches the round by the slowest task. This module wraps
//! [`CostModel`] with a seeded failure process so the Figs 8-9 pipelines
//! can be re-simulated under faults — the robustness argument the paper
//! makes for distribution ("more robust to hardware failures") becomes a
//! measurable ablation.

use super::cost::{CostModel, RoundWork};
use crate::util::rng::Rng;

/// Fault process parameters.
#[derive(Clone, Debug)]
pub struct FaultModel {
    /// Probability that any given node fails during a round.
    pub node_failure_per_round: f64,
    /// Detection + reschedule latency added when a failure happens (s).
    pub detection_latency_s: f64,
    /// Probability a round contains a severe straggler.
    pub straggler_per_round: f64,
    /// Multiplier a severe straggler applies to the round's parallel part.
    pub straggler_factor: f64,
}

impl Default for FaultModel {
    fn default() -> Self {
        FaultModel {
            node_failure_per_round: 0.002, // ~1 failure / 500 node-rounds
            detection_latency_s: 30.0,     // Hadoop 1.x task-timeout scale
            straggler_per_round: 0.05,
            straggler_factor: 1.8,
        }
    }
}

/// Outcome of simulating one job under faults.
#[derive(Clone, Debug, Default)]
pub struct FaultyRun {
    /// Total simulated wall-clock including redo and detection costs.
    pub total_time: f64,
    /// Node failures injected.
    pub failures: usize,
    /// Rounds stretched by a severe straggler.
    pub straggled_rounds: usize,
}

/// Simulate a job's rounds on `nodes` workers under the fault process.
/// A failed round pays the failure latency plus a re-execution of the
/// failed node's share (1/nodes of the parallel work).
pub fn simulate_with_faults(
    cost: &CostModel,
    faults: &FaultModel,
    nodes: usize,
    rounds: &[RoundWork],
    seed: u64,
) -> FaultyRun {
    let mut rng = Rng::new(seed);
    let mut out = FaultyRun::default();
    for &w in rounds {
        let base = cost.round_time(nodes, w);
        let parallel = base - cost.round_overhead_s;
        let mut t = base;
        // node failures are independent per node
        let mut failed = 0usize;
        for _ in 0..nodes {
            if rng.chance(faults.node_failure_per_round) {
                failed += 1;
            }
        }
        if failed > 0 {
            out.failures += failed;
            t += faults.detection_latency_s
                + parallel * failed as f64 / nodes as f64;
        }
        if rng.chance(faults.straggler_per_round) {
            out.straggled_rounds += 1;
            t += parallel * (faults.straggler_factor - 1.0);
        }
        out.total_time += t;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn work() -> Vec<RoundWork> {
        vec![
            RoundWork {
                map_records: 5e5,
                shuffle_bytes: 1e7,
                reduce_records: 5e5,
                cpu_edge_ops: 0.0,
            };
            40
        ]
    }

    #[test]
    fn faults_only_add_time() {
        let cost = CostModel::default();
        let clean: f64 = work()
            .iter()
            .map(|&w| cost.round_time(8, w))
            .sum();
        let faulty = simulate_with_faults(
            &cost,
            &FaultModel::default(),
            8,
            &work(),
            1,
        );
        assert!(faulty.total_time >= clean);
    }

    #[test]
    fn zero_fault_model_is_exact() {
        let cost = CostModel::default();
        let clean: f64 =
            work().iter().map(|&w| cost.round_time(8, w)).sum();
        let none = FaultModel {
            node_failure_per_round: 0.0,
            straggler_per_round: 0.0,
            ..Default::default()
        };
        let run = simulate_with_faults(&cost, &none, 8, &work(), 2);
        assert!((run.total_time - clean).abs() < 1e-9);
        assert_eq!(run.failures, 0);
        assert_eq!(run.straggled_rounds, 0);
    }

    #[test]
    fn more_nodes_more_failures_but_cheaper_each() {
        let cost = CostModel::default();
        let heavy = FaultModel {
            node_failure_per_round: 0.05,
            ..Default::default()
        };
        let f4 = simulate_with_faults(&cost, &heavy, 4, &work(), 3);
        let f32 = simulate_with_faults(&cost, &heavy, 32, &work(), 3);
        assert!(f32.failures > f4.failures, "{} {}", f32.failures, f4.failures);
    }

    #[test]
    fn deterministic_per_seed() {
        let cost = CostModel::default();
        let fm = FaultModel::default();
        let a = simulate_with_faults(&cost, &fm, 8, &work(), 9);
        let b = simulate_with_faults(&cost, &fm, 8, &work(), 9);
        assert_eq!(a.total_time, b.total_time);
        assert_eq!(a.failures, b.failures);
    }
}
