//! ETSCH and the vertex-centric baseline as cluster jobs (Fig 9).
//!
//! The ETSCH job runs the real engine with `k = nodes` partitions (the
//! paper: "setting the number of desired partitions equal to the number
//! of available nodes") and measures per-round work volumes; the baseline
//! is the Pregel-style SSSP executed as an *actual* [`VertexJob`] on the
//! threaded MapReduce engine, one superstep per MapReduce round — exactly
//! the structure the paper's "standard baseline" has in Hadoop.

use std::sync::atomic::{AtomicU32, Ordering};

use super::cost::{CostModel, RoundWork};
use super::mapreduce::{run_round, VertexJob};
use crate::etsch::{sssp::Sssp, Etsch};
use crate::graph::Graph;
use crate::partition::view::PartitionView;
use crate::partition::EdgePartition;

const MSG_BYTES: f64 = 12.0;
/// Hadoop passes the graph structure through every iteration (the §VI
/// critique of MapReduce for graphs) — account a per-round re-emission.
const GRAPH_PASS_BYTES: f64 = 16.0;

/// Simulated-time result of a cluster SSSP run.
#[derive(Clone, Debug)]
pub struct ClusterSsspRun {
    /// ETSCH rounds / BSP supersteps executed.
    pub rounds: usize,
    /// Total simulated wall-clock (seconds).
    pub total_time: f64,
    /// Simulated wall-clock per round.
    pub round_times: Vec<f64>,
    /// Messages exchanged across the run.
    pub messages: usize,
    /// Final per-vertex distances (for cross-engine correctness checks).
    pub distances: Vec<u32>,
}

/// ETSCH SSSP on `nodes` workers with a given (DFEP) partitioning.
pub fn run_etsch_sssp(
    g: &Graph,
    p: &EdgePartition,
    source: u32,
    nodes: usize,
    cost: &CostModel,
) -> ClusterSsspRun {
    // one shared derived-state build serves the engine and the per-round
    // work-volume measurements below
    let view = PartitionView::build(g, p);
    let mut engine = Etsch::from_view(g, &view);
    let dist = engine.run(&mut Sssp::new(source));
    let stats = engine.stats();
    // per-round volumes: the local phase reads every replica vertex as a
    // record but walks the partition's edges *in memory* inside one map
    // task (the whole point of ETSCH's local computation); aggregation
    // shuffles frontier states.
    let replica_vertices: f64 = view
        .subgraphs()
        .iter()
        .map(|s| s.vertex_count() as f64)
        .sum();
    let part_edges: f64 =
        view.subgraphs().iter().map(|s| s.edge_count as f64).sum();
    let frontier = (stats.messages_ceiling as f64
        / stats.rounds.max(1) as f64)
        .max(1.0);
    let per_round = RoundWork {
        map_records: replica_vertices,
        shuffle_bytes: frontier * MSG_BYTES
            + replica_vertices * GRAPH_PASS_BYTES,
        reduce_records: replica_vertices,
        cpu_edge_ops: part_edges * 2.0, // Dijkstra visits each edge twice
    };
    let round_times: Vec<f64> = (0..stats.rounds)
        .map(|_| cost.round_time(nodes, per_round))
        .collect();
    ClusterSsspRun {
        rounds: stats.rounds,
        total_time: round_times.iter().sum(),
        round_times,
        messages: stats.messages_exchanged,
        distances: dist,
    }
}

/// The baseline vertex-centric SSSP as a real MapReduce job.
struct BspSsspJob<'g> {
    g: &'g Graph,
    dist: Vec<AtomicU32>,
}

impl VertexJob for BspSsspJob<'_> {
    type Msg = u32;

    fn map(&self, v: u32, emit: &mut dyn FnMut(u32, u32)) {
        let d = self.dist[v as usize].load(Ordering::Relaxed);
        if d == u32::MAX {
            return;
        }
        for &w in self.g.neighbor_vertices(v) {
            emit(w, d + 1);
        }
    }

    fn reduce(&self, v: u32, msgs: &[u32]) -> bool {
        let best = *msgs.iter().min().unwrap();
        let cur = self.dist[v as usize].load(Ordering::Relaxed);
        if best < cur {
            self.dist[v as usize].store(best, Ordering::Relaxed);
            true
        } else {
            false
        }
    }
}

/// Run the baseline on the threaded engine; simulated time from measured
/// per-superstep volumes.
pub fn run_baseline_sssp(
    g: &Graph,
    source: u32,
    nodes: usize,
    cost: &CostModel,
) -> ClusterSsspRun {
    let n = g.vertex_count();
    let job = BspSsspJob {
        g,
        dist: (0..n)
            .map(|v| {
                AtomicU32::new(if v as u32 == source { 0 } else { u32::MAX })
            })
            .collect(),
    };
    let mut round_times = Vec::new();
    let mut messages = 0usize;
    loop {
        let out = run_round(&job, n, nodes.min(8), MSG_BYTES);
        messages += out.messages;
        let mut w = out.work;
        // Hadoop re-reads and re-writes the whole graph every superstep
        w.shuffle_bytes += (n + 2 * g.edge_count()) as f64 * GRAPH_PASS_BYTES;
        round_times.push(cost.round_time(nodes, w));
        if out.changed == 0 {
            break;
        }
    }
    ClusterSsspRun {
        rounds: round_times.len(),
        total_time: round_times.iter().sum(),
        round_times,
        messages,
        distances: job
            .dist
            .into_iter()
            .map(|a| a.into_inner())
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::GraphKind;
    use crate::graph::stats::bfs_distances;
    use crate::partition::{dfep::Dfep, Partitioner};

    fn setup() -> (Graph, EdgePartition) {
        let g = GraphKind::RoadNetwork {
            rows: 12, cols: 12, drop: 0.15, subdiv: 2, shortcuts: 0,
        }
        .generate(1);
        let p = Dfep::default().partition_graph(&g, 4, 1).unwrap();
        (g, p)
    }

    #[test]
    fn both_engines_compute_correct_distances() {
        let (g, p) = setup();
        let cost = CostModel::default();
        let want = bfs_distances(&g, 0);
        let etsch = run_etsch_sssp(&g, &p, 0, 4, &cost);
        let base = run_baseline_sssp(&g, 0, 4, &cost);
        assert_eq!(etsch.distances, want);
        assert_eq!(base.distances, want);
    }

    #[test]
    fn etsch_needs_fewer_rounds_than_baseline() {
        let (g, p) = setup();
        let cost = CostModel::default();
        let etsch = run_etsch_sssp(&g, &p, 0, 4, &cost);
        let base = run_baseline_sssp(&g, 0, 4, &cost);
        assert!(
            etsch.rounds < base.rounds,
            "etsch {} !< baseline {}",
            etsch.rounds,
            base.rounds
        );
    }

    #[test]
    fn etsch_faster_on_few_nodes_fig9_shape() {
        let (g, p) = setup();
        let cost = CostModel::default();
        let e = run_etsch_sssp(&g, &p, 0, 2, &cost);
        let b = run_baseline_sssp(&g, 0, 2, &cost);
        assert!(
            e.total_time < b.total_time,
            "etsch {} !< baseline {}",
            e.total_time,
            b.total_time
        );
    }
}
