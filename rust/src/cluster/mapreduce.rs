//! A small executable MapReduce engine (vertex-keyed, iterative) running
//! on the shared [`crate::util::pool`] — the structural substrate under
//! the Hadoop-shaped DFEP and ETSCH jobs.
//!
//! This is a *real* parallel engine: mappers run shard-parallel over
//! fixed-size vertex ranges, emit keyed messages, a shuffle groups them
//! by key, and reducers run key-parallel. Shard boundaries are constants
//! (not a function of the worker count), and the shuffle walks shards in
//! index order into a `BTreeMap`, so the message order every reducer sees
//! is identical for any thread count. Wall-clock on this box is
//! meaningless for a 16-node cluster, so jobs ALSO report their
//! [`RoundWork`] volumes and the [`CostModel`] turns those into simulated
//! cluster time (Figs 8-9).

use std::collections::BTreeMap;

use super::cost::RoundWork;
use crate::util::pool;

/// One round of a vertex-keyed MapReduce job.
///
/// `V` = per-vertex record, `M` = message. The engine calls `map` on every
/// vertex record (sharded over the shared pool), shuffles messages by
/// destination vertex, then calls `reduce` per vertex with its messages.
pub trait VertexJob: Sync {
    /// Message type shuffled between vertices.
    type Msg: Send;

    /// Map phase: may emit messages to any vertex.
    fn map(&self, v: u32, emit: &mut dyn FnMut(u32, Self::Msg));

    /// Reduce phase: combine `msgs` into the vertex's new state
    /// (state lives inside the job; `reduce` returns whether it changed).
    fn reduce(&self, v: u32, msgs: &[Self::Msg]) -> bool;
}

/// Outcome of one engine round.
#[derive(Clone, Copy, Debug)]
pub struct RoundOutcome {
    /// Messages shuffled this round.
    pub messages: usize,
    /// Vertices whose reduce reported a state change.
    pub changed: usize,
    /// Work volumes for the cluster cost model.
    pub work: RoundWork,
}

/// Vertices per map shard (constant, so sharding — and therefore the
/// shuffle's message order — is independent of the pool's thread count).
const MAP_SHARD: usize = 4096;
/// Keys per reduce shard.
const REDUCE_SHARD: usize = 2048;

/// Run one synchronized MapReduce round over vertices `0..n`.
///
/// `msg_bytes` sizes the shuffle volume for the cost model. The `workers`
/// argument is the *simulated* cluster width used by callers for their
/// cost accounting; actual parallelism comes from the shared pool.
pub fn run_round<J: VertexJob>(
    job: &J,
    n: usize,
    _workers: usize,
    msg_bytes: f64,
) -> RoundOutcome
where
    J::Msg: Send + Sync + 'static,
{
    // ---- map phase (pool-sharded over fixed vertex ranges) ----
    let n_shards = n.div_ceil(MAP_SHARD);
    let mut shard_out: Vec<Vec<(u32, J::Msg)>> = Vec::new();
    shard_out.resize_with(n_shards, Vec::new);
    pool::run_mut(&mut shard_out, &|s, local: &mut Vec<(u32, J::Msg)>| {
        let lo = s * MAP_SHARD;
        let hi = ((s + 1) * MAP_SHARD).min(n);
        for v in lo..hi {
            job.map(v as u32, &mut |dst, msg| {
                local.push((dst, msg));
            });
        }
    });
    // ---- shuffle (serial, shard order => deterministic) ----
    let mut grouped: BTreeMap<u32, Vec<J::Msg>> = BTreeMap::new();
    let mut messages = 0usize;
    for shard in shard_out {
        for (dst, msg) in shard {
            messages += 1;
            grouped.entry(dst).or_default().push(msg);
        }
    }
    // ---- reduce phase (pool-sharded over fixed key ranges) ----
    let entries: Vec<(u32, Vec<J::Msg>)> = grouped.into_iter().collect();
    let n_rshards = entries.len().div_ceil(REDUCE_SHARD);
    let mut changed_per: Vec<usize> = vec![0; n_rshards];
    {
        let entries = &entries;
        pool::run_mut(&mut changed_per, &|s, changed: &mut usize| {
            let lo = s * REDUCE_SHARD;
            let hi = ((s + 1) * REDUCE_SHARD).min(entries.len());
            for (v, msgs) in &entries[lo..hi] {
                if job.reduce(*v, msgs) {
                    *changed += 1;
                }
            }
        });
    }
    let changed: usize = changed_per.iter().sum();
    RoundOutcome {
        messages,
        changed,
        work: RoundWork {
            map_records: n as f64,
            shuffle_bytes: messages as f64 * msg_bytes,
            reduce_records: messages as f64,
            cpu_edge_ops: 0.0,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU32, Ordering};

    /// Toy job: every vertex sends its id to vertex 0; vertex 0 sums.
    struct SumJob {
        n: usize,
        total: AtomicU32,
    }

    impl VertexJob for SumJob {
        type Msg = u32;

        fn map(&self, v: u32, emit: &mut dyn FnMut(u32, u32)) {
            emit(0, v);
        }

        fn reduce(&self, v: u32, msgs: &[u32]) -> bool {
            if v == 0 {
                self.total
                    .fetch_add(msgs.iter().sum::<u32>(), Ordering::SeqCst);
                true
            } else {
                false
            }
        }
    }

    #[test]
    fn map_shuffle_reduce_roundtrip() {
        let job = SumJob { n: 100, total: AtomicU32::new(0) };
        let out = run_round(&job, job.n, 4, 8.0);
        assert_eq!(out.messages, 100);
        assert_eq!(out.changed, 1);
        assert_eq!(job.total.load(Ordering::SeqCst), (0..100).sum::<u32>());
        assert_eq!(out.work.map_records, 100.0);
        assert_eq!(out.work.shuffle_bytes, 800.0);
    }

    #[test]
    fn worker_count_does_not_change_semantics() {
        for workers in [1, 2, 7] {
            let job = SumJob { n: 57, total: AtomicU32::new(0) };
            run_round(&job, job.n, workers, 8.0);
            assert_eq!(
                job.total.load(Ordering::SeqCst),
                (0..57).sum::<u32>(),
                "workers {workers}"
            );
        }
    }
}
