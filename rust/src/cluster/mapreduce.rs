//! A small executable MapReduce engine (vertex-keyed, iterative) running
//! on std threads — the structural substrate under the Hadoop-shaped DFEP
//! and ETSCH jobs.
//!
//! This is a *real* parallel engine: mappers run partition-parallel over
//! input shards, emit keyed messages, a shuffle groups them by key, and
//! reducers run key-parallel. Wall-clock on this box is meaningless for a
//! 16-node cluster, so jobs ALSO report their [`RoundWork`] volumes and
//! the [`CostModel`] turns those into simulated cluster time (Figs 8-9).

use std::collections::HashMap;
use std::sync::Mutex;

use super::cost::RoundWork;

/// One round of a vertex-keyed MapReduce job.
///
/// `V` = per-vertex record, `M` = message. The engine calls `map` on every
/// vertex record (sharded across `workers` threads), shuffles messages by
/// destination vertex, then calls `reduce` per vertex with its messages.
pub trait VertexJob: Sync {
    type Msg: Send;

    /// Map phase: may emit messages to any vertex.
    fn map(&self, v: u32, emit: &mut dyn FnMut(u32, Self::Msg));

    /// Reduce phase: combine `msgs` into the vertex's new state
    /// (state lives inside the job; `reduce` returns whether it changed).
    fn reduce(&self, v: u32, msgs: &[Self::Msg]) -> bool;
}

/// Outcome of one engine round.
#[derive(Clone, Copy, Debug)]
pub struct RoundOutcome {
    pub messages: usize,
    pub changed: usize,
    pub work: RoundWork,
}

/// Run one synchronized MapReduce round over vertices `0..n`.
///
/// `msg_bytes` sizes the shuffle volume for the cost model.
pub fn run_round<J: VertexJob>(
    job: &J,
    n: usize,
    workers: usize,
    msg_bytes: f64,
) -> RoundOutcome
where
    J::Msg: Send + Sync + 'static,
{
    let workers = workers.max(1);
    // ---- map phase (sharded) ----
    let shards: Vec<Mutex<Vec<(u32, J::Msg)>>> =
        (0..workers).map(|_| Mutex::new(Vec::new())).collect();
    let chunk = n.div_ceil(workers);
    std::thread::scope(|scope| {
        for (w, shard) in shards.iter().enumerate() {
            let lo = w * chunk;
            let hi = ((w + 1) * chunk).min(n);
            scope.spawn(move || {
                let mut local = Vec::new();
                for v in lo..hi {
                    job.map(v as u32, &mut |dst, msg| {
                        local.push((dst, msg));
                    });
                }
                shard.lock().unwrap().extend(local);
            });
        }
    });
    // ---- shuffle ----
    let mut grouped: HashMap<u32, Vec<J::Msg>> = HashMap::new();
    let mut messages = 0usize;
    for shard in shards {
        for (dst, msg) in shard.into_inner().unwrap() {
            messages += 1;
            grouped.entry(dst).or_default().push(msg);
        }
    }
    // ---- reduce phase (key-parallel) ----
    let entries: Vec<(u32, Vec<J::Msg>)> = grouped.into_iter().collect();
    let changed_total = Mutex::new(0usize);
    let rchunk = entries.len().div_ceil(workers);
    std::thread::scope(|scope| {
        for slice in entries.chunks(rchunk.max(1)) {
            let changed_total = &changed_total;
            scope.spawn(move || {
                let mut changed = 0usize;
                for (v, msgs) in slice {
                    if job.reduce(*v, msgs) {
                        changed += 1;
                    }
                }
                *changed_total.lock().unwrap() += changed;
            });
        }
    });
    let changed = changed_total.into_inner().unwrap();
    RoundOutcome {
        messages,
        changed,
        work: RoundWork {
            map_records: n as f64,
            shuffle_bytes: messages as f64 * msg_bytes,
            reduce_records: messages as f64,
            cpu_edge_ops: 0.0,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU32, Ordering};

    /// Toy job: every vertex sends its id to vertex 0; vertex 0 sums.
    struct SumJob {
        n: usize,
        total: AtomicU32,
    }

    impl VertexJob for SumJob {
        type Msg = u32;

        fn map(&self, v: u32, emit: &mut dyn FnMut(u32, u32)) {
            emit(0, v);
        }

        fn reduce(&self, v: u32, msgs: &[u32]) -> bool {
            if v == 0 {
                self.total
                    .fetch_add(msgs.iter().sum::<u32>(), Ordering::SeqCst);
                true
            } else {
                false
            }
        }
    }

    #[test]
    fn map_shuffle_reduce_roundtrip() {
        let job = SumJob { n: 100, total: AtomicU32::new(0) };
        let out = run_round(&job, job.n, 4, 8.0);
        assert_eq!(out.messages, 100);
        assert_eq!(out.changed, 1);
        assert_eq!(job.total.load(Ordering::SeqCst), (0..100).sum::<u32>());
        assert_eq!(out.work.map_records, 100.0);
        assert_eq!(out.work.shuffle_bytes, 800.0);
    }

    #[test]
    fn worker_count_does_not_change_semantics() {
        for workers in [1, 2, 7] {
            let job = SumJob { n: 57, total: AtomicU32::new(0) };
            run_round(&job, job.n, workers, 8.0);
            assert_eq!(
                job.total.load(Ordering::SeqCst),
                (0..57).sum::<u32>(),
                "workers {workers}"
            );
        }
    }
}
