//! Hadoop-shaped DFEP (paper §V-D) on the simulated cluster.
//!
//! The paper's implementation packs each DFEP iteration into a *single*
//! MapReduce round: Map runs per vertex (emitting funding messages and a
//! copy of the vertex), Reduce receives a vertex plus the funding sent on
//! common edges, and the per-edge auction is executed redundantly by both
//! endpoints with deterministic tie-breaking ("special care to make sure
//! that both executions will get the same results"). The K start edges
//! are chosen by a min-K selection job (random number per edge, combiner,
//! single reducer).
//!
//! Semantics here reuse the exact round functions of
//! [`crate::partition::dfep`] (so ownership results match the reference
//! implementation bit-for-bit); what this module adds is the *job shape*:
//! per-round MapReduce work volumes measured from the real state, fed to
//! the [`CostModel`] to produce simulated cluster wall-clock (Fig 8).

use super::cost::{CostModel, RoundWork};
use crate::graph::Graph;
use crate::partition::dfep::{finalize, DfepState, FREE};
use crate::partition::EdgePartition;
use crate::util::rng::Rng;

/// Bytes per shuffled funding message (vertex id + partition id + amount).
const MSG_BYTES: f64 = 16.0;
/// Bytes per vertex-copy record the Map phase re-emits (adjacency slice).
const VERTEX_COPY_BYTES: f64 = 24.0;

/// Result of a simulated cluster DFEP run.
#[derive(Clone, Debug)]
pub struct ClusterDfepRun {
    /// The partition produced (bit-identical to the reference engine).
    pub partition: EdgePartition,
    /// Simulated wall-clock per round (seconds) for the chosen node count.
    pub round_times: Vec<f64>,
    /// Total simulated wall-clock including start-edge selection.
    pub total_time: f64,
    /// Work volumes per round (node-count independent; reusable to
    /// re-simulate other cluster sizes).
    pub work: Vec<RoundWork>,
    /// Extra fixed rounds: the start-edge selection job.
    pub selection_time: f64,
}

/// The paper's start-edge selection: each edge draws a random number, the
/// K smallest win (combiner + single reducer in Hadoop; here: exact
/// deterministic equivalent).
pub fn select_start_edges(g: &Graph, k: usize, rng: &mut Rng) -> Vec<u32> {
    let m = g.edge_count();
    let mut draws: Vec<(u64, u32)> =
        (0..m as u32).map(|e| (rng.next_u64(), e)).collect();
    draws.sort_unstable();
    draws.truncate(k.min(m));
    draws.into_iter().map(|(_, e)| e).collect()
}

/// Run DFEP with the MapReduce job shape on `nodes` simulated workers.
pub fn run_cluster_dfep(
    g: &Graph,
    k: usize,
    nodes: usize,
    seed: u64,
    cost: &CostModel,
    max_rounds: usize,
) -> ClusterDfepRun {
    let mut rng = Rng::new(seed);
    let n = g.vertex_count();
    let m = g.edge_count();

    // --- selection job: one map over edges + combiner tree + 1 reducer ---
    let start_edges = select_start_edges(g, k, &mut rng);
    let selection_work = RoundWork {
        map_records: m as f64,
        shuffle_bytes: (nodes * k) as f64 * 12.0, // combiner output only
        reduce_records: (nodes * k) as f64,
            cpu_edge_ops: 0.0,
        };
    let selection_time = cost.round_time(nodes, selection_work);

    // --- DFEP rounds, work measured from real state ---
    let initial = (m as f64 / k as f64).max(1.0);
    let mut st = DfepState::new(g, k, initial, &mut rng);
    // seed funding on the selected edges' lower endpoints (the paper
    // starts from edges; the reference simulator starts from vertices —
    // the cluster version follows the paper's Hadoop description)
    st.money.clear();
    for h in st.holders.iter_mut() {
        h.clear();
    }
    for (i, &e) in start_edges.iter().enumerate() {
        let (u, _) = g.endpoints(e);
        st.credit(i % k, u as usize, initial);
    }

    let mut work = Vec::new();
    let mut round_times = Vec::new();
    let mut stall = 0usize;
    while st.free_edges > 0 && st.rounds < max_rounds {
        let before = st.free_edges;
        // funding messages this round: one per (partition, vertex with
        // cash, eligible edge) — measure before mutation
        let mut funding_msgs = 0usize;
        for i in 0..k {
            // cache-linear walk over partition i's flat ledger row
            let row = st.money.part(i);
            for v in 0..n as u32 {
                if row[v as usize] <= 0.0 {
                    continue;
                }
                funding_msgs += g
                    .neighbor_edges(v)
                    .iter()
                    .filter(|&&e| {
                        let o = st.owner[e as usize];
                        o == FREE || o == i as u32
                    })
                    .count();
            }
        }
        st.funding_round(g, None, None);
        st.coordinator_step(10.0);
        let w = RoundWork {
            map_records: n as f64,
            shuffle_bytes: funding_msgs as f64 * MSG_BYTES
                + n as f64 * VERTEX_COPY_BYTES,
            reduce_records: n as f64 + funding_msgs as f64,
            cpu_edge_ops: 0.0,
        };
        round_times.push(cost.round_time(nodes, w));
        work.push(w);
        if st.free_edges == before {
            stall += 1;
            if stall >= 3 {
                crate::partition::dfep::reseed_on_free_edge(
                    g, &mut st, &mut rng,
                );
                stall = 0;
            }
        } else {
            stall = 0;
        }
    }
    let rounds = st.rounds;
    let owner = finalize(g, st.owner, k);
    let total_time =
        selection_time + round_times.iter().sum::<f64>();
    ClusterDfepRun {
        partition: EdgePartition { k, owner, rounds },
        round_times,
        total_time,
        work,
        selection_time,
    }
}

/// Re-simulate an existing run's time at a different cluster size.
pub fn resimulate(
    run: &ClusterDfepRun,
    nodes: usize,
    cost: &CostModel,
) -> f64 {
    let sel = RoundWork {
        map_records: run.work.first().map(|w| w.map_records).unwrap_or(0.0),
        shuffle_bytes: 1e4,
        reduce_records: 1e3,
            cpu_edge_ops: 0.0,
        };
    cost.round_time(nodes, sel) + cost.job_time(nodes, &run.work)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::GraphKind;
    use crate::partition::metrics;

    fn g() -> Graph {
        GraphKind::PowerlawCluster { n: 500, m: 5, p: 0.3 }.generate(3)
    }

    #[test]
    fn produces_valid_partition() {
        let run =
            run_cluster_dfep(&g(), 8, 4, 1, &CostModel::default(), 1000);
        run.partition.validate(&g()).unwrap();
        assert!(run.total_time > 0.0);
        assert_eq!(run.round_times.len(), run.work.len());
    }

    #[test]
    fn start_edge_selection_is_k_distinct() {
        let g = g();
        let mut rng = Rng::new(4);
        let picks = select_start_edges(&g, 10, &mut rng);
        assert_eq!(picks.len(), 10);
        let set: std::collections::HashSet<_> = picks.iter().collect();
        assert_eq!(set.len(), 10);
    }

    #[test]
    fn more_nodes_reduce_simulated_time() {
        let g = g();
        let cost = CostModel::default();
        let run = run_cluster_dfep(&g, 16, 2, 2, &cost, 1000);
        let t2 = run.total_time;
        let t16 = resimulate(&run, 16, &cost);
        assert!(t16 < t2, "t2 {t2} t16 {t16}");
    }

    #[test]
    fn balance_comparable_to_reference_dfep() {
        let g = g();
        let run =
            run_cluster_dfep(&g, 8, 4, 5, &CostModel::default(), 1000);
        let nst = metrics::nstdev(&g, &run.partition);
        assert!(nst < 0.8, "cluster DFEP unbalanced: {nst}");
    }
}
