//! Versioned binary message schema for the distributed runtime.
//!
//! Every message is one `util::frame` payload:
//!
//! ```text
//! payload := version:u16  tag:u8  fields...      (all integers LE)
//! ```
//!
//! Encoding is hand-rolled (the vendored crate set has no serde): each
//! message variant has a fixed tag and a fixed field order, documented in
//! DESIGN.md "Distributed runtime". `f64` fields travel as raw IEEE-754
//! bits (`to_le_bytes`), so bid values round-trip bit-exactly — a
//! requirement for the owners-bit-identical determinism guarantee.
//!
//! Versioning: the `u16` prefix is checked on decode; a peer speaking a
//! different schema version is rejected with [`ErrorKind::Transport`]
//! before any field is interpreted. Bump [`PROTO_VERSION`] on any schema
//! change — coordinator and workers are always the same binary, so a
//! mismatch means a stale worker process from a previous build.

use crate::partition::dfep::Bid;
use crate::util::error::{Error, ErrorKind, Result};

/// Wire schema version (see module docs for the bump policy).
pub(crate) const PROTO_VERSION: u16 = 1;

/// Wire bytes of one encoded bid: edge `u32` + partition `u32` +
/// offer `f64` + from-lo `f64`.
pub(crate) const BID_WIRE_BYTES: usize = 24;

fn terr(msg: String) -> Error {
    Error::msg(msg).with_kind(ErrorKind::Transport)
}

/// Append-only little-endian encoder.
#[derive(Default)]
pub(crate) struct Enc {
    pub buf: Vec<u8>,
}

impl Enc {
    pub fn message(tag: u8) -> Enc {
        let mut e = Enc::default();
        e.u16(PROTO_VERSION);
        e.u8(tag);
        e
    }

    pub fn u8(&mut self, x: u8) {
        self.buf.push(x);
    }

    pub fn u16(&mut self, x: u16) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }

    pub fn u32(&mut self, x: u32) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }

    pub fn u64(&mut self, x: u64) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }

    pub fn i64(&mut self, x: i64) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }

    pub fn f64(&mut self, x: f64) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }

    pub fn vec_u32(&mut self, xs: &[u32]) {
        self.u32(xs.len() as u32);
        for &x in xs {
            self.u32(x);
        }
    }

    pub fn pairs_u32(&mut self, xs: &[(u32, u32)]) {
        self.u32(xs.len() as u32);
        for &(a, b) in xs {
            self.u32(a);
            self.u32(b);
        }
    }

    pub fn bids(&mut self, xs: &[Bid]) {
        self.u32(xs.len() as u32);
        for &(e, p, offer, from_lo) in xs {
            self.u32(e);
            self.u32(p);
            self.f64(offer);
            self.f64(from_lo);
        }
    }
}

/// Checked little-endian decoder over a borrowed payload.
pub(crate) struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    pub fn new(buf: &'a [u8]) -> Dec<'a> {
        Dec { buf, pos: 0 }
    }

    /// Read and check the version prefix, returning the message tag.
    pub fn message(buf: &'a [u8]) -> Result<(u8, Dec<'a>)> {
        let mut d = Dec::new(buf);
        let v = d.u16()?;
        if v != PROTO_VERSION {
            return Err(terr(format!(
                "protocol version mismatch: got {v}, want {PROTO_VERSION}"
            )));
        }
        let tag = d.u8()?;
        Ok((tag, d))
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.buf.len() - self.pos < n {
            return Err(terr(format!(
                "truncated message: need {n} bytes at offset {}, have {}",
                self.pos,
                self.buf.len() - self.pos
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn i64(&mut self) -> Result<i64> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Length-prefixed count with a sanity cap against corrupt frames:
    /// each element needs at least `elem_bytes` more bytes in the buffer.
    fn count(&mut self, elem_bytes: usize) -> Result<usize> {
        let len = self.u32()? as usize;
        if len * elem_bytes > self.buf.len() - self.pos {
            return Err(terr(format!(
                "corrupt length {len}: exceeds remaining payload"
            )));
        }
        Ok(len)
    }

    pub fn vec_u32(&mut self) -> Result<Vec<u32>> {
        let len = self.count(4)?;
        let mut out = Vec::with_capacity(len);
        for _ in 0..len {
            out.push(self.u32()?);
        }
        Ok(out)
    }

    pub fn pairs_u32(&mut self) -> Result<Vec<(u32, u32)>> {
        let len = self.count(8)?;
        let mut out = Vec::with_capacity(len);
        for _ in 0..len {
            out.push((self.u32()?, self.u32()?));
        }
        Ok(out)
    }

    pub fn bids(&mut self) -> Result<Vec<Bid>> {
        let len = self.count(BID_WIRE_BYTES)?;
        let mut out = Vec::with_capacity(len);
        for _ in 0..len {
            out.push((self.u32()?, self.u32()?, self.f64()?, self.f64()?));
        }
        Ok(out)
    }

    /// Assert the payload was fully consumed (schema drift tripwire).
    pub fn done(&self) -> Result<()> {
        if self.pos != self.buf.len() {
            return Err(terr(format!(
                "{} trailing bytes after message",
                self.buf.len() - self.pos
            )));
        }
        Ok(())
    }
}

/// Worker bootstrap: everything a (re)spawned worker needs to rebuild
/// the graph and its replica of the run state.
#[derive(Clone, Debug, PartialEq)]
pub(crate) struct InitMsg {
    /// This worker's rank in `0..workers` (owns partitions `i % workers
    /// == rank`).
    pub rank: u32,
    /// Total worker count.
    pub workers: u32,
    /// Partition count.
    pub k: u32,
    /// DFEP run seed (every replica seeds the same rng stream).
    pub seed: u64,
    /// `Dfep::funding_cap`.
    pub cap: f64,
    /// `Dfep::initial_fraction`.
    pub init_frac: f64,
    /// `Dfep::frontier_first`.
    pub frontier_first: bool,
    /// Failure injection: round at which to die, `-1` = never.
    pub fail_round: i64,
    /// Stall this long before dying (`0` = drop the connection at once).
    pub fail_stall_ms: u64,
    /// Vertex count (the edge list alone loses trailing isolated ids).
    pub n: u32,
    /// Canonical (sorted, deduplicated, `u < v`) edge list — rebuilding
    /// through `GraphBuilder` reproduces identical edge ids.
    pub edges: Vec<(u32, u32)>,
}

/// Coordinator → worker messages.
#[derive(Clone, Debug, PartialEq)]
pub(crate) enum CoordMsg {
    /// Bootstrap (tag 1).
    Init(InitMsg),
    /// Begin round `round`; run the stall reseed first when `reseed`
    /// (tag 2).
    StartRound { round: u64, reseed: bool },
    /// The stitched global bid list for round `round` (tag 3).
    Bids { round: u64, bids: Vec<Bid> },
    /// Request a checkpoint blob of the current state (tag 4).
    Snapshot { round: u64 },
    /// Overwrite state from a checkpoint blob (tag 5).
    Restore { blob: Vec<u8> },
    /// Flush stale in-flight replies; worker echoes the token (tag 6).
    Barrier { token: u64 },
    /// Request the pre-finalize ownership vector (tag 7).
    FetchOwners,
    /// Enter the ETSCH SSSP phase on the finalized partition (tag 8).
    SsspStart { source: u32, owner: Vec<u32> },
    /// One SSSP superstep: globally-improved `(vertex, dist)` pairs
    /// (tag 9).
    SsspStep { step: u64, updates: Vec<(u32, u32)> },
    /// Clean shutdown (tag 10).
    Shutdown,
}

/// Worker → coordinator messages.
#[derive(Clone, Debug, PartialEq)]
pub(crate) enum WorkerMsg {
    /// Bootstrap complete (tag 1).
    Ready { rank: u32 },
    /// Bids from this worker's owned partitions, canonical partition-major
    /// order (tag 2).
    Bids { round: u64, bids: Vec<Bid> },
    /// Round complete; `owner_hash` is an FNV-1a digest of the replicated
    /// ownership vector, used as a replica-divergence tripwire (tag 3).
    RoundDone { round: u64, free_edges: u64, owner_hash: u64 },
    /// Checkpoint blob (tag 4).
    Snapshot { round: u64, blob: Vec<u8> },
    /// Echo of [`CoordMsg::Barrier`] (tag 5).
    BarrierAck { token: u64 },
    /// Pre-finalize ownership vector (tag 6).
    Owners { owner: Vec<u32> },
    /// Locally-improved `(vertex, dist)` pairs from one SSSP superstep
    /// (tag 7).
    SsspDelta { step: u64, updates: Vec<(u32, u32)> },
}

impl CoordMsg {
    pub fn encode(&self) -> Vec<u8> {
        match self {
            CoordMsg::Init(m) => {
                let mut e = Enc::message(1);
                e.u32(m.rank);
                e.u32(m.workers);
                e.u32(m.k);
                e.u64(m.seed);
                e.f64(m.cap);
                e.f64(m.init_frac);
                e.u8(m.frontier_first as u8);
                e.i64(m.fail_round);
                e.u64(m.fail_stall_ms);
                e.u32(m.n);
                e.pairs_u32(&m.edges);
                e.buf
            }
            CoordMsg::StartRound { round, reseed } => {
                let mut e = Enc::message(2);
                e.u64(*round);
                e.u8(*reseed as u8);
                e.buf
            }
            CoordMsg::Bids { round, bids } => {
                let mut e = Enc::message(3);
                e.u64(*round);
                e.bids(bids);
                e.buf
            }
            CoordMsg::Snapshot { round } => {
                let mut e = Enc::message(4);
                e.u64(*round);
                e.buf
            }
            CoordMsg::Restore { blob } => {
                let mut e = Enc::message(5);
                e.u32(blob.len() as u32);
                e.buf.extend_from_slice(blob);
                e.buf
            }
            CoordMsg::Barrier { token } => {
                let mut e = Enc::message(6);
                e.u64(*token);
                e.buf
            }
            CoordMsg::FetchOwners => Enc::message(7).buf,
            CoordMsg::SsspStart { source, owner } => {
                let mut e = Enc::message(8);
                e.u32(*source);
                e.vec_u32(owner);
                e.buf
            }
            CoordMsg::SsspStep { step, updates } => {
                let mut e = Enc::message(9);
                e.u64(*step);
                e.pairs_u32(updates);
                e.buf
            }
            CoordMsg::Shutdown => Enc::message(10).buf,
        }
    }

    pub fn decode(buf: &[u8]) -> Result<CoordMsg> {
        let (tag, mut d) = Dec::message(buf)?;
        let msg = match tag {
            1 => CoordMsg::Init(InitMsg {
                rank: d.u32()?,
                workers: d.u32()?,
                k: d.u32()?,
                seed: d.u64()?,
                cap: d.f64()?,
                init_frac: d.f64()?,
                frontier_first: d.u8()? != 0,
                fail_round: d.i64()?,
                fail_stall_ms: d.u64()?,
                n: d.u32()?,
                edges: d.pairs_u32()?,
            }),
            2 => CoordMsg::StartRound {
                round: d.u64()?,
                reseed: d.u8()? != 0,
            },
            3 => CoordMsg::Bids { round: d.u64()?, bids: d.bids()? },
            4 => CoordMsg::Snapshot { round: d.u64()? },
            5 => {
                let len = d.count(1)?;
                CoordMsg::Restore { blob: d.take(len)?.to_vec() }
            }
            6 => CoordMsg::Barrier { token: d.u64()? },
            7 => CoordMsg::FetchOwners,
            8 => CoordMsg::SsspStart {
                source: d.u32()?,
                owner: d.vec_u32()?,
            },
            9 => CoordMsg::SsspStep {
                step: d.u64()?,
                updates: d.pairs_u32()?,
            },
            10 => CoordMsg::Shutdown,
            t => return Err(terr(format!("unknown coordinator tag {t}"))),
        };
        d.done()?;
        Ok(msg)
    }
}

impl WorkerMsg {
    pub fn encode(&self) -> Vec<u8> {
        match self {
            WorkerMsg::Ready { rank } => {
                let mut e = Enc::message(1);
                e.u32(*rank);
                e.buf
            }
            WorkerMsg::Bids { round, bids } => {
                let mut e = Enc::message(2);
                e.u64(*round);
                e.bids(bids);
                e.buf
            }
            WorkerMsg::RoundDone { round, free_edges, owner_hash } => {
                let mut e = Enc::message(3);
                e.u64(*round);
                e.u64(*free_edges);
                e.u64(*owner_hash);
                e.buf
            }
            WorkerMsg::Snapshot { round, blob } => {
                let mut e = Enc::message(4);
                e.u64(*round);
                e.u32(blob.len() as u32);
                e.buf.extend_from_slice(blob);
                e.buf
            }
            WorkerMsg::BarrierAck { token } => {
                let mut e = Enc::message(5);
                e.u64(*token);
                e.buf
            }
            WorkerMsg::Owners { owner } => {
                let mut e = Enc::message(6);
                e.vec_u32(owner);
                e.buf
            }
            WorkerMsg::SsspDelta { step, updates } => {
                let mut e = Enc::message(7);
                e.u64(*step);
                e.pairs_u32(updates);
                e.buf
            }
        }
    }

    pub fn decode(buf: &[u8]) -> Result<WorkerMsg> {
        let (tag, mut d) = Dec::message(buf)?;
        let msg = match tag {
            1 => WorkerMsg::Ready { rank: d.u32()? },
            2 => WorkerMsg::Bids { round: d.u64()?, bids: d.bids()? },
            3 => WorkerMsg::RoundDone {
                round: d.u64()?,
                free_edges: d.u64()?,
                owner_hash: d.u64()?,
            },
            4 => {
                let round = d.u64()?;
                let len = d.count(1)?;
                WorkerMsg::Snapshot { round, blob: d.take(len)?.to_vec() }
            }
            5 => WorkerMsg::BarrierAck { token: d.u64()? },
            6 => WorkerMsg::Owners { owner: d.vec_u32()? },
            7 => WorkerMsg::SsspDelta {
                step: d.u64()?,
                updates: d.pairs_u32()?,
            },
            t => return Err(terr(format!("unknown worker tag {t}"))),
        };
        d.done()?;
        Ok(msg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_coord(m: CoordMsg) {
        let buf = m.encode();
        assert_eq!(CoordMsg::decode(&buf).unwrap(), m);
    }

    fn roundtrip_worker(m: WorkerMsg) {
        let buf = m.encode();
        assert_eq!(WorkerMsg::decode(&buf).unwrap(), m);
    }

    #[test]
    fn every_message_roundtrips() {
        roundtrip_coord(CoordMsg::Init(InitMsg {
            rank: 2,
            workers: 3,
            k: 8,
            seed: 42,
            cap: 10.0,
            init_frac: 1.0,
            frontier_first: true,
            fail_round: -1,
            fail_stall_ms: 0,
            n: 5,
            edges: vec![(0, 1), (1, 2), (3, 4)],
        }));
        roundtrip_coord(CoordMsg::StartRound { round: 7, reseed: true });
        roundtrip_coord(CoordMsg::Bids {
            round: 7,
            bids: vec![(3, 1, 2.5, 1.25), (9, 0, 0.1, 0.0)],
        });
        roundtrip_coord(CoordMsg::Snapshot { round: 4 });
        roundtrip_coord(CoordMsg::Restore { blob: vec![1, 2, 3, 0, 255] });
        roundtrip_coord(CoordMsg::Barrier { token: 99 });
        roundtrip_coord(CoordMsg::FetchOwners);
        roundtrip_coord(CoordMsg::SsspStart {
            source: 3,
            owner: vec![0, 1, 2, 1],
        });
        roundtrip_coord(CoordMsg::SsspStep {
            step: 2,
            updates: vec![(4, 1), (7, 2)],
        });
        roundtrip_coord(CoordMsg::Shutdown);
        roundtrip_worker(WorkerMsg::Ready { rank: 1 });
        roundtrip_worker(WorkerMsg::Bids {
            round: 3,
            bids: vec![(0, 0, 1.0, 0.5)],
        });
        roundtrip_worker(WorkerMsg::RoundDone {
            round: 3,
            free_edges: 17,
            owner_hash: 0xDEADBEEF,
        });
        roundtrip_worker(WorkerMsg::Snapshot { round: 4, blob: vec![9; 40] });
        roundtrip_worker(WorkerMsg::BarrierAck { token: 99 });
        roundtrip_worker(WorkerMsg::Owners { owner: vec![1, 1, 0] });
        roundtrip_worker(WorkerMsg::SsspDelta {
            step: 5,
            updates: vec![(2, 3)],
        });
    }

    #[test]
    fn bids_roundtrip_bit_exactly() {
        // adversarial f64 values: subnormal, negative zero, huge
        let bids = vec![
            (1u32, 2u32, f64::MIN_POSITIVE / 2.0, -0.0),
            (2, 3, 1e300, 1.0 / 3.0),
        ];
        let m = CoordMsg::Bids { round: 1, bids: bids.clone() };
        let CoordMsg::Bids { bids: got, .. } =
            CoordMsg::decode(&m.encode()).unwrap()
        else {
            panic!("wrong variant");
        };
        for (a, b) in bids.iter().zip(&got) {
            assert_eq!(a.2.to_bits(), b.2.to_bits());
            assert_eq!(a.3.to_bits(), b.3.to_bits());
        }
    }

    #[test]
    fn version_and_corruption_are_transport_errors() {
        use crate::util::error::ErrorKind;
        let mut buf = CoordMsg::Shutdown.encode();
        buf[0] = 0xFF; // mangle the version
        let e = CoordMsg::decode(&buf).unwrap_err();
        assert_eq!(e.kind(), ErrorKind::Transport);
        // truncation
        let buf = CoordMsg::Barrier { token: 1 }.encode();
        let e = CoordMsg::decode(&buf[..buf.len() - 1]).unwrap_err();
        assert_eq!(e.kind(), ErrorKind::Transport);
        // trailing garbage
        let mut buf = CoordMsg::FetchOwners.encode();
        buf.push(0);
        assert_eq!(
            CoordMsg::decode(&buf).unwrap_err().kind(),
            ErrorKind::Transport
        );
        // unknown tag
        let mut buf = CoordMsg::Shutdown.encode();
        buf[2] = 200;
        assert_eq!(
            CoordMsg::decode(&buf).unwrap_err().kind(),
            ErrorKind::Transport
        );
        // corrupt length prefix larger than the payload
        let mut e = Enc::message(6);
        e.u32(u32::MAX); // Barrier expects a u64 token; claim a huge body
        assert!(CoordMsg::decode(&e.buf).is_err());
    }
}
