//! Tiny CLI argument parser (the vendored crate set has no `clap`).
//!
//! Grammar: `repro <command> [--key value]... [--flag]...`
//! Unknown keys are errors; every command documents its keys in `repro
//! help`.

use std::collections::BTreeMap;

use crate::util::error::Result;
use crate::{anyhow, bail};

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// The subcommand (first argument; `"help"` when absent).
    pub command: String,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Args> {
        let mut it = args.into_iter().peekable();
        let command = it.next().unwrap_or_else(|| "help".to_string());
        let mut out = Args { command, ..Default::default() };
        while let Some(a) = it.next() {
            let Some(key) = a.strip_prefix("--") else {
                bail!("unexpected positional argument '{a}'");
            };
            // --key=value or --key value or --flag
            if let Some((k, v)) = key.split_once('=') {
                out.opts.insert(k.to_string(), v.to_string());
            } else if let Some(v) = it.next_if(|n| !n.starts_with("--")) {
                out.opts.insert(key.to_string(), v);
            } else {
                out.flags.push(key.to_string());
            }
        }
        Ok(out)
    }

    /// Value of `--key`, if given.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    /// Value of `--key`, or `default` when absent.
    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    /// `--key` parsed as usize (error on malformed, `default` if absent).
    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key}: bad integer '{v}'")),
        }
    }

    /// `--key` parsed as u64 (error on malformed, `default` if absent).
    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => {
                v.parse().map_err(|_| anyhow!("--{key}: bad integer '{v}'"))
            }
        }
    }

    /// `--key` parsed as f64 (error on malformed, `default` if absent).
    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => {
                v.parse().map_err(|_| anyhow!("--{key}: bad float '{v}'"))
            }
        }
    }

    /// True when the bare `--name` flag was passed.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<Args> {
        Args::parse(s.split_whitespace().map(str::to_string))
    }

    #[test]
    fn commands_opts_flags() {
        let a = parse("partition --graph astroph --k 20 --verbose").unwrap();
        assert_eq!(a.command, "partition");
        assert_eq!(a.get("graph"), Some("astroph"));
        assert_eq!(a.get_usize("k", 0).unwrap(), 20);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn equals_form() {
        let a = parse("run --k=7 --frac=0.5").unwrap();
        assert_eq!(a.get_usize("k", 0).unwrap(), 7);
        assert_eq!(a.get_f64("frac", 0.0).unwrap(), 0.5);
    }

    #[test]
    fn defaults_and_errors() {
        let a = parse("run").unwrap();
        assert_eq!(a.get_usize("k", 42).unwrap(), 42);
        let a = parse("run --k abc").unwrap();
        assert!(a.get_usize("k", 0).is_err());
        assert!(parse("run positional").is_err());
    }

    #[test]
    fn empty_is_help() {
        let a = Args::parse(Vec::<String>::new()).unwrap();
        assert_eq!(a.command, "help");
    }
}
