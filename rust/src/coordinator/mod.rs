//! Leader/coordinator: run configuration, orchestration of partition +
//! process phases, and the CLI surface of the `repro` binary.

pub mod batch;
pub mod cli;
pub mod runs;
pub mod serve;

pub use batch::{BatchReport, BatchRequest, SharedPrep, Variant};
pub use runs::{PartitionRequest, RunReport, Timings, Workload};
pub use serve::{ServeClient, ServeConfig, Server};
