//! Partitioning-as-a-service: the long-running `repro serve` HTTP server.
//!
//! The paper frames edge partitioning as a *preprocessing service* for
//! downstream graph processing; this module is that service. A
//! [`Server`] holds resolved graphs and computed [`RunReport`]s warm in
//! memory and answers `PartitionRequest`-shaped JSON over hand-rolled
//! HTTP/1.1 ([`crate::util::http`], std-only — no server framework in
//! the vendored crate set). See DESIGN.md "Serving layer" for the full
//! endpoint table, wire schema and shedding policy.
//!
//! ## Endpoints
//!
//! - `POST /partition` — body: [`PartitionRequest::to_json`] (`"v": 1`);
//!   response: [`RunReport::to_json`] (append `?owners=1` for the
//!   per-edge ownership array).
//! - `POST /batch` — body: [`BatchRequest::to_json`] (`"v": 1`);
//!   response: [`BatchReport::to_json`]. The graph resolves once, every
//!   variant is looked up in the result cache individually, and only the
//!   misses run — as one batch-engine invocation fanned over the ambient
//!   pool lanes. Computed variants land in the cache, so a follow-up
//!   `POST /partition` for any of them is a hit.
//! - `GET /healthz` — liveness probe.
//! - `GET /stats` — flat JSON counters: cache hit rate, in-flight count,
//!   shed counts, per-endpoint latency, and graph-resolve latency
//!   (`resolve_count` / `resolve_mean_ms` / `resolve_max_ms`) so cold-path
//!   `POST /partition` p99s are attributable to dataset resolution
//!   rather than partitioning.
//!
//! ## Result cache + single flight
//!
//! Results are cached under [`cache_key`] — dataset, graph seed, the
//! *canonical* spec form ([`crate::partition::spec::PartitionerSpec::canonical`]), `k`, run
//! seed, gain samples and workload — so every spelling of the same
//! experiment (`hdrf` vs `hdrf:lambda=1.1`, alias vs canonical name)
//! hits one entry. The `threads` override is deliberately excluded:
//! reports are bit-identical across pool widths (pinned by the pool
//! invariants test). Concurrent identical requests are *single-flight*:
//! the first computes, the rest block on the entry and are served the
//! same `Arc`'d report; the `computations` probe counter on `/stats`
//! pins this in the serving integration test. Failed computations are
//! not cached — the entry is removed so a later retry recomputes.
//!
//! ## Shedding
//!
//! Bounded queues and bodies, never unbounded growth: a full accept
//! queue answers 503 immediately, a body over the limit answers 413 and
//! closes, more than `max_compute` distinct in-flight computations
//! answers 429 ([`ErrorKind::Busy`]), and a request that waits longer
//! than the per-request timeout on someone else's computation answers
//! 503 ([`ErrorKind::Overloaded`]). The computation itself is not
//! preempted (it is useful work; its result lands in the cache). A
//! panicking handler answers 500 and wakes any single-flight waiters.
//!
//! ## Threading
//!
//! The server runs on its *own* [`ThreadPool`] — shard 0 is the accept
//! loop, shards 1..=workers the connection workers — while request
//! execution fans out through the ambient global pool. Nesting `run` on
//! one pool deadlocks (see `util::pool`), so the two pools must stay
//! distinct.
//!
//! ## Fault plane
//!
//! [`ServeConfig::fault`] arms a seeded
//! [`FaultPlan`](crate::util::fault::FaultPlan) per accepted
//! connection (tagged in dequeue order) over the server-side HTTP
//! read/write paths. A corrupt request body (digest mismatch) answers
//! 503 with kind [`ErrorKind::Transport`] and closes; injected read
//! and write failures drop the connection. [`ServeClient`] retries
//! transport-level failures — and 503 responses carrying kind
//! `transport` — with deterministic jittered exponential backoff
//! ([`RetryPolicy`]), while shed signals (`overloaded`, `busy`) pass
//! through untouched. Fired-fault and corrupt-request counters ride on
//! `/stats` (`fault_*`, `transport_corrupt`).

use std::collections::{HashMap, VecDeque};
use std::io::{BufRead, BufReader};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use crate::anyhow;
use crate::bench::harness::JsonSink;
use crate::coordinator::batch::{BatchReport, BatchRequest, SharedPrep};
use crate::coordinator::runs::{resolve_graph, PartitionRequest, RunReport};
use crate::graph::Graph;
use crate::util::error::{ErrorKind, Result};
use crate::util::fault::{FaultArm, FaultCounters, FaultPlan, RetryPolicy};
use crate::util::http::{self, Request, WireError};
use crate::util::pool::ThreadPool;
use crate::util::rng::Rng;
use crate::util::timer::LatencyStat;

/// The documented [`ErrorKind`] → HTTP status mapping (DESIGN.md
/// "Serving layer"). Exhaustive by construction; the unit test walks
/// [`ErrorKind::ALL`] against the documented table.
pub fn status_for(kind: ErrorKind) -> u16 {
    match kind {
        ErrorKind::InvalidSpec => 400,
        ErrorKind::InvalidRequest => 400,
        ErrorKind::DatasetNotFound => 404,
        ErrorKind::Busy => 429,
        ErrorKind::Overloaded => 503,
        ErrorKind::Io => 500,
        ErrorKind::Transport => 503,
        ErrorKind::Internal => 500,
    }
}

/// The result-cache key of a request: every field that affects the
/// report, with the spec in canonical form so spelling variants collide
/// (`threads` excluded — reports are thread-count invariant).
pub fn cache_key(req: &PartitionRequest) -> String {
    use crate::coordinator::runs::Workload;
    let workload = match req.workload {
        None => "-".to_string(),
        Some(Workload::Sssp { source }) => format!("sssp:{source}"),
    };
    format!(
        "{}|{}|{}|{}|{}|{}|{}",
        req.dataset,
        req.graph_seed,
        req.spec.canonical(),
        req.k,
        req.seed,
        req.gain_samples,
        workload,
    )
}

/// Everything tunable about a [`Server`], with production-ish defaults.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Bind address (`127.0.0.1:7411`; port 0 picks an ephemeral port).
    pub addr: String,
    /// Connection-worker threads (the accept loop rides on one more).
    pub workers: usize,
    /// Largest accepted request body in bytes (413 beyond).
    pub max_body_bytes: usize,
    /// Accepted-connection queue bound (503 beyond).
    pub max_queue: usize,
    /// Distinct in-flight computations bound (429 beyond).
    pub max_compute: usize,
    /// Per-request budget in seconds: the read timeout per socket read,
    /// and the longest a request waits on another request's in-flight
    /// computation before shedding with 503.
    pub request_timeout_s: f64,
    /// Result-cache capacity in entries (FIFO eviction beyond).
    pub cache_capacity: usize,
    /// Resolved-graph cache capacity in entries (FIFO eviction beyond).
    pub graph_capacity: usize,
    /// Seeded fault plan armed per accepted connection over the HTTP
    /// read/write paths (`None` = zero-overhead clean serving).
    pub fault: Option<FaultPlan>,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            addr: "127.0.0.1:7411".to_string(),
            workers: 4,
            max_body_bytes: 1 << 20,
            max_queue: 128,
            max_compute: 8,
            request_timeout_s: 30.0,
            cache_capacity: 256,
            graph_capacity: 8,
            fault: None,
        }
    }
}

/// One single-flight cache slot.
enum Flight {
    /// Someone is computing this key; wait on the cache condvar.
    InFlight,
    /// Computed; served by `Arc` clone.
    Done(Arc<RunReport>),
}

/// Result cache: single-flight map + FIFO eviction order over the
/// completed entries + in-flight count for the 429 bound.
#[derive(Default)]
struct Cache {
    map: HashMap<String, Flight>,
    order: VecDeque<String>,
    in_flight: usize,
}

/// Resolved-graph cache, FIFO-bounded like the result cache.
#[derive(Default)]
struct GraphCache {
    map: HashMap<(String, u64), Arc<Graph>>,
    order: VecDeque<(String, u64)>,
}

/// Monotonic serving counters, all exposed on `/stats`.
#[derive(Default)]
struct Counters {
    requests: AtomicUsize,
    in_flight: AtomicUsize,
    cache_hits: AtomicUsize,
    cache_misses: AtomicUsize,
    computations: AtomicUsize,
    shed_queue_full: AtomicUsize,
    shed_body_too_large: AtomicUsize,
    shed_timeout: AtomicUsize,
    shed_busy: AtomicUsize,
    /// Requests rejected because the body digest did not verify
    /// (real corruption or an injected fault) — answered 503
    /// `transport`, which well-behaved clients retry.
    transport_corrupt: AtomicUsize,
    responses_4xx: AtomicUsize,
    responses_5xx: AtomicUsize,
    latency: Mutex<[LatencyStat; ENDPOINTS.len()]>,
    /// Graph-resolution latency alone (satellite of the endpoint
    /// latencies): cold `POST /partition` and `POST /batch` responses
    /// include dataset generation/scaling time, and this stat is what
    /// separates that from partitioning when reading `/stats`.
    resolve: Mutex<LatencyStat>,
}

const ENDPOINTS: [&str; 5] = ["partition", "batch", "healthz", "stats", "other"];

fn endpoint_index(path: &str) -> usize {
    match path {
        "/partition" => 0,
        "/batch" => 1,
        "/healthz" => 2,
        "/stats" => 3,
        _ => 4,
    }
}

/// Recover a mutex guard even if a panicking holder poisoned it (the
/// serving loops must outlive any one bad request).
fn relock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

struct Inner {
    cfg: ServeConfig,
    listener: TcpListener,
    local_addr: SocketAddr,
    stop: AtomicBool,
    queue: Mutex<VecDeque<TcpStream>>,
    queue_cv: Condvar,
    cache: Mutex<Cache>,
    cache_cv: Condvar,
    graphs: Mutex<GraphCache>,
    stats: Counters,
    /// Fired-fault tallies across every connection arm.
    fault_counters: Arc<FaultCounters>,
    /// Connection dequeue counter — the fault-arm tag, so each
    /// connection draws its own deterministic fault stream.
    conn_seq: AtomicU64,
}

/// The `repro serve` server. Cheap to clone (shared state behind an
/// `Arc`); [`bind`](Server::bind) then either [`serve`](Server::serve)
/// on the current thread or [`spawn`](Server::spawn) a handle.
#[derive(Clone)]
pub struct Server {
    inner: Arc<Inner>,
}

impl Server {
    /// Bind the listener (kind [`ErrorKind::Io`] on failure). No worker
    /// runs until [`serve`](Self::serve).
    pub fn bind(cfg: ServeConfig) -> Result<Server> {
        let listener = TcpListener::bind(&cfg.addr).map_err(|e| {
            anyhow!("bind {}: {e}", cfg.addr).with_kind(ErrorKind::Io)
        })?;
        listener.set_nonblocking(true).map_err(|e| {
            anyhow!("set_nonblocking: {e}").with_kind(ErrorKind::Io)
        })?;
        let local_addr = listener
            .local_addr()
            .map_err(|e| anyhow!("local_addr: {e}").with_kind(ErrorKind::Io))?;
        Ok(Server {
            inner: Arc::new(Inner {
                cfg,
                listener,
                local_addr,
                stop: AtomicBool::new(false),
                queue: Mutex::new(VecDeque::new()),
                queue_cv: Condvar::new(),
                cache: Mutex::new(Cache::default()),
                cache_cv: Condvar::new(),
                graphs: Mutex::new(GraphCache::default()),
                stats: Counters::default(),
                fault_counters: FaultCounters::shared(),
                conn_seq: AtomicU64::new(0),
            }),
        })
    }

    /// The bound address (resolves port 0 to the real ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.inner.local_addr
    }

    /// Ask every loop to exit; [`serve`](Self::serve) returns shortly
    /// after (bounded by one accept/read poll interval).
    pub fn stop(&self) {
        self.inner.stop.store(true, Ordering::SeqCst);
        self.inner.queue_cv.notify_all();
    }

    /// Run the accept loop + connection workers until
    /// [`stop`](Self::stop) is called. Blocks the calling thread. The server
    /// runs on a dedicated pool; request execution uses the ambient
    /// global pool (never nest the two — see `util::pool`).
    pub fn serve(&self) {
        let shards = self.inner.cfg.workers.max(1) + 1;
        let pool = ThreadPool::new(shards);
        let inner = &self.inner;
        pool.run(shards, &|i| {
            if i == 0 {
                inner.accept_loop();
            } else {
                inner.worker_loop();
            }
        });
    }

    /// [`bind`](Self::bind) + [`serve`](Self::serve) on a background
    /// thread; the returned handle stops and joins the server on drop
    /// (used by the tests, the load bench and embedding callers).
    pub fn spawn(cfg: ServeConfig) -> Result<ServeHandle> {
        let server = Server::bind(cfg)?;
        let runner = server.clone();
        let thread = std::thread::Builder::new()
            .name("repro-serve".to_string())
            .spawn(move || runner.serve())
            .map_err(|e| anyhow!("spawn serve: {e}").with_kind(ErrorKind::Io))?;
        Ok(ServeHandle { server, thread: Some(thread) })
    }
}

/// A running [`Server`] on a background thread. Stops and joins on drop.
pub struct ServeHandle {
    server: Server,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl ServeHandle {
    /// The bound address of the running server.
    pub fn addr(&self) -> SocketAddr {
        self.server.addr()
    }

    /// Stop the server and wait for its loops to exit.
    pub fn stop(&mut self) {
        self.server.stop();
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for ServeHandle {
    fn drop(&mut self) {
        self.stop();
    }
}

/// The accept/worker poll interval: how long a stop request can go
/// unnoticed, and the idle granularity of keep-alive connections.
const POLL: Duration = Duration::from_millis(100);

impl Inner {
    fn accept_loop(&self) {
        while !self.stop.load(Ordering::SeqCst) {
            match self.listener.accept() {
                Ok((stream, _peer)) => self.enqueue(stream),
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(POLL);
                }
                Err(_) => std::thread::sleep(POLL),
            }
        }
        self.queue_cv.notify_all();
    }

    /// Queue an accepted connection, or shed it with an inline 503 when
    /// the queue is at its bound.
    fn enqueue(&self, stream: TcpStream) {
        {
            let mut q = relock(&self.queue);
            if q.len() < self.cfg.max_queue {
                q.push_back(stream);
                self.queue_cv.notify_one();
                return;
            }
        }
        self.stats.shed_queue_full.fetch_add(1, Ordering::SeqCst);
        let _ = stream.set_nonblocking(false);
        let _ = stream.set_write_timeout(Some(Duration::from_secs(1)));
        let mut w = &stream;
        let body = error_body("connection queue full; retry later", ErrorKind::Overloaded);
        let _ = http::write_response(&mut w, 503, body.as_bytes(), false);
    }

    fn worker_loop(&self) {
        loop {
            let stream = {
                let mut q = relock(&self.queue);
                loop {
                    if let Some(s) = q.pop_front() {
                        break Some(s);
                    }
                    if self.stop.load(Ordering::SeqCst) {
                        break None;
                    }
                    let (qq, _timeout) = self
                        .queue_cv
                        .wait_timeout(q, POLL)
                        .unwrap_or_else(|p| p.into_inner());
                    q = qq;
                }
            };
            match stream {
                Some(s) => self.handle_connection(s),
                None => return,
            }
        }
    }

    /// Serve one keep-alive connection until close, error, stop, or a
    /// shedding condition that requires dropping the stream.
    fn handle_connection(&self, stream: TcpStream) {
        if stream.set_nonblocking(false).is_err() {
            return;
        }
        let _ = stream.set_nodelay(true);
        let _ = stream.set_read_timeout(Some(POLL));
        let _ = stream.set_write_timeout(Some(Duration::from_secs(10)));
        let Ok(read_half) = stream.try_clone() else {
            return;
        };
        let mut arm = self.cfg.fault.as_ref().map(|p| {
            let tag = self.conn_seq.fetch_add(1, Ordering::SeqCst);
            p.arm(tag, Arc::clone(&self.fault_counters))
        });
        let mut reader = BufReader::new(read_half);
        let mut writer = stream;
        let per_read = Duration::from_secs_f64(self.cfg.request_timeout_s.max(0.05));
        loop {
            // idle poll: wait for the next request's first byte with a
            // short timeout so stop() stays responsive on idle
            // keep-alive connections
            match reader.fill_buf() {
                Ok(buf) if buf.is_empty() => return, // peer closed
                Ok(_) => {}
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock
                            | std::io::ErrorKind::TimedOut
                            | std::io::ErrorKind::Interrupted
                    ) =>
                {
                    if self.stop.load(Ordering::SeqCst) {
                        return;
                    }
                    continue;
                }
                Err(_) => return,
            }
            // bytes are waiting: switch to the real per-read budget for
            // the span of this request
            let _ = reader.get_ref().set_read_timeout(Some(per_read));
            let outcome = http::read_request_with(
                &mut reader,
                self.cfg.max_body_bytes,
                arm.as_mut(),
            );
            let _ = reader.get_ref().set_read_timeout(Some(POLL));
            match outcome {
                Ok(None) => return,
                Ok(Some(req)) => {
                    if !self.respond(&req, &mut writer, arm.as_mut()) {
                        return;
                    }
                    if !req.keep_alive || self.stop.load(Ordering::SeqCst) {
                        return;
                    }
                }
                Err(WireError::TooLarge) => {
                    self.stats.shed_body_too_large.fetch_add(1, Ordering::SeqCst);
                    // drain (bounded) what the client already sent, so
                    // closing the socket with unread bytes in the receive
                    // buffer does not RST the 413 off the wire; truly
                    // huge bodies still get cut off mid-send
                    let mut scratch = [0u8; 4096];
                    let mut drained = 0usize;
                    while drained < 64 * 1024 {
                        match std::io::Read::read(&mut reader, &mut scratch) {
                            Ok(0) | Err(_) => break,
                            Ok(n) => drained += n,
                        }
                    }
                    let body = error_body(
                        &format!("request exceeds {} bytes", self.cfg.max_body_bytes),
                        ErrorKind::InvalidRequest,
                    );
                    let _ = http::write_response(&mut writer, 413, body.as_bytes(), false);
                    return; // any remaining body would garble the stream
                }
                Err(WireError::Malformed(msg)) => {
                    let body = error_body(
                        &format!("malformed request: {msg}"),
                        ErrorKind::InvalidRequest,
                    );
                    let _ = http::write_response(&mut writer, 400, body.as_bytes(), false);
                    return;
                }
                Err(WireError::Corrupt(msg)) => {
                    // the bytes parsed but the body digest did not
                    // verify: the stream cannot be trusted past this
                    // request, so answer 503 transport (retryable) and
                    // close
                    self.stats.transport_corrupt.fetch_add(1, Ordering::SeqCst);
                    let body = error_body(
                        &format!("corrupt request body: {msg}"),
                        ErrorKind::Transport,
                    );
                    let _ = http::write_response(&mut writer, 503, body.as_bytes(), false);
                    return;
                }
                Err(WireError::Io(_)) => return,
            }
        }
    }

    /// Route, execute and answer one parsed request; false when the
    /// response could not be written (connection is dead).
    fn respond(
        &self,
        req: &Request,
        writer: &mut TcpStream,
        arm: Option<&mut FaultArm>,
    ) -> bool {
        self.stats.requests.fetch_add(1, Ordering::SeqCst);
        self.stats.in_flight.fetch_add(1, Ordering::SeqCst);
        let t0 = Instant::now();
        let routed = catch_unwind(AssertUnwindSafe(|| self.route(req)));
        let (status, body) = routed.unwrap_or_else(|_| {
            (500, error_body("request handler panicked", ErrorKind::Internal))
        });
        self.stats.in_flight.fetch_sub(1, Ordering::SeqCst);
        {
            let mut lat = relock(&self.stats.latency);
            lat[endpoint_index(&req.path)].record(t0.elapsed().as_secs_f64());
        }
        if status >= 500 {
            self.stats.responses_5xx.fetch_add(1, Ordering::SeqCst);
        } else if status >= 400 {
            self.stats.responses_4xx.fetch_add(1, Ordering::SeqCst);
        }
        http::write_response_with(writer, status, body.as_bytes(), req.keep_alive, arm)
            .is_ok()
    }

    fn route(&self, req: &Request) -> (u16, String) {
        match (req.method.as_str(), req.path.as_str()) {
            ("GET", "/healthz") => (200, "{\n  \"ok\": true\n}\n".to_string()),
            ("GET", "/stats") => (200, self.stats_json()),
            ("POST", "/partition") => self.handle_partition(req),
            ("POST", "/batch") => self.handle_batch(req),
            (_, "/partition" | "/batch" | "/healthz" | "/stats") => (
                405,
                error_body(
                    "method not allowed (POST /partition, POST /batch, \
                     GET /healthz, GET /stats)",
                    ErrorKind::InvalidRequest,
                ),
            ),
            _ => (
                404,
                error_body(
                    &format!("no such endpoint '{}'", req.path),
                    ErrorKind::InvalidRequest,
                ),
            ),
        }
    }

    fn handle_partition(&self, req: &Request) -> (u16, String) {
        let Ok(text) = std::str::from_utf8(&req.body) else {
            return (400, error_body("request body is not UTF-8", ErrorKind::InvalidRequest));
        };
        let preq = match PartitionRequest::from_json(text) {
            Ok(p) => p,
            Err(e) => return (status_for(e.kind()), error_body(&e.to_string(), e.kind())),
        };
        match self.run_cached(&preq) {
            Ok(report) => {
                let json = if req.query_flag("owners") {
                    report.to_json_with_owners()
                } else {
                    report.to_json()
                };
                (200, json)
            }
            Err(e) => (status_for(e.kind()), error_body(&e.to_string(), e.kind())),
        }
    }

    fn handle_batch(&self, req: &Request) -> (u16, String) {
        let Ok(text) = std::str::from_utf8(&req.body) else {
            return (400, error_body("request body is not UTF-8", ErrorKind::InvalidRequest));
        };
        let breq = match BatchRequest::from_json(text) {
            Ok(b) => b,
            Err(e) => return (status_for(e.kind()), error_body(&e.to_string(), e.kind())),
        };
        match self.run_batch(&breq) {
            Ok(report) => (200, report.to_json()),
            Err(e) => (status_for(e.kind()), error_body(&e.to_string(), e.kind())),
        }
    }

    /// One batch against the caches: resolve (or reuse) the graph once,
    /// consult the result cache per variant, run only the misses as a
    /// single batch-engine invocation, and publish each computed variant
    /// under its own [`cache_key`] so later `POST /partition` requests
    /// hit. The whole batch occupies *one* `max_compute` slot (it is one
    /// handler thread doing useful work, however many variants it
    /// carries). Variants already in flight elsewhere are recomputed
    /// here rather than waited on — reports are bit-identical, so the
    /// duplicated work costs time, never correctness — and only the
    /// flights this batch claimed are published.
    fn run_batch(&self, breq: &BatchRequest) -> Result<BatchReport> {
        if breq.variants.is_empty() {
            return Err(anyhow!("batch has no variants").with_kind(ErrorKind::InvalidRequest));
        }
        let graph = self.graph_for(&breq.dataset, breq.graph_seed)?;
        let keys: Vec<String> = breq
            .variants
            .iter()
            .map(|v| cache_key(&breq.request_for(v)))
            .collect();
        let nvars = keys.len();
        let mut done: Vec<Option<Arc<RunReport>>> = vec![None; nvars];
        let mut misses: Vec<usize> = Vec::new();
        let mut claimed = vec![false; nvars];
        {
            let mut cache = relock(&self.cache);
            for (i, key) in keys.iter().enumerate() {
                match cache.map.get(key) {
                    Some(Flight::Done(report)) => done[i] = Some(report.clone()),
                    Some(Flight::InFlight) => misses.push(i),
                    None => {
                        misses.push(i);
                        // claim unless a duplicate variant earlier in
                        // this same batch already did
                        if !keys[..i].iter().zip(&claimed).any(|(k, &c)| c && k == key) {
                            claimed[i] = true;
                        }
                    }
                }
            }
            let hits = nvars - misses.len();
            if hits > 0 {
                self.stats.cache_hits.fetch_add(hits, Ordering::SeqCst);
            }
            if !misses.is_empty() {
                if cache.in_flight >= self.cfg.max_compute.max(1) {
                    drop(cache);
                    self.stats.shed_busy.fetch_add(1, Ordering::SeqCst);
                    return Err(anyhow!(
                        "{} distinct computations already in flight; \
                         retry later",
                        self.cfg.max_compute
                    )
                    .with_kind(ErrorKind::Busy));
                }
                cache.in_flight += 1;
                for (i, key) in keys.iter().enumerate() {
                    if claimed[i] {
                        cache.map.insert(key.clone(), Flight::InFlight);
                    }
                }
            }
        }

        if misses.is_empty() {
            // every variant served from cache: profile the (cached)
            // graph and assemble in variant order; no engine run, so the
            // execution-side accounting is honestly zero
            let (shared, shared_secs) =
                crate::util::timer::time(|| SharedPrep::compute(&graph));
            let reports =
                done.into_iter().map(|r| (*r.expect("all hits")).clone()).collect();
            return Ok(BatchReport {
                dataset: breq.dataset.clone(),
                vertices: graph.vertex_count(),
                edges: graph.edge_count(),
                shared,
                reports,
                lanes: 0,
                resolve_secs: 0.0,
                shared_secs,
                exec_secs: 0.0,
                scratch_peak_bytes: 0,
            });
        }

        // unwind claimed flights if the engine panics, so waiters retry
        // instead of hanging until their deadline
        struct BatchGuard<'a> {
            inner: &'a Inner,
            keys: &'a [String],
            claimed: &'a [bool],
            armed: bool,
        }
        impl Drop for BatchGuard<'_> {
            fn drop(&mut self) {
                if !self.armed {
                    return;
                }
                let mut cache = relock(&self.inner.cache);
                for (key, &c) in self.keys.iter().zip(self.claimed) {
                    if c {
                        cache.map.remove(key);
                    }
                }
                cache.in_flight = cache.in_flight.saturating_sub(1);
                self.inner.cache_cv.notify_all();
            }
        }
        let mut guard =
            BatchGuard { inner: self, keys: &keys, claimed: &claimed, armed: true };
        self.stats.computations.fetch_add(misses.len(), Ordering::SeqCst);
        let sub = BatchRequest {
            dataset: breq.dataset.clone(),
            graph_seed: breq.graph_seed,
            variants: misses.iter().map(|&i| breq.variants[i].clone()).collect(),
            gain_samples: breq.gain_samples,
            workload: breq.workload,
            threads: breq.threads,
        };
        let out = sub.execute_on(&graph);
        guard.armed = false;
        let mut cache = relock(&self.cache);
        cache.in_flight = cache.in_flight.saturating_sub(1);
        match out {
            Ok(mut subrep) => {
                self.stats.cache_misses.fetch_add(misses.len(), Ordering::SeqCst);
                for (j, &i) in misses.iter().enumerate() {
                    let mut report = subrep.reports[j].clone();
                    report.dataset = breq.dataset.clone();
                    let report = Arc::new(report);
                    if claimed[i] {
                        cache.map.insert(keys[i].clone(), Flight::Done(report.clone()));
                        cache.order.push_back(keys[i].clone());
                    }
                    done[i] = Some(report);
                }
                while cache.order.len() > self.cfg.cache_capacity.max(1) {
                    if let Some(old) = cache.order.pop_front() {
                        cache.map.remove(&old);
                    }
                }
                self.cache_cv.notify_all();
                drop(cache);
                subrep.dataset = breq.dataset.clone();
                subrep.reports = done
                    .into_iter()
                    .map(|r| (*r.expect("every variant is a hit or a miss")).clone())
                    .collect();
                Ok(subrep)
            }
            Err(e) => {
                for (key, &c) in keys.iter().zip(&claimed) {
                    if c {
                        cache.map.remove(key);
                    }
                }
                self.cache_cv.notify_all();
                Err(e)
            }
        }
    }

    /// Single-flight cached execution of one request (see module docs).
    fn run_cached(&self, preq: &PartitionRequest) -> Result<Arc<RunReport>> {
        let key = cache_key(preq);
        let deadline = Instant::now()
            + Duration::from_secs_f64(self.cfg.request_timeout_s.max(0.05));
        let mut cache = relock(&self.cache);
        loop {
            match cache.map.get(&key) {
                Some(Flight::Done(report)) => {
                    self.stats.cache_hits.fetch_add(1, Ordering::SeqCst);
                    return Ok(report.clone());
                }
                Some(Flight::InFlight) => {
                    let now = Instant::now();
                    if now >= deadline {
                        drop(cache);
                        self.stats.shed_timeout.fetch_add(1, Ordering::SeqCst);
                        return Err(anyhow!(
                            "timed out after {:.1}s waiting for an \
                             in-flight identical computation; retry later",
                            self.cfg.request_timeout_s
                        )
                        .with_kind(ErrorKind::Overloaded));
                    }
                    let (c, _timeout) = self
                        .cache_cv
                        .wait_timeout(cache, deadline - now)
                        .unwrap_or_else(|p| p.into_inner());
                    cache = c;
                }
                None => {
                    if cache.in_flight >= self.cfg.max_compute.max(1) {
                        drop(cache);
                        self.stats.shed_busy.fetch_add(1, Ordering::SeqCst);
                        return Err(anyhow!(
                            "{} distinct computations already in flight; \
                             retry later",
                            self.cfg.max_compute
                        )
                        .with_kind(ErrorKind::Busy));
                    }
                    cache.map.insert(key.clone(), Flight::InFlight);
                    cache.in_flight += 1;
                    drop(cache);
                    return self.compute_flight(preq, &key);
                }
            }
        }
    }

    /// Compute the report for `key` (this thread won the flight), then
    /// publish it and wake waiters. The guard makes the InFlight entry
    /// panic-safe: if the computation unwinds, the entry is removed and
    /// waiters retry instead of hanging until their deadline.
    fn compute_flight(&self, preq: &PartitionRequest, key: &str) -> Result<Arc<RunReport>> {
        struct FlightGuard<'a> {
            inner: &'a Inner,
            key: &'a str,
            armed: bool,
        }
        impl Drop for FlightGuard<'_> {
            fn drop(&mut self) {
                if !self.armed {
                    return;
                }
                let mut cache = relock(&self.inner.cache);
                cache.map.remove(self.key);
                cache.in_flight = cache.in_flight.saturating_sub(1);
                self.inner.cache_cv.notify_all();
            }
        }
        let mut guard = FlightGuard { inner: self, key, armed: true };
        let out = self.compute(preq);
        guard.armed = false;
        let mut cache = relock(&self.cache);
        cache.in_flight = cache.in_flight.saturating_sub(1);
        match out {
            Ok(report) => {
                let report = Arc::new(report);
                cache.map.insert(key.to_string(), Flight::Done(report.clone()));
                cache.order.push_back(key.to_string());
                while cache.order.len() > self.cfg.cache_capacity.max(1) {
                    if let Some(old) = cache.order.pop_front() {
                        cache.map.remove(&old);
                    }
                }
                self.stats.cache_misses.fetch_add(1, Ordering::SeqCst);
                self.cache_cv.notify_all();
                Ok(report)
            }
            Err(e) => {
                // errors are not cached: remove the flight so a retry
                // (possibly with the dataset now available) recomputes
                cache.map.remove(key);
                self.cache_cv.notify_all();
                Err(e)
            }
        }
    }

    /// The actual work: resolve (or reuse) the graph, execute the
    /// facade. Increments the `computations` probe counter the
    /// single-flight test pins.
    fn compute(&self, preq: &PartitionRequest) -> Result<RunReport> {
        self.stats.computations.fetch_add(1, Ordering::SeqCst);
        let graph = self.graph_for(&preq.dataset, preq.graph_seed)?;
        let mut report = preq.execute_on(&graph)?;
        // execute_on leaves the label empty (it cannot vouch for an
        // arbitrary graph); the server resolved from preq.dataset itself
        report.dataset = preq.dataset.clone();
        Ok(report)
    }

    /// Resolved-graph cache lookup. Resolution runs outside the lock, so
    /// two *different* requests racing on a brand-new dataset may both
    /// resolve it (identical requests are already single-flighted); the
    /// loser's copy is dropped.
    fn graph_for(&self, dataset: &str, graph_seed: u64) -> Result<Arc<Graph>> {
        let key = (dataset.to_string(), graph_seed);
        {
            let graphs = relock(&self.graphs);
            if let Some(g) = graphs.map.get(&key) {
                return Ok(g.clone());
            }
        }
        let (outcome, secs) =
            crate::util::timer::time(|| resolve_graph(dataset, graph_seed));
        // attribute resolve time (success or failure) separately from
        // partitioning: this is the cold-path share of request latency
        relock(&self.stats.resolve).record(secs);
        let resolved = Arc::new(outcome?);
        let mut graphs = relock(&self.graphs);
        if let Some(g) = graphs.map.get(&key) {
            return Ok(g.clone());
        }
        graphs.map.insert(key.clone(), resolved.clone());
        graphs.order.push_back(key);
        while graphs.order.len() > self.cfg.graph_capacity.max(1) {
            if let Some(old) = graphs.order.pop_front() {
                graphs.map.remove(&old);
            }
        }
        Ok(resolved)
    }

    fn stats_json(&self) -> String {
        let load = |c: &AtomicUsize| c.load(Ordering::SeqCst) as f64;
        let mut sink = JsonSink::new();
        sink.num("v", 1.0);
        sink.num("requests_total", load(&self.stats.requests));
        sink.num("in_flight", load(&self.stats.in_flight));
        let hits = load(&self.stats.cache_hits);
        let misses = load(&self.stats.cache_misses);
        sink.num("cache_hits", hits);
        sink.num("cache_misses", misses);
        let rate = if hits + misses > 0.0 { hits / (hits + misses) } else { 0.0 };
        sink.num("cache_hit_rate", rate);
        sink.num("computations", load(&self.stats.computations));
        {
            let cache = relock(&self.cache);
            sink.num("cache_entries", cache.order.len() as f64);
            sink.num("computations_in_flight", cache.in_flight as f64);
        }
        sink.num("graphs_resident", relock(&self.graphs).map.len() as f64);
        {
            let resolve = *relock(&self.stats.resolve);
            sink.num("resolve_count", resolve.count as f64);
            sink.num("resolve_mean_ms", resolve.mean_s() * 1e3);
            sink.num("resolve_max_ms", resolve.max_s * 1e3);
        }
        sink.num("shed_queue_full", load(&self.stats.shed_queue_full));
        sink.num("shed_body_too_large", load(&self.stats.shed_body_too_large));
        sink.num("shed_timeout", load(&self.stats.shed_timeout));
        sink.num("shed_busy", load(&self.stats.shed_busy));
        sink.num("transport_corrupt", load(&self.stats.transport_corrupt));
        sink.num("fault_active", self.cfg.fault.is_some() as u8 as f64);
        let f = self.fault_counters.snapshot();
        sink.num("fault_drops", f.drops as f64);
        sink.num("fault_delays", f.delays as f64);
        sink.num("fault_corruptions", f.corruptions as f64);
        sink.num("fault_short_reads", f.short_reads as f64);
        sink.num("fault_torn_writes", f.torn_writes as f64);
        sink.num("responses_4xx", load(&self.stats.responses_4xx));
        sink.num("responses_5xx", load(&self.stats.responses_5xx));
        let lat = *relock(&self.stats.latency);
        for (i, name) in ENDPOINTS.iter().enumerate() {
            sink.num(&format!("lat_{name}_count"), lat[i].count as f64);
            sink.num(&format!("lat_{name}_mean_s"), lat[i].mean_s());
            sink.num(&format!("lat_{name}_max_s"), lat[i].max_s);
        }
        sink.render()
    }
}

/// Render the documented wire error body: `{"error": ..., "kind": ...}`.
fn error_body(msg: &str, kind: ErrorKind) -> String {
    let mut sink = JsonSink::new();
    sink.text("error", msg);
    sink.text("kind", kind.as_str());
    sink.render()
}

/// A tiny blocking SDK client for a [`Server`]: keep-alive, with
/// bounded deterministically-jittered retries ([`RetryPolicy`]) over
/// transport-level failures — dead connections, garbled exchanges, and
/// 503 responses whose machine-readable kind is `transport`. Shed
/// signals (`overloaded`, `busy`) are *not* retried here; they pass
/// through so callers can apply their own admission policy.
pub struct ServeClient {
    addr: SocketAddr,
    conn: Option<BufReader<TcpStream>>,
    policy: RetryPolicy,
    rng: Rng,
    retries: u64,
}

/// Largest response body the client accepts (owners arrays scale with
/// `|E|`, so this is deliberately roomy).
const CLIENT_MAX_BODY: usize = 256 << 20;

impl ServeClient {
    /// A client for the server at `addr`. Connects lazily on the first
    /// request. Backoff jitter is seeded from the address, so a given
    /// client's retry schedule is reproducible.
    pub fn connect(addr: SocketAddr) -> ServeClient {
        let seed =
            crate::util::frame::fnv1a64(addr.to_string().as_bytes());
        ServeClient {
            addr,
            conn: None,
            policy: RetryPolicy::default(),
            rng: Rng::new(seed),
            retries: 0,
        }
    }

    /// Replace the retry policy (`attempts = 1` disables retries).
    pub fn with_retry(mut self, policy: RetryPolicy) -> ServeClient {
        self.policy = policy;
        self
    }

    /// How many retry attempts (sleeps) this client has performed —
    /// zero on an undisturbed connection.
    pub fn retries(&self) -> u64 {
        self.retries
    }

    /// One request/response exchange: `(status, body)`. Transport
    /// failures retry on a fresh connection with jittered exponential
    /// backoff, up to the policy's attempt budget; what comes back
    /// after that is a typed [`ErrorKind::Transport`] error.
    pub fn request(&mut self, method: &str, target: &str, body: &[u8]) -> Result<(u16, String)> {
        let attempts = self.policy.attempts.max(1);
        let mut last_err = String::from("no attempt made");
        for attempt in 0..attempts {
            if attempt > 0 {
                self.retries += 1;
                std::thread::sleep(self.policy.delay(attempt - 1, &mut self.rng));
            }
            if self.conn.is_none() {
                match TcpStream::connect(self.addr) {
                    Ok(stream) => {
                        let _ = stream.set_nodelay(true);
                        self.conn = Some(BufReader::new(stream));
                    }
                    Err(e) => {
                        last_err = format!("connect {}: {e}", self.addr);
                        continue;
                    }
                }
            }
            match self.exchange(method, target, body) {
                Ok((status, text)) => {
                    if status == 503 {
                        // 503 is retry-worthy only when the server says
                        // the *exchange* was damaged (kind transport);
                        // overloaded-shed 503s pass through untouched
                        let (msg, kind) = parse_error_body(&text);
                        if kind == ErrorKind::Transport {
                            self.conn = None;
                            last_err =
                                format!("server answered 503 transport: {msg}");
                            continue;
                        }
                    }
                    return Ok((status, text));
                }
                Err(e) => {
                    // drop the dead connection; retry on a fresh one
                    self.conn = None;
                    last_err = e;
                }
            }
        }
        Err(anyhow!(
            "request {method} {target} failed after {attempts} \
             attempts: {last_err}"
        )
        .with_kind(ErrorKind::Transport))
    }

    fn exchange(
        &mut self,
        method: &str,
        target: &str,
        body: &[u8],
    ) -> std::result::Result<(u16, String), String> {
        let conn = self.conn.as_mut().expect("connection established");
        http::write_request(conn.get_mut(), method, target, body).map_err(|e| e.to_string())?;
        let (status, bytes) = http::read_response(conn, CLIENT_MAX_BODY)
            .map_err(|e| e.to_string())?;
        Ok((status, String::from_utf8_lossy(&bytes).into_owned()))
    }

    /// `GET` a path.
    pub fn get(&mut self, target: &str) -> Result<(u16, String)> {
        self.request("GET", target, b"")
    }

    /// `POST /partition` and parse the report. Non-200 answers become
    /// errors carrying the server's machine-readable kind. With
    /// `owners`, the report includes the bit-exact ownership vector.
    pub fn partition(&mut self, req: &PartitionRequest, owners: bool) -> Result<RunReport> {
        let target = if owners { "/partition?owners=1" } else { "/partition" };
        let (status, body) = self.request("POST", target, req.to_json().as_bytes())?;
        if status != 200 {
            let (msg, kind) = parse_error_body(&body);
            return Err(anyhow!("server answered {status}: {msg}").with_kind(kind));
        }
        RunReport::from_json(&body)
    }

    /// `POST /batch` and parse the batch report. Non-200 answers become
    /// errors carrying the server's machine-readable kind. Per-variant
    /// reports come back with owners, bit-identical to local execution.
    pub fn batch(&mut self, req: &BatchRequest) -> Result<BatchReport> {
        let (status, body) = self.request("POST", "/batch", req.to_json().as_bytes())?;
        if status != 200 {
            let (msg, kind) = parse_error_body(&body);
            return Err(anyhow!("server answered {status}: {msg}").with_kind(kind));
        }
        BatchReport::from_json(&body)
    }
}

/// Best-effort parse of a wire error body back into `(message, kind)`.
fn parse_error_body(body: &str) -> (String, ErrorKind) {
    let Ok(doc) = crate::util::json::parse(body) else {
        return (body.trim().to_string(), ErrorKind::Internal);
    };
    let msg = doc.get("error").and_then(|v| v.as_str()).unwrap_or("").to_string();
    let kind = doc
        .get("kind")
        .and_then(|v| v.as_str())
        .and_then(ErrorKind::parse)
        .unwrap_or(ErrorKind::Internal);
    (msg, kind)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The exhaustive kind → status table documented in DESIGN.md
    /// "Serving layer". Walking `ALL` keeps this test honest when a new
    /// kind is added: the match in `status_for` must be extended, and so
    /// must this table.
    #[test]
    fn kind_status_table_is_exhaustive_and_documented() {
        let documented = [
            (ErrorKind::InvalidSpec, 400),
            (ErrorKind::InvalidRequest, 400),
            (ErrorKind::DatasetNotFound, 404),
            (ErrorKind::Busy, 429),
            (ErrorKind::Overloaded, 503),
            (ErrorKind::Io, 500),
            (ErrorKind::Transport, 503),
            (ErrorKind::Internal, 500),
        ];
        assert_eq!(documented.len(), ErrorKind::ALL.len());
        for (kind, status) in documented {
            assert_eq!(status_for(kind), status, "{kind:?}");
            // every status in the table has a real reason phrase
            assert_ne!(http::status_text(status), "Unknown", "{status}");
        }
    }

    #[test]
    fn cache_key_canonicalizes_spec_and_separates_fields() {
        use crate::coordinator::runs::Workload;
        let base = PartitionRequest::new("hdrf").unwrap().dataset("er:n=200,m=600").k(4).seed(7);
        // default-elided vs explicit-default vs padded spelling collide
        let explicit = PartitionRequest::new("hdrf:lambda=1.1")
            .unwrap()
            .dataset("er:n=200,m=600")
            .k(4)
            .seed(7);
        assert_eq!(cache_key(&base), cache_key(&explicit));
        // the threads override is excluded (reports are thread-invariant)
        assert_eq!(cache_key(&base), cache_key(&base.clone().threads(8)));
        // every other field separates
        assert_ne!(cache_key(&base), cache_key(&base.clone().k(5)));
        assert_ne!(cache_key(&base), cache_key(&base.clone().seed(8)));
        assert_ne!(cache_key(&base), cache_key(&base.clone().graph_seed(9)));
        assert_ne!(cache_key(&base), cache_key(&base.clone().dataset("er:n=201,m=600")));
        assert_ne!(cache_key(&base), cache_key(&base.clone().gain_samples(2)));
        assert_ne!(
            cache_key(&base),
            cache_key(&base.clone().workload(Workload::Sssp { source: 0 }))
        );
        // a real parameter override separates
        let tuned = PartitionRequest::new("hdrf:lambda=1.5")
            .unwrap()
            .dataset("er:n=200,m=600")
            .k(4)
            .seed(7);
        assert_ne!(cache_key(&base), cache_key(&tuned));
    }

    #[test]
    fn error_body_round_trips_kind() {
        let body = error_body("no such dataset", ErrorKind::DatasetNotFound);
        let (msg, kind) = parse_error_body(&body);
        assert_eq!(msg, "no such dataset");
        assert_eq!(kind, ErrorKind::DatasetNotFound);
        let (_msg, kind) = parse_error_body("total garbage");
        assert_eq!(kind, ErrorKind::Internal);
    }
}
