//! End-to-end run orchestration: one [`PartitionRequest`] in, one
//! [`RunReport`] out — the single facade the CLI, the examples and the
//! benches all share.
//!
//! A request names a partitioner by [`PartitionerSpec`], a dataset by
//! graph-spec string, `k`, the run seed, an optional pool-thread override
//! and an optional ETSCH [`Workload`]; [`PartitionRequest::execute`]
//! resolves the graph, partitions it through the unified
//! [`Partitioner`](crate::partition::Partitioner) trait, derives the §V-A
//! metrics off one shared [`PartitionView`] build, optionally runs the
//! workload on the same view, and returns everything with wall-clock
//! timings. [`RunReport::to_json`] serializes the report through the
//! crate's flat JSON writer ([`crate::bench::harness::JsonSink`]).

use crate::anyhow;
use crate::util::error::Result;

use crate::etsch::{gain, sssp::Sssp, Etsch};
use crate::graph::{datasets, generators::GraphKind, Graph};
use crate::partition::{
    metrics::{self, Report},
    spec::PartitionerSpec,
    view::PartitionView,
    EdgePartition, Partitioner,
};
use crate::util::pool;

/// One experiment, fully named: everything
/// [`execute`](PartitionRequest::execute) needs to produce a
/// [`RunReport`], and nothing it has to guess.
#[derive(Clone, Debug)]
pub struct PartitionRequest {
    /// Which partitioner, with parameters (`dfep`, `hdrf:lambda=1.5`...).
    pub spec: PartitionerSpec,
    /// Graph spec: a dataset name (`astroph`, `usroads@0.05`) or a
    /// generator (`er:n=1000,m=3000`) — see [`resolve_graph`].
    pub dataset: String,
    /// Number of parts.
    pub k: usize,
    /// Seed controlling all randomness of the partitioner run.
    pub seed: u64,
    /// Seed for dataset generation/scaling.
    pub graph_seed: u64,
    /// Sources for the gain estimate (0 = skip gain).
    pub gain_samples: usize,
    /// Pool-thread override for the whole run (`None` = ambient pool).
    pub threads: Option<usize>,
    /// Optional ETSCH workload to run on the produced partition.
    pub workload: Option<Workload>,
}

impl Default for PartitionRequest {
    fn default() -> Self {
        PartitionRequest {
            spec: PartitionerSpec::parse("dfep").expect("dfep is registered"),
            dataset: "astroph@0.05".to_string(),
            k: 20,
            seed: 1,
            graph_seed: 42,
            gain_samples: 0,
            threads: None,
            workload: None,
        }
    }
}

/// An ETSCH workload a request can attach to the produced partition.
#[derive(Clone, Copy, Debug)]
pub enum Workload {
    /// Single-source shortest paths from `source`.
    Sssp {
        /// Source vertex.
        source: u32,
    },
}

/// The result of running a [`Workload`].
#[derive(Clone, Debug)]
pub struct WorkloadReport {
    /// Workload name (`"sssp"`).
    pub name: &'static str,
    /// ETSCH rounds executed.
    pub rounds: usize,
    /// Messages exchanged (change-driven count).
    pub messages: usize,
    /// Vertices reached / touched by the workload.
    pub reached: usize,
    /// Wall-clock seconds (engine build + run).
    pub secs: f64,
}

/// Wall-clock breakdown of one run.
#[derive(Clone, Copy, Debug, Default)]
pub struct Timings {
    /// Dataset resolution (generation/scaling) seconds.
    pub resolve_secs: f64,
    /// Partitioner seconds.
    pub partition_secs: f64,
    /// Shared-view build + metric evaluation seconds.
    pub evaluate_secs: f64,
}

/// Everything one run produced (the paper's per-plot quantities plus
/// timings and the partition itself).
#[derive(Clone, Debug)]
pub struct RunReport {
    /// Canonical spec string of the partitioner that ran.
    pub spec: String,
    /// The dataset spec that was resolved — set by
    /// [`execute`](PartitionRequest::execute); empty when the caller
    /// supplied the graph directly via
    /// [`execute_on`](PartitionRequest::execute_on) (the request's
    /// `dataset` field is not trusted to describe an arbitrary graph).
    pub dataset: String,
    /// Number of parts requested.
    pub k: usize,
    /// The run seed.
    pub seed: u64,
    /// `|V|` of the resolved graph.
    pub vertices: usize,
    /// `|E|` of the resolved graph.
    pub edges: usize,
    /// The §V-A metric report.
    pub metrics: Report,
    /// Path-compression gain (None when `gain_samples == 0`).
    pub gain: Option<f64>,
    /// The workload result, when one was attached.
    pub workload: Option<WorkloadReport>,
    /// Wall-clock breakdown.
    pub timings: Timings,
    /// The partition itself.
    pub partition: EdgePartition,
}

impl RunReport {
    /// Serialize the report as a flat JSON object through the crate's
    /// one JSON writer (the same format the bench artifacts use).
    pub fn to_json(&self) -> String {
        let mut sink = crate::bench::harness::JsonSink::new();
        sink.text("spec", &self.spec);
        if !self.dataset.is_empty() {
            sink.text("dataset", &self.dataset);
        }
        sink.num("k", self.k as f64);
        sink.num("seed", self.seed as f64);
        sink.num("vertices", self.vertices as f64);
        sink.num("edges", self.edges as f64);
        sink.num("rounds", self.metrics.rounds as f64);
        sink.num("largest", self.metrics.largest);
        sink.num("nstdev", self.metrics.nstdev);
        sink.num("messages", self.metrics.messages as f64);
        sink.num("disconnected", self.metrics.disconnected);
        if let Some(gain) = self.gain {
            sink.num("gain", gain);
        }
        sink.num("resolve_secs", self.timings.resolve_secs);
        sink.num("partition_secs", self.timings.partition_secs);
        sink.num("evaluate_secs", self.timings.evaluate_secs);
        if let Some(w) = &self.workload {
            sink.text("workload", w.name);
            sink.num("workload_rounds", w.rounds as f64);
            sink.num("workload_messages", w.messages as f64);
            sink.num("workload_reached", w.reached as f64);
            sink.num("workload_secs", w.secs);
        }
        sink.render()
    }
}

impl PartitionRequest {
    /// Resolve the dataset, then [`execute_on`](Self::execute_on) it.
    pub fn execute(&self) -> Result<RunReport> {
        let (g, resolve_secs) = crate::util::timer::time(|| {
            resolve_graph(&self.dataset, self.graph_seed)
        });
        let g = g?;
        let mut report = self.execute_on(&g)?;
        report.dataset = self.dataset.clone();
        report.timings.resolve_secs = resolve_secs;
        Ok(report)
    }

    /// Run on an already-resolved graph (the benches resolve once and
    /// execute many requests against it). Honors the
    /// [`threads`](Self::threads) override for the entire run.
    pub fn execute_on(&self, g: &Graph) -> Result<RunReport> {
        match self.threads {
            Some(t) => pool::with_threads(t, || self.run_inner(g)),
            None => self.run_inner(g),
        }
    }

    fn run_inner(&self, g: &Graph) -> Result<RunReport> {
        let partitioner = self.spec.build();
        let (partition, partition_secs) = crate::util::timer::time(|| {
            partitioner.partition_graph(g, self.k, self.seed)
        });
        let partition = partition?;
        partition.validate(g)?;
        // one shared derived-state build serves the metrics, the gain
        // estimate and the attached workload
        let (out, evaluate_secs) = crate::util::timer::time(|| {
            let view = PartitionView::build(g, &partition);
            let metrics = metrics::evaluate_with(g, &partition, &view);
            let gain = (self.gain_samples > 0).then(|| {
                let mut engine = Etsch::from_view(g, &view);
                gain::average_gain_with(
                    g,
                    &mut engine,
                    self.gain_samples,
                    self.seed,
                )
            });
            let workload = self
                .workload
                .map(|w| run_workload(g, &view, w));
            (metrics, gain, workload)
        });
        let (metrics, gain, workload) = out;
        Ok(RunReport {
            spec: self.spec.to_string(),
            // only execute() (which resolved the graph itself) knows the
            // graph really is self.dataset; direct execute_on callers get
            // an empty field instead of a possibly-wrong label
            dataset: String::new(),
            k: self.k,
            seed: self.seed,
            vertices: g.vertex_count(),
            edges: g.edge_count(),
            metrics,
            gain,
            workload,
            timings: Timings {
                resolve_secs: 0.0,
                partition_secs,
                evaluate_secs,
            },
            partition,
        })
    }
}

fn run_workload(
    g: &Graph,
    view: &PartitionView,
    w: Workload,
) -> WorkloadReport {
    match w {
        Workload::Sssp { source } => {
            let (out, secs) = crate::util::timer::time(|| {
                let mut engine = Etsch::from_view(g, view);
                let dist = engine.run(&mut Sssp::new(source));
                let stats = engine.stats().clone();
                (dist, stats)
            });
            let (dist, stats) = out;
            WorkloadReport {
                name: "sssp",
                rounds: stats.rounds,
                messages: stats.messages_exchanged,
                reached: dist
                    .iter()
                    .filter(|&&d| d != crate::etsch::sssp::UNREACHED)
                    .count(),
                secs,
            }
        }
    }
}

/// Resolve a graph source: a named dataset ("astroph", optionally scaled
/// like "astroph@0.1") or a generator spec ("er:n=1000,m=3000").
pub fn resolve_graph(spec: &str, seed: u64) -> Result<Graph> {
    if let Some((name, frac)) = spec.split_once('@') {
        let d = datasets::by_name(name)
            .ok_or_else(|| anyhow!("unknown dataset '{name}'"))?;
        let frac: f64 = frac.parse()?;
        return Ok(d.scaled(frac, seed));
    }
    if let Some(d) = datasets::by_name(spec) {
        return Ok(d.generate(seed));
    }
    if let Some((kind, args)) = spec.split_once(':') {
        let mut n = 1000usize;
        let mut m = 3000usize;
        let mut p = 0.3f64;
        for kv in args.split(',') {
            let (key, val) = kv
                .split_once('=')
                .ok_or_else(|| anyhow!("bad generator arg '{kv}'"))?;
            match key {
                "n" => n = val.parse()?,
                "m" => m = val.parse()?,
                "p" => p = val.parse()?,
                _ => return Err(anyhow!("unknown generator key '{key}'")),
            }
        }
        let g = match kind {
            "er" => GraphKind::ErdosRenyi { n, m },
            "ba" => GraphKind::BarabasiAlbert { n, m: m.min(12) },
            "plc" => GraphKind::PowerlawCluster { n, m: m.min(12), p },
            "road" => {
                let side = (n as f64).sqrt() as usize;
                GraphKind::RoadNetwork {
                    rows: side.max(4),
                    cols: side.max(4),
                    drop: 0.2,
                    subdiv: 3,
                    shortcuts: 0,
                }
            }
            other => return Err(anyhow!("unknown generator '{other}'")),
        };
        return Ok(g.generate(seed));
    }
    Err(anyhow!(
        "cannot resolve graph '{spec}' (try astroph, usroads, \
         astroph@0.1, er:n=1000,m=3000)"
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolve_named_and_scaled() {
        assert!(resolve_graph("astroph@0.02", 1).is_ok());
        assert!(resolve_graph("er:n=200,m=500", 1).is_ok());
        assert!(resolve_graph("bogus", 1).is_err());
        assert!(resolve_graph("er:n=abc", 1).is_err());
    }

    #[test]
    fn request_produces_full_report() {
        let req = PartitionRequest {
            spec: PartitionerSpec::parse("dfep").unwrap(),
            dataset: "er:n=300,m=900".to_string(),
            k: 4,
            seed: 3,
            graph_seed: 2,
            gain_samples: 2,
            threads: None,
            workload: Some(Workload::Sssp { source: 0 }),
        };
        let res = req.execute().unwrap();
        let g = resolve_graph("er:n=300,m=900", 2).unwrap();
        res.partition.validate(&g).unwrap();
        assert!(res.gain.unwrap() >= 0.0);
        assert!(res.metrics.rounds > 0);
        let w = res.workload.as_ref().unwrap();
        assert_eq!(w.name, "sssp");
        assert!(w.reached > 0);
        // the JSON serialization parses back and carries the key fields
        let parsed = crate::util::json::parse(&res.to_json()).unwrap();
        assert_eq!(
            parsed.get("spec").unwrap().as_str().unwrap(),
            "dfep"
        );
        assert_eq!(
            parsed.get("k").unwrap().as_usize().unwrap(),
            4
        );
        assert!(parsed.get("workload_rounds").is_some());
    }

    #[test]
    fn bad_specs_and_datasets_error() {
        let mut req = PartitionRequest {
            dataset: "nosuchdataset".to_string(),
            ..Default::default()
        };
        assert!(req.execute().is_err());
        req.dataset = "er:n=100,m=200".to_string();
        req.k = 0;
        let e = req.execute().unwrap_err().to_string();
        assert!(e.contains("k must be >= 1"), "{e}");
    }

    #[test]
    fn parameterized_spec_flows_through() {
        let g = resolve_graph("er:n=200,m=600", 1).unwrap();
        let req = PartitionRequest {
            spec: PartitionerSpec::parse("hdrf:lambda=1.5").unwrap(),
            k: 6,
            seed: 2,
            ..Default::default()
        };
        let res = req.execute_on(&g).unwrap();
        assert_eq!(res.spec, "hdrf:lambda=1.5");
        res.partition.validate(&g).unwrap();
    }
}
