//! End-to-end run orchestration: one [`PartitionRequest`] in, one
//! [`RunReport`] out — the single facade the CLI, the examples and the
//! benches all share.
//!
//! A request names a partitioner by [`PartitionerSpec`], a dataset by
//! graph-spec string, `k`, the run seed, an optional pool-thread override
//! and an optional ETSCH [`Workload`]. Any registry spec works here —
//! including the composable `refine:base=<spec>` local-search meta-spec
//! ([`crate::partition::refine`]), which needs no facade support of its
//! own. [`PartitionRequest::execute`]
//! resolves the graph, partitions it through the unified
//! [`Partitioner`](crate::partition::Partitioner) trait, derives the §V-A
//! metrics off one shared [`PartitionView`] build, optionally runs the
//! workload on the same view, and returns everything with wall-clock
//! timings. [`RunReport::to_json`] serializes the report through the
//! crate's flat JSON writer ([`crate::bench::harness::JsonSink`]).
//!
//! ## Construction idiom
//!
//! Requests are built with the chainable builder, not struct literals:
//!
//! ```
//! use dfep::coordinator::runs::PartitionRequest;
//!
//! let req = PartitionRequest::new("hdrf:lambda=1.5")
//!     .unwrap()
//!     .dataset("er:n=300,m=900")
//!     .k(8)
//!     .seed(3);
//! let report = req.execute().unwrap();
//! assert_eq!(report.k, 8);
//! ```
//!
//! ## Wire format (`"v": 1`)
//!
//! Both sides of the facade round-trip through flat JSON objects so the
//! serving layer (DESIGN.md "Serving layer") can speak them over HTTP:
//! [`PartitionRequest::to_json`] / [`PartitionRequest::from_json`] and
//! [`RunReport::to_json`] / [`RunReport::from_json`], all versioned with
//! a `"v": 1` field (absent means 1; anything else is rejected).
//! Unknown-field policy: *requests* are parsed strictly — an unknown
//! field is an [`ErrorKind::InvalidRequest`] error, so typos fail loudly
//! instead of silently running the default experiment — while *reports*
//! are parsed leniently (unknown fields ignored), so older clients keep
//! working when a newer server adds report fields.

use std::collections::BTreeMap;

use crate::anyhow;
use crate::util::error::{Error, ErrorKind, Result};
use crate::util::json::Json;

use crate::etsch::{gain, sssp::Sssp, Etsch};
use crate::graph::{datasets, generators::GraphKind, Graph};
use crate::partition::{
    metrics::{self, Report},
    spec::PartitionerSpec,
    view::PartitionView,
    EdgePartition, Partitioner,
};
use crate::util::pool;

/// One experiment, fully named: everything
/// [`execute`](PartitionRequest::execute) needs to produce a
/// [`RunReport`], and nothing it has to guess. Build with
/// [`new`](Self::new) / [`of`](Self::of) and the chainable setters; the
/// fields stay public for pattern-matching and inspection.
#[derive(Clone, Debug, PartialEq)]
pub struct PartitionRequest {
    /// Which partitioner, with parameters (`dfep`, `hdrf:lambda=1.5`...).
    pub spec: PartitionerSpec,
    /// Graph spec: a dataset name (`astroph`, `usroads@0.05`) or a
    /// generator (`er:n=1000,m=3000`) — see [`resolve_graph`].
    pub dataset: String,
    /// Number of parts.
    pub k: usize,
    /// Seed controlling all randomness of the partitioner run.
    pub seed: u64,
    /// Seed for dataset generation/scaling.
    pub graph_seed: u64,
    /// Sources for the gain estimate (0 = skip gain).
    pub gain_samples: usize,
    /// Pool-thread override for the whole run (`None` = ambient pool).
    pub threads: Option<usize>,
    /// Optional ETSCH workload to run on the produced partition.
    pub workload: Option<Workload>,
}

impl Default for PartitionRequest {
    fn default() -> Self {
        PartitionRequest {
            spec: PartitionerSpec::parse("dfep").expect("dfep is registered"),
            dataset: "astroph@0.05".to_string(),
            k: 20,
            seed: 1,
            graph_seed: 42,
            gain_samples: 0,
            threads: None,
            workload: None,
        }
    }
}

impl PartitionRequest {
    /// Builder entry point: parse a spec string and start from the
    /// defaults (`PartitionRequest::new("hdrf:lambda=1.5")?.k(32)`).
    /// Spec errors carry [`ErrorKind::InvalidSpec`].
    pub fn new(spec: &str) -> Result<PartitionRequest> {
        Ok(PartitionRequest::of(PartitionerSpec::parse(spec)?))
    }

    /// Builder entry point from an already-parsed spec (the programmatic
    /// counterpart of [`new`](Self::new); infallible).
    pub fn of(spec: PartitionerSpec) -> PartitionRequest {
        PartitionRequest { spec, ..Default::default() }
    }

    /// Set the dataset / graph spec (see [`resolve_graph`]).
    pub fn dataset(mut self, dataset: impl Into<String>) -> Self {
        self.dataset = dataset.into();
        self
    }

    /// Set the number of parts.
    pub fn k(mut self, k: usize) -> Self {
        self.k = k;
        self
    }

    /// Set the partitioner run seed.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Set the dataset generation/scaling seed.
    pub fn graph_seed(mut self, graph_seed: u64) -> Self {
        self.graph_seed = graph_seed;
        self
    }

    /// Set the number of gain-estimate sources (0 skips the estimate).
    pub fn gain_samples(mut self, gain_samples: usize) -> Self {
        self.gain_samples = gain_samples;
        self
    }

    /// Pin the pool-thread count for the whole run.
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = Some(threads);
        self
    }

    /// Attach an ETSCH workload.
    pub fn workload(mut self, workload: Workload) -> Self {
        self.workload = Some(workload);
        self
    }

    /// Serialize as a `"v": 1` wire request (see the [module
    /// docs](self)). `threads` and `workload` appear only when set.
    pub fn to_json(&self) -> String {
        let mut sink = crate::bench::harness::JsonSink::new();
        sink.num("v", 1.0);
        sink.text("spec", &self.spec.to_string());
        sink.text("dataset", &self.dataset);
        sink.num("k", self.k as f64);
        sink.num("seed", self.seed as f64);
        sink.num("graph_seed", self.graph_seed as f64);
        sink.num("gain_samples", self.gain_samples as f64);
        if let Some(t) = self.threads {
            sink.num("threads", t as f64);
        }
        if let Some(Workload::Sssp { source }) = self.workload {
            sink.text("workload", "sssp");
            sink.num("workload_source", source as f64);
        }
        sink.render()
    }

    /// Parse a `"v": 1` wire request. `spec` and `dataset` are required;
    /// everything else falls back to [`Default`]. Parsing is *strict*:
    /// unknown fields, a missing/unsupported version, non-integer
    /// numerics, `k == 0` or `threads == 0` are
    /// [`ErrorKind::InvalidRequest`] errors, and a bad spec string is
    /// [`ErrorKind::InvalidSpec`].
    pub fn from_json(text: &str) -> Result<PartitionRequest> {
        const KNOWN: [&str; 9] = [
            "v",
            "spec",
            "dataset",
            "k",
            "seed",
            "graph_seed",
            "gain_samples",
            "threads",
            "workload",
        ];
        let doc = crate::util::json::parse(text)
            .map_err(|e| req_err(format!("invalid request JSON: {e}")))?;
        let obj = doc
            .as_obj()
            .ok_or_else(|| req_err("request must be a JSON object"))?;
        for key in obj.keys() {
            let known = KNOWN.contains(&key.as_str())
                || key == "workload_source";
            if !known {
                return Err(req_err(format!(
                    "unknown request field '{key}' (known: {}, \
                     workload_source)",
                    KNOWN.join(", ")
                )));
            }
        }
        check_version(obj)?;
        let spec = PartitionerSpec::parse(req_str(obj, "spec")?)?;
        let mut req =
            PartitionRequest::of(spec).dataset(req_str(obj, "dataset")?);
        if let Some(v) = obj.get("k") {
            req = req.k(req_uint(v, "k")? as usize);
        }
        if req.k == 0 {
            return Err(req_err("field 'k' must be >= 1"));
        }
        if let Some(v) = obj.get("seed") {
            req = req.seed(req_uint(v, "seed")?);
        }
        if let Some(v) = obj.get("graph_seed") {
            req = req.graph_seed(req_uint(v, "graph_seed")?);
        }
        if let Some(v) = obj.get("gain_samples") {
            req = req.gain_samples(req_uint(v, "gain_samples")? as usize);
        }
        if let Some(v) = obj.get("threads") {
            let t = req_uint(v, "threads")? as usize;
            if t == 0 {
                return Err(req_err("field 'threads' must be >= 1"));
            }
            req = req.threads(t);
        }
        match obj.get("workload") {
            None => {
                if obj.contains_key("workload_source") {
                    return Err(req_err(
                        "field 'workload_source' requires 'workload'",
                    ));
                }
            }
            Some(w) => {
                let name = w.as_str().ok_or_else(|| {
                    req_err("field 'workload' must be a string")
                })?;
                if name != "sssp" {
                    return Err(req_err(format!(
                        "unknown workload '{name}' (known: sssp)"
                    )));
                }
                let source = match obj.get("workload_source") {
                    Some(v) => req_uint(v, "workload_source")? as u32,
                    None => 0,
                };
                req = req.workload(Workload::Sssp { source });
            }
        }
        Ok(req)
    }
}

pub(crate) fn req_err(msg: impl Into<String>) -> Error {
    Error::msg(msg).with_kind(ErrorKind::InvalidRequest)
}

/// Reject any `"v"` other than (a missing) 1 — both request and report
/// parsing share the version gate.
pub(crate) fn check_version(obj: &BTreeMap<String, Json>) -> Result<()> {
    match obj.get("v") {
        None => Ok(()),
        Some(v) if v.as_f64() == Some(1.0) => Ok(()),
        Some(_) => {
            Err(req_err("unsupported wire version (this crate speaks v=1)"))
        }
    }
}

pub(crate) fn req_str<'a>(
    obj: &'a BTreeMap<String, Json>,
    field: &str,
) -> Result<&'a str> {
    match obj.get(field) {
        None => Err(req_err(format!("missing field '{field}'"))),
        Some(v) => v
            .as_str()
            .ok_or_else(|| req_err(format!("field '{field}' must be a string"))),
    }
}

/// A JSON number that is a non-negative integer exactly representable in
/// an f64 (the parser is f64-backed, so larger values would silently
/// round — reject them instead).
pub(crate) fn req_uint(v: &Json, field: &str) -> Result<u64> {
    let err = || {
        req_err(format!("field '{field}' must be a non-negative integer"))
    };
    let n = v.as_f64().ok_or_else(err)?;
    if !n.is_finite() || n < 0.0 || n.fract() != 0.0 || n > 2f64.powi(53) {
        return Err(err());
    }
    Ok(n as u64)
}

/// An ETSCH workload a request can attach to the produced partition.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Workload {
    /// Single-source shortest paths from `source`.
    Sssp {
        /// Source vertex.
        source: u32,
    },
}

/// The result of running a [`Workload`].
#[derive(Clone, Debug)]
pub struct WorkloadReport {
    /// Workload name (`"sssp"`).
    pub name: &'static str,
    /// ETSCH rounds executed.
    pub rounds: usize,
    /// Messages exchanged (change-driven count).
    pub messages: usize,
    /// Vertices reached / touched by the workload.
    pub reached: usize,
    /// Wall-clock seconds (engine build + run).
    pub secs: f64,
}

/// Wall-clock breakdown of one run.
#[derive(Clone, Copy, Debug, Default)]
pub struct Timings {
    /// Dataset resolution (generation/scaling) seconds.
    pub resolve_secs: f64,
    /// Partitioner seconds.
    pub partition_secs: f64,
    /// Shared-view build + metric evaluation seconds.
    pub evaluate_secs: f64,
}

/// Everything one run produced (the paper's per-plot quantities plus
/// timings and the partition itself).
#[derive(Clone, Debug)]
pub struct RunReport {
    /// Canonical spec string of the partitioner that ran.
    pub spec: String,
    /// The dataset spec that was resolved — set by
    /// [`execute`](PartitionRequest::execute); empty when the caller
    /// supplied the graph directly via
    /// [`execute_on`](PartitionRequest::execute_on) (the request's
    /// `dataset` field is not trusted to describe an arbitrary graph).
    pub dataset: String,
    /// Number of parts requested.
    pub k: usize,
    /// The run seed.
    pub seed: u64,
    /// `|V|` of the resolved graph.
    pub vertices: usize,
    /// `|E|` of the resolved graph.
    pub edges: usize,
    /// The §V-A metric report.
    pub metrics: Report,
    /// Path-compression gain (None when `gain_samples == 0`).
    pub gain: Option<f64>,
    /// The workload result, when one was attached.
    pub workload: Option<WorkloadReport>,
    /// Wall-clock breakdown.
    pub timings: Timings,
    /// The partition itself.
    pub partition: EdgePartition,
}

impl RunReport {
    /// Serialize the report as a flat `"v": 1` JSON object through the
    /// crate's one JSON writer (the same format the bench artifacts
    /// use). The per-edge ownership vector is *not* included — it is
    /// `|E|`-sized; callers that want it over the wire use
    /// [`to_json_with_owners`](Self::to_json_with_owners).
    pub fn to_json(&self) -> String {
        let mut sink = self.sink();
        sink.render()
    }

    /// [`to_json`](Self::to_json) plus an `"owners"` array (`owners[e]`
    /// = partition of edge `e`), so a remote client can reconstruct the
    /// partition bit-identically.
    pub fn to_json_with_owners(&self) -> String {
        let mut sink = self.sink();
        let cells: Vec<String> =
            self.partition.owner.iter().map(|o| o.to_string()).collect();
        sink.raw("owners", format!("[{}]", cells.join(",")));
        sink.render()
    }

    fn sink(&self) -> crate::bench::harness::JsonSink {
        let mut sink = crate::bench::harness::JsonSink::new();
        sink.num("v", 1.0);
        sink.text("spec", &self.spec);
        if !self.dataset.is_empty() {
            sink.text("dataset", &self.dataset);
        }
        sink.num("k", self.k as f64);
        sink.num("seed", self.seed as f64);
        sink.num("vertices", self.vertices as f64);
        sink.num("edges", self.edges as f64);
        sink.num("rounds", self.metrics.rounds as f64);
        sink.num("largest", self.metrics.largest);
        sink.num("nstdev", self.metrics.nstdev);
        sink.num("messages", self.metrics.messages as f64);
        sink.num("disconnected", self.metrics.disconnected);
        if let Some(gain) = self.gain {
            sink.num("gain", gain);
        }
        sink.num("resolve_secs", self.timings.resolve_secs);
        sink.num("partition_secs", self.timings.partition_secs);
        sink.num("evaluate_secs", self.timings.evaluate_secs);
        if let Some(w) = &self.workload {
            sink.text("workload", w.name);
            sink.num("workload_rounds", w.rounds as f64);
            sink.num("workload_messages", w.messages as f64);
            sink.num("workload_reached", w.reached as f64);
            sink.num("workload_secs", w.secs);
        }
        sink
    }

    /// Parse a `"v": 1` wire report back into a [`RunReport`]. Parsing
    /// is *lenient* (unknown fields are ignored — see the [module
    /// docs](self) for the asymmetric unknown-field policy); `spec` and
    /// `k` are required. The embedded [`EdgePartition`] is reconstructed
    /// from the `"owners"` array when present
    /// ([`to_json_with_owners`](Self::to_json_with_owners)); otherwise
    /// `partition.owner` comes back empty.
    pub fn from_json(text: &str) -> Result<RunReport> {
        let doc = crate::util::json::parse(text)
            .map_err(|e| Error::msg(format!("invalid report JSON: {e}")))?;
        let obj = doc
            .as_obj()
            .ok_or_else(|| Error::msg("report must be a JSON object"))?;
        Self::from_obj(obj)
    }

    /// [`from_json`](Self::from_json) on an already-parsed object — the
    /// batch wire format embeds run reports as array elements, so the
    /// batch parser feeds them through here without re-serializing.
    pub(crate) fn from_obj(obj: &BTreeMap<String, Json>) -> Result<RunReport> {
        check_version(obj)?;
        let spec = req_str(obj, "spec")?.to_string();
        let k = req_uint(
            obj.get("k").ok_or_else(|| Error::msg("missing field 'k'"))?,
            "k",
        )? as usize;
        let uint = |field: &str| -> Result<u64> {
            match obj.get(field) {
                Some(v) => req_uint(v, field),
                None => Ok(0),
            }
        };
        let num = |field: &str| -> Result<f64> {
            match obj.get(field) {
                Some(v) => v.as_f64().ok_or_else(|| {
                    Error::msg(format!("field '{field}' must be a number"))
                }),
                None => Ok(0.0),
            }
        };
        let metrics = Report {
            k,
            largest: num("largest")?,
            nstdev: num("nstdev")?,
            messages: uint("messages")? as usize,
            rounds: uint("rounds")? as usize,
            disconnected: num("disconnected")?,
        };
        let gain = match obj.get("gain") {
            Some(v) => Some(v.as_f64().ok_or_else(|| {
                Error::msg("field 'gain' must be a number")
            })?),
            None => None,
        };
        let workload = match obj.get("workload").and_then(|v| v.as_str()) {
            // `name` is &'static str in-process; map the one known name
            Some(name) => Some(WorkloadReport {
                name: if name == "sssp" { "sssp" } else { "unknown" },
                rounds: uint("workload_rounds")? as usize,
                messages: uint("workload_messages")? as usize,
                reached: uint("workload_reached")? as usize,
                secs: num("workload_secs")?,
            }),
            None => None,
        };
        let owner: Vec<u32> = match obj.get("owners").and_then(|v| v.as_arr())
        {
            Some(cells) => {
                let mut owner = Vec::with_capacity(cells.len());
                for c in cells {
                    owner.push(req_uint(c, "owners")? as u32);
                }
                owner
            }
            None => Vec::new(),
        };
        let rounds = metrics.rounds;
        Ok(RunReport {
            spec,
            dataset: obj
                .get("dataset")
                .and_then(|v| v.as_str())
                .unwrap_or("")
                .to_string(),
            k,
            seed: uint("seed")?,
            vertices: uint("vertices")? as usize,
            edges: uint("edges")? as usize,
            metrics,
            gain,
            workload,
            timings: Timings {
                resolve_secs: num("resolve_secs")?,
                partition_secs: num("partition_secs")?,
                evaluate_secs: num("evaluate_secs")?,
            },
            partition: EdgePartition { k, owner, rounds },
        })
    }
}

impl PartitionRequest {
    /// Resolve the dataset, then [`execute_on`](Self::execute_on) it.
    pub fn execute(&self) -> Result<RunReport> {
        let (g, resolve_secs) = crate::util::timer::time(|| {
            resolve_graph(&self.dataset, self.graph_seed)
        });
        let g = g?;
        let mut report = self.execute_on(&g)?;
        report.dataset = self.dataset.clone();
        report.timings.resolve_secs = resolve_secs;
        Ok(report)
    }

    /// Run on an already-resolved graph (the benches resolve once and
    /// execute many requests against it). Honors the
    /// [`threads`](Self::threads) override for the entire run.
    pub fn execute_on(&self, g: &Graph) -> Result<RunReport> {
        match self.threads {
            Some(t) => pool::with_threads(t, || self.run_inner(g)),
            None => self.run_inner(g),
        }
    }

    fn run_inner(&self, g: &Graph) -> Result<RunReport> {
        let partitioner = self.spec.build();
        let (partition, partition_secs) = crate::util::timer::time(|| {
            partitioner.partition_graph(g, self.k, self.seed)
        });
        let partition = partition?;
        partition.validate(g)?;
        // one shared derived-state build serves the metrics, the gain
        // estimate and the attached workload
        let (out, evaluate_secs) = crate::util::timer::time(|| {
            let view = PartitionView::build(g, &partition);
            let metrics = metrics::evaluate_with(g, &partition, &view);
            let gain = (self.gain_samples > 0).then(|| {
                let mut engine = Etsch::from_view(g, &view);
                gain::average_gain_with(
                    g,
                    &mut engine,
                    self.gain_samples,
                    self.seed,
                )
            });
            let workload = self
                .workload
                .map(|w| run_workload(g, &view, w));
            (metrics, gain, workload)
        });
        let (metrics, gain, workload) = out;
        Ok(RunReport {
            spec: self.spec.to_string(),
            // only execute() (which resolved the graph itself) knows the
            // graph really is self.dataset; direct execute_on callers get
            // an empty field instead of a possibly-wrong label
            dataset: String::new(),
            k: self.k,
            seed: self.seed,
            vertices: g.vertex_count(),
            edges: g.edge_count(),
            metrics,
            gain,
            workload,
            timings: Timings {
                resolve_secs: 0.0,
                partition_secs,
                evaluate_secs,
            },
            partition,
        })
    }
}

fn run_workload(
    g: &Graph,
    view: &PartitionView,
    w: Workload,
) -> WorkloadReport {
    match w {
        Workload::Sssp { source } => {
            let (out, secs) = crate::util::timer::time(|| {
                let mut engine = Etsch::from_view(g, view);
                let dist = engine.run(&mut Sssp::new(source));
                let stats = engine.stats().clone();
                (dist, stats)
            });
            let (dist, stats) = out;
            WorkloadReport {
                name: "sssp",
                rounds: stats.rounds,
                messages: stats.messages_exchanged,
                reached: dist
                    .iter()
                    .filter(|&&d| d != crate::etsch::sssp::UNREACHED)
                    .count(),
                secs,
            }
        }
    }
}

/// Resolve a graph source: a named dataset ("astroph", optionally scaled
/// like "astroph@0.1") or a generator spec ("er:n=1000,m=3000").
///
/// Errors are kind-tagged for the serving layer: an unresolvable name is
/// [`ErrorKind::DatasetNotFound`], a malformed scale fraction or
/// generator argument is [`ErrorKind::InvalidRequest`].
pub fn resolve_graph(spec: &str, seed: u64) -> Result<Graph> {
    if let Some((name, frac)) = spec.split_once('@') {
        let d = datasets::by_name(name).ok_or_else(|| {
            anyhow!("unknown dataset '{name}'")
                .with_kind(ErrorKind::DatasetNotFound)
        })?;
        let frac: f64 = frac.parse().map_err(|_| {
            anyhow!("bad scale fraction '{frac}' in '{spec}'")
                .with_kind(ErrorKind::InvalidRequest)
        })?;
        return Ok(d.scaled(frac, seed));
    }
    if let Some(d) = datasets::by_name(spec) {
        return Ok(d.generate(seed));
    }
    if let Some((kind, args)) = spec.split_once(':') {
        let mut n = 1000usize;
        let mut m = 3000usize;
        let mut p = 0.3f64;
        for kv in args.split(',') {
            let (key, val) = kv.split_once('=').ok_or_else(|| {
                anyhow!("bad generator arg '{kv}'")
                    .with_kind(ErrorKind::InvalidRequest)
            })?;
            let bad_num = || {
                anyhow!("generator key '{key}': bad number '{val}'")
                    .with_kind(ErrorKind::InvalidRequest)
            };
            match key {
                "n" => n = val.parse().map_err(|_| bad_num())?,
                "m" => m = val.parse().map_err(|_| bad_num())?,
                "p" => p = val.parse().map_err(|_| bad_num())?,
                _ => {
                    return Err(anyhow!("unknown generator key '{key}'")
                        .with_kind(ErrorKind::InvalidRequest))
                }
            }
        }
        let g = match kind {
            "er" => GraphKind::ErdosRenyi { n, m },
            "ba" => GraphKind::BarabasiAlbert { n, m: m.min(12) },
            "plc" => GraphKind::PowerlawCluster { n, m: m.min(12), p },
            "road" => {
                let side = (n as f64).sqrt() as usize;
                GraphKind::RoadNetwork {
                    rows: side.max(4),
                    cols: side.max(4),
                    drop: 0.2,
                    subdiv: 3,
                    shortcuts: 0,
                }
            }
            other => {
                return Err(anyhow!("unknown generator '{other}'")
                    .with_kind(ErrorKind::DatasetNotFound))
            }
        };
        return Ok(g.generate(seed));
    }
    Err(anyhow!(
        "cannot resolve graph '{spec}' (try astroph, usroads, \
         astroph@0.1, er:n=1000,m=3000)"
    )
    .with_kind(ErrorKind::DatasetNotFound))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolve_named_and_scaled() {
        assert!(resolve_graph("astroph@0.02", 1).is_ok());
        assert!(resolve_graph("er:n=200,m=500", 1).is_ok());
        assert!(resolve_graph("bogus", 1).is_err());
        assert!(resolve_graph("er:n=abc", 1).is_err());
    }

    #[test]
    fn request_produces_full_report() {
        let req = PartitionRequest::new("dfep")
            .unwrap()
            .dataset("er:n=300,m=900")
            .k(4)
            .seed(3)
            .graph_seed(2)
            .gain_samples(2)
            .workload(Workload::Sssp { source: 0 });
        let res = req.execute().unwrap();
        let g = resolve_graph("er:n=300,m=900", 2).unwrap();
        res.partition.validate(&g).unwrap();
        assert!(res.gain.unwrap() >= 0.0);
        assert!(res.metrics.rounds > 0);
        let w = res.workload.as_ref().unwrap();
        assert_eq!(w.name, "sssp");
        assert!(w.reached > 0);
        // the JSON serialization parses back and carries the key fields
        let parsed = crate::util::json::parse(&res.to_json()).unwrap();
        assert_eq!(
            parsed.get("spec").unwrap().as_str().unwrap(),
            "dfep"
        );
        assert_eq!(
            parsed.get("k").unwrap().as_usize().unwrap(),
            4
        );
        assert!(parsed.get("workload_rounds").is_some());
    }

    #[test]
    fn bad_specs_and_datasets_error() {
        let mut req =
            PartitionRequest::new("dfep").unwrap().dataset("nosuchdataset");
        let e = req.execute().unwrap_err();
        assert_eq!(e.kind(), ErrorKind::DatasetNotFound);
        req.dataset = "er:n=100,m=200".to_string();
        req.k = 0;
        let e = req.execute().unwrap_err().to_string();
        assert!(e.contains("k must be >= 1"), "{e}");
    }

    #[test]
    fn parameterized_spec_flows_through() {
        let g = resolve_graph("er:n=200,m=600", 1).unwrap();
        let req = PartitionRequest::new("hdrf:lambda=1.5").unwrap().k(6).seed(2);
        let res = req.execute_on(&g).unwrap();
        assert_eq!(res.spec, "hdrf:lambda=1.5");
        res.partition.validate(&g).unwrap();
    }

    #[test]
    fn request_json_round_trips() {
        let req = PartitionRequest::new("hdrf:lambda=1.5")
            .unwrap()
            .dataset("er:n=200,m=600")
            .k(6)
            .seed(9)
            .graph_seed(3)
            .gain_samples(2)
            .threads(2)
            .workload(Workload::Sssp { source: 7 });
        let back = PartitionRequest::from_json(&req.to_json()).unwrap();
        assert_eq!(back, req);
        // optional fields defaulted: minimal request parses
        let min = PartitionRequest::from_json(
            r#"{"spec": "dfep", "dataset": "astroph@0.02"}"#,
        )
        .unwrap();
        assert_eq!(min.k, PartitionRequest::default().k);
        assert_eq!(min.threads, None);
        assert_eq!(min.workload, None);
    }

    #[test]
    fn request_json_is_strict() {
        let err = |t: &str| PartitionRequest::from_json(t).unwrap_err();
        // non-JSON, non-object, unknown field, bad version
        assert_eq!(err("nope").kind(), ErrorKind::InvalidRequest);
        assert_eq!(err("[1]").kind(), ErrorKind::InvalidRequest);
        let e = err(r#"{"spec": "dfep", "dataset": "astroph", "kk": 3}"#);
        assert_eq!(e.kind(), ErrorKind::InvalidRequest);
        assert!(e.to_string().contains("unknown request field 'kk'"), "{e}");
        let e = err(r#"{"v": 2, "spec": "dfep", "dataset": "astroph"}"#);
        assert!(e.to_string().contains("unsupported wire version"), "{e}");
        // missing requireds, zero k/threads, fractional numerics
        assert_eq!(err(r#"{"dataset": "astroph"}"#).kind(), ErrorKind::InvalidRequest);
        assert_eq!(err(r#"{"spec": "dfep"}"#).kind(), ErrorKind::InvalidRequest);
        let base = r#"{"spec": "dfep", "dataset": "astroph""#;
        assert_eq!(err(&format!("{base}, \"k\": 0}}")).kind(), ErrorKind::InvalidRequest);
        assert_eq!(err(&format!("{base}, \"threads\": 0}}")).kind(), ErrorKind::InvalidRequest);
        assert_eq!(err(&format!("{base}, \"k\": 2.5}}")).kind(), ErrorKind::InvalidRequest);
        assert_eq!(
            err(&format!("{base}, \"workload_source\": 3}}")).kind(),
            ErrorKind::InvalidRequest
        );
        assert_eq!(
            err(&format!("{base}, \"workload\": \"pagerank\"}}")).kind(),
            ErrorKind::InvalidRequest
        );
        // a bad spec keeps its InvalidSpec kind
        let e = err(r#"{"spec": "hdrf:lambda=abc", "dataset": "astroph"}"#);
        assert_eq!(e.kind(), ErrorKind::InvalidSpec);
    }

    #[test]
    fn report_json_round_trips_with_owners() {
        let req = PartitionRequest::new("dfep")
            .unwrap()
            .dataset("er:n=200,m=600")
            .k(4)
            .seed(5)
            .graph_seed(1)
            .gain_samples(1)
            .workload(Workload::Sssp { source: 0 });
        let res = req.execute().unwrap();
        let back = RunReport::from_json(&res.to_json_with_owners()).unwrap();
        assert_eq!(back.spec, res.spec);
        assert_eq!(back.dataset, res.dataset);
        assert_eq!(back.k, res.k);
        assert_eq!(back.seed, res.seed);
        assert_eq!(back.vertices, res.vertices);
        assert_eq!(back.edges, res.edges);
        assert_eq!(back.metrics.nstdev.to_bits(), res.metrics.nstdev.to_bits());
        assert_eq!(back.metrics.largest.to_bits(), res.metrics.largest.to_bits());
        assert_eq!(back.metrics.messages, res.metrics.messages);
        assert_eq!(back.gain.unwrap().to_bits(), res.gain.unwrap().to_bits());
        assert_eq!(back.partition.owner, res.partition.owner);
        assert_eq!(back.partition.rounds, res.partition.rounds);
        let w = back.workload.as_ref().unwrap();
        assert_eq!(w.name, "sssp");
        assert_eq!(w.messages, res.workload.as_ref().unwrap().messages);
        // without owners the partition comes back empty (documented)
        let lean = RunReport::from_json(&res.to_json()).unwrap();
        assert!(lean.partition.owner.is_empty());
        // lenient: unknown report fields are ignored
        let ok = RunReport::from_json(
            r#"{"spec": "dfep", "k": 2, "brand_new_field": 1}"#,
        )
        .unwrap();
        assert_eq!(ok.k, 2);
    }
}
