//! End-to-end run orchestration: dataset -> partitioner -> metrics ->
//! optional ETSCH workload — the single entry point the CLI, examples and
//! benches all share.

use crate::anyhow;
use crate::util::error::Result;

use crate::etsch::{gain, sssp::Sssp, Etsch};
use crate::graph::{datasets, generators::GraphKind, Graph};
use crate::partition::{
    baselines::{GreedyBfs, HashEdge, RandomEdge},
    dfep::Dfep,
    dfepc::Dfepc,
    fennel::StreamingGreedy,
    jabeja::JaBeJa,
    metrics::{self, Report},
    multilevel::Multilevel,
    streaming::{Dbh, Hdrf, Restream},
    view::PartitionView,
    EdgePartition, Partitioner,
};

/// Which partitioner to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PartitionerKind {
    /// The paper's funding-based partitioner ([`Dfep`]).
    Dfep,
    /// The §IV-A variant with poor/rich raids ([`Dfepc`]).
    Dfepc,
    /// The comparison baseline ([`JaBeJa`]).
    JaBeJa,
    /// Uniform random edge assignment ([`RandomEdge`]).
    Random,
    /// Round-robin edge assignment ([`HashEdge`]).
    Hash,
    /// Lockstep greedy BFS growth ([`GreedyBfs`]).
    GreedyBfs,
    /// Fennel-style streaming greedy ([`StreamingGreedy`]).
    Streaming,
    /// METIS-style multilevel partitioner ([`Multilevel`]).
    Multilevel,
    /// Ingest-time degree-aware greedy ([`Hdrf`]).
    Hdrf,
    /// Ingest-time degree-based hashing ([`Dbh`]).
    Dbh,
    /// HDRF plus restreaming refinement ([`Restream`]).
    Restream,
}

impl PartitionerKind {
    /// Parse a CLI `--algo` string (case-insensitive).
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_lowercase().as_str() {
            "dfep" => Self::Dfep,
            "dfepc" => Self::Dfepc,
            "jabeja" | "ja-be-ja" => Self::JaBeJa,
            "random" => Self::Random,
            "hash" => Self::Hash,
            "greedy" | "greedybfs" => Self::GreedyBfs,
            "streaming" | "fennel" => Self::Streaming,
            "multilevel" | "metis" => Self::Multilevel,
            "hdrf" => Self::Hdrf,
            "dbh" => Self::Dbh,
            "restream" | "re-stream" => Self::Restream,
            other => return Err(anyhow!("unknown partitioner '{other}'")),
        })
    }

    /// Construct the partitioner with its default configuration.
    pub fn build(&self) -> Box<dyn Partitioner> {
        match self {
            Self::Dfep => Box::new(Dfep::default()),
            Self::Dfepc => Box::new(Dfepc::default()),
            Self::JaBeJa => Box::new(JaBeJa::default()),
            Self::Random => Box::new(RandomEdge),
            Self::Hash => Box::new(HashEdge),
            Self::GreedyBfs => Box::new(GreedyBfs),
            Self::Streaming => Box::new(StreamingGreedy::default()),
            Self::Multilevel => Box::new(Multilevel::default()),
            Self::Hdrf => Box::new(Hdrf::default()),
            Self::Dbh => Box::new(Dbh::default()),
            Self::Restream => Box::new(Restream::default()),
        }
    }

    /// Every kind, in display order (the ablation sweep iterates this).
    pub fn all() -> &'static [PartitionerKind] {
        &[
            Self::Dfep,
            Self::Dfepc,
            Self::JaBeJa,
            Self::Random,
            Self::Hash,
            Self::GreedyBfs,
            Self::Streaming,
            Self::Multilevel,
            Self::Hdrf,
            Self::Dbh,
            Self::Restream,
        ]
    }
}

/// A single experiment configuration.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Which partitioner to run.
    pub partitioner: PartitionerKind,
    /// Number of parts.
    pub k: usize,
    /// Seed controlling all randomness of the run.
    pub seed: u64,
    /// sources for the gain estimate (0 = skip gain)
    pub gain_samples: usize,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            partitioner: PartitionerKind::Dfep,
            k: 20,
            seed: 1,
            gain_samples: 0,
        }
    }
}

/// Metrics of one run (the paper's per-plot quantities).
#[derive(Clone, Debug)]
pub struct RunResult {
    /// The §V-A metric report.
    pub report: Report,
    /// Path-compression gain (None when `gain_samples == 0`).
    pub gain: Option<f64>,
    /// The partition itself.
    pub partition: EdgePartition,
    /// Wall-clock seconds the partitioner took.
    pub partition_secs: f64,
}

/// Resolve a graph source: a named dataset ("astroph", optionally scaled
/// like "astroph@0.1") or a generator spec ("er:n=1000,m=3000").
pub fn resolve_graph(spec: &str, seed: u64) -> Result<Graph> {
    if let Some((name, frac)) = spec.split_once('@') {
        let d = datasets::by_name(name)
            .ok_or_else(|| anyhow!("unknown dataset '{name}'"))?;
        let frac: f64 = frac.parse()?;
        return Ok(d.scaled(frac, seed));
    }
    if let Some(d) = datasets::by_name(spec) {
        return Ok(d.generate(seed));
    }
    if let Some((kind, args)) = spec.split_once(':') {
        let mut n = 1000usize;
        let mut m = 3000usize;
        let mut p = 0.3f64;
        for kv in args.split(',') {
            let (key, val) = kv
                .split_once('=')
                .ok_or_else(|| anyhow!("bad generator arg '{kv}'"))?;
            match key {
                "n" => n = val.parse()?,
                "m" => m = val.parse()?,
                "p" => p = val.parse()?,
                _ => return Err(anyhow!("unknown generator key '{key}'")),
            }
        }
        let g = match kind {
            "er" => GraphKind::ErdosRenyi { n, m },
            "ba" => GraphKind::BarabasiAlbert { n, m: m.min(12) },
            "plc" => GraphKind::PowerlawCluster { n, m: m.min(12), p },
            "road" => {
                let side = (n as f64).sqrt() as usize;
                GraphKind::RoadNetwork {
                    rows: side.max(4),
                    cols: side.max(4),
                    drop: 0.2,
                    subdiv: 3,
                    shortcuts: 0,
                }
            }
            other => return Err(anyhow!("unknown generator '{other}'")),
        };
        return Ok(g.generate(seed));
    }
    Err(anyhow!(
        "cannot resolve graph '{spec}' (try astroph, usroads, \
         astroph@0.1, er:n=1000,m=3000)"
    ))
}

/// Run one experiment.
pub fn run(g: &Graph, cfg: &RunConfig) -> RunResult {
    let partitioner = cfg.partitioner.build();
    let (partition, partition_secs) = crate::util::timer::time(|| {
        partitioner.partition(g, cfg.k, cfg.seed)
    });
    // one shared derived-state build serves the metrics and (when gain is
    // requested) every ETSCH run
    let view = PartitionView::build(g, &partition);
    let report = metrics::evaluate_with(g, &partition, &view);
    let gain = if cfg.gain_samples > 0 {
        let mut engine = Etsch::from_view(g, &view);
        Some(gain::average_gain_with(
            g,
            &mut engine,
            cfg.gain_samples,
            cfg.seed,
        ))
    } else {
        None
    };
    RunResult { report, gain, partition, partition_secs }
}

/// Convenience: run ETSCH SSSP on a partition and report rounds/messages.
pub fn run_sssp(
    g: &Graph,
    p: &EdgePartition,
    source: u32,
) -> (Vec<u32>, usize, usize) {
    let mut engine = Etsch::new(g, p);
    let dist = engine.run(&mut Sssp::new(source));
    let stats = engine.stats();
    (dist, stats.rounds, stats.messages_exchanged)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolve_named_and_scaled() {
        assert!(resolve_graph("astroph@0.02", 1).is_ok());
        assert!(resolve_graph("er:n=200,m=500", 1).is_ok());
        assert!(resolve_graph("bogus", 1).is_err());
        assert!(resolve_graph("er:n=abc", 1).is_err());
    }

    #[test]
    fn run_produces_metrics() {
        let g = resolve_graph("er:n=300,m=900", 2).unwrap();
        let cfg = RunConfig {
            partitioner: PartitionerKind::Dfep,
            k: 4,
            seed: 3,
            gain_samples: 2,
        };
        let res = run(&g, &cfg);
        res.partition.validate(&g).unwrap();
        assert!(res.gain.unwrap() >= 0.0);
        assert!(res.report.rounds > 0);
    }

    #[test]
    fn parse_all_partitioners() {
        for s in ["dfep", "DFEPC", "jabeja", "random", "hash", "greedy",
                  "fennel", "multilevel", "hdrf", "DBH", "restream"] {
            assert!(PartitionerKind::parse(s).is_ok(), "{s}");
        }
        assert!(PartitionerKind::parse("x").is_err());
    }
}
