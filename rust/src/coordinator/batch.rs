//! Batched multi-variant execution: one graph, many `(spec, k, seed)`
//! runs — the engine behind `repro batch`, the serving layer's
//! `POST /batch` and the figure benches' parameter sweeps.
//!
//! A [`BatchRequest`] names the dataset **once** and a list of
//! [`Variant`]s to run against it. Variant specs are ordinary registry
//! specs, so `refine:base=<spec>` sweeps (refined vs unrefined cells)
//! batch like any other variant. [`BatchRequest::execute`] resolves
//! the graph once, profiles it once ([`SharedPrep`] — the degree array
//! and stream-order hints every variant would otherwise re-derive), and
//! then fans the variants out over the ambient
//! [`pool`](crate::util::pool) in *lanes*:
//!
//! - lane `l` executes variant indices `l, l + lanes, l + 2·lanes, ...`
//!   in order, entirely on one pool worker;
//! - inside a lane every variant runs under
//!   [`pool::with_inline`](crate::util::pool::with_inline), so the
//!   variant's own data-parallel phases (funding rounds, view build,
//!   metrics) execute as sequential loops instead of re-submitting to
//!   the pool the lanes occupy — variant-level parallelism replaces
//!   round-level parallelism, which is the right trade for sweeps (N
//!   independent runs saturate the pool with zero synchronization per
//!   round);
//! - a lane's DFEP/DFEPC variants chain through the engine's per-thread
//!   parked state (see
//!   [`DfepState::reset`](crate::partition::dfep::DfepState::reset)):
//!   the `k x n` money ledger, the round scratch and the owner/degree
//!   buffers are allocated by the lane's first variant and *reused* by
//!   every later one, so steady-state rounds allocate nothing
//!   (`tests/batch.rs` pins this with a counting allocator).
//!
//! ## Determinism
//!
//! Results are merged into [`BatchReport::reports`] by **variant
//! index**, never by completion or lane order. Each variant is executed
//! by the exact sequential facade
//! ([`PartitionRequest::execute_on`]) under an inline (1-thread) pool,
//! and the crate-wide pool contract makes every run a pure function of
//! `(graph, request)` independent of thread count — so a batch is
//! bit-identical to running its variants sequentially, at any lane
//! count, in any variant order (`tests/batch.rs`).
//!
//! ## Wire format (`"v": 1`)
//!
//! [`BatchRequest::to_json`] / [`from_json`](BatchRequest::from_json)
//! and [`BatchReport::to_json`] /
//! [`from_json`](BatchReport::from_json) follow the same conventions as
//! the single-run wire format in [`super::runs`]: strict requests
//! (unknown fields rejected), lenient reports, version-gated with
//! `"v": 1`. The report embeds one full run report (with owners) per
//! variant, in variant order.

use crate::bench::harness::JsonSink;
use crate::graph::Graph;
use crate::partition::spec::PartitionerSpec;
use crate::util::error::Result;
use crate::util::pool;

use super::runs::{
    check_version, req_err, req_str, req_uint, resolve_graph,
    PartitionRequest, RunReport, Workload,
};

/// One run of a batch: which partitioner, how many parts, which seed.
/// Everything else (dataset, graph seed, gain sampling, workload) is
/// batch-level — shared by every variant.
#[derive(Clone, Debug, PartialEq)]
pub struct Variant {
    /// Partitioner spec string (`dfep`, `hdrf:lambda=1.5`, ...).
    pub spec: PartitionerSpec,
    /// Number of parts.
    pub k: usize,
    /// Partitioner run seed.
    pub seed: u64,
}

impl Variant {
    /// Parse a spec string into a variant (spec errors carry
    /// [`ErrorKind::InvalidSpec`](crate::util::error::ErrorKind)).
    pub fn new(spec: &str, k: usize, seed: u64) -> Result<Variant> {
        Ok(Variant { spec: PartitionerSpec::parse(spec)?, k, seed })
    }
}

/// A multi-variant experiment against one resolved graph. Build with
/// [`new`](Self::new) and the chainable setters, mirroring
/// [`PartitionRequest`]'s construction idiom.
#[derive(Clone, Debug, PartialEq)]
pub struct BatchRequest {
    /// Graph spec shared by every variant (see
    /// [`resolve_graph`](super::runs::resolve_graph)).
    pub dataset: String,
    /// Seed for dataset generation/scaling.
    pub graph_seed: u64,
    /// The runs to execute, in report order.
    pub variants: Vec<Variant>,
    /// Gain-estimate sources per variant (0 = skip).
    pub gain_samples: usize,
    /// Optional ETSCH workload attached to every variant.
    pub workload: Option<Workload>,
    /// Pool-thread override for the whole batch (`None` = ambient pool).
    pub threads: Option<usize>,
}

impl BatchRequest {
    /// A batch against `dataset` with the default graph seed and no
    /// variants yet.
    pub fn new(dataset: impl Into<String>) -> BatchRequest {
        BatchRequest {
            dataset: dataset.into(),
            graph_seed: PartitionRequest::default().graph_seed,
            variants: Vec::new(),
            gain_samples: 0,
            workload: None,
            threads: None,
        }
    }

    /// Set the dataset generation/scaling seed.
    pub fn graph_seed(mut self, graph_seed: u64) -> Self {
        self.graph_seed = graph_seed;
        self
    }

    /// Append one variant.
    pub fn variant(mut self, v: Variant) -> Self {
        self.variants.push(v);
        self
    }

    /// Set the per-variant gain-sample count (0 skips the estimate).
    pub fn gain_samples(mut self, gain_samples: usize) -> Self {
        self.gain_samples = gain_samples;
        self
    }

    /// Attach an ETSCH workload to every variant.
    pub fn workload(mut self, workload: Workload) -> Self {
        self.workload = Some(workload);
        self
    }

    /// Pin the pool-thread count for the whole batch.
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = Some(threads);
        self
    }

    /// Resolve the dataset once, then
    /// [`execute_on`](Self::execute_on) it.
    pub fn execute(&self) -> Result<BatchReport> {
        let (g, resolve_secs) = crate::util::timer::time(|| {
            resolve_graph(&self.dataset, self.graph_seed)
        });
        let g = g?;
        let mut report = self.execute_on(&g)?;
        report.dataset = self.dataset.clone();
        for r in &mut report.reports {
            r.dataset = self.dataset.clone();
        }
        report.resolve_secs = resolve_secs;
        Ok(report)
    }

    /// Run every variant against an already-resolved graph. Honors the
    /// [`threads`](Self::threads) override for the entire batch.
    ///
    /// Fails fast (before any variant runs) on an empty variant list or
    /// `k == 0`; a variant that fails *during* execution surfaces as the
    /// error of the lowest failing variant index, matching what a
    /// sequential loop over
    /// [`PartitionRequest::execute_on`] would return first.
    pub fn execute_on(&self, g: &Graph) -> Result<BatchReport> {
        match self.threads {
            Some(t) => pool::with_threads(t, || self.run_inner(g)),
            None => self.run_inner(g),
        }
    }

    /// The request each variant expands to — exactly what a sequential
    /// caller would execute (the bit-equality baseline in
    /// `tests/batch.rs`).
    pub fn request_for(&self, v: &Variant) -> PartitionRequest {
        let mut req = PartitionRequest::of(v.spec.clone())
            .dataset(&*self.dataset)
            .k(v.k)
            .seed(v.seed)
            .graph_seed(self.graph_seed)
            .gain_samples(self.gain_samples);
        if let Some(w) = self.workload {
            req = req.workload(w);
        }
        req
    }

    fn run_inner(&self, g: &Graph) -> Result<BatchReport> {
        if self.variants.is_empty() {
            return Err(req_err("batch has no variants"));
        }
        if let Some(v) = self.variants.iter().find(|v| v.k == 0) {
            return Err(req_err(format!(
                "variant '{}' has k == 0 (must be >= 1)",
                v.spec
            )));
        }
        let (shared, shared_secs) =
            crate::util::timer::time(|| SharedPrep::compute(g));
        let reqs: Vec<PartitionRequest> =
            self.variants.iter().map(|v| self.request_for(v)).collect();

        struct Lane {
            /// `(variant index, outcome)` in lane execution order.
            results: Vec<(usize, Result<RunReport>)>,
            /// Parked-state scratch high-water after the lane finished.
            peak_bytes: usize,
        }
        let nvars = reqs.len();
        let lanes = pool::current_threads().min(nvars).max(1);
        let mut outs: Vec<Lane> = (0..lanes)
            .map(|_| Lane { results: Vec::new(), peak_bytes: 0 })
            .collect();
        let (_, exec_secs) = crate::util::timer::time(|| {
            pool::run_mut(&mut outs, &|l, lane| {
                // round-level parallelism off, variant-level on: the
                // inner facade runs single-threaded on this worker, and
                // its DFEP states chain through the worker's parked
                // state across the lane's variants
                pool::with_inline(|| {
                    let mut idx = l;
                    while idx < nvars {
                        lane.results.push((idx, reqs[idx].execute_on(g)));
                        idx += lanes;
                    }
                    lane.peak_bytes =
                        crate::partition::dfep::parked_scratch_peak_bytes();
                });
            });
        });

        // merge strictly by variant index — lane assignment and
        // completion order never reach the report
        let mut slots: Vec<Option<Result<RunReport>>> =
            (0..nvars).map(|_| None).collect();
        let mut peak_bytes = 0usize;
        for lane in outs {
            peak_bytes = peak_bytes.max(lane.peak_bytes);
            for (idx, res) in lane.results {
                slots[idx] = Some(res);
            }
        }
        let mut reports = Vec::with_capacity(nvars);
        for slot in slots {
            reports.push(slot.expect("every variant index was assigned")?);
        }
        Ok(BatchReport {
            dataset: String::new(),
            vertices: g.vertex_count(),
            edges: g.edge_count(),
            shared,
            reports,
            lanes,
            resolve_secs: 0.0,
            shared_secs,
            exec_secs,
            scratch_peak_bytes: peak_bytes,
        })
    }

    /// Serialize as a `"v": 1` wire request: the batch-level fields plus
    /// a `"variants"` array of `{spec, k, seed}` objects.
    pub fn to_json(&self) -> String {
        let mut sink = JsonSink::new();
        sink.num("v", 1.0);
        sink.text("dataset", &self.dataset);
        sink.num("graph_seed", self.graph_seed as f64);
        sink.num("gain_samples", self.gain_samples as f64);
        if let Some(t) = self.threads {
            sink.num("threads", t as f64);
        }
        if let Some(Workload::Sssp { source }) = self.workload {
            sink.text("workload", "sssp");
            sink.num("workload_source", source as f64);
        }
        let vars: Vec<String> = self
            .variants
            .iter()
            .map(|v| {
                let mut vs = JsonSink::new();
                vs.text("spec", &v.spec.to_string());
                vs.num("k", v.k as f64);
                vs.num("seed", v.seed as f64);
                vs.render()
            })
            .collect();
        sink.raw("variants", format!("[{}]", vars.join(",")));
        sink.render()
    }

    /// Parse a `"v": 1` wire request. Strict like the single-run parser:
    /// unknown fields (at the top level and inside variant objects), a
    /// bad version, non-integer numerics, `k == 0`, `threads == 0`, a
    /// missing or empty `variants` array — all
    /// [`ErrorKind::InvalidRequest`](crate::util::error::ErrorKind)
    /// errors; bad spec strings keep
    /// [`ErrorKind::InvalidSpec`](crate::util::error::ErrorKind).
    pub fn from_json(text: &str) -> Result<BatchRequest> {
        const KNOWN: [&str; 7] = [
            "v",
            "dataset",
            "graph_seed",
            "gain_samples",
            "threads",
            "workload",
            "variants",
        ];
        let doc = crate::util::json::parse(text)
            .map_err(|e| req_err(format!("invalid batch JSON: {e}")))?;
        let obj = doc
            .as_obj()
            .ok_or_else(|| req_err("batch request must be a JSON object"))?;
        for key in obj.keys() {
            let known = KNOWN.contains(&key.as_str())
                || key == "workload_source";
            if !known {
                return Err(req_err(format!(
                    "unknown batch field '{key}' (known: {}, \
                     workload_source)",
                    KNOWN.join(", ")
                )));
            }
        }
        check_version(obj)?;
        let mut req = BatchRequest::new(req_str(obj, "dataset")?);
        if let Some(v) = obj.get("graph_seed") {
            req = req.graph_seed(req_uint(v, "graph_seed")?);
        }
        if let Some(v) = obj.get("gain_samples") {
            req = req.gain_samples(req_uint(v, "gain_samples")? as usize);
        }
        if let Some(v) = obj.get("threads") {
            let t = req_uint(v, "threads")? as usize;
            if t == 0 {
                return Err(req_err("field 'threads' must be >= 1"));
            }
            req = req.threads(t);
        }
        match obj.get("workload") {
            None => {
                if obj.contains_key("workload_source") {
                    return Err(req_err(
                        "field 'workload_source' requires 'workload'",
                    ));
                }
            }
            Some(w) => {
                let name = w.as_str().ok_or_else(|| {
                    req_err("field 'workload' must be a string")
                })?;
                if name != "sssp" {
                    return Err(req_err(format!(
                        "unknown workload '{name}' (known: sssp)"
                    )));
                }
                let source = match obj.get("workload_source") {
                    Some(v) => req_uint(v, "workload_source")? as u32,
                    None => 0,
                };
                req = req.workload(Workload::Sssp { source });
            }
        }
        let vars = obj
            .get("variants")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| {
                req_err("field 'variants' must be an array of objects")
            })?;
        if vars.is_empty() {
            return Err(req_err("field 'variants' must not be empty"));
        }
        for v in vars {
            let vobj = v.as_obj().ok_or_else(|| {
                req_err("each variant must be a JSON object")
            })?;
            for key in vobj.keys() {
                if !["spec", "k", "seed"].contains(&key.as_str()) {
                    return Err(req_err(format!(
                        "unknown variant field '{key}' \
                         (known: spec, k, seed)"
                    )));
                }
            }
            let spec = PartitionerSpec::parse(req_str(vobj, "spec")?)?;
            let defaults = PartitionRequest::default();
            let k = match vobj.get("k") {
                Some(v) => req_uint(v, "k")? as usize,
                None => defaults.k,
            };
            if k == 0 {
                return Err(req_err("variant field 'k' must be >= 1"));
            }
            let seed = match vobj.get("seed") {
                Some(v) => req_uint(v, "seed")?,
                None => defaults.seed,
            };
            req = req.variant(Variant { spec, k, seed });
        }
        Ok(req)
    }
}

/// Read-only state derived from the graph once per batch — what every
/// variant would otherwise recompute on its own: the per-vertex degree
/// array (the CSR offset deltas the streaming baselines and the DFEP
/// free-degree initialization both re-derive) and its summary shape.
/// The edge-order hint records that the resolved graph's edge list is
/// already in canonical (sorted, deduplicated) stream order, so
/// stream-ingesting variants can consume it without re-sorting.
#[derive(Clone, Debug, PartialEq)]
pub struct SharedPrep {
    /// Degree of every vertex, in vertex order.
    pub degrees: Vec<u32>,
    /// Maximum degree.
    pub max_degree: u32,
    /// Mean degree (`2|E| / |V|`).
    pub avg_degree: f64,
}

impl SharedPrep {
    /// Profile `g` (one O(|V|) pass over the CSR offsets).
    pub fn compute(g: &Graph) -> SharedPrep {
        let n = g.vertex_count();
        let degrees: Vec<u32> = (0..n as u32)
            .map(|v| g.neighbor_vertices(v).len() as u32)
            .collect();
        let max_degree = degrees.iter().copied().max().unwrap_or(0);
        let avg_degree = if n == 0 {
            0.0
        } else {
            2.0 * g.edge_count() as f64 / n as f64
        };
        SharedPrep { degrees, max_degree, avg_degree }
    }
}

/// Everything one batch produced: per-variant run reports in variant
/// order, the shared graph profile, and the batch-level timing and
/// memory accounting.
#[derive(Clone, Debug)]
pub struct BatchReport {
    /// The dataset spec that was resolved — set by
    /// [`execute`](BatchRequest::execute), empty for
    /// [`execute_on`](BatchRequest::execute_on) (same policy as
    /// [`RunReport::dataset`]).
    pub dataset: String,
    /// `|V|` of the resolved graph.
    pub vertices: usize,
    /// `|E|` of the resolved graph.
    pub edges: usize,
    /// The once-per-batch graph profile.
    pub shared: SharedPrep,
    /// One report per variant, in request order — bit-identical to what
    /// sequential [`PartitionRequest::execute_on`] calls would produce.
    pub reports: Vec<RunReport>,
    /// Lanes the batch actually fanned out over.
    pub lanes: usize,
    /// Dataset resolution seconds (0 for `execute_on`).
    pub resolve_secs: f64,
    /// Shared-profile seconds.
    pub shared_secs: f64,
    /// Wall-clock seconds for the variant fan-out (all lanes).
    pub exec_secs: f64,
    /// High-water round-scratch bytes across lanes (the reuse footprint
    /// of the parked DFEP states; 0 when no DFEP-family variant ran).
    pub scratch_peak_bytes: usize,
}

impl BatchReport {
    /// Serialize as a `"v": 1` wire report: batch-level scalars plus a
    /// `"reports"` array of full per-variant run reports (with owners,
    /// so a remote client can reconstruct every partition
    /// bit-identically).
    pub fn to_json(&self) -> String {
        let mut sink = JsonSink::new();
        sink.num("v", 1.0);
        if !self.dataset.is_empty() {
            sink.text("dataset", &self.dataset);
        }
        sink.num("vertices", self.vertices as f64);
        sink.num("edges", self.edges as f64);
        sink.num("variants", self.reports.len() as f64);
        sink.num("lanes", self.lanes as f64);
        sink.num("max_degree", self.shared.max_degree as f64);
        sink.num("avg_degree", self.shared.avg_degree);
        sink.num("resolve_secs", self.resolve_secs);
        sink.num("shared_secs", self.shared_secs);
        sink.num("exec_secs", self.exec_secs);
        sink.num("scratch_peak_bytes", self.scratch_peak_bytes as f64);
        let reps: Vec<String> =
            self.reports.iter().map(RunReport::to_json_with_owners).collect();
        sink.raw("reports", format!("[{}]", reps.join(",")));
        sink.render()
    }

    /// Parse a `"v": 1` wire report. Lenient like the single-run report
    /// parser (unknown fields ignored); the `degrees` array is not on
    /// the wire, so the embedded [`SharedPrep`] carries only the
    /// summary shape.
    pub fn from_json(text: &str) -> Result<BatchReport> {
        let doc = crate::util::json::parse(text).map_err(|e| {
            crate::util::error::Error::msg(format!(
                "invalid batch report JSON: {e}"
            ))
        })?;
        let obj = doc.as_obj().ok_or_else(|| {
            crate::util::error::Error::msg(
                "batch report must be a JSON object",
            )
        })?;
        check_version(obj)?;
        let uint = |field: &str| -> Result<u64> {
            match obj.get(field) {
                Some(v) => req_uint(v, field),
                None => Ok(0),
            }
        };
        let num = |field: &str| -> Result<f64> {
            match obj.get(field) {
                Some(v) => v.as_f64().ok_or_else(|| {
                    crate::util::error::Error::msg(format!(
                        "field '{field}' must be a number"
                    ))
                }),
                None => Ok(0.0),
            }
        };
        let mut reports = Vec::new();
        if let Some(arr) = obj.get("reports").and_then(|v| v.as_arr()) {
            for r in arr {
                let robj = r.as_obj().ok_or_else(|| {
                    crate::util::error::Error::msg(
                        "each batch report entry must be a JSON object",
                    )
                })?;
                reports.push(RunReport::from_obj(robj)?);
            }
        }
        Ok(BatchReport {
            dataset: obj
                .get("dataset")
                .and_then(|v| v.as_str())
                .unwrap_or("")
                .to_string(),
            vertices: uint("vertices")? as usize,
            edges: uint("edges")? as usize,
            shared: SharedPrep {
                degrees: Vec::new(),
                max_degree: uint("max_degree")? as u32,
                avg_degree: num("avg_degree")?,
            },
            reports,
            lanes: uint("lanes")? as usize,
            resolve_secs: num("resolve_secs")?,
            shared_secs: num("shared_secs")?,
            exec_secs: num("exec_secs")?,
            scratch_peak_bytes: uint("scratch_peak_bytes")? as usize,
        })
    }
}

/// `variants` for a `(spec, k)` grid over `seeds` — the shape every
/// figure sweep uses (`bench::figures`).
pub fn grid(
    specs: &[&str],
    ks: &[usize],
    seeds: &[u64],
) -> Result<Vec<Variant>> {
    let mut out = Vec::with_capacity(specs.len() * ks.len() * seeds.len());
    for spec in specs {
        for &k in ks {
            for &seed in seeds {
                out.push(Variant::new(spec, k, seed)?);
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_batch() -> BatchRequest {
        BatchRequest::new("er:n=300,m=900")
            .graph_seed(2)
            .variant(Variant::new("dfep", 4, 1).unwrap())
            .variant(Variant::new("random", 4, 1).unwrap())
            .variant(Variant::new("hdrf:lambda=1.5", 6, 3).unwrap())
    }

    #[test]
    fn batch_matches_sequential_reports() {
        let batch = small_batch();
        let g = resolve_graph(&batch.dataset, batch.graph_seed).unwrap();
        let rep = batch.execute_on(&g).unwrap();
        assert_eq!(rep.reports.len(), 3);
        assert_eq!(rep.vertices, g.vertex_count());
        for (v, r) in batch.variants.iter().zip(&rep.reports) {
            let seq = batch.request_for(v).execute_on(&g).unwrap();
            assert_eq!(r.spec, seq.spec);
            assert_eq!(r.partition.owner, seq.partition.owner);
            assert_eq!(
                r.metrics.nstdev.to_bits(),
                seq.metrics.nstdev.to_bits()
            );
            assert_eq!(r.metrics.messages, seq.metrics.messages);
        }
    }

    #[test]
    fn execute_resolves_once_and_labels_reports() {
        let rep = small_batch().execute().unwrap();
        assert_eq!(rep.dataset, "er:n=300,m=900");
        assert!(rep.resolve_secs >= 0.0);
        for r in &rep.reports {
            assert_eq!(r.dataset, "er:n=300,m=900");
        }
    }

    #[test]
    fn errors_surface_lowest_failing_variant() {
        // k > edges makes DFEP-family check_k fail; variant 1 of 3
        let batch = BatchRequest::new("er:n=30,m=60")
            .variant(Variant::new("random", 4, 1).unwrap())
            .variant(Variant::new("dfep", 0, 1).unwrap())
            .variant(Variant::new("random", 8, 1).unwrap());
        let err = batch.execute().unwrap_err().to_string();
        assert!(err.contains("k == 0"), "{err}");
        let empty = BatchRequest::new("er:n=30,m=60").execute();
        assert!(empty.unwrap_err().to_string().contains("no variants"));
    }

    #[test]
    fn shared_prep_profiles_degrees() {
        let g = resolve_graph("er:n=100,m=300", 1).unwrap();
        let prep = SharedPrep::compute(&g);
        assert_eq!(prep.degrees.len(), g.vertex_count());
        assert_eq!(
            prep.degrees.iter().map(|&d| d as usize).sum::<usize>(),
            2 * g.edge_count()
        );
        assert_eq!(
            prep.max_degree,
            prep.degrees.iter().copied().max().unwrap()
        );
    }

    #[test]
    fn request_json_round_trips() {
        let req = small_batch()
            .gain_samples(2)
            .threads(2)
            .workload(Workload::Sssp { source: 7 });
        let back = BatchRequest::from_json(&req.to_json()).unwrap();
        assert_eq!(back, req);
    }

    #[test]
    fn request_json_is_strict() {
        let err = |t: &str| BatchRequest::from_json(t).unwrap_err();
        let base = r#""dataset": "astroph",
            "variants": [{"spec": "dfep", "k": 4, "seed": 1}]"#;
        assert!(err(&format!("{{{base}, \"bogus\": 1}}"))
            .to_string()
            .contains("unknown batch field"));
        assert!(err(&format!(
            r#"{{"dataset": "a", "variants": [{{"spec": "dfep", "kk": 4}}]}}"#
        ))
        .to_string()
        .contains("unknown variant field"));
        assert!(err(r#"{"dataset": "a", "variants": []}"#)
            .to_string()
            .contains("must not be empty"));
        assert!(err(r#"{"dataset": "a"}"#)
            .to_string()
            .contains("variants"));
        assert!(err(&format!("{{\"v\": 2, {base}}}"))
            .to_string()
            .contains("unsupported wire version"));
        assert!(err(
            r#"{"dataset": "a",
                "variants": [{"spec": "dfep", "k": 0}]}"#
        )
        .to_string()
        .contains("must be >= 1"));
    }

    #[test]
    fn report_json_round_trips() {
        let rep = small_batch().execute().unwrap();
        let back = BatchReport::from_json(&rep.to_json()).unwrap();
        assert_eq!(back.dataset, rep.dataset);
        assert_eq!(back.vertices, rep.vertices);
        assert_eq!(back.edges, rep.edges);
        assert_eq!(back.lanes, rep.lanes);
        assert_eq!(back.shared.max_degree, rep.shared.max_degree);
        assert_eq!(back.reports.len(), rep.reports.len());
        for (b, r) in back.reports.iter().zip(&rep.reports) {
            assert_eq!(b.spec, r.spec);
            assert_eq!(b.partition.owner, r.partition.owner);
            assert_eq!(
                b.metrics.nstdev.to_bits(),
                r.metrics.nstdev.to_bits()
            );
        }
    }

    #[test]
    fn grid_enumerates_spec_major() {
        let vars = grid(&["dfep", "random"], &[2, 8], &[1, 2]).unwrap();
        assert_eq!(vars.len(), 8);
        assert_eq!(vars[0], Variant::new("dfep", 2, 1).unwrap());
        assert_eq!(vars[3], Variant::new("dfep", 8, 2).unwrap());
        assert_eq!(vars[4], Variant::new("random", 2, 1).unwrap());
    }
}
