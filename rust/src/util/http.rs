//! Hand-rolled HTTP/1.1 wire helpers for the serving layer.
//!
//! The vendored crate set has no `hyper`/`tiny_http`, and the server
//! (DESIGN.md "Serving layer") needs only a narrow, bounded subset:
//! `Content-Length`-framed requests and responses over keep-alive
//! connections. Everything here is generic over [`BufRead`]/[`Write`] so
//! the framing is unit-tested against in-memory cursors, with the real
//! `TcpStream`s supplied by `coordinator::serve`.
//!
//! Bounds enforced at the wire (the shedding story depends on them):
//! the request line + headers must fit in [`MAX_HEAD_BYTES`], and a
//! declared `Content-Length` above the caller's `max_body` limit is
//! rejected *before* any body byte is read ([`WireError::TooLarge`] —
//! the server answers 413 and closes the connection, since the unread
//! body would garble the next request).
//!
//! Integrity: both write paths stamp an [`X-Body-Fnv`](BODY_DIGEST)
//! header carrying the fnv1a64 of the body; both read paths verify it
//! when present (and stay compatible with peers that omit it). A
//! mismatch is [`WireError::Corrupt`] — the server answers 503
//! (`transport`) and closes, the client treats it as retryable. The
//! server-side `_with` variants additionally accept a
//! [`FaultArm`](crate::util::fault::FaultArm) so the chaos plane can
//! drop, delay, corrupt or tear individual requests/responses;
//! injected corruption flips a body byte *before* digest verification
//! so it exercises the real check.

use std::fmt;
use std::io::{BufRead, Read, Write};

use crate::util::fault::{FaultArm, ReadFault, WriteFault};
use crate::util::frame::fnv1a64;

/// Upper bound on request line + headers, total bytes.
pub const MAX_HEAD_BYTES: usize = 8 * 1024;

/// Header carrying the fnv1a64 body digest, 16 lowercase hex digits.
pub const BODY_DIGEST: &str = "X-Body-Fnv";

/// One parsed request: method, split target, framed body.
#[derive(Clone, Debug)]
pub struct Request {
    /// Uppercase method (`GET`, `POST`, ...) as sent.
    pub method: String,
    /// Path component of the target (`/partition`), query stripped.
    pub path: String,
    /// Raw query string after `?` (empty when absent).
    pub query: String,
    /// The request body (empty without `Content-Length`).
    pub body: Vec<u8>,
    /// Whether the client asked to keep the connection open (HTTP/1.1
    /// default, overridden by `Connection: close`).
    pub keep_alive: bool,
}

impl Request {
    /// True when the query string contains `key`, `key=1` or `key=true`
    /// (the only query syntax the server supports).
    pub fn query_flag(&self, key: &str) -> bool {
        self.query.split('&').any(|kv| {
            kv == key
                || kv.strip_prefix(key).and_then(|r| r.strip_prefix('='))
                    == Some("1")
                || kv.strip_prefix(key).and_then(|r| r.strip_prefix('='))
                    == Some("true")
        })
    }
}

/// What went wrong reading one request/response from the wire.
#[derive(Debug)]
pub enum WireError {
    /// Declared body exceeds the caller's limit, or the head exceeds
    /// [`MAX_HEAD_BYTES`]. The server answers 413 and closes.
    TooLarge,
    /// The bytes are not the HTTP subset this module speaks. The server
    /// answers 400 and closes.
    Malformed(String),
    /// The body arrived but failed its [`BODY_DIGEST`] check — bit rot
    /// in flight. The server answers 503 (`transport`) and closes; the
    /// client treats it as retryable.
    Corrupt(String),
    /// The underlying transport failed (includes read timeouts). The
    /// server drops the connection silently.
    Io(std::io::Error),
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::TooLarge => f.write_str("request too large"),
            WireError::Malformed(m) => write!(f, "malformed request: {m}"),
            WireError::Corrupt(m) => write!(f, "corrupt body: {m}"),
            WireError::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

/// Parse a [`BODY_DIGEST`] header value (16 hex digits).
fn parse_digest(value: &str) -> Result<u64, WireError> {
    u64::from_str_radix(value.trim(), 16).map_err(|_| {
        WireError::Malformed(format!("bad {BODY_DIGEST} '{value}'"))
    })
}

/// Verify a body against a digest parsed from the head (if any).
fn check_digest(
    body: &[u8],
    expected: Option<u64>,
) -> Result<(), WireError> {
    if let Some(want) = expected {
        let got = fnv1a64(body);
        if got != want {
            return Err(WireError::Corrupt(format!(
                "{BODY_DIGEST} mismatch: header {want:016x}, body \
                 {got:016x}"
            )));
        }
    }
    Ok(())
}

/// Apply an inbound fault verdict to a freshly read body. Runs before
/// digest verification so injected corruption trips the real check.
fn apply_read_fault(
    body: &mut [u8],
    arm: Option<&mut FaultArm>,
) -> Result<(), WireError> {
    if let Some(arm) = arm {
        match arm.on_read(body.len()) {
            ReadFault::Pass => {}
            ReadFault::Drop => {
                return Err(WireError::Io(std::io::Error::new(
                    std::io::ErrorKind::ConnectionReset,
                    "injected connection drop",
                )));
            }
            ReadFault::CorruptAt(i) => body[i] ^= 0xA5,
            ReadFault::Short => {
                return Err(WireError::Io(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "injected short read",
                )));
            }
        }
    }
    Ok(())
}

impl From<std::io::Error> for WireError {
    fn from(e: std::io::Error) -> WireError {
        WireError::Io(e)
    }
}

/// Read one CRLF-terminated line, counting its bytes against `budget`.
fn read_line(
    r: &mut impl BufRead,
    budget: &mut usize,
) -> Result<Option<String>, WireError> {
    let mut line = String::new();
    let n = r.read_line(&mut line)?;
    if n == 0 {
        return Ok(None);
    }
    if n > *budget {
        return Err(WireError::TooLarge);
    }
    *budget -= n;
    if !line.ends_with('\n') {
        return Err(WireError::Malformed("truncated line".into()));
    }
    while line.ends_with('\n') || line.ends_with('\r') {
        line.pop();
    }
    Ok(Some(line))
}

/// Read one request. `Ok(None)` is a clean EOF *before* the request line
/// (the peer closed an idle keep-alive connection); EOF mid-request is
/// [`WireError::Malformed`].
pub fn read_request(
    r: &mut impl BufRead,
    max_body: usize,
) -> Result<Option<Request>, WireError> {
    read_request_with(r, max_body, None)
}

/// [`read_request`] with an optional fault-injection arm (one decision
/// per request, drawn after the body arrives).
pub fn read_request_with(
    r: &mut impl BufRead,
    max_body: usize,
    arm: Option<&mut FaultArm>,
) -> Result<Option<Request>, WireError> {
    let mut budget = MAX_HEAD_BYTES;
    let Some(start) = read_line(r, &mut budget)? else {
        return Ok(None);
    };
    let mut parts = start.split_whitespace();
    let (method, target, version) =
        match (parts.next(), parts.next(), parts.next(), parts.next()) {
            (Some(m), Some(t), Some(v), None) => (m, t, v),
            _ => {
                return Err(WireError::Malformed(format!(
                    "bad request line '{start}'"
                )))
            }
        };
    if !version.starts_with("HTTP/1.") {
        return Err(WireError::Malformed(format!(
            "unsupported version '{version}'"
        )));
    }
    let mut keep_alive = version != "HTTP/1.0";
    let mut content_length = 0usize;
    let mut digest = None;
    loop {
        let Some(line) = read_line(r, &mut budget)? else {
            return Err(WireError::Malformed("eof in headers".into()));
        };
        if line.is_empty() {
            break;
        }
        let Some((name, value)) = line.split_once(':') else {
            return Err(WireError::Malformed(format!("bad header '{line}'")));
        };
        let name = name.trim().to_ascii_lowercase();
        let value = value.trim();
        match name.as_str() {
            "content-length" => {
                content_length = value.parse().map_err(|_| {
                    WireError::Malformed(format!(
                        "bad content-length '{value}'"
                    ))
                })?;
            }
            "connection" => {
                let v = value.to_ascii_lowercase();
                if v.contains("close") {
                    keep_alive = false;
                } else if v.contains("keep-alive") {
                    keep_alive = true;
                }
            }
            "x-body-fnv" => digest = Some(parse_digest(value)?),
            // transfer-encoding (chunked bodies) is out of scope; a
            // client using it would declare no content-length and the
            // chunk header would fail the next request-line parse
            _ => {}
        }
    }
    if content_length > max_body {
        return Err(WireError::TooLarge);
    }
    let mut body = vec![0u8; content_length];
    r.read_exact(&mut body)
        .map_err(|_| WireError::Malformed("eof in body".into()))?;
    apply_read_fault(&mut body, arm)?;
    check_digest(&body, digest)?;
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (target.to_string(), String::new()),
    };
    Ok(Some(Request {
        method: method.to_string(),
        path,
        query,
        body,
        keep_alive,
    }))
}

/// The reason phrase for the status codes the server emits.
pub fn status_text(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Write one `Content-Length`-framed response and flush.
pub fn write_response(
    w: &mut impl Write,
    status: u16,
    body: &[u8],
    keep_alive: bool,
) -> std::io::Result<()> {
    write_response_with(w, status, body, keep_alive, None)
}

/// [`write_response`] with an optional fault-injection arm. A firing
/// `drop` fails before any byte lands; a firing `torn_write` puts the
/// head and half the body on the wire, then fails — the client sees a
/// response that never completes.
pub fn write_response_with(
    w: &mut impl Write,
    status: u16,
    body: &[u8],
    keep_alive: bool,
    arm: Option<&mut FaultArm>,
) -> std::io::Result<()> {
    let head = format!(
        "HTTP/1.1 {status} {}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\n{BODY_DIGEST}: {:016x}\r\n\
         Connection: {}\r\n\r\n",
        status_text(status),
        body.len(),
        fnv1a64(body),
        if keep_alive { "keep-alive" } else { "close" },
    );
    if let Some(arm) = arm {
        match arm.on_write() {
            WriteFault::Pass => {}
            WriteFault::Drop => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::BrokenPipe,
                    "injected connection drop",
                ));
            }
            WriteFault::Torn => {
                w.write_all(head.as_bytes())?;
                w.write_all(&body[..body.len() / 2])?;
                let _ = w.flush();
                return Err(std::io::Error::new(
                    std::io::ErrorKind::BrokenPipe,
                    "injected torn write",
                ));
            }
        }
    }
    w.write_all(head.as_bytes())?;
    w.write_all(body)?;
    w.flush()
}

/// Client side: write one framed request and flush.
pub fn write_request(
    w: &mut impl Write,
    method: &str,
    target: &str,
    body: &[u8],
) -> std::io::Result<()> {
    let head = format!(
        "{method} {target} HTTP/1.1\r\nHost: repro\r\n\
         Content-Length: {}\r\n{BODY_DIGEST}: {:016x}\r\n\
         Connection: keep-alive\r\n\r\n",
        body.len(),
        fnv1a64(body),
    );
    w.write_all(head.as_bytes())?;
    w.write_all(body)?;
    w.flush()
}

/// Client side: read one response, returning `(status, body)`. `max_body`
/// bounds the accepted `Content-Length` like [`read_request`].
pub fn read_response(
    r: &mut impl BufRead,
    max_body: usize,
) -> Result<(u16, Vec<u8>), WireError> {
    let mut budget = MAX_HEAD_BYTES;
    let Some(start) = read_line(r, &mut budget)? else {
        return Err(WireError::Malformed("eof before status line".into()));
    };
    let mut parts = start.split_whitespace();
    let status: u16 = match (parts.next(), parts.next()) {
        (Some(v), Some(code)) if v.starts_with("HTTP/1.") => {
            code.parse().map_err(|_| {
                WireError::Malformed(format!("bad status line '{start}'"))
            })?
        }
        _ => {
            return Err(WireError::Malformed(format!(
                "bad status line '{start}'"
            )))
        }
    };
    let mut content_length = 0usize;
    let mut digest = None;
    loop {
        let Some(line) = read_line(r, &mut budget)? else {
            return Err(WireError::Malformed("eof in headers".into()));
        };
        if line.is_empty() {
            break;
        }
        if let Some((name, value)) = line.split_once(':') {
            if name.trim().eq_ignore_ascii_case("content-length") {
                content_length = value.trim().parse().map_err(|_| {
                    WireError::Malformed(format!(
                        "bad content-length '{}'",
                        value.trim()
                    ))
                })?;
            } else if name.trim().eq_ignore_ascii_case(BODY_DIGEST) {
                digest = Some(parse_digest(value)?);
            }
        }
    }
    if content_length > max_body {
        return Err(WireError::TooLarge);
    }
    let mut body = vec![0u8; content_length];
    r.read_exact(&mut body)
        .map_err(|_| WireError::Malformed("eof in body".into()))?;
    check_digest(&body, digest)?;
    Ok((status, body))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn req(text: &str) -> Result<Option<Request>, WireError> {
        read_request(&mut Cursor::new(text.as_bytes().to_vec()), 1024)
    }

    #[test]
    fn parses_post_with_body_and_query() {
        let r = req(
            "POST /partition?owners=1 HTTP/1.1\r\nHost: x\r\n\
             Content-Length: 4\r\n\r\nabcd",
        )
        .unwrap()
        .unwrap();
        assert_eq!(r.method, "POST");
        assert_eq!(r.path, "/partition");
        assert_eq!(r.query, "owners=1");
        assert_eq!(r.body, b"abcd");
        assert!(r.keep_alive);
        assert!(r.query_flag("owners"));
        assert!(!r.query_flag("other"));
    }

    #[test]
    fn keep_alive_defaults_and_overrides() {
        let r = req("GET /healthz HTTP/1.1\r\n\r\n").unwrap().unwrap();
        assert!(r.keep_alive);
        assert!(r.body.is_empty());
        let r = req("GET / HTTP/1.1\r\nConnection: close\r\n\r\n")
            .unwrap()
            .unwrap();
        assert!(!r.keep_alive);
        let r = req("GET / HTTP/1.0\r\n\r\n").unwrap().unwrap();
        assert!(!r.keep_alive);
        let r = req("GET / HTTP/1.0\r\nConnection: keep-alive\r\n\r\n")
            .unwrap()
            .unwrap();
        assert!(r.keep_alive);
    }

    #[test]
    fn clean_eof_vs_malformed() {
        assert!(req("").unwrap().is_none());
        assert!(matches!(req("GARBAGE\r\n\r\n"), Err(WireError::Malformed(_))));
        assert!(matches!(
            req("GET / HTTP/1.1\r\nContent-Length: 9\r\n\r\nshort"),
            Err(WireError::Malformed(_))
        ));
        assert!(matches!(
            req("GET / SPDY/3\r\n\r\n"),
            Err(WireError::Malformed(_))
        ));
        assert!(matches!(
            req("GET / HTTP/1.1\r\nContent-Length: abc\r\n\r\n"),
            Err(WireError::Malformed(_))
        ));
    }

    #[test]
    fn oversized_body_and_head_are_too_large() {
        // declared body over the limit fails before reading the body
        assert!(matches!(
            req("POST / HTTP/1.1\r\nContent-Length: 2000\r\n\r\n"),
            Err(WireError::TooLarge)
        ));
        // an absurd header block trips the head budget
        let mut text = String::from("GET / HTTP/1.1\r\n");
        for i in 0..600 {
            text.push_str(&format!("X-Pad-{i}: {}\r\n", "y".repeat(20)));
        }
        text.push_str("\r\n");
        assert!(matches!(req(&text), Err(WireError::TooLarge)));
    }

    #[test]
    fn response_round_trips_through_cursor() {
        let mut wire = Vec::new();
        write_response(&mut wire, 200, b"{\"ok\": true}", true).unwrap();
        let (status, body) =
            read_response(&mut Cursor::new(wire), 1024).unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, b"{\"ok\": true}");
        let mut wire = Vec::new();
        write_response(&mut wire, 503, b"busy", false).unwrap();
        let text = String::from_utf8(wire.clone()).unwrap();
        assert!(text.starts_with("HTTP/1.1 503 Service Unavailable\r\n"));
        assert!(text.contains("Connection: close\r\n"));
        let (status, body) =
            read_response(&mut Cursor::new(wire), 1024).unwrap();
        assert_eq!(status, 503);
        assert_eq!(body, b"busy");
    }

    #[test]
    fn request_round_trips_through_cursor() {
        let mut wire = Vec::new();
        write_request(&mut wire, "POST", "/partition", b"{}").unwrap();
        let r = read_request(&mut Cursor::new(wire), 1024).unwrap().unwrap();
        assert_eq!(r.method, "POST");
        assert_eq!(r.path, "/partition");
        assert_eq!(r.body, b"{}");
        // two pipelined requests parse back-to-back
        let mut wire = Vec::new();
        write_request(&mut wire, "GET", "/stats", b"").unwrap();
        write_request(&mut wire, "GET", "/healthz", b"").unwrap();
        let mut cur = Cursor::new(wire);
        let a = read_request(&mut cur, 1024).unwrap().unwrap();
        let b = read_request(&mut cur, 1024).unwrap().unwrap();
        assert_eq!(a.path, "/stats");
        assert_eq!(b.path, "/healthz");
        assert!(read_request(&mut cur, 1024).unwrap().is_none());
    }

    #[test]
    fn body_digest_detects_corruption_both_directions() {
        // response: flip one body byte after framing
        let mut wire = Vec::new();
        write_response(&mut wire, 200, b"{\"ok\": true}", true).unwrap();
        let n = wire.len();
        wire[n - 3] ^= 0x01;
        assert!(matches!(
            read_response(&mut Cursor::new(wire), 1024),
            Err(WireError::Corrupt(_))
        ));
        // request likewise
        let mut wire = Vec::new();
        write_request(&mut wire, "POST", "/partition", b"{\"k\": 4}")
            .unwrap();
        let n = wire.len();
        wire[n - 2] ^= 0x01;
        assert!(matches!(
            read_request(&mut Cursor::new(wire), 1024),
            Err(WireError::Corrupt(_))
        ));
        // a garbled digest header is malformed, not corrupt
        assert!(matches!(
            req("GET / HTTP/1.1\r\nX-Body-Fnv: zz\r\n\r\n"),
            Err(WireError::Malformed(_))
        ));
        // peers that omit the digest still parse (legacy compatibility)
        let r = req("POST / HTTP/1.1\r\nContent-Length: 4\r\n\r\nabcd")
            .unwrap()
            .unwrap();
        assert_eq!(r.body, b"abcd");
    }

    #[test]
    fn fault_arms_inject_on_server_paths() {
        use crate::util::fault::{FaultCounters, FaultPlan};
        // injected corruption trips the real digest check
        let plan = FaultPlan { corrupt: 1.0, ..FaultPlan::default() };
        let mut arm = plan.arm(0, FaultCounters::shared());
        let mut wire = Vec::new();
        write_request(&mut wire, "POST", "/x", b"body bytes").unwrap();
        let err =
            read_request_with(&mut Cursor::new(wire), 1024, Some(&mut arm))
                .unwrap_err();
        assert!(matches!(err, WireError::Corrupt(_)), "{err}");
        // a torn response leaves a body the client can never finish
        let plan = FaultPlan { torn_write: 1.0, ..FaultPlan::default() };
        let mut arm = plan.arm(0, FaultCounters::shared());
        let mut wire = Vec::new();
        let err = write_response_with(
            &mut wire,
            200,
            b"0123456789",
            true,
            Some(&mut arm),
        )
        .unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::BrokenPipe);
        assert!(matches!(
            read_response(&mut Cursor::new(wire), 1024),
            Err(WireError::Malformed(_))
        ));
        // a dropped write lands nothing on the wire
        let plan = FaultPlan { drop: 1.0, ..FaultPlan::default() };
        let mut arm = plan.arm(0, FaultCounters::shared());
        let mut sink = Vec::new();
        assert!(write_response_with(
            &mut sink,
            200,
            b"x",
            true,
            Some(&mut arm)
        )
        .is_err());
        assert!(sink.is_empty());
    }
}
