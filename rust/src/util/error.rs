//! Minimal error type + context helpers.
//!
//! The vendored crate set has no `anyhow`; this module provides the same
//! ergonomics for the subset the crate actually uses: a string-backed
//! [`Error`], a defaulted [`Result`] alias, the [`anyhow!`](crate::anyhow)
//! and [`bail!`](crate::bail) macros, and a [`Context`] extension trait
//! for `Result` and `Option`.
//!
//! Errors additionally carry a stable machine-readable [`ErrorKind`] so
//! remote callers (the serving layer) can dispatch without parsing the
//! human-readable message. Plain `anyhow!` errors are
//! [`ErrorKind::Internal`]; producers that know better tag with
//! [`Error::with_kind`]. The kind survives plain `?` propagation but is
//! deliberately reset to `Internal` by [`Context`] wrapping (a wrapped
//! error describes a new, composite failure).

use std::fmt;

/// Stable machine-readable classification of an [`Error`] — the part a
/// remote caller can dispatch on. The serving layer maps kinds to HTTP
/// statuses (see DESIGN.md "Serving layer"); the message stays free-form
/// and undocumented, the kind is API.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ErrorKind {
    /// A partitioner spec failed to parse or validate
    /// (`hdrf:lambda=abc`).
    InvalidSpec,
    /// A request was malformed outside the spec field: bad JSON, an
    /// unknown field, an out-of-range value, a bad generator argument.
    InvalidRequest,
    /// The named dataset / graph spec does not resolve to a graph.
    DatasetNotFound,
    /// Too many distinct computations in flight; retry later.
    Busy,
    /// The server shed the request (queue full or deadline exceeded).
    Overloaded,
    /// An operating-system I/O failure (bind, accept, read, write).
    Io,
    /// A cluster worker became unreachable mid-conversation: frame codec
    /// failure, dropped connection, read timeout, or a respawn that did
    /// not come back. Maps to 503 (service unavailable) at the serving
    /// layer — the cluster is temporarily degraded, a retry may succeed.
    Transport,
    /// Anything unclassified — the default for plain `anyhow!` errors.
    Internal,
}

impl ErrorKind {
    /// Every kind, in declaration order (for exhaustive table tests).
    pub const ALL: [ErrorKind; 8] = [
        ErrorKind::InvalidSpec,
        ErrorKind::InvalidRequest,
        ErrorKind::DatasetNotFound,
        ErrorKind::Busy,
        ErrorKind::Overloaded,
        ErrorKind::Io,
        ErrorKind::Transport,
        ErrorKind::Internal,
    ];

    /// Inverse of [`as_str`](Self::as_str): recover a kind from its wire
    /// label (`None` for labels this crate version does not know).
    pub fn parse(s: &str) -> Option<ErrorKind> {
        ErrorKind::ALL.iter().copied().find(|k| k.as_str() == s)
    }

    /// Stable snake_case label — the `"kind"` field of wire errors.
    pub fn as_str(self) -> &'static str {
        match self {
            ErrorKind::InvalidSpec => "invalid_spec",
            ErrorKind::InvalidRequest => "invalid_request",
            ErrorKind::DatasetNotFound => "dataset_not_found",
            ErrorKind::Busy => "busy",
            ErrorKind::Overloaded => "overloaded",
            ErrorKind::Io => "io",
            ErrorKind::Transport => "transport",
            ErrorKind::Internal => "internal",
        }
    }
}

/// A string-backed error. Context wraps are flattened into the message at
/// attachment time (`"<context>: <cause>"`), which is all the callers in
/// this crate need.
pub struct Error {
    msg: String,
    kind: ErrorKind,
}

impl Error {
    /// Construct from any message (kind [`ErrorKind::Internal`]).
    pub fn msg(msg: impl Into<String>) -> Error {
        Error { msg: msg.into(), kind: ErrorKind::Internal }
    }

    /// Tag with a machine-readable kind (builder-style).
    pub fn with_kind(mut self, kind: ErrorKind) -> Error {
        self.kind = kind;
        self
    }

    /// The machine-readable kind.
    pub fn kind(&self) -> ErrorKind {
        self.kind
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

/// `Result` defaulted to [`Error`], like `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

// NOTE: `Error` deliberately does NOT implement `std::error::Error`, so
// this blanket conversion cannot overlap the identity `From<Error> for
// Error` that the `?` operator needs.
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e.to_string())
    }
}

/// Attach human-readable context to an error (or a missing value).
pub trait Context<T> {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    /// Wrap the error with a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{c}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Build an [`Error`] from a format string or any displayable expression.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::util::error::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::util::error::Error::msg($err.to_string())
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($fmt, $($arg)*))
    };
}

/// `return Err(anyhow!(...))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<u32> {
        Err(crate::anyhow!("boom {}", 42))
    }

    #[test]
    fn format_and_expr_forms() {
        assert_eq!(fails().unwrap_err().to_string(), "boom 42");
        let x = 7;
        assert_eq!(crate::anyhow!("x = {x}").to_string(), "x = 7");
        let s = String::from("owned");
        assert_eq!(crate::anyhow!(s).to_string(), "owned");
    }

    #[test]
    fn bail_returns_early() {
        fn f(flag: bool) -> Result<u32> {
            if flag {
                crate::bail!("flagged {}", 1);
            }
            Ok(5)
        }
        assert_eq!(f(false).unwrap(), 5);
        assert_eq!(f(true).unwrap_err().to_string(), "flagged 1");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<u32> {
            Ok(s.parse::<u32>()?)
        }
        assert_eq!(parse("12").unwrap(), 12);
        assert!(parse("nope").is_err());
    }

    #[test]
    fn kinds_default_tag_and_label() {
        // plain construction is Internal; with_kind retags
        assert_eq!(fails().unwrap_err().kind(), ErrorKind::Internal);
        let e = Error::msg("nope").with_kind(ErrorKind::DatasetNotFound);
        assert_eq!(e.kind(), ErrorKind::DatasetNotFound);
        assert_eq!(e.to_string(), "nope");
        // `?` conversion from std errors stays Internal
        fn conv() -> Result<u32> {
            Ok("nope".parse::<u32>()?)
        }
        assert_eq!(conv().unwrap_err().kind(), ErrorKind::Internal);
        // labels are distinct and snake_case-stable
        let labels: std::collections::HashSet<_> =
            ErrorKind::ALL.iter().map(|k| k.as_str()).collect();
        assert_eq!(labels.len(), ErrorKind::ALL.len());
        assert_eq!(ErrorKind::InvalidSpec.as_str(), "invalid_spec");
    }

    #[test]
    fn context_resets_kind_to_internal() {
        let r: Result<u32> =
            Err(Error::msg("x").with_kind(ErrorKind::InvalidSpec));
        let e = r.context("wrapping").unwrap_err();
        assert_eq!(e.kind(), ErrorKind::Internal);
        assert_eq!(e.to_string(), "wrapping: x");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<u32, std::num::ParseIntError> =
            "x".parse::<u32>();
        let e = r.context("reading count").unwrap_err().to_string();
        assert!(e.starts_with("reading count: "), "{e}");
        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "k")).unwrap_err();
        assert_eq!(e.to_string(), "missing k");
        assert_eq!(Some(3).context("fine").unwrap(), 3);
    }
}
