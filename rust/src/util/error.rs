//! Minimal error type + context helpers.
//!
//! The vendored crate set has no `anyhow`; this module provides the same
//! ergonomics for the subset the crate actually uses: a string-backed
//! [`Error`], a defaulted [`Result`] alias, the [`anyhow!`](crate::anyhow)
//! and [`bail!`](crate::bail) macros, and a [`Context`] extension trait
//! for `Result` and `Option`.

use std::fmt;

/// A string-backed error. Context wraps are flattened into the message at
/// attachment time (`"<context>: <cause>"`), which is all the callers in
/// this crate need.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from any message.
    pub fn msg(msg: impl Into<String>) -> Error {
        Error { msg: msg.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

/// `Result` defaulted to [`Error`], like `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

// NOTE: `Error` deliberately does NOT implement `std::error::Error`, so
// this blanket conversion cannot overlap the identity `From<Error> for
// Error` that the `?` operator needs.
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e.to_string())
    }
}

/// Attach human-readable context to an error (or a missing value).
pub trait Context<T> {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    /// Wrap the error with a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{c}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Build an [`Error`] from a format string or any displayable expression.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::util::error::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::util::error::Error::msg($err.to_string())
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($fmt, $($arg)*))
    };
}

/// `return Err(anyhow!(...))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<u32> {
        Err(crate::anyhow!("boom {}", 42))
    }

    #[test]
    fn format_and_expr_forms() {
        assert_eq!(fails().unwrap_err().to_string(), "boom 42");
        let x = 7;
        assert_eq!(crate::anyhow!("x = {x}").to_string(), "x = 7");
        let s = String::from("owned");
        assert_eq!(crate::anyhow!(s).to_string(), "owned");
    }

    #[test]
    fn bail_returns_early() {
        fn f(flag: bool) -> Result<u32> {
            if flag {
                crate::bail!("flagged {}", 1);
            }
            Ok(5)
        }
        assert_eq!(f(false).unwrap(), 5);
        assert_eq!(f(true).unwrap_err().to_string(), "flagged 1");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<u32> {
            Ok(s.parse::<u32>()?)
        }
        assert_eq!(parse("12").unwrap(), 12);
        assert!(parse("nope").is_err());
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<u32, std::num::ParseIntError> =
            "x".parse::<u32>();
        let e = r.context("reading count").unwrap_err().to_string();
        assert!(e.starts_with("reading count: "), "{e}");
        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "k")).unwrap_err();
        assert_eq!(e.to_string(), "missing k");
        assert_eq!(Some(3).context("fine").unwrap(), 3);
    }
}
