//! Deterministic PRNG: PCG32 seeded via SplitMix64.
//!
//! Every experiment in the paper is an average over 100 seeded samples;
//! everything here is reproducible from a single `u64` seed, and streams
//! can be forked (`fork`) so parallel workers stay deterministic
//! regardless of scheduling.

/// SplitMix64 — used to expand a user seed into PCG state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// PCG32 (XSH-RR 64/32) — small, fast, statistically solid.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
    inc: u64,
}

impl Rng {
    /// Create from a seed; distinct seeds give independent streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let state = splitmix64(&mut sm);
        let inc = splitmix64(&mut sm) | 1;
        let mut rng = Rng { state, inc };
        rng.next_u32(); // warm up
        rng
    }

    /// Fork an independent stream (for per-worker determinism).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Snapshot the raw generator state for checkpointing.
    pub fn state(&self) -> (u64, u64) {
        (self.state, self.inc)
    }

    /// Rebuild a generator from a [`state`](Self::state) snapshot.
    ///
    /// Unlike [`new`](Self::new) this restores the raw fields verbatim (no
    /// seed expansion, no warm-up draw), so the restored stream continues
    /// exactly where the snapshotted one left off.
    pub fn from_state(state: u64, inc: u64) -> Rng {
        Rng { state, inc }
    }

    /// Next 32 uniform bits (the core PCG32 step).
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6364136223846793005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64 uniform bits (two PCG32 steps).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, bound)` (Lemire's method, bias-free).
    #[inline]
    pub fn below(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0);
        let bound = bound as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (k << n assumed).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        if k * 4 >= n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            return all;
        }
        let mut seen = std::collections::HashSet::with_capacity(k * 2);
        let mut out = Vec::with_capacity(k);
        while out.len() < k {
            let x = self.below(n);
            if seen.insert(x) {
                out.push(x);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a: Vec<u32> = {
            let mut r = Rng::new(7);
            (0..16).map(|_| r.next_u32()).collect()
        };
        let b: Vec<u32> = {
            let mut r = Rng::new(7);
            (0..16).map(|_| r.next_u32()).collect()
        };
        assert_eq!(a, b);
        let c: Vec<u32> = {
            let mut r = Rng::new(8);
            (0..16).map(|_| r.next_u32()).collect()
        };
        assert_ne!(a, c);
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut r = Rng::new(1);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "count {c} out of band");
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(2);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(4);
        for &(n, k) in &[(100, 5), (100, 50), (10, 10)] {
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k);
            assert!(s.iter().all(|&x| x < n));
        }
    }

    #[test]
    fn state_roundtrip_resumes_stream() {
        let mut r = Rng::new(6);
        for _ in 0..100 {
            r.next_u32();
        }
        let (state, inc) = r.state();
        let expect: Vec<u32> = (0..16).map(|_| r.next_u32()).collect();
        let mut restored = Rng::from_state(state, inc);
        let got: Vec<u32> = (0..16).map(|_| restored.next_u32()).collect();
        assert_eq!(expect, got);
    }

    #[test]
    fn forked_streams_differ() {
        let mut r = Rng::new(5);
        let mut f1 = r.fork(1);
        let mut f2 = r.fork(2);
        let a: Vec<u32> = (0..8).map(|_| f1.next_u32()).collect();
        let b: Vec<u32> = (0..8).map(|_| f2.next_u32()).collect();
        assert_ne!(a, b);
    }
}
