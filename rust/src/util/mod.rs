//! Small self-contained substrates (the vendored crate set has no `rand`,
//! `serde_json` or `criterion`, so we ship our own deterministic PRNG,
//! JSON parser and stats helpers).

pub mod json;
pub mod rng;
pub mod stats;
pub mod timer;
