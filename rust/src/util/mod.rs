//! Small self-contained substrates (the vendored crate set has no `rand`,
//! `serde_json`, `anyhow`, `rayon` or `criterion`, so we ship our own
//! deterministic PRNG, JSON parser, error type, scoped thread pool and
//! stats helpers).

pub mod error;
pub mod fault;
pub mod frame;
pub mod http;
pub mod json;
pub mod pool;
pub mod rng;
pub mod stats;
pub mod timer;
