//! Wall-clock timing helpers for the bench harness.

use std::time::Instant;

/// Time a closure, returning (result, seconds).
pub fn time<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Run `f` repeatedly: `warmup` unmeasured runs then `iters` measured ones;
/// returns per-iteration seconds.
pub fn time_n(warmup: usize, iters: usize, mut f: impl FnMut()) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    (0..iters)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_returns_result() {
        let (v, secs) = time(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }

    #[test]
    fn time_n_counts() {
        let mut calls = 0;
        let t = time_n(2, 5, || calls += 1);
        assert_eq!(t.len(), 5);
        assert_eq!(calls, 7);
    }
}
