//! Wall-clock timing helpers for the bench harness.

use std::time::Instant;

/// Time a closure, returning (result, seconds).
pub fn time<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Run `f` repeatedly: `warmup` unmeasured runs then `iters` measured ones;
/// returns per-iteration seconds.
pub fn time_n(warmup: usize, iters: usize, mut f: impl FnMut()) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    (0..iters)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect()
}

/// Accumulating latency counter (count / total / max) — the per-endpoint
/// statistic the serving layer exposes on `/stats`. Deliberately tiny:
/// O(1) memory, no histogram; the load-generator bench derives p50/p99
/// from its own full sample vectors instead.
#[derive(Clone, Copy, Debug, Default)]
pub struct LatencyStat {
    /// Number of recorded observations.
    pub count: usize,
    /// Sum of observed seconds.
    pub total_s: f64,
    /// Largest observed seconds.
    pub max_s: f64,
}

impl LatencyStat {
    /// Fold in one observation.
    pub fn record(&mut self, secs: f64) {
        self.count += 1;
        self.total_s += secs;
        if secs > self.max_s {
            self.max_s = secs;
        }
    }

    /// Mean seconds (0.0 before any observation).
    pub fn mean_s(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.total_s / self.count as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_stat_accumulates() {
        let mut s = LatencyStat::default();
        assert_eq!(s.mean_s(), 0.0);
        s.record(0.5);
        s.record(1.5);
        s.record(1.0);
        assert_eq!(s.count, 3);
        assert_eq!(s.mean_s(), 1.0);
        assert_eq!(s.max_s, 1.5);
    }

    #[test]
    fn time_returns_result() {
        let (v, secs) = time(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }

    #[test]
    fn time_n_counts() {
        let mut calls = 0;
        let t = time_n(2, 5, || calls += 1);
        assert_eq!(t.len(), 5);
        assert_eq!(calls, 7);
    }
}
