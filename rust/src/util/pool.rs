//! Shared scoped thread pool (std-only, reusable workers, deterministic
//! shard -> thread assignment).
//!
//! The DFEP funding rounds, ETSCH's local-computation phase and the
//! MapReduce engine all fan work out through this pool instead of
//! spawning ad-hoc threads per round. Design constraints:
//!
//! - **Reusable workers.** Workers are spawned once and parked on a
//!   channel; a round costs two channel hops per shard, not a
//!   thread spawn + join per shard.
//! - **Deterministic assignment.** Shard `i` always runs on worker
//!   `i % threads`. More importantly, callers are written so results are
//!   a pure function of the shard *index*, and shard outputs are merged
//!   in fixed shard order — results are bit-identical for every thread
//!   count (see the pool invariants test and DESIGN.md "Determinism").
//! - **Scoped borrows.** Tasks may borrow the caller's stack. Safety
//!   comes from [`ThreadPool::run`] blocking on a completion latch before
//!   returning, so no task can outlive the borrowed data.
//!
//! Sizing: the global pool uses `DFEP_POOL_THREADS` if set, else
//! `std::thread::available_parallelism()`. Tests pin exact thread counts
//! with [`with_threads`]. Nesting `run` calls on the same pool is not
//! supported (workers would block on workers); none of the crate's
//! callers nest.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, OnceLock};

/// Completion latch: counts outstanding tasks of one `run` call.
struct Latch {
    remaining: Mutex<usize>,
    cv: Condvar,
    panicked: AtomicBool,
}

impl Latch {
    fn new(count: usize) -> Latch {
        Latch {
            remaining: Mutex::new(count),
            cv: Condvar::new(),
            panicked: AtomicBool::new(false),
        }
    }

    fn count_down(&self) {
        let mut left = self.remaining.lock().unwrap();
        *left -= 1;
        if *left == 0 {
            self.cv.notify_all();
        }
    }

    fn wait(&self) {
        let mut left = self.remaining.lock().unwrap();
        while *left > 0 {
            left = self.cv.wait(left).unwrap();
        }
    }
}

/// A type-erased borrowed task. `call` is a monomorphized trampoline that
/// casts `ctx` back to the caller's closure; the latch pointer is valid
/// because `run` blocks on it before returning.
struct Task {
    call: unsafe fn(*const (), usize),
    ctx: *const (),
    shard: usize,
    latch: *const Latch,
}

// SAFETY: the pointers reference stack data of the thread blocked inside
// `ThreadPool::run`; they are dereferenced only while that call is blocked
// on the latch, and the closure behind `ctx` is required to be `Sync`.
unsafe impl Send for Task {}

/// Fixed set of parked workers, one injection channel per worker.
pub struct ThreadPool {
    senders: Vec<mpsc::Sender<Task>>,
    handles: Vec<std::thread::JoinHandle<()>>,
    threads: usize,
}

impl ThreadPool {
    /// The shared no-worker pool behind [`with_inline`]: `threads == 1`
    /// and no channels, so [`run`](Self::run) always takes the inline
    /// path and shards execute on the caller in index order.
    fn inline() -> ThreadPool {
        ThreadPool { senders: Vec::new(), handles: Vec::new(), threads: 1 }
    }

    /// Spawn a pool of `threads` workers (min 1).
    pub fn new(threads: usize) -> ThreadPool {
        let threads = threads.max(1);
        let mut senders = Vec::with_capacity(threads);
        let mut handles = Vec::with_capacity(threads);
        for w in 0..threads {
            let (tx, rx) = mpsc::channel::<Task>();
            let handle = std::thread::Builder::new()
                .name(format!("dfep-pool-{w}"))
                .spawn(move || {
                    while let Ok(task) = rx.recv() {
                        let result = catch_unwind(AssertUnwindSafe(|| unsafe {
                            (task.call)(task.ctx, task.shard)
                        }));
                        // SAFETY: the submitting thread is blocked on this
                        // latch until every task counted down.
                        let latch = unsafe { &*task.latch };
                        if result.is_err() {
                            latch.panicked.store(true, Ordering::SeqCst);
                        }
                        latch.count_down();
                    }
                })
                .expect("spawn pool worker");
            senders.push(tx);
            handles.push(handle);
        }
        ThreadPool { senders, handles, threads }
    }

    /// Number of workers.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(0), f(1), ..., f(shards - 1)`, shard `i` on worker
    /// `i % threads`; blocks until all shards complete. With one worker
    /// (or one shard) the shards run inline on the caller in index order.
    pub fn run<F: Fn(usize) + Sync>(&self, shards: usize, f: &F) {
        if shards == 0 {
            return;
        }
        if self.threads == 1 || shards == 1 {
            for i in 0..shards {
                f(i);
            }
            return;
        }
        unsafe fn trampoline<F: Fn(usize) + Sync>(ctx: *const (), shard: usize) {
            let f = unsafe { &*(ctx as *const F) };
            f(shard);
        }
        let latch = Latch::new(shards);
        for i in 0..shards {
            let task = Task {
                call: trampoline::<F>,
                ctx: f as *const F as *const (),
                shard: i,
                latch: &latch,
            };
            self.senders[i % self.threads]
                .send(task)
                .expect("pool worker exited");
        }
        latch.wait();
        if latch.panicked.load(Ordering::SeqCst) {
            panic!("pool task panicked");
        }
    }

    /// Run `f(i, &mut items[i])` for every item, one shard per item.
    /// Items are mutated in place through disjoint `&mut` borrows.
    pub fn run_mut<T: Send, F: Fn(usize, &mut T) + Sync>(
        &self,
        items: &mut [T],
        f: &F,
    ) {
        struct SharedPtr<T>(*mut T);
        // SAFETY: each shard index dereferences a distinct element, so the
        // `&mut` borrows handed to `f` are disjoint.
        unsafe impl<T: Send> Sync for SharedPtr<T> {}
        let base = SharedPtr(items.as_mut_ptr());
        let len = items.len();
        self.run(len, &|i| {
            debug_assert!(i < len);
            let item = unsafe { &mut *base.0.add(i) };
            f(i, item);
        });
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        // closing the channels lets workers drain and exit
        self.senders.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn default_threads() -> usize {
    std::env::var("DFEP_POOL_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        })
}

static GLOBAL: OnceLock<ThreadPool> = OnceLock::new();

std::thread_local! {
    static OVERRIDE: std::cell::RefCell<Vec<Arc<ThreadPool>>> =
        const { std::cell::RefCell::new(Vec::new()) };
}

/// The process-wide pool (created on first use).
pub fn global() -> &'static ThreadPool {
    GLOBAL.get_or_init(|| ThreadPool::new(default_threads()))
}

/// Thread count of the pool [`run`]/[`run_mut`] would use right now.
pub fn current_threads() -> usize {
    OVERRIDE.with(|o| o.borrow().last().map(|p| p.threads()))
        .unwrap_or_else(|| global().threads())
}

/// Run `f` with a temporary pool of exactly `threads` workers installed
/// for the current thread (used by tests and the hotpath bench to pin
/// 1/2/8-thread configurations).
pub fn with_threads<R>(threads: usize, f: impl FnOnce() -> R) -> R {
    struct PopGuard;
    impl Drop for PopGuard {
        fn drop(&mut self) {
            OVERRIDE.with(|o| {
                o.borrow_mut().pop();
            });
        }
    }
    let pool = Arc::new(ThreadPool::new(threads));
    OVERRIDE.with(|o| o.borrow_mut().push(pool));
    let _guard = PopGuard;
    f()
}

static INLINE: OnceLock<Arc<ThreadPool>> = OnceLock::new();

/// Run `f` with the no-worker inline pool installed for the current
/// thread: every [`run`]/[`run_mut`] inside `f` executes its shards on
/// the caller in index order, exactly like a 1-thread pool, without
/// spawning anything.
///
/// This is the sanctioned way to nest data-parallel code under an outer
/// [`run_mut`]: the outer call fans items out across the ambient pool's
/// workers, each worker wraps its item in `with_inline`, and the inner
/// `run` calls collapse to sequential loops instead of re-submitting to
/// the pool the workers themselves belong to (which would deadlock —
/// see the module docs). Because a 1-thread run is the determinism
/// baseline, the nested work computes bit-identical results to any
/// other thread count.
pub fn with_inline<R>(f: impl FnOnce() -> R) -> R {
    struct PopGuard;
    impl Drop for PopGuard {
        fn drop(&mut self) {
            OVERRIDE.with(|o| {
                o.borrow_mut().pop();
            });
        }
    }
    let pool =
        Arc::clone(INLINE.get_or_init(|| Arc::new(ThreadPool::inline())));
    OVERRIDE.with(|o| o.borrow_mut().push(pool));
    let _guard = PopGuard;
    f()
}

fn current_pool() -> Option<Arc<ThreadPool>> {
    OVERRIDE.with(|o| o.borrow().last().cloned())
}

/// [`ThreadPool::run`] on the current pool (TLS override or global).
pub fn run<F: Fn(usize) + Sync>(shards: usize, f: &F) {
    match current_pool() {
        Some(p) => p.run(shards, f),
        None => global().run(shards, f),
    }
}

/// [`ThreadPool::run_mut`] on the current pool (TLS override or global).
pub fn run_mut<T: Send, F: Fn(usize, &mut T) + Sync>(items: &mut [T], f: &F) {
    match current_pool() {
        Some(p) => p.run_mut(items, f),
        None => global().run_mut(items, f),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn runs_every_shard_exactly_once() {
        let pool = ThreadPool::new(4);
        let hits: Vec<AtomicUsize> =
            (0..100).map(|_| AtomicUsize::new(0)).collect();
        pool.run(100, &|i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn run_mut_gives_disjoint_mut_access() {
        let pool = ThreadPool::new(3);
        let mut items: Vec<usize> = vec![0; 57];
        pool.run_mut(&mut items, &|i, x| *x = i * 2);
        for (i, &x) in items.iter().enumerate() {
            assert_eq!(x, i * 2);
        }
    }

    #[test]
    fn results_identical_across_thread_counts() {
        let compute = |threads: usize| -> Vec<u64> {
            let pool = ThreadPool::new(threads);
            let mut out = vec![0u64; 64];
            pool.run_mut(&mut out, &|i, x| {
                // per-shard pure function of the index
                let mut v = i as u64 + 1;
                for _ in 0..1000 {
                    v = v.wrapping_mul(6364136223846793005).wrapping_add(1);
                }
                *x = v;
            });
            out
        };
        let base = compute(1);
        for t in [2, 3, 8] {
            assert_eq!(compute(t), base, "{t} threads");
        }
    }

    #[test]
    fn with_threads_overrides_current_pool() {
        with_threads(3, || {
            assert_eq!(current_threads(), 3);
            with_threads(1, || assert_eq!(current_threads(), 1));
            assert_eq!(current_threads(), 3);
        });
    }

    #[test]
    fn with_inline_runs_shards_on_caller_in_order() {
        with_inline(|| {
            assert_eq!(current_threads(), 1);
            let caller = std::thread::current().id();
            let order = std::sync::Mutex::new(Vec::new());
            run(16, &|i| {
                assert_eq!(std::thread::current().id(), caller);
                order.lock().unwrap().push(i);
            });
            assert_eq!(*order.lock().unwrap(), (0..16).collect::<Vec<_>>());
        });
    }

    #[test]
    fn with_inline_nests_under_run_mut_without_deadlock() {
        // the batch engine's shape: outer run_mut over lanes on a real
        // pool, each lane running inner data-parallel code inline
        with_threads(4, || {
            let mut lanes: Vec<u64> = vec![0; 8];
            run_mut(&mut lanes, &|l, out| {
                with_inline(|| {
                    let total = std::sync::atomic::AtomicUsize::new(0);
                    run(32, &|i| {
                        total.fetch_add(l * 100 + i, Ordering::SeqCst);
                    });
                    *out = total.load(Ordering::SeqCst) as u64;
                })
            });
            for (l, &v) in lanes.iter().enumerate() {
                assert_eq!(v as usize, l * 3200 + (0..32).sum::<usize>());
            }
        });
    }

    #[test]
    fn pool_is_reusable_across_many_rounds() {
        let pool = ThreadPool::new(2);
        let counter = AtomicUsize::new(0);
        for _ in 0..200 {
            pool.run(5, &|_| {
                counter.fetch_add(1, Ordering::SeqCst);
            });
        }
        assert_eq!(counter.load(Ordering::SeqCst), 1000);
    }

    #[test]
    fn task_panic_propagates_without_deadlock() {
        let pool = ThreadPool::new(2);
        let res = catch_unwind(AssertUnwindSafe(|| {
            pool.run(8, &|i| {
                if i == 5 {
                    panic!("shard 5 exploded");
                }
            });
        }));
        assert!(res.is_err());
        // pool still usable afterwards
        let n = AtomicUsize::new(0);
        pool.run(4, &|_| {
            n.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(n.load(Ordering::SeqCst), 4);
    }
}
