//! Descriptive statistics used by the metrics module and bench harness.

/// Arithmetic mean; 0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stdev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64)
        .sqrt()
}

/// Maximum; 0 for an empty slice.
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max).max(0.0)
}

/// Minimum; 0 for an empty slice.
pub fn min(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().cloned().fold(f64::INFINITY, f64::min)
    }
}

/// p-th percentile (0..=100) by linear interpolation on sorted data.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Summary of repeated measurements (the bench harness prints these).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    /// Sample count.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Population standard deviation.
    pub stdev: f64,
    /// Minimum.
    pub min: f64,
    /// Median.
    pub p50: f64,
    /// 95th percentile.
    pub p95: f64,
    /// Maximum.
    pub max: f64,
}

impl Summary {
    /// Summarize a sample (all fields 0 for an empty slice).
    pub fn of(xs: &[f64]) -> Summary {
        Summary {
            n: xs.len(),
            mean: mean(xs),
            stdev: stdev(xs),
            min: min(xs),
            p50: percentile(xs, 50.0),
            p95: percentile(xs, 95.0),
            max: max(xs),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_stdev_basics() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stdev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn empty_slices_are_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stdev(&[]), 0.0);
        assert_eq!(max(&[]), 0.0);
        assert_eq!(min(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn summary_fields_consistent() {
        let xs = [3.0, 1.0, 2.0];
        let s = Summary::of(&xs);
        assert_eq!(s.n, 3);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.p50, 2.0);
    }
}
