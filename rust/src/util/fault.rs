//! Deterministic, seed-driven fault injection and retry/backoff policy.
//!
//! The chaos plane for the cluster and serving layers: a [`FaultPlan`]
//! parsed from a spec string describes *what* to inject (drops, delays,
//! byte corruption, short reads, torn writes) and a seed makes every
//! chaos run replayable bit-for-bit. The plan itself is inert config;
//! each wire connection or file sink gets its own [`FaultArm`] — a
//! forked deterministic RNG stream plus shared [`FaultCounters`] — so
//! decisions depend only on `(plan seed, arm tag, operation index)`,
//! never on wall-clock or thread scheduling.
//!
//! Spec grammar (comma-separated `key=value`, optional `fault:` prefix):
//!
//! ```text
//! spec     := ["fault:"] kv ("," kv)*
//! kv       := "seed=" u64        -- RNG seed (default 0)
//!           | "drop=" prob       -- P(op fails as a dead connection)
//!           | "delay_ms=" range  -- uniform sleep per op, "lo..hi" or "n"
//!           | "corrupt=" prob    -- P(one payload byte is flipped on read)
//!           | "short_read=" prob -- P(read ends in premature EOF)
//!           | "torn_write=" prob -- P(write persists only a prefix)
//! prob     := f64 in [0, 1]
//! range    := u64 | u64 ".." u64
//! ```
//!
//! Example: `fault:seed=7,drop=0.01,delay_ms=0..50,corrupt=0.001`.
//!
//! Injection sites live next to the I/O they wrap:
//! [`crate::util::frame`] (cluster frames), [`crate::util::http`]
//! (serve requests/responses) and [`crate::graph::io`] (checkpoint
//! blobs). A `None` arm is a no-op, so the disabled path costs one
//! branch per operation.
//!
//! The module also hosts [`RetryPolicy`], the bounded
//! deterministic-jitter exponential backoff used by `ServeClient` and
//! the cluster worker connect path.

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::util::error::{Error, ErrorKind, Result};
use crate::util::rng::Rng;

/// Golden-ratio mixing constant (same idiom as [`Rng::fork`]) used to
/// derive per-arm seeds from the plan seed and an arm tag.
const TAG_MIX: u64 = 0x9E37_79B9_7F4A_7C15;

/// A parsed fault-injection plan: pure configuration, no state.
///
/// Build one with [`FaultPlan::parse`] (or literally, for tests), then
/// hand out per-connection [`FaultArm`]s via [`FaultPlan::arm`]. The
/// default plan injects nothing ([`is_noop`](FaultPlan::is_noop)).
#[derive(Clone, Debug, PartialEq)]
pub struct FaultPlan {
    /// Seed for every arm's decision stream; same seed ⇒ same faults.
    pub seed: u64,
    /// Per-operation probability the op fails as a dead connection.
    pub drop: f64,
    /// Uniform per-operation sleep range in milliseconds `[lo, hi]`.
    pub delay_ms: (u64, u64),
    /// Per-read probability one payload byte is flipped (pre-checksum).
    pub corrupt: f64,
    /// Per-read probability of a premature EOF.
    pub short_read: f64,
    /// Per-write probability only a prefix of the payload lands.
    pub torn_write: f64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            seed: 0,
            drop: 0.0,
            delay_ms: (0, 0),
            corrupt: 0.0,
            short_read: 0.0,
            torn_write: 0.0,
        }
    }
}

fn spec_err(msg: String) -> Error {
    Error::msg(msg).with_kind(ErrorKind::InvalidSpec)
}

fn parse_prob(key: &str, val: &str) -> Result<f64> {
    let p: f64 = val.trim().parse().map_err(|_| {
        spec_err(format!("fault spec: {key}={val} is not a number"))
    })?;
    if !(0.0..=1.0).contains(&p) {
        return Err(spec_err(format!(
            "fault spec: {key}={val} must be a probability in [0, 1]"
        )));
    }
    Ok(p)
}

fn parse_range(val: &str) -> Result<(u64, u64)> {
    let val = val.trim();
    let parse_one = |s: &str| -> Result<u64> {
        s.trim().parse().map_err(|_| {
            spec_err(format!("fault spec: delay_ms bound `{s}` is not a u64"))
        })
    };
    let (lo, hi) = match val.split_once("..") {
        Some((lo, hi)) => (parse_one(lo)?, parse_one(hi)?),
        None => {
            let n = parse_one(val)?;
            (n, n)
        }
    };
    if lo > hi {
        return Err(spec_err(format!(
            "fault spec: delay_ms range {lo}..{hi} is inverted"
        )));
    }
    Ok((lo, hi))
}

impl FaultPlan {
    /// Parse a plan from its spec string (grammar in the module docs).
    ///
    /// Unknown keys, malformed values, probabilities outside `[0, 1]`
    /// and inverted delay ranges are all
    /// [`ErrorKind::InvalidSpec`] errors. An empty spec (or a bare
    /// `fault:` prefix) parses to the no-op default plan.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let body = spec.strip_prefix("fault:").unwrap_or(spec).trim();
        let mut plan = FaultPlan::default();
        if body.is_empty() {
            return Ok(plan);
        }
        for part in body.split(',') {
            let (key, val) = part.split_once('=').ok_or_else(|| {
                spec_err(format!(
                    "fault spec: field `{part}` is not key=value"
                ))
            })?;
            match key.trim() {
                "seed" => {
                    plan.seed = val.trim().parse().map_err(|_| {
                        spec_err(format!(
                            "fault spec: seed={val} is not a u64"
                        ))
                    })?;
                }
                "drop" => plan.drop = parse_prob("drop", val)?,
                "delay_ms" => plan.delay_ms = parse_range(val)?,
                "corrupt" => plan.corrupt = parse_prob("corrupt", val)?,
                "short_read" => {
                    plan.short_read = parse_prob("short_read", val)?
                }
                "torn_write" => {
                    plan.torn_write = parse_prob("torn_write", val)?
                }
                other => {
                    return Err(spec_err(format!(
                        "fault spec: unknown key `{other}` (expected seed, \
                         drop, delay_ms, corrupt, short_read, torn_write)"
                    )));
                }
            }
        }
        Ok(plan)
    }

    /// True when the plan injects nothing (all rates zero, no delay).
    pub fn is_noop(&self) -> bool {
        self.drop == 0.0
            && self.corrupt == 0.0
            && self.short_read == 0.0
            && self.torn_write == 0.0
            && self.delay_ms.1 == 0
    }

    /// Fork a decision stream for one connection or sink.
    ///
    /// `tag` must be stable across replays of the same run (the cluster
    /// uses `rank` + incarnation, serve uses the accept-order index);
    /// two arms with the same `(seed, tag)` make identical decisions.
    /// Fired faults are tallied into the shared `counters`.
    pub fn arm(
        &self,
        tag: u64,
        counters: Arc<FaultCounters>,
    ) -> FaultArm {
        FaultArm {
            drop: self.drop,
            delay_ms: self.delay_ms,
            corrupt: self.corrupt,
            short_read: self.short_read,
            torn_write: self.torn_write,
            rng: Rng::new(self.seed ^ tag.wrapping_mul(TAG_MIX)),
            counters,
        }
    }
}

impl fmt::Display for FaultPlan {
    /// Canonical spec round-trip: `fault:seed=...` plus non-zero knobs.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "fault:seed={}", self.seed)?;
        if self.drop > 0.0 {
            write!(f, ",drop={}", self.drop)?;
        }
        if self.delay_ms.1 > 0 {
            write!(f, ",delay_ms={}..{}", self.delay_ms.0, self.delay_ms.1)?;
        }
        if self.corrupt > 0.0 {
            write!(f, ",corrupt={}", self.corrupt)?;
        }
        if self.short_read > 0.0 {
            write!(f, ",short_read={}", self.short_read)?;
        }
        if self.torn_write > 0.0 {
            write!(f, ",torn_write={}", self.torn_write)?;
        }
        Ok(())
    }
}

/// Shared tally of faults actually fired, one counter per knob.
///
/// Lives in an `Arc` shared by every [`FaultArm`] of a deployment so
/// the serve `/stats` endpoint and [`ClusterReport`] can surface how
/// much chaos a run absorbed — and so the soak tests can assert that
/// the same seed replays the same fault sequence.
///
/// [`ClusterReport`]: crate::cluster::runtime::ClusterReport
#[derive(Debug, Default)]
pub struct FaultCounters {
    /// Operations failed as a dead connection.
    pub drops: AtomicU64,
    /// Operations delayed by a non-zero injected sleep.
    pub delays: AtomicU64,
    /// Reads with one payload byte flipped.
    pub corruptions: AtomicU64,
    /// Reads cut short with a premature EOF.
    pub short_reads: AtomicU64,
    /// Writes that persisted only a prefix.
    pub torn_writes: AtomicU64,
}

impl FaultCounters {
    /// A fresh zeroed tally behind an `Arc`, ready to share across arms.
    pub fn shared() -> Arc<FaultCounters> {
        Arc::new(FaultCounters::default())
    }

    /// A consistent point-in-time copy of all counters.
    pub fn snapshot(&self) -> FaultSnapshot {
        FaultSnapshot {
            drops: self.drops.load(Ordering::Relaxed),
            delays: self.delays.load(Ordering::Relaxed),
            corruptions: self.corruptions.load(Ordering::Relaxed),
            short_reads: self.short_reads.load(Ordering::Relaxed),
            torn_writes: self.torn_writes.load(Ordering::Relaxed),
        }
    }
}

/// Plain-value copy of [`FaultCounters`] for reports and JSON sinks.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultSnapshot {
    /// Operations failed as a dead connection.
    pub drops: u64,
    /// Operations delayed by a non-zero injected sleep.
    pub delays: u64,
    /// Reads with one payload byte flipped.
    pub corruptions: u64,
    /// Reads cut short with a premature EOF.
    pub short_reads: u64,
    /// Writes that persisted only a prefix.
    pub torn_writes: u64,
}

impl FaultSnapshot {
    /// Sum of every counter (delays included).
    pub fn total(&self) -> u64 {
        self.drops
            + self.delays
            + self.corruptions
            + self.short_reads
            + self.torn_writes
    }
}

/// Verdict for one outbound operation (frame, response, or blob).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WriteFault {
    /// Perform the write normally.
    Pass,
    /// Fail the write as a dead connection; nothing is written.
    Drop,
    /// Persist only a prefix of the payload, then fail (wire) or
    /// silently "succeed" (disk — modeling a lying fsync).
    Torn,
}

/// Verdict for one inbound operation, decided after its bytes arrived.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReadFault {
    /// Deliver the payload untouched.
    Pass,
    /// Fail the read as a reset connection.
    Drop,
    /// Flip the payload byte at this index before any checksum check.
    CorruptAt(usize),
    /// Fail the read with a premature EOF.
    Short,
}

/// One connection's (or sink's) fault decision stream.
///
/// Created by [`FaultPlan::arm`]; never cloned or shared — each wire
/// connection and each file sink owns exactly one arm so the decision
/// sequence is a pure function of `(plan seed, tag, op index)`.
///
/// Draw order is fixed and documented (it is part of the replay
/// contract): every operation first draws the delay (when the plan has
/// one) and sleeps it, then draws the remaining knobs in the order
/// listed on [`on_write`](FaultArm::on_write) /
/// [`on_read`](FaultArm::on_read), stopping at the first knob that
/// fires. Draws are only made for knobs the plan enables, so a given
/// plan always consumes the same stream positions.
#[derive(Debug)]
pub struct FaultArm {
    drop: f64,
    delay_ms: (u64, u64),
    corrupt: f64,
    short_read: f64,
    torn_write: f64,
    rng: Rng,
    counters: Arc<FaultCounters>,
}

impl FaultArm {
    /// Draw (and sleep) the injected delay for one operation.
    fn delay(&mut self) {
        let (lo, hi) = self.delay_ms;
        if hi == 0 {
            return;
        }
        let span = hi.max(lo) - lo;
        let d = lo + self.rng.below(span as usize + 1) as u64;
        if d > 0 {
            self.counters.delays.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(Duration::from_millis(d));
        }
    }

    /// Decide the fate of one outbound operation.
    ///
    /// Draw order: delay (slept here), then `drop`, then `torn_write`.
    pub fn on_write(&mut self) -> WriteFault {
        self.delay();
        if self.drop > 0.0 && self.rng.chance(self.drop) {
            self.counters.drops.fetch_add(1, Ordering::Relaxed);
            return WriteFault::Drop;
        }
        if self.torn_write > 0.0 && self.rng.chance(self.torn_write) {
            self.counters.torn_writes.fetch_add(1, Ordering::Relaxed);
            return WriteFault::Torn;
        }
        WriteFault::Pass
    }

    /// Decide the fate of one inbound operation whose payload is
    /// `len` bytes long.
    ///
    /// Draw order: delay (slept here), then `drop`, then `corrupt`
    /// (the flipped byte index is drawn only when corruption fires and
    /// `len > 0`; an empty payload passes untouched), then
    /// `short_read`.
    pub fn on_read(&mut self, len: usize) -> ReadFault {
        self.delay();
        if self.drop > 0.0 && self.rng.chance(self.drop) {
            self.counters.drops.fetch_add(1, Ordering::Relaxed);
            return ReadFault::Drop;
        }
        if self.corrupt > 0.0 && self.rng.chance(self.corrupt) && len > 0 {
            self.counters.corruptions.fetch_add(1, Ordering::Relaxed);
            return ReadFault::CorruptAt(self.rng.below(len));
        }
        if self.short_read > 0.0 && self.rng.chance(self.short_read) {
            self.counters.short_reads.fetch_add(1, Ordering::Relaxed);
            return ReadFault::Short;
        }
        ReadFault::Pass
    }
}

/// Bounded retries with deterministic-jitter exponential backoff.
///
/// Attempt `i` (0-based) sleeps
/// `min(max_ms, base_ms · 2^i) · (0.5 + 0.5·u)` milliseconds where `u`
/// is drawn from a caller-owned deterministic [`Rng`] — so two clients
/// seeded differently decorrelate (no thundering herd) yet any single
/// run replays exactly. Used by `ServeClient` and the cluster worker
/// connect path.
#[derive(Clone, Debug, PartialEq)]
pub struct RetryPolicy {
    /// Total attempts (first try included); `1` disables retries.
    pub attempts: u32,
    /// Backoff base for the first retry, in milliseconds.
    pub base_ms: u64,
    /// Ceiling on a single backoff sleep, in milliseconds.
    pub max_ms: u64,
}

impl Default for RetryPolicy {
    /// Four attempts, 10 ms base, 500 ms cap — tuned for loopback.
    fn default() -> Self {
        RetryPolicy { attempts: 4, base_ms: 10, max_ms: 500 }
    }
}

impl RetryPolicy {
    /// The jittered backoff to sleep after failed attempt `attempt`
    /// (0-based). Deterministic given the `rng` stream position.
    pub fn delay(&self, attempt: u32, rng: &mut Rng) -> Duration {
        let exp = self.base_ms.saturating_mul(1u64 << attempt.min(20));
        let capped = exp.min(self.max_ms);
        let jittered = (capped as f64) * (0.5 + 0.5 * rng.f64());
        Duration::from_millis(jittered as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_spec_and_defaults() {
        let p = FaultPlan::parse(
            "fault:seed=7,drop=0.01,delay_ms=0..50,corrupt=0.001,\
             short_read=0.01,torn_write=0.005",
        )
        .unwrap();
        assert_eq!(p.seed, 7);
        assert_eq!(p.drop, 0.01);
        assert_eq!(p.delay_ms, (0, 50));
        assert_eq!(p.corrupt, 0.001);
        assert_eq!(p.short_read, 0.01);
        assert_eq!(p.torn_write, 0.005);
        assert!(!p.is_noop());
        // prefix optional, empty spec is the no-op default
        assert_eq!(FaultPlan::parse("seed=3").unwrap().seed, 3);
        assert!(FaultPlan::parse("").unwrap().is_noop());
        assert!(FaultPlan::parse("fault:").unwrap().is_noop());
        // single-value delay range
        assert_eq!(
            FaultPlan::parse("delay_ms=5").unwrap().delay_ms,
            (5, 5)
        );
    }

    #[test]
    fn parse_rejects_nonsense_with_invalid_spec_kind() {
        for bad in [
            "fault:drop=2.0",
            "fault:drop=-0.1",
            "fault:drop=abc",
            "fault:delay_ms=9..3",
            "fault:delay_ms=x..3",
            "fault:seed=notanum",
            "fault:warp=0.5",
            "fault:dropless",
        ] {
            let err = FaultPlan::parse(bad).unwrap_err();
            assert_eq!(
                err.kind(),
                ErrorKind::InvalidSpec,
                "{bad}: {err}"
            );
        }
    }

    #[test]
    fn display_round_trips_through_parse() {
        let p = FaultPlan::parse(
            "fault:seed=9,drop=0.25,delay_ms=1..4,torn_write=0.5",
        )
        .unwrap();
        assert_eq!(FaultPlan::parse(&p.to_string()).unwrap(), p);
        assert_eq!(FaultPlan::default().to_string(), "fault:seed=0");
    }

    #[test]
    fn same_seed_and_tag_replays_identical_decisions() {
        let plan = FaultPlan::parse(
            "fault:seed=11,drop=0.2,corrupt=0.2,short_read=0.2,\
             torn_write=0.2",
        )
        .unwrap();
        let run = |tag: u64| {
            let c = FaultCounters::shared();
            let mut arm = plan.arm(tag, c.clone());
            let reads: Vec<ReadFault> =
                (0..64).map(|_| arm.on_read(100)).collect();
            let writes: Vec<WriteFault> =
                (0..64).map(|_| arm.on_write()).collect();
            (reads, writes, c.snapshot())
        };
        let a = run(1);
        let b = run(1);
        assert_eq!(a, b, "same (seed, tag) must replay identically");
        let c = run(2);
        assert_ne!(a.0, c.0, "different tags must decorrelate");
    }

    #[test]
    fn noop_plan_never_fires_and_counts_nothing() {
        let c = FaultCounters::shared();
        let mut arm = FaultPlan::default().arm(0, c.clone());
        for _ in 0..100 {
            assert_eq!(arm.on_read(64), ReadFault::Pass);
            assert_eq!(arm.on_write(), WriteFault::Pass);
        }
        assert_eq!(c.snapshot().total(), 0);
    }

    #[test]
    fn certain_faults_fire_and_tally() {
        let plan =
            FaultPlan { drop: 1.0, ..FaultPlan::default() };
        let c = FaultCounters::shared();
        let mut arm = plan.arm(0, c.clone());
        assert_eq!(arm.on_read(8), ReadFault::Drop);
        assert_eq!(arm.on_write(), WriteFault::Drop);
        assert_eq!(c.snapshot().drops, 2);

        let plan = FaultPlan {
            corrupt: 1.0,
            torn_write: 1.0,
            ..FaultPlan::default()
        };
        let c = FaultCounters::shared();
        let mut arm = plan.arm(0, c.clone());
        match arm.on_read(16) {
            ReadFault::CorruptAt(i) => assert!(i < 16),
            other => panic!("expected corruption, got {other:?}"),
        }
        // an empty payload cannot be corrupted — passes untouched
        assert_eq!(arm.on_read(0), ReadFault::Pass);
        assert_eq!(arm.on_write(), WriteFault::Torn);
        let snap = c.snapshot();
        assert_eq!((snap.corruptions, snap.torn_writes), (1, 1));

        let plan =
            FaultPlan { short_read: 1.0, ..FaultPlan::default() };
        let c = FaultCounters::shared();
        let mut arm = plan.arm(0, c.clone());
        assert_eq!(arm.on_read(8), ReadFault::Short);
        assert_eq!(c.snapshot().short_reads, 1);
    }

    #[test]
    fn delay_sleeps_and_counts() {
        let plan = FaultPlan {
            delay_ms: (1, 2),
            ..FaultPlan::default()
        };
        let c = FaultCounters::shared();
        let mut arm = plan.arm(0, c.clone());
        let t0 = std::time::Instant::now();
        for _ in 0..4 {
            arm.on_read(8);
        }
        assert!(t0.elapsed() >= Duration::from_millis(4));
        assert_eq!(c.snapshot().delays, 4);
    }

    #[test]
    fn retry_backoff_is_bounded_and_deterministic() {
        let pol = RetryPolicy::default();
        let mut a = Rng::new(5);
        let mut b = Rng::new(5);
        for i in 0..6 {
            let da = pol.delay(i, &mut a);
            let db = pol.delay(i, &mut b);
            assert_eq!(da, db, "same rng stream must replay");
            let cap = pol.base_ms.saturating_mul(1 << i).min(pol.max_ms);
            assert!(da <= Duration::from_millis(cap), "attempt {i}: {da:?}");
            assert!(
                da >= Duration::from_millis(cap / 2 - 1),
                "attempt {i}: {da:?} under half-floor"
            );
        }
        // the cap holds even for absurd attempt numbers
        let d = pol.delay(63, &mut a);
        assert!(d <= Duration::from_millis(pol.max_ms));
    }
}
