//! Minimal JSON parser — just enough for `artifacts/manifest.json`.
//!
//! (The vendored crate set has no `serde_json`; the manifest is small and
//! machine-generated, so a compact recursive-descent parser is plenty.)

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number (f64-backed, like JavaScript).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted map keeps iteration deterministic).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Object field lookup (None on non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Borrow as a string, if this is one.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Read as a number, if this is one.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Read as a number truncated to usize.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// Borrow as an array, if this is one.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Borrow as an object map, if this is one.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct JsonError {
    /// Byte offset the parser stopped at.
    pub pos: usize,
    /// What went wrong.
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    s: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: &str) -> Result<T, JsonError> {
        Err(JsonError { pos: self.i, msg: msg.into() })
    }

    fn skip_ws(&mut self) {
        while self.i < self.s.len()
            && matches!(self.s[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.s.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            self.err(&format!("expected '{}'", c as char))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => self.err("unexpected character"),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.s[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            self.err(&format!("expected '{word}'"))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return self.err("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.s.len() {
                                return self.err("bad \\u escape");
                            }
                            let hex = std::str::from_utf8(
                                &self.s[self.i + 1..self.i + 5],
                            )
                            .map_err(|_| JsonError {
                                pos: self.i,
                                msg: "bad \\u escape".into(),
                            })?;
                            let cp = u32::from_str_radix(hex, 16).map_err(
                                |_| JsonError {
                                    pos: self.i,
                                    msg: "bad \\u escape".into(),
                                },
                            )?;
                            out.push(
                                char::from_u32(cp).unwrap_or('\u{fffd}'),
                            );
                            self.i += 4;
                        }
                        _ => return self.err("bad escape"),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full UTF-8 scalar
                    let start = self.i;
                    let len = utf8_len(self.s[start]);
                    let end = (start + len).min(self.s.len());
                    out.push_str(
                        std::str::from_utf8(&self.s[start..end]).map_err(
                            |_| JsonError {
                                pos: start,
                                msg: "invalid utf-8".into(),
                            },
                        )?,
                    );
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.s[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| JsonError { pos: start, msg: "bad number".into() })
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

/// Parse a complete JSON document.
pub fn parse(text: &str) -> Result<Json, JsonError> {
    let mut p = Parser { s: text.as_bytes(), i: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.s.len() {
        return p.err("trailing garbage");
    }
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_doc() {
        let doc = r#"{
          "minplus_block_256": {
            "file": "minplus_block_256.hlo.txt",
            "inputs": [
              {"shape": [256, 256], "dtype": "f32"},
              {"shape": [256], "dtype": "f32"}
            ],
            "outputs": [{"shape": [256], "dtype": "f32"}]
          }
        }"#;
        let v = parse(doc).unwrap();
        let entry = v.get("minplus_block_256").unwrap();
        assert_eq!(
            entry.get("file").unwrap().as_str().unwrap(),
            "minplus_block_256.hlo.txt"
        );
        let inputs = entry.get("inputs").unwrap().as_arr().unwrap();
        assert_eq!(inputs.len(), 2);
        let shape = inputs[0].get("shape").unwrap().as_arr().unwrap();
        assert_eq!(shape[0].as_usize().unwrap(), 256);
    }

    #[test]
    fn scalars_and_escapes() {
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            parse(r#""a\nbA""#).unwrap(),
            Json::Str("a\nbA".into())
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn nested_arrays() {
        let v = parse("[[1,2],[3],[]]").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[0].as_arr().unwrap().len(), 2);
        assert_eq!(a[2].as_arr().unwrap().len(), 0);
    }
}
