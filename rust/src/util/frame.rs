//! Checksummed, length-prefixed binary frame codec (v2) for the
//! cluster runtime.
//!
//! Sibling of [`crate::util::http`]: where `http` frames text requests for
//! the serving surface, `frame` moves opaque binary payloads between the
//! `repro cluster` coordinator and its workers over localhost TCP.
//!
//! Grammar (all integers little-endian):
//!
//! ```text
//! frame   := magic len crc payload
//! magic   := u32            -- codec tag "FRM2" (0x324D5246)
//! len     := u32            -- byte length of payload, <= MAX_FRAME_BYTES
//! crc     := u64            -- fnv1a64 of payload
//! payload := len * u8       -- opaque (cluster::proto encodes messages here)
//! ```
//!
//! The 16-byte header is the only framing overhead; message typing and
//! versioning live inside the payload (`cluster::proto`). The magic tag
//! versions the codec itself, so a v1 capture (bare 4-byte length
//! prefix) fails loudly as [`FrameError::Corrupt`] instead of being
//! misparsed; the checksum turns any in-flight bit flip into the same
//! typed error. Oversized frames are rejected on both ends so a
//! corrupted length field cannot trigger a multi-gigabyte allocation.
//!
//! The `_with` variants accept an optional [`FaultArm`] so the chaos
//! plane ([`crate::util::fault`]) can drop, delay, corrupt, shorten or
//! tear individual frames; `None` is a single-branch no-op.

use std::io::{Read, Write};

use crate::util::error::{Error, ErrorKind};
use crate::util::fault::{FaultArm, ReadFault, WriteFault};

/// Hard cap on a single frame payload (64 MiB). Large enough for an edge
/// list shipped at init on any graph we generate in tests or CI, small
/// enough to catch a corrupted length field immediately.
pub const MAX_FRAME_BYTES: usize = 64 * 1024 * 1024;

/// Codec tag leading every frame: ASCII `"FRM2"`, little-endian.
pub const FRAME_MAGIC: u32 = u32::from_le_bytes(*b"FRM2");

/// Bytes added on the wire per frame (magic + length + checksum).
pub const FRAME_HEADER_BYTES: usize = 16;

/// Total wire bytes for a payload of `payload_len` bytes.
pub fn wire_len(payload_len: usize) -> usize {
    payload_len + FRAME_HEADER_BYTES
}

/// FNV-1a 64-bit over a byte stream — the checksum used by frames,
/// HTTP body digests and checkpoint blobs. Not cryptographic; it
/// detects accidental corruption, not adversaries.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Errors while reading or writing a frame.
#[derive(Debug)]
pub enum FrameError {
    /// Frame length exceeds [`MAX_FRAME_BYTES`] (corrupt header or abuse).
    TooLarge(usize),
    /// The frame failed integrity checks: wrong magic (a v1 capture or
    /// desynchronized stream) or a checksum mismatch (bit rot in
    /// flight). The connection is unusable past this point.
    Corrupt(String),
    /// Underlying socket/file error (includes EOF and read timeouts).
    Io(std::io::Error),
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::TooLarge(n) => {
                write!(f, "frame of {n} bytes exceeds cap {MAX_FRAME_BYTES}")
            }
            FrameError::Corrupt(m) => write!(f, "corrupt frame: {m}"),
            FrameError::Io(e) => write!(f, "frame io: {e}"),
        }
    }
}

impl std::error::Error for FrameError {}

impl From<std::io::Error> for FrameError {
    fn from(e: std::io::Error) -> Self {
        FrameError::Io(e)
    }
}

impl FrameError {
    /// True when the peer closed the connection cleanly (EOF mid-header).
    pub fn is_eof(&self) -> bool {
        matches!(self, FrameError::Io(e)
            if e.kind() == std::io::ErrorKind::UnexpectedEof)
    }

    /// True when a configured read timeout expired (stalled peer).
    pub fn is_timeout(&self) -> bool {
        matches!(self, FrameError::Io(e)
            if matches!(
                e.kind(),
                std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
            ))
    }

    /// True when the frame failed an integrity check (magic or crc).
    pub fn is_corrupt(&self) -> bool {
        matches!(self, FrameError::Corrupt(_))
    }

    /// Convert into the crate [`Error`], tagged
    /// [`ErrorKind::Transport`] with the given context prefix. (An
    /// inherent method rather than a `From` impl: the blanket
    /// `std::error::Error` conversion in `util::error` would collide,
    /// and it tags `Internal` — frame failures are transport facts.)
    pub fn into_error(self, context: &str) -> Error {
        Error::msg(format!("{context}: {self}"))
            .with_kind(ErrorKind::Transport)
    }
}

fn injected(kind: std::io::ErrorKind, what: &str) -> FrameError {
    FrameError::Io(std::io::Error::new(kind, format!("injected {what}")))
}

/// Write one frame (header + payload) and flush.
pub fn write_frame<W: Write>(
    w: &mut W,
    payload: &[u8],
) -> Result<(), FrameError> {
    write_frame_with(w, payload, None)
}

/// [`write_frame`] with an optional fault-injection arm.
///
/// A firing `drop` fails before any byte lands; a firing `torn_write`
/// puts the header and half the payload on the wire, then fails — the
/// peer sees a frame that never completes (timeout or EOF).
pub fn write_frame_with<W: Write>(
    w: &mut W,
    payload: &[u8],
    arm: Option<&mut FaultArm>,
) -> Result<(), FrameError> {
    if payload.len() > MAX_FRAME_BYTES {
        return Err(FrameError::TooLarge(payload.len()));
    }
    let mut header = [0u8; FRAME_HEADER_BYTES];
    header[0..4].copy_from_slice(&FRAME_MAGIC.to_le_bytes());
    header[4..8].copy_from_slice(&(payload.len() as u32).to_le_bytes());
    header[8..16].copy_from_slice(&fnv1a64(payload).to_le_bytes());
    if let Some(arm) = arm {
        match arm.on_write() {
            WriteFault::Pass => {}
            WriteFault::Drop => {
                return Err(injected(
                    std::io::ErrorKind::BrokenPipe,
                    "connection drop",
                ));
            }
            WriteFault::Torn => {
                w.write_all(&header)?;
                w.write_all(&payload[..payload.len() / 2])?;
                let _ = w.flush();
                return Err(injected(
                    std::io::ErrorKind::BrokenPipe,
                    "torn write",
                ));
            }
        }
    }
    w.write_all(&header)?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Read one frame, returning its verified payload.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Vec<u8>, FrameError> {
    read_frame_with(r, None)
}

/// [`read_frame`] with an optional fault-injection arm.
///
/// Injected corruption flips one payload byte *before* checksum
/// verification, so the chaos plane exercises the real integrity
/// check rather than bypassing it.
pub fn read_frame_with<R: Read>(
    r: &mut R,
    arm: Option<&mut FaultArm>,
) -> Result<Vec<u8>, FrameError> {
    let mut header = [0u8; FRAME_HEADER_BYTES];
    r.read_exact(&mut header)?;
    let magic = u32::from_le_bytes(header[0..4].try_into().unwrap());
    if magic != FRAME_MAGIC {
        return Err(FrameError::Corrupt(format!(
            "bad magic {magic:#010x} (expected {FRAME_MAGIC:#010x}; a v1 \
             capture or desynchronized stream)"
        )));
    }
    let len = u32::from_le_bytes(header[4..8].try_into().unwrap()) as usize;
    if len > MAX_FRAME_BYTES {
        return Err(FrameError::TooLarge(len));
    }
    let crc = u64::from_le_bytes(header[8..16].try_into().unwrap());
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    if let Some(arm) = arm {
        match arm.on_read(payload.len()) {
            ReadFault::Pass => {}
            ReadFault::Drop => {
                return Err(injected(
                    std::io::ErrorKind::ConnectionReset,
                    "connection drop",
                ));
            }
            ReadFault::CorruptAt(i) => payload[i] ^= 0xA5,
            ReadFault::Short => {
                return Err(injected(
                    std::io::ErrorKind::UnexpectedEof,
                    "short read",
                ));
            }
        }
    }
    let actual = fnv1a64(&payload);
    if actual != crc {
        return Err(FrameError::Corrupt(format!(
            "checksum mismatch: header {crc:#018x}, payload {actual:#018x}"
        )));
    }
    Ok(payload)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::fault::{FaultCounters, FaultPlan};
    use std::io::Cursor;

    #[test]
    fn fnv1a64_matches_published_vectors() {
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
    }

    #[test]
    fn roundtrip_preserves_payload() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        write_frame(&mut buf, b"").unwrap();
        write_frame(&mut buf, &[0xFFu8; 1000]).unwrap();
        let mut c = Cursor::new(buf);
        assert_eq!(read_frame(&mut c).unwrap(), b"hello");
        assert_eq!(read_frame(&mut c).unwrap(), b"");
        assert_eq!(read_frame(&mut c).unwrap(), vec![0xFFu8; 1000]);
        assert_eq!(wire_len(5), 21);
    }

    #[test]
    fn bit_flips_are_detected_as_corrupt() {
        let mut wire = Vec::new();
        write_frame(&mut wire, b"payload bytes").unwrap();
        // flip one payload byte
        let mut flipped = wire.clone();
        flipped[FRAME_HEADER_BYTES + 3] ^= 0x01;
        let err = read_frame(&mut Cursor::new(flipped)).unwrap_err();
        assert!(err.is_corrupt(), "payload flip: {err}");
        // flip one checksum byte
        let mut flipped = wire.clone();
        flipped[9] ^= 0x80;
        let err = read_frame(&mut Cursor::new(flipped)).unwrap_err();
        assert!(err.is_corrupt(), "crc flip: {err}");
        // the typed mapping: corrupt frames become ErrorKind::Transport
        let e = err.into_error("read from worker 3");
        assert_eq!(
            e.kind(),
            crate::util::error::ErrorKind::Transport
        );
        assert!(e.to_string().starts_with("read from worker 3: "));
    }

    #[test]
    fn v1_captures_fail_loudly_on_magic() {
        // a v1 frame: bare u32 length prefix, no magic, no checksum
        let mut v1 = Vec::new();
        v1.extend_from_slice(&100u32.to_le_bytes());
        v1.extend_from_slice(&[7u8; 100]);
        let err = read_frame(&mut Cursor::new(v1)).unwrap_err();
        assert!(err.is_corrupt(), "v1 capture must not be misparsed: {err}");
        assert!(err.to_string().contains("magic"), "{err}");
    }

    #[test]
    fn truncated_stream_is_eof() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"payload").unwrap();
        buf.truncate(FRAME_HEADER_BYTES + 4); // cut mid-payload
        let mut c = Cursor::new(buf);
        let err = read_frame(&mut c).unwrap_err();
        assert!(err.is_eof(), "expected EOF error, got {err}");
        // a cut mid-header also reports is_eof
        let mut buf = Vec::new();
        write_frame(&mut buf, b"payload").unwrap();
        buf.truncate(6);
        assert!(read_frame(&mut Cursor::new(buf)).unwrap_err().is_eof());
        // clean EOF at a frame boundary also reports is_eof
        let mut empty = Cursor::new(Vec::new());
        assert!(read_frame(&mut empty).unwrap_err().is_eof());
    }

    #[test]
    fn oversized_frames_rejected_both_ways() {
        // valid magic but a length claiming 2 GiB — reader must refuse
        // to allocate
        let mut buf = Vec::new();
        buf.extend_from_slice(&FRAME_MAGIC.to_le_bytes());
        buf.extend_from_slice(&(2u32 << 30).to_le_bytes());
        buf.extend_from_slice(&0u64.to_le_bytes());
        let mut c = Cursor::new(buf);
        assert!(matches!(
            read_frame(&mut c),
            Err(FrameError::TooLarge(_))
        ));
        // writer refuses equally
        let huge = vec![0u8; MAX_FRAME_BYTES + 1];
        let mut sink = Vec::new();
        assert!(matches!(
            write_frame(&mut sink, &huge),
            Err(FrameError::TooLarge(_))
        ));
        assert!(sink.is_empty());
    }

    #[test]
    fn fault_arm_injects_typed_failures() {
        let plan = FaultPlan { drop: 1.0, ..FaultPlan::default() };
        let mut arm = plan.arm(0, FaultCounters::shared());
        let mut sink = Vec::new();
        let err =
            write_frame_with(&mut sink, b"x", Some(&mut arm)).unwrap_err();
        assert!(!err.is_eof() && !err.is_corrupt(), "{err}");
        assert!(sink.is_empty(), "a dropped write must land nothing");

        // injected corruption trips the real checksum check
        let plan = FaultPlan { corrupt: 1.0, ..FaultPlan::default() };
        let mut arm = plan.arm(0, FaultCounters::shared());
        let mut wire = Vec::new();
        write_frame(&mut wire, b"some payload").unwrap();
        let err = read_frame_with(&mut Cursor::new(wire), Some(&mut arm))
            .unwrap_err();
        assert!(err.is_corrupt(), "{err}");

        // injected short read surfaces as EOF
        let plan = FaultPlan { short_read: 1.0, ..FaultPlan::default() };
        let mut arm = plan.arm(0, FaultCounters::shared());
        let mut wire = Vec::new();
        write_frame(&mut wire, b"some payload").unwrap();
        let err = read_frame_with(&mut Cursor::new(wire), Some(&mut arm))
            .unwrap_err();
        assert!(err.is_eof(), "{err}");

        // a torn write leaves a frame the reader can never complete
        let plan = FaultPlan { torn_write: 1.0, ..FaultPlan::default() };
        let mut arm = plan.arm(0, FaultCounters::shared());
        let mut wire = Vec::new();
        let err = write_frame_with(&mut wire, b"0123456789", Some(&mut arm))
            .unwrap_err();
        assert!(!err.is_eof(), "{err}");
        assert_eq!(wire.len(), FRAME_HEADER_BYTES + 5);
        assert!(read_frame(&mut Cursor::new(wire)).unwrap_err().is_eof());
    }
}
