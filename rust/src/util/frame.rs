//! Length-prefixed binary frame codec for the cluster runtime.
//!
//! Sibling of [`crate::util::http`]: where `http` frames text requests for
//! the serving surface, `frame` moves opaque binary payloads between the
//! `repro cluster` coordinator and its workers over localhost TCP.
//!
//! Grammar (all integers little-endian):
//!
//! ```text
//! frame   := len payload
//! len     := u32            -- byte length of payload, <= MAX_FRAME_BYTES
//! payload := len * u8       -- opaque (cluster::proto encodes messages here)
//! ```
//!
//! The 4-byte prefix is the only framing overhead; message typing and
//! versioning live inside the payload (`cluster::proto`). Oversized frames
//! are rejected on both ends so a corrupted length prefix cannot trigger a
//! multi-gigabyte allocation.

use std::io::{Read, Write};

/// Hard cap on a single frame payload (64 MiB). Large enough for an edge
/// list shipped at init on any graph we generate in tests or CI, small
/// enough to catch a corrupted length prefix immediately.
pub const MAX_FRAME_BYTES: usize = 64 * 1024 * 1024;

/// Bytes added on the wire per frame (the `u32` length prefix).
pub const FRAME_HEADER_BYTES: usize = 4;

/// Total wire bytes for a payload of `payload_len` bytes.
pub fn wire_len(payload_len: usize) -> usize {
    payload_len + FRAME_HEADER_BYTES
}

/// Errors while reading or writing a frame.
#[derive(Debug)]
pub enum FrameError {
    /// Frame length exceeds [`MAX_FRAME_BYTES`] (corrupt prefix or abuse).
    TooLarge(usize),
    /// Underlying socket/file error (includes EOF and read timeouts).
    Io(std::io::Error),
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::TooLarge(n) => {
                write!(f, "frame of {n} bytes exceeds cap {MAX_FRAME_BYTES}")
            }
            FrameError::Io(e) => write!(f, "frame io: {e}"),
        }
    }
}

impl std::error::Error for FrameError {}

impl From<std::io::Error> for FrameError {
    fn from(e: std::io::Error) -> Self {
        FrameError::Io(e)
    }
}

impl FrameError {
    /// True when the peer closed the connection cleanly (EOF mid-prefix).
    pub fn is_eof(&self) -> bool {
        matches!(self, FrameError::Io(e)
            if e.kind() == std::io::ErrorKind::UnexpectedEof)
    }

    /// True when a configured read timeout expired (stalled peer).
    pub fn is_timeout(&self) -> bool {
        matches!(self, FrameError::Io(e)
            if matches!(
                e.kind(),
                std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
            ))
    }
}

/// Write one frame (length prefix + payload) and flush.
pub fn write_frame<W: Write>(
    w: &mut W,
    payload: &[u8],
) -> Result<(), FrameError> {
    if payload.len() > MAX_FRAME_BYTES {
        return Err(FrameError::TooLarge(payload.len()));
    }
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Read one frame, returning its payload.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Vec<u8>, FrameError> {
    let mut prefix = [0u8; 4];
    r.read_exact(&mut prefix)?;
    let len = u32::from_le_bytes(prefix) as usize;
    if len > MAX_FRAME_BYTES {
        return Err(FrameError::TooLarge(len));
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    Ok(payload)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn roundtrip_preserves_payload() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        write_frame(&mut buf, b"").unwrap();
        write_frame(&mut buf, &[0xFFu8; 1000]).unwrap();
        let mut c = Cursor::new(buf);
        assert_eq!(read_frame(&mut c).unwrap(), b"hello");
        assert_eq!(read_frame(&mut c).unwrap(), b"");
        assert_eq!(read_frame(&mut c).unwrap(), vec![0xFFu8; 1000]);
        assert_eq!(wire_len(5), 9);
    }

    #[test]
    fn truncated_stream_is_eof() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"payload").unwrap();
        buf.truncate(6); // cut mid-payload
        let mut c = Cursor::new(buf);
        let err = read_frame(&mut c).unwrap_err();
        assert!(err.is_eof(), "expected EOF error, got {err}");
        // clean EOF at a frame boundary also reports is_eof
        let mut empty = Cursor::new(Vec::new());
        assert!(read_frame(&mut empty).unwrap_err().is_eof());
    }

    #[test]
    fn oversized_frames_rejected_both_ways() {
        let mut buf = Vec::new();
        // corrupt prefix claiming 2 GiB — reader must refuse to allocate
        buf.extend_from_slice(&(2u32 << 30).to_le_bytes());
        let mut c = Cursor::new(buf);
        assert!(matches!(
            read_frame(&mut c),
            Err(FrameError::TooLarge(_))
        ));
        // writer refuses equally (exercised via a tiny fake cap check)
        let huge = vec![0u8; MAX_FRAME_BYTES + 1];
        let mut sink = Vec::new();
        assert!(matches!(
            write_frame(&mut sink, &huge),
            Err(FrameError::TooLarge(_))
        ));
        assert!(sink.is_empty());
    }
}
