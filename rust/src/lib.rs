//! # DFEP + ETSCH — distributed edge partitioning for graph processing
//!
//! Production-quality reproduction of *"Distributed Edge Partitioning for
//! Graph Processing"* (Guerrieri & Montresor, 2014): the **DFEP**
//! funding-based edge partitioner (plus its **DFEPC** variant), the
//! **ETSCH** edge-partition-centric processing framework, the paper's
//! baselines (JaBeJa, random/hash partitioners, a Pregel-style
//! vertex-centric engine), the simulation harness of Section V-C and a
//! simulated Hadoop/EC2 cluster standing in for Section V-D.
//!
//! Architecture (see `DESIGN.md`): this crate is **Layer 3** — the rust
//! coordinator that owns the event loop, the partitioning rounds and the
//! metrics. The numeric hot path of ETSCH's local-computation phase
//! (tropical-semiring relaxation) and the vectorized DFEP funding round are
//! **Layer 2/1** JAX + Pallas programs, AOT-lowered to HLO text at build
//! time (`make artifacts`) and executed via PJRT from [`runtime`] (in the
//! vendored-crate-free build, a std-only reference interpreter stands in
//! for the PJRT client — see `runtime::xla`). Python never runs on the
//! request path.
//!
//! Shared-memory parallelism comes from [`util::pool`]: DFEP's funding
//! rounds, ETSCH's local-computation phase, the MapReduce engine and the
//! [`partition::view::PartitionView`] build all shard over the same
//! reusable worker pool, with fixed-order reductions so results are
//! bit-identical for every thread count.
//!
//! Derived partition state (per-part edge CSRs, local subgraphs, the
//! replica table, frontier flags) is built exactly once per
//! (graph, partition) by [`partition::view::PartitionView`] and shared by
//! the metrics, the ETSCH engine and the cluster simulators.
//!
//! When the graph outgrows memory, [`graph::stream::EdgeStream`] delivers
//! the edge sequence in bounded-memory chunks and the ingest-time
//! partitioners in [`partition::streaming`] (HDRF, DBH, restreaming
//! refinement) place each edge as it arrives — no CSR is ever built.
//!
//! Partitioners are addressed by spec string (`name:key=val,...`) through
//! [`partition::spec::PartitionerSpec`] and the [`partition::registry`];
//! the coordinator facade
//! ([`coordinator::runs::PartitionRequest`]) turns a spec + dataset + `k`
//! + seed into a full [`coordinator::runs::RunReport`].
//!
//! Quick tour:
//!
//! ```no_run
//! use dfep::graph::generators::GraphKind;
//! use dfep::partition::spec::PartitionerSpec;
//! use dfep::partition::Partitioner;
//! use dfep::etsch::{Etsch, sssp::Sssp};
//!
//! # fn main() -> dfep::util::error::Result<()> {
//! let g = GraphKind::PowerlawCluster { n: 2000, m: 8, p: 0.3 }
//!     .generate(42);
//! let spec: PartitionerSpec = "hdrf:lambda=1.5".parse()?;
//! let part = spec.build().partition_graph(&g, 8, 42)?;
//! let mut engine = Etsch::new(&g, &part);
//! let dist = engine.run(&mut Sssp::new(0));
//! println!("rounds = {}", engine.rounds_executed());
//! # Ok(()) }
//! ```

// Docs are part of the public contract: every public item must carry
// rustdoc (CI builds `cargo doc --no-deps` with `-D warnings`).
#![warn(missing_docs)]
// Style lints the codebase predates; correctness lints stay on.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::type_complexity,
    clippy::manual_div_ceil,
    clippy::new_without_default,
    clippy::comparison_chain,
    clippy::collapsible_else_if,
    clippy::collapsible_if,
    clippy::uninlined_format_args
)]

pub mod bench;
pub mod cluster;
pub mod coordinator;
pub mod etsch;
pub mod graph;
pub mod partition;
pub mod runtime;
pub mod testing;
pub mod util;
