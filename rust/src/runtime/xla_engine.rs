//! XLA-offloaded DFEP: run the paper's funding round (steps 1+2) through
//! the AOT `funding_step_*` artifact — the L2 JAX program vectorized over
//! all K partitions — with step 3 (the centralized coordinator) in rust,
//! exactly the split the paper describes.
//!
//! This engine handles graphs that fit one artifact shape class
//! (padding vertices/edges up to the compiled size). The pure-rust
//! [`crate::partition::dfep::Dfep`] remains the general-purpose engine;
//! tests cross-check the two produce equally good partitions under the
//! same semantics.

use crate::bail;
use crate::util::error::Result;

use super::{Runtime, Tensor};
use crate::graph::Graph;
use crate::partition::dfep::{finalize, greedy_fund_frontier};
use crate::partition::money::MoneyLedger;
use crate::partition::EdgePartition;
use crate::util::rng::Rng;

/// Shape class of a compiled funding artifact.
#[derive(Clone, Copy, Debug)]
pub struct FundingShape {
    /// Compiled partition count.
    pub k: usize,
    /// Compiled (padded) vertex capacity.
    pub v: usize,
    /// Compiled (padded) edge capacity.
    pub e: usize,
}

/// Known artifact shapes, smallest first (see model.py artifact_registry).
pub const FUNDING_SHAPES: &[(&str, FundingShape)] = &[
    ("funding_step_8_1024_4096", FundingShape { k: 8, v: 1024, e: 4096 }),
    (
        "funding_step_32_4096_16384",
        FundingShape { k: 32, v: 4096, e: 16384 },
    ),
];

/// Pick the smallest artifact that fits (k, |V|, |E|).
pub fn pick_shape(k: usize, nv: usize, ne: usize) -> Option<&'static str> {
    FUNDING_SHAPES
        .iter()
        .find(|(_, s)| k <= s.k && nv <= s.v && ne <= s.e)
        .map(|(name, _)| *name)
}

/// DFEP with XLA-offloaded rounds.
pub struct XlaDfep {
    /// Per-edge funding cap (same semantics as [`crate::partition::dfep::Dfep`]).
    pub funding_cap: f64,
    /// Initial funding multiplier on `|E|/k`.
    pub initial_fraction: f64,
    /// Round bound.
    pub max_rounds: usize,
}

impl Default for XlaDfep {
    fn default() -> Self {
        XlaDfep { funding_cap: 10.0, initial_fraction: 1.0, max_rounds: 2000 }
    }
}

impl XlaDfep {
    /// Run DFEP with the funding rounds executed by the XLA artifact
    /// (steps 1+2 on the device, step 3 in rust).
    pub fn partition(
        &self,
        rt: &Runtime,
        g: &Graph,
        k: usize,
        seed: u64,
    ) -> Result<EdgePartition> {
        let nv = g.vertex_count();
        let ne = g.edge_count();
        let Some(name) = pick_shape(k, nv, ne) else {
            bail!(
                "no funding artifact fits k={k}, |V|={nv}, |E|={ne} \
                 (largest: {:?})",
                FUNDING_SHAPES.last().unwrap().1
            );
        };
        let shape = FUNDING_SHAPES
            .iter()
            .find(|(n, _)| *n == name)
            .unwrap()
            .1;
        let exe = rt.load(name)?;

        // ---- pack padded inputs ----
        let mut src = vec![0i32; shape.e];
        let mut dst = vec![0i32; shape.e];
        let mut owner = vec![-2i32; shape.e]; // padding
        for (e, u, v) in g.edge_iter() {
            src[e as usize] = u as i32;
            dst[e as usize] = v as i32;
            owner[e as usize] = -1; // free
        }
        let mut rng = Rng::new(seed);
        let initial =
            (self.initial_fraction * ne as f64 / k as f64).max(1.0);
        // rust-side state lives in the shared flat ledger (stride = the
        // artifact's padded vertex capacity, so rows line up with the
        // compiled money tensor); it is packed to / unpacked from the
        // artifact's f32 tensor every round
        let mut money = MoneyLedger::new(shape.k, shape.v);
        for i in 0..k {
            *money.cell_mut(i, rng.below(nv)) = initial;
        }

        // ---- rounds: steps 1+2 on XLA, step 3 in rust ----
        let mut rounds = 0usize;
        let mut stall = 0usize;
        let mut sizes = vec![0usize; k];
        loop {
            let free = owner.iter().filter(|&&o| o == -1).count();
            if free == 0 || rounds >= self.max_rounds {
                break;
            }
            // one pack pass per round: the filled buffer moves into the
            // tensor (same cost as the old money.clone())
            let mut money_f32 = vec![0f32; shape.k * shape.v];
            money.fill_f32(&mut money_f32);
            let out = exe.run(&[
                Tensor::I32(src.clone()),
                Tensor::I32(dst.clone()),
                Tensor::I32(owner.clone()),
                Tensor::F32(money_f32),
            ])?;
            let new_owner = out[0].as_i32()?;
            let bought = out[2].as_f32()?;
            owner.copy_from_slice(new_owner);
            money.load_f32(out[1].as_f32()?);
            for i in 0..k {
                sizes[i] += bought[i] as usize;
            }
            rounds += 1;

            // intra-partition money transport (same rationale as
            // DfepState::pool_at_frontier): route each partition's cash
            // to its true frontier, greedily concentrated
            pool_at_frontier(g, &owner, &mut money, k);

            // step 3 (rust coordinator): inject inversely to size, plus
            // one base unit so the end-game stays injection-paced
            let avg =
                sizes.iter().sum::<usize>() as f64 / k as f64;
            for i in 0..k {
                let s = sizes[i] as f64;
                let units = if s < 1.0 {
                    self.funding_cap
                } else {
                    (avg / s + 1.0).min(self.funding_cap)
                };
                let row = &mut money.part_mut(i)[..nv];
                let holders =
                    row.iter().filter(|&&c| c > 0.0).count();
                if holders == 0 {
                    // deposit on any region vertex so the partition keeps
                    // receiving funding
                    if let Some(e) = (0..ne).find(|&e| owner[e] == i as i32)
                    {
                        row[src[e] as usize] += units;
                    }
                    continue;
                }
                let per = units / holders as f64;
                for c in row.iter_mut() {
                    if *c > 0.0 {
                        *c += per;
                    }
                }
            }

            let free_after = owner.iter().filter(|&&o| o == -1).count();
            if free_after == free {
                stall += 1;
                if stall >= 3 {
                    // reseed smallest partition on a free edge's endpoint
                    if let Some(e) =
                        (0..ne).find(|&e| owner[e] == -1)
                    {
                        let i = (0..k).min_by_key(|&i| sizes[i]).unwrap();
                        *money.cell_mut(i, src[e] as usize) += 2.0;
                    }
                    stall = 0;
                }
            } else {
                stall = 0;
            }
        }

        // unpack + finalize leftovers exactly like the rust engine
        let partial: Vec<u32> = (0..ne)
            .map(|e| {
                if owner[e] < 0 {
                    u32::MAX
                } else {
                    owner[e] as u32
                }
            })
            .collect();
        let owner = finalize(g, partial, k);
        Ok(EdgePartition { k, owner, rounds })
    }
}

/// Route each partition's liquid cash to its true frontier (region
/// vertices adjacent to free edges), greedily funding the cheapest
/// frontier vertices first — the twin of `DfepState::pool_at_frontier`
/// operating on the shared [`MoneyLedger`] with the artifact's padded
/// stride.
fn pool_at_frontier(
    g: &Graph,
    owner: &[i32],
    money: &mut MoneyLedger,
    k: usize,
) {
    let n = g.vertex_count();
    let mut free_deg = vec![0u32; n];
    for (e, u, w) in g.edge_iter() {
        if owner[e as usize] == -1 {
            free_deg[u as usize] += 1;
            free_deg[w as usize] += 1;
        }
    }
    let mut frontier_of: Vec<Vec<u32>> = vec![Vec::new(); k];
    let mut stamp = vec![u32::MAX; n];
    for (e, u, w) in g.edge_iter() {
        if owner[e as usize] != -1 {
            continue;
        }
        for x in [u as usize, w as usize] {
            for &e2 in g.neighbor_edges(x as u32) {
                let p = owner[e2 as usize];
                if p >= 0 && stamp[x] != p as u32 {
                    stamp[x] = p as u32;
                    frontier_of[p as usize].push(x as u32);
                }
            }
        }
    }
    for (i, frontier) in frontier_of.iter_mut().enumerate() {
        let row = &mut money.part_mut(i)[..n];
        let mut pool = 0.0f64;
        let mut first_holder = None;
        for (v, c) in row.iter_mut().enumerate() {
            if *c > 0.0 {
                first_holder = first_holder.or(Some(v));
                pool += *c;
                *c = 0.0;
            }
        }
        if pool <= 0.0 {
            continue;
        }
        if frontier.is_empty() {
            row[first_holder.unwrap()] += pool;
            continue;
        }
        // single-slot stamp can push a vertex once per adjacent owner —
        // dedup, then hand off to the one shared greedy fill (same code
        // as the reference engine, so the two cannot diverge)
        frontier.sort_unstable();
        frontier.dedup();
        greedy_fund_frontier(row, frontier, &free_deg, pool, |_| {});
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::GraphKind;
    use crate::partition::{dfep::Dfep, metrics, Partitioner};

    fn runtime() -> Option<Runtime> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts");
        Runtime::open(&dir).ok()
    }

    #[test]
    fn shape_picking() {
        assert_eq!(
            pick_shape(4, 500, 2000),
            Some("funding_step_8_1024_4096")
        );
        assert_eq!(
            pick_shape(16, 3000, 10_000),
            Some("funding_step_32_4096_16384")
        );
        assert_eq!(pick_shape(64, 10, 10), None);
        assert_eq!(pick_shape(4, 1_000_000, 10), None);
    }

    #[test]
    fn xla_dfep_produces_valid_balanced_partition() {
        let Some(rt) = runtime() else {
            eprintln!("skipping: artifacts/ not built");
            return;
        };
        let g = GraphKind::PowerlawCluster { n: 600, m: 3, p: 0.3 }
            .generate(2);
        assert!(g.edge_count() <= 4096);
        let p = XlaDfep::default().partition(&rt, &g, 8, 1).unwrap();
        p.validate(&g).unwrap();
        let nst = metrics::nstdev(&g, &p);
        assert!(nst < 0.8, "nstdev {nst}");
    }

    #[test]
    fn xla_and_rust_engines_agree_in_quality() {
        let Some(rt) = runtime() else {
            eprintln!("skipping: artifacts/ not built");
            return;
        };
        let g = GraphKind::ErdosRenyi { n: 500, m: 1500 }.generate(5);
        let px = XlaDfep::default().partition(&rt, &g, 4, 3).unwrap();
        let pr = Dfep::default().partition_graph(&g, 4, 3).unwrap();
        let nx = metrics::nstdev(&g, &px);
        let nr = metrics::nstdev(&g, &pr);
        // same algorithm, different engines: quality must be in the same
        // band (not bit-identical: float order differs)
        assert!(nx < nr + 0.35, "xla {nx} vs rust {nr}");
        let mx = metrics::messages(&g, &px) as f64;
        let mr = metrics::messages(&g, &pr) as f64;
        assert!(mx < mr * 2.0 + 100.0, "messages {mx} vs {mr}");
    }
}
