//! Block-tiled tropical relaxation: compose the fixed-size
//! `minplus_block_256` artifact over an arbitrary-size partition subgraph.
//!
//! The partition adjacency is cut into 256x256 dense tiles; all-INF tiles
//! are skipped entirely (the block-sparse schedule the coordinator owns —
//! on a TPU this is exactly the HBM->VMEM tile stream the BlockSpec grid
//! expresses, here it is PJRT calls per tile). One sweep is
//!
//!   y[bi] = min over bj of  minplus(A[bi,bj], x[bj])      (tiles)
//!   x'    = min(x, y)
//!
//! and sweeps repeat until fixpoint. The pure-rust CSR engine in
//! [`crate::etsch::sssp`] stays the default for huge graphs; this path
//! exists to run the paper's local phase on the AOT-compiled L1 kernel
//! and is cross-checked against it in tests.

use crate::util::error::Result;

use super::{Executable, Runtime, Tensor, INF32};
use crate::etsch::Subgraph;

/// Tile size (matches the `minplus_block_256` artifact).
pub const BLOCK: usize = 256;

/// A partition subgraph pre-packed into dense tropical tiles.
pub struct TiledSubgraph {
    /// number of vertex blocks
    pub nb: usize,
    /// padded vertex count = nb * BLOCK
    pub padded: usize,
    /// nonempty tiles: (bi, bj, row-major 256x256 data)
    pub tiles: Vec<(usize, usize, Vec<f32>)>,
    /// real vertex count
    pub nv: usize,
}

impl TiledSubgraph {
    /// Pack a subgraph with unit edge weights (`w = 1` for SSSP; pass
    /// `w = 0` for min-label spreading).
    pub fn pack(sub: &Subgraph, w: f32) -> TiledSubgraph {
        let nv = sub.vertex_count();
        let nb = nv.div_ceil(BLOCK).max(1);
        let padded = nb * BLOCK;
        // bucket edges per tile (both directions; diagonal handled by the
        // min(x, y) step so tiles hold only edge weights)
        let mut buckets: std::collections::HashMap<(usize, usize), Vec<(usize, usize)>> =
            std::collections::HashMap::new();
        for u in 0..nv as u32 {
            for &v in sub.neighbor_vertices(u) {
                let (r, c) = (u as usize, v as usize);
                buckets
                    .entry((r / BLOCK, c / BLOCK))
                    .or_default()
                    .push((r % BLOCK, c % BLOCK));
            }
        }
        let mut tiles: Vec<(usize, usize, Vec<f32>)> = buckets
            .into_iter()
            .map(|((bi, bj), entries)| {
                let mut data = vec![INF32; BLOCK * BLOCK];
                for (r, c) in entries {
                    data[r * BLOCK + c] = w;
                }
                (bi, bj, data)
            })
            .collect();
        tiles.sort_by_key(|&(bi, bj, _)| (bi, bj));
        TiledSubgraph { nb, padded, tiles, nv }
    }

    /// Fraction of tiles that are nonempty (block-sparsity diagnostic).
    pub fn density(&self) -> f64 {
        self.tiles.len() as f64 / (self.nb * self.nb) as f64
    }
}

/// One relaxation sweep via the block artifact. `x.len() == padded`.
pub fn sweep(
    exe: &Executable,
    t: &TiledSubgraph,
    x: &[f32],
) -> Result<Vec<f32>> {
    let mut y = x.to_vec();
    for &(bi, bj, ref data) in &t.tiles {
        let xs = &x[bj * BLOCK..(bj + 1) * BLOCK];
        let out = exe.run(&[
            Tensor::F32(data.clone()),
            Tensor::F32(xs.to_vec()),
        ])?;
        let part = out[0].as_f32()?;
        let ys = &mut y[bi * BLOCK..(bi + 1) * BLOCK];
        for (yi, &pi) in ys.iter_mut().zip(part) {
            if pi < *yi {
                *yi = pi;
            }
        }
    }
    Ok(y)
}

/// Relax to fixpoint (bounded by `max_sweeps`); returns final labels and
/// sweeps used.
pub fn relax_to_fixpoint(
    rt: &Runtime,
    t: &TiledSubgraph,
    init: &[f32],
    max_sweeps: usize,
) -> Result<(Vec<f32>, usize)> {
    assert_eq!(init.len(), t.nv);
    let exe = rt.load("minplus_block_256")?;
    let mut x = vec![INF32; t.padded];
    x[..t.nv].copy_from_slice(init);
    let mut sweeps = 0;
    while sweeps < max_sweeps {
        let nx = sweep(&exe, t, &x)?;
        sweeps += 1;
        if nx == x {
            break;
        }
        x = nx;
    }
    x.truncate(t.nv);
    Ok((x, sweeps))
}

/// Multi-source fixpoint on one padded 256-vertex partition via the fused
/// `multi_relax_256x64` artifact: up to 64 source columns relax at once
/// (the betweenness-style all-sources sweep; columns beyond the request
/// are padded with INF and ignored).
pub fn multi_relax_256(
    rt: &Runtime,
    adj: &[f32],          // 256*256 tropical adjacency (0 diagonal)
    sources: &[u32],      // local source vertices, <= 64
) -> Result<Vec<Vec<f32>>> {
    assert_eq!(adj.len(), BLOCK * BLOCK);
    assert!(sources.len() <= 64, "at most 64 sources per call");
    let exe = rt.load("multi_relax_256x64")?;
    // column-major-ish packing: b[v * 64 + s]
    let mut b = vec![INF32; BLOCK * 64];
    for (s, &v) in sources.iter().enumerate() {
        b[v as usize * 64 + s] = 0.0;
    }
    let out = exe.run(&[
        Tensor::F32(adj.to_vec()),
        Tensor::F32(b),
    ])?;
    let flat = out[0].as_f32()?;
    Ok(sources
        .iter()
        .enumerate()
        .map(|(s, _)| {
            (0..BLOCK).map(|v| flat[v * 64 + s]).collect()
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::GraphKind;
    use crate::graph::stats::bfs_distances;
    use crate::partition::view::PartitionView;
    use crate::partition::{dfep::Dfep, Partitioner};

    fn runtime() -> Option<Runtime> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts");
        Runtime::open(&dir).ok()
    }

    #[test]
    fn xla_relaxation_matches_bfs_inside_partition() {
        let Some(rt) = runtime() else {
            eprintln!("skipping: artifacts/ not built");
            return;
        };
        // graph bigger than one block so tiling is exercised
        let g = GraphKind::ErdosRenyi { n: 700, m: 2100 }.generate(3);
        let p = Dfep::default().partition_graph(&g, 2, 1).unwrap();
        let view = PartitionView::build(&g, &p);
        let sub = &view.subgraphs()[0];
        assert!(sub.vertex_count() > BLOCK, "want multi-tile case");
        let t = TiledSubgraph::pack(sub, 1.0);
        assert!(t.density() <= 1.0);

        // SSSP from local vertex 0, but only within the subgraph
        let mut init = vec![INF32; sub.vertex_count()];
        init[0] = 0.0;
        let (x, sweeps) =
            relax_to_fixpoint(&rt, &t, &init, 2048).unwrap();
        assert!(sweeps >= 1);

        // reference: BFS on the local structure
        let mut dist = vec![u32::MAX; sub.vertex_count()];
        dist[0] = 0;
        let mut q = std::collections::VecDeque::from([0u32]);
        while let Some(u) = q.pop_front() {
            for &w in sub.neighbor_vertices(u) {
                if dist[w as usize] == u32::MAX {
                    dist[w as usize] = dist[u as usize] + 1;
                    q.push_back(w);
                }
            }
        }
        for l in 0..sub.vertex_count() {
            if dist[l] == u32::MAX {
                assert!(x[l] >= INF32 / 2.0, "vertex {l}");
            } else {
                assert_eq!(x[l], dist[l] as f32, "vertex {l}");
            }
        }
    }

    #[test]
    fn multi_source_matches_single_source() {
        let Some(rt) = runtime() else {
            eprintln!("skipping: artifacts/ not built");
            return;
        };
        // small path graph in a 256 block
        let mut adj = vec![INF32; BLOCK * BLOCK];
        for i in 0..BLOCK {
            adj[i * BLOCK + i] = 0.0;
        }
        for i in 0..19usize {
            adj[i * BLOCK + i + 1] = 1.0;
            adj[(i + 1) * BLOCK + i] = 1.0;
        }
        let sources = [0u32, 5, 19];
        let cols = multi_relax_256(&rt, &adj, &sources).unwrap();
        for (ci, &s) in sources.iter().enumerate() {
            for v in 0..20usize {
                let want = (v as i64 - s as i64).unsigned_abs() as f32;
                assert_eq!(cols[ci][v], want, "source {s} vertex {v}");
            }
        }
    }

    #[test]
    fn empty_tiles_are_skipped() {
        let Some(_rt) = runtime() else {
            eprintln!("skipping: artifacts/ not built");
            return;
        };
        let g = GraphKind::ErdosRenyi { n: 600, m: 1200 }.generate(4);
        let p = Dfep::default().partition_graph(&g, 2, 2).unwrap();
        let view = PartitionView::build(&g, &p);
        let t = TiledSubgraph::pack(&view.subgraphs()[0], 1.0);
        // a sparse graph far from dense: strictly fewer tiles than nb^2
        // is not guaranteed for tiny nb, but density must be <= 1 and the
        // tile list sorted
        for w in t.tiles.windows(2) {
            assert!((w[0].0, w[0].1) < (w[1].0, w[1].1));
        }
    }
}
