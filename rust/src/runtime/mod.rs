//! PJRT runtime: load `artifacts/*.hlo.txt` (AOT-lowered JAX/Pallas
//! programs) and execute them from rust. Python never runs here.
//!
//! Interchange is HLO *text*: jax >= 0.5 emits HloModuleProtos with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see python/compile/aot.py). In this vendored-crate-free
//! build the PJRT client is the std-only reference interpreter in
//! [`xla`] (`platform_name() == "cpu-sim"`); the module keeps the real
//! binding's API surface so a hardware PJRT client swaps back in without
//! touching the callers.

pub mod blocktiled;
pub mod manifest;
pub mod xla;
pub mod xla_engine;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::bail;
use crate::util::error::{Context, Result};

pub use manifest::{ArtifactSpec, Dtype, Manifest, TensorSpec};

/// A tensor crossing the rust <-> XLA boundary.
#[derive(Clone, Debug, PartialEq)]
pub enum Tensor {
    /// f32 payload (flat, row-major).
    F32(Vec<f32>),
    /// i32 payload (flat, row-major).
    I32(Vec<i32>),
}

impl Tensor {
    /// Element count.
    pub fn len(&self) -> usize {
        match self {
            Tensor::F32(v) => v.len(),
            Tensor::I32(v) => v.len(),
        }
    }

    /// True when the tensor has no elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Element type.
    pub fn dtype(&self) -> Dtype {
        match self {
            Tensor::F32(_) => Dtype::F32,
            Tensor::I32(_) => Dtype::I32,
        }
    }

    /// View as f32 elements (error on dtype mismatch).
    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    /// View as i32 elements (error on dtype mismatch).
    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32(v) => Ok(v),
            _ => bail!("tensor is not i32"),
        }
    }

    fn to_literal(&self, spec: &TensorSpec) -> Result<xla::Literal> {
        let dims: Vec<i64> =
            spec.shape.iter().map(|&d| d as i64).collect();
        let lit = match self {
            Tensor::F32(v) => xla::Literal::vec1(v),
            Tensor::I32(v) => xla::Literal::vec1(v),
        };
        if spec.shape.len() == 1 {
            Ok(lit)
        } else {
            Ok(lit.reshape(&dims)?)
        }
    }

    fn from_literal(lit: &xla::Literal, spec: &TensorSpec) -> Result<Tensor> {
        Ok(match spec.dtype {
            Dtype::F32 => Tensor::F32(lit.to_vec::<f32>()?),
            Dtype::I32 => Tensor::I32(lit.to_vec::<i32>()?),
        })
    }
}

/// One compiled artifact, ready to execute.
pub struct Executable {
    spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
    /// executions performed (perf accounting)
    calls: Mutex<u64>,
}

impl Executable {
    /// The manifest interface this executable was validated against.
    pub fn spec(&self) -> &ArtifactSpec {
        &self.spec
    }

    /// Executions performed so far (perf accounting).
    pub fn calls(&self) -> u64 {
        *self.calls.lock().unwrap()
    }

    /// Execute with shape/dtype validation; returns one tensor per output.
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        let mut lits = Vec::with_capacity(inputs.len());
        for (t, s) in inputs.iter().zip(self.spec.inputs.iter()) {
            if t.len() != s.element_count() {
                bail!(
                    "{}: input size {} != spec {} ({:?})",
                    self.spec.name,
                    t.len(),
                    s.element_count(),
                    s.shape
                );
            }
            if t.dtype() != s.dtype {
                bail!("{}: input dtype mismatch", self.spec.name);
            }
            lits.push(t.to_literal(s)?);
        }
        let result = self.exe.execute::<xla::Literal>(&lits)?[0][0]
            .to_literal_sync()?;
        *self.calls.lock().unwrap() += 1;
        // aot.py lowers with return_tuple=True: always a tuple, even 1-ary
        let parts = result.to_tuple()?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "{}: got {} outputs, manifest says {}",
                self.spec.name,
                parts.len(),
                self.spec.outputs.len()
            );
        }
        parts
            .iter()
            .zip(self.spec.outputs.iter())
            .map(|(l, s)| Tensor::from_literal(l, s))
            .collect()
    }
}

/// The runtime: a PJRT CPU client plus lazily compiled artifacts.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    dir: PathBuf,
    cache: Mutex<HashMap<String, std::sync::Arc<Executable>>>,
}

impl Runtime {
    /// Open the artifacts directory (default `artifacts/`).
    pub fn open(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client =
            xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Runtime {
            client,
            manifest,
            dir: dir.to_path_buf(),
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// Default artifacts location: `$DFEP_ARTIFACTS` or `./artifacts`.
    pub fn open_default() -> Result<Runtime> {
        let dir = std::env::var("DFEP_ARTIFACTS")
            .unwrap_or_else(|_| "artifacts".to_string());
        Self::open(Path::new(&dir))
    }

    /// PJRT platform name (`"cpu-sim"` for the reference interpreter).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// The loaded artifact manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Directory the artifacts were loaded from.
    pub fn artifacts_dir(&self) -> &Path {
        &self.dir
    }

    /// Compile (or fetch cached) an artifact by name.
    pub fn load(&self, name: &str) -> Result<std::sync::Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.get(name)?.clone();
        let proto = xla::HloModuleProto::from_text_file(
            spec.file
                .to_str()
                .with_context(|| format!("non-utf8 path {:?}", spec.file))?,
        )
        .with_context(|| format!("parse HLO text {}", spec.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile {name}"))?;
        let exe = std::sync::Arc::new(Executable {
            spec,
            exe,
            calls: Mutex::new(0),
        });
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }
}

/// Tropical "infinity" shared with the python side (kernels/minplus.py):
/// a large finite f32 so padded entries stay inert under +.
pub const INF32: f32 = 1.5e38;

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime() -> Option<Runtime> {
        // integration tests need `make artifacts` to have run
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts");
        Runtime::open(&dir).ok()
    }

    #[test]
    fn minplus_block_roundtrip() {
        let Some(rt) = runtime() else {
            eprintln!("skipping: artifacts/ not built");
            return;
        };
        let exe = rt.load("minplus_block_256").unwrap();
        // A = path graph adjacency (0-1-2), rest INF; x = [0, INF, ...]
        let n = 256;
        let mut a = vec![INF32; n * n];
        a[0 * n + 1] = 1.0;
        a[1 * n + 0] = 1.0;
        a[1 * n + 2] = 1.0;
        a[2 * n + 1] = 1.0;
        for i in 0..n {
            a[i * n + i] = 0.0;
        }
        let mut x = vec![INF32; n];
        x[0] = 0.0;
        let out = exe
            .run(&[Tensor::F32(a), Tensor::F32(x)])
            .unwrap();
        let y = out[0].as_f32().unwrap();
        assert_eq!(y[0], 0.0);
        assert_eq!(y[1], 1.0);
        assert!(y[2] >= INF32 / 2.0); // two hops needs two applications
        assert_eq!(exe.calls(), 1);
    }

    #[test]
    fn relax_while_reaches_fixpoint() {
        let Some(rt) = runtime() else {
            eprintln!("skipping: artifacts/ not built");
            return;
        };
        let exe = rt.load("relax_while_256").unwrap();
        let n = 256;
        // path graph over first 10 vertices
        let mut a = vec![INF32; n * n];
        for i in 0..9 {
            a[i * n + i + 1] = 1.0;
            a[(i + 1) * n + i] = 1.0;
        }
        let mut x = vec![INF32; n];
        x[0] = 0.0;
        let out = exe.run(&[Tensor::F32(a), Tensor::F32(x)]).unwrap();
        let y = out[0].as_f32().unwrap();
        for i in 0..10 {
            assert_eq!(y[i], i as f32, "vertex {i}");
        }
        let steps = out[1].as_i32().unwrap()[0];
        assert!((1..=11).contains(&steps), "steps {steps}");
    }

    #[test]
    fn shape_validation_rejects_bad_inputs() {
        let Some(rt) = runtime() else {
            eprintln!("skipping: artifacts/ not built");
            return;
        };
        let exe = rt.load("minplus_block_256").unwrap();
        let err = exe.run(&[
            Tensor::F32(vec![0.0; 16]),
            Tensor::F32(vec![0.0; 256]),
        ]);
        assert!(err.is_err());
        let err2 = exe.run(&[Tensor::F32(vec![0.0; 256 * 256])]);
        assert!(err2.is_err());
    }
}
