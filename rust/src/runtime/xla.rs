//! Std-only stand-in for the PJRT/XLA binding.
//!
//! The container this crate builds in has no `xla` crate (and no network
//! to fetch one), so the PJRT surface the runtime uses is provided here
//! as a *reference interpreter*: artifacts are identified by their HLO
//! module name (the text emitted by `python/compile/aot.py` always starts
//! with `HloModule <name>`), and `execute` runs the kernel's reference
//! semantics in pure rust. Shapes and dtypes still flow through
//! `manifest.json` and are validated by [`super::Executable::run`], so
//! swapping a real PJRT client back in is a drop-in change to this module
//! only. `platform_name()` reports `"cpu-sim"` to make the substitution
//! visible in `repro xla-info`.

use std::fmt;

/// Backend error (implements `std::error::Error`, so `?` converts it into
/// the crate error type).
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla-sim: {}", self.0)
    }
}

impl std::error::Error for Error {}

fn err<T>(msg: impl Into<String>) -> Result<T> {
    Err(Error(msg.into()))
}

/// `Result` defaulted to the runtime [`Error`], mirroring the real
/// binding's alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Tensor payload.
#[derive(Clone, Debug)]
enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// A host-side literal crossing the runtime boundary.
#[derive(Clone, Debug)]
pub struct Literal {
    data: Data,
    #[allow(dead_code)] // carried for API fidelity; kernels use lengths
    shape: Vec<i64>,
}

/// Element types the simulated backend moves across the boundary.
pub trait NativeType: Copy {
    /// Wrap a host vector into the tensor payload.
    fn wrap(v: Vec<Self>) -> Data;
    /// Borrow the payload back as a typed slice (None on dtype mismatch).
    fn unwrap(d: &Data) -> Option<&[Self]>;
}

impl NativeType for f32 {
    fn wrap(v: Vec<f32>) -> Data {
        Data::F32(v)
    }
    fn unwrap(d: &Data) -> Option<&[f32]> {
        match d {
            Data::F32(v) => Some(v),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(v: Vec<i32>) -> Data {
        Data::I32(v)
    }
    fn unwrap(d: &Data) -> Option<&[i32]> {
        match d {
            Data::I32(v) => Some(v),
            _ => None,
        }
    }
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal { shape: vec![v.len() as i64], data: T::wrap(v.to_vec()) }
    }

    fn tuple(parts: Vec<Literal>) -> Literal {
        Literal { shape: Vec::new(), data: Data::Tuple(parts) }
    }

    fn len(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::Tuple(p) => p.len(),
        }
    }

    /// Reinterpret with a new shape (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let count: i64 = dims.iter().product();
        if count as usize != self.len() {
            return err(format!(
                "reshape {:?} onto {} elements",
                dims,
                self.len()
            ));
        }
        Ok(Literal { shape: dims.to_vec(), data: self.data.clone() })
    }

    /// Copy out as a host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data)
            .map(|s| s.to_vec())
            .ok_or_else(|| Error("dtype mismatch in to_vec".into()))
    }

    /// Destructure a tuple literal.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.data {
            Data::Tuple(p) => Ok(p),
            _ => err("literal is not a tuple"),
        }
    }

    fn as_f32(&self) -> Result<&[f32]> {
        f32::unwrap(&self.data).ok_or_else(|| Error("expected f32".into()))
    }

    fn as_i32(&self) -> Result<&[i32]> {
        i32::unwrap(&self.data).ok_or_else(|| Error("expected i32".into()))
    }
}

/// Parsed HLO module: only the module name drives the interpreter.
pub struct HloModuleProto {
    name: String,
}

impl HloModuleProto {
    /// Read an `.hlo.txt` artifact and extract its module name.
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error(format!("read {path}: {e}")))?;
        for line in text.lines() {
            let t = line.trim();
            if let Some(rest) = t.strip_prefix("HloModule") {
                let name = rest
                    .trim()
                    .split(|c: char| c.is_whitespace() || c == ',')
                    .next()
                    .unwrap_or("")
                    .trim_matches(|c| c == '"' || c == '\'')
                    .to_string();
                if name.is_empty() {
                    return err(format!("{path}: empty HloModule name"));
                }
                return Ok(HloModuleProto { name });
            }
        }
        err(format!("{path}: no HloModule header"))
    }
}

/// Compilation input: the interpreter dispatches on the module name.
pub struct XlaComputation {
    name: String,
}

impl XlaComputation {
    /// Wrap a parsed module (the interpreter needs only its name).
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { name: proto.name.clone() }
    }
}

/// The kernel set `python/compile/model.py` registers.
enum Kernel {
    /// `minplus_block_N`: y[i] = min_j (A[i][j] + x[j]).
    MinplusBlock { n: usize },
    /// `relax_while_N`: iterate x = min(x, A (+) x) to fixpoint;
    /// outputs (x, steps).
    RelaxWhile { n: usize },
    /// `multi_relax_NxC`: per-column fixpoint over C packed sources.
    MultiRelax { n: usize, cols: usize },
    /// `funding_step_K_V_E`: one DFEP funding round (steps 1+2),
    /// vectorized over all K partitions.
    FundingStep { k: usize, v: usize, e: usize },
}

fn parse_kernel(name: &str) -> Result<Kernel> {
    let uint = |s: &str| -> Result<usize> {
        s.parse::<usize>()
            .map_err(|_| Error(format!("bad size '{s}' in kernel '{name}'")))
    };
    if let Some(rest) = name.strip_prefix("minplus_block_") {
        return Ok(Kernel::MinplusBlock { n: uint(rest)? });
    }
    if let Some(rest) = name.strip_prefix("relax_while_") {
        return Ok(Kernel::RelaxWhile { n: uint(rest)? });
    }
    if let Some(rest) = name.strip_prefix("multi_relax_") {
        let (n, c) = rest
            .split_once('x')
            .ok_or_else(|| Error(format!("bad multi_relax name '{name}'")))?;
        return Ok(Kernel::MultiRelax { n: uint(n)?, cols: uint(c)? });
    }
    if let Some(rest) = name.strip_prefix("funding_step_") {
        let parts: Vec<&str> = rest.split('_').collect();
        if parts.len() == 3 {
            return Ok(Kernel::FundingStep {
                k: uint(parts[0])?,
                v: uint(parts[1])?,
                e: uint(parts[2])?,
            });
        }
    }
    err(format!("unknown kernel '{name}' (sim backend)"))
}

/// One tropical mat-vec: out[i] = min_j (a[i*n + j] + x[j]).
fn minplus(a: &[f32], x: &[f32], n: usize, out: &mut [f32]) {
    for i in 0..n {
        let row = &a[i * n..(i + 1) * n];
        let mut best = f32::INFINITY;
        for (aj, xj) in row.iter().zip(x.iter()) {
            let cand = aj + xj;
            if cand < best {
                best = cand;
            }
        }
        out[i] = best;
    }
}

impl Kernel {
    fn run(&self, inputs: &[&Literal]) -> Result<Literal> {
        let arg = |i: usize| -> Result<&Literal> {
            inputs
                .get(i)
                .copied()
                .ok_or_else(|| Error(format!("missing input {i}")))
        };
        match *self {
            Kernel::MinplusBlock { n } => {
                let a = arg(0)?.as_f32()?;
                let x = arg(1)?.as_f32()?;
                if a.len() != n * n || x.len() != n {
                    return err("minplus_block input sizes");
                }
                let mut y = vec![0f32; n];
                minplus(a, x, n, &mut y);
                Ok(Literal::tuple(vec![Literal::vec1(&y)]))
            }
            Kernel::RelaxWhile { n } => {
                let a = arg(0)?.as_f32()?;
                let mut x = arg(1)?.as_f32()?.to_vec();
                if a.len() != n * n || x.len() != n {
                    return err("relax_while input sizes");
                }
                let mut y = vec![0f32; n];
                let mut steps = 0i32;
                // fixpoint is reached within n sweeps on any input
                for _ in 0..=n {
                    minplus(a, &x, n, &mut y);
                    let mut changed = false;
                    for (xi, &yi) in x.iter_mut().zip(y.iter()) {
                        if yi < *xi {
                            *xi = yi;
                            changed = true;
                        }
                    }
                    steps += 1;
                    if !changed {
                        break;
                    }
                }
                Ok(Literal::tuple(vec![
                    Literal::vec1(&x),
                    Literal::vec1(&[steps]),
                ]))
            }
            Kernel::MultiRelax { n, cols } => {
                let a = arg(0)?.as_f32()?;
                let mut b = arg(1)?.as_f32()?.to_vec();
                if a.len() != n * n || b.len() != n * cols {
                    return err("multi_relax input sizes");
                }
                // per-column fixpoint; b is packed b[v * cols + s]
                let mut x = vec![0f32; n];
                let mut y = vec![0f32; n];
                for s in 0..cols {
                    for v in 0..n {
                        x[v] = b[v * cols + s];
                    }
                    for _ in 0..=n {
                        minplus(a, &x, n, &mut y);
                        let mut changed = false;
                        for (xi, &yi) in x.iter_mut().zip(y.iter()) {
                            if yi < *xi {
                                *xi = yi;
                                changed = true;
                            }
                        }
                        if !changed {
                            break;
                        }
                    }
                    for v in 0..n {
                        b[v * cols + s] = x[v];
                    }
                }
                Ok(Literal::tuple(vec![Literal::vec1(&b)]))
            }
            Kernel::FundingStep { k, v, e } => {
                let src = arg(0)?.as_i32()?;
                let dst = arg(1)?.as_i32()?;
                let owner = arg(2)?.as_i32()?;
                let money = arg(3)?.as_f32()?;
                if src.len() != e
                    || dst.len() != e
                    || owner.len() != e
                    || money.len() != k * v
                {
                    return err("funding_step input sizes");
                }
                funding_step(k, v, src, dst, owner, money)
            }
        }
    }
}

/// Reference semantics of one DFEP funding round over padded flat state:
/// step 1 splits each holder's cash over eligible incident edges
/// (frontier-first), step 2 auctions every bid-receiving free edge
/// (lowest partition id wins ties; winner pays 1, remainder returns
/// half/half; losers get exact refunds; own-edge bids circulate
/// half/half). Padding edges carry owner -2 and are never touched.
fn funding_step(
    k: usize,
    nv: usize,
    src: &[i32],
    dst: &[i32],
    owner: &[i32],
    money: &[f32],
) -> Result<Literal> {
    // incidence over real edges (owner != -2)
    let mut deg = vec![0u32; nv];
    for (e, (&s, &d)) in src.iter().zip(dst.iter()).enumerate() {
        if owner[e] == -2 {
            continue;
        }
        if (s as usize) >= nv || (d as usize) >= nv {
            return err("funding_step: endpoint out of range");
        }
        deg[s as usize] += 1;
        deg[d as usize] += 1;
    }
    let mut offsets = vec![0usize; nv + 1];
    for i in 0..nv {
        offsets[i + 1] = offsets[i] + deg[i] as usize;
    }
    let mut incident = vec![0u32; offsets[nv]];
    let mut cursor = offsets.clone();
    for (e, (&s, &d)) in src.iter().zip(dst.iter()).enumerate() {
        if owner[e] == -2 {
            continue;
        }
        incident[cursor[s as usize]] = e as u32;
        cursor[s as usize] += 1;
        incident[cursor[d as usize]] = e as u32;
        cursor[d as usize] += 1;
    }

    let mut new_money = money.to_vec();
    // bids: (edge, partition, offer, contribution-from-src-endpoint)
    let mut bids: Vec<(u32, u32, f64, f64)> = Vec::new();
    let mut eligible: Vec<u32> = Vec::with_capacity(32);
    for i in 0..k {
        for vtx in 0..nv {
            let cash = new_money[i * nv + vtx] as f64;
            if cash <= 0.0 {
                continue;
            }
            eligible.clear();
            let mut has_buyable = false;
            for &eid in &incident[offsets[vtx]..offsets[vtx + 1]] {
                let o = owner[eid as usize];
                let buyable = o == -1;
                if buyable && !has_buyable {
                    has_buyable = true;
                    eligible.clear();
                }
                if buyable || (o == i as i32 && !has_buyable) {
                    eligible.push(eid);
                }
            }
            if eligible.is_empty() {
                continue; // stranded cash stays put
            }
            let share = cash / eligible.len() as f64;
            for &eid in &eligible {
                let from_src = if src[eid as usize] as usize == vtx {
                    share
                } else {
                    0.0
                };
                bids.push((eid, i as u32, share, from_src));
            }
            new_money[i * nv + vtx] = 0.0;
        }
    }

    bids.sort_unstable_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
    let mut new_owner = owner.to_vec();
    let mut bought = vec![0f32; k];
    let mut credit = |part: usize, vtx: usize, amount: f64| {
        if amount > 0.0 {
            new_money[part * nv + vtx] += amount as f32;
        }
    };
    let mut idx = 0usize;
    let mut merged: Vec<(u32, f64, f64)> = Vec::with_capacity(8);
    while idx < bids.len() {
        let eid = bids[idx].0;
        merged.clear();
        while idx < bids.len() && bids[idx].0 == eid {
            let (_, i, offer, lo) = bids[idx];
            if let Some(last) = merged.last_mut() {
                if last.0 == i {
                    last.1 += offer;
                    last.2 += lo;
                    idx += 1;
                    continue;
                }
            }
            merged.push((i, offer, lo));
            idx += 1;
        }
        let (u, w) = (src[eid as usize] as usize, dst[eid as usize] as usize);
        let mut best = u32::MAX;
        let mut best_offer = 0.0f64;
        for &(i, offer, _) in &merged {
            if offer > best_offer {
                best_offer = offer;
                best = i;
            }
        }
        let sold =
            owner[eid as usize] == -1 && best != u32::MAX && best_offer >= 1.0;
        if sold {
            new_owner[eid as usize] = best as i32;
            bought[best as usize] += 1.0;
        }
        let cur = new_owner[eid as usize];
        for &(i, offer, lo) in &merged {
            if offer <= 0.0 {
                continue;
            }
            if sold && i == best {
                let rem = (offer - 1.0) * 0.5;
                credit(i as usize, u, rem);
                credit(i as usize, w, rem);
            } else if !sold && cur >= 0 && i == cur as u32 {
                credit(i as usize, u, offer * 0.5);
                credit(i as usize, w, offer * 0.5);
            } else {
                credit(i as usize, u, lo);
                credit(i as usize, w, offer - lo);
            }
        }
    }
    Ok(Literal::tuple(vec![
        Literal::vec1(&new_owner),
        Literal::vec1(&new_money),
        Literal::vec1(&bought),
    ]))
}

/// Device-side handle of one execution output.
pub struct PjRtBuffer {
    lit: Literal,
}

impl PjRtBuffer {
    /// Copy the device buffer back to a host literal (synchronous, like
    /// the real binding's API).
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.lit.clone())
    }
}

/// A "compiled" artifact: the dispatched reference kernel.
pub struct PjRtLoadedExecutable {
    kernel: Kernel,
}

impl PjRtLoadedExecutable {
    /// Execute; mirrors PJRT's per-device nesting (`[device][output]`).
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        let lits: Vec<&Literal> = args.iter().map(|l| l.borrow()).collect();
        let out = self.kernel.run(&lits)?;
        Ok(vec![vec![PjRtBuffer { lit: out }]])
    }
}

/// The simulated PJRT client.
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    /// Create the CPU client (always succeeds in the simulator).
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _priv: () })
    }

    /// Platform identifier — `"cpu-sim"` marks the reference interpreter.
    pub fn platform_name(&self) -> String {
        "cpu-sim".to_string()
    }

    /// "Compile": dispatch the module name onto the registered kernel set.
    pub fn compile(&self, comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Ok(PjRtLoadedExecutable { kernel: parse_kernel(&comp.name)? })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn compile(name: &str) -> PjRtLoadedExecutable {
        let client = PjRtClient::cpu().unwrap();
        client
            .compile(&XlaComputation { name: name.to_string() })
            .unwrap()
    }

    #[test]
    fn minplus_block_semantics() {
        let exe = compile("minplus_block_4");
        let inf = 1.5e38f32;
        let mut a = vec![inf; 16];
        for i in 0..4 {
            a[i * 4 + i] = 0.0;
        }
        a[4 + 0] = 1.0; // edge 1 <- 0
        let mut x = vec![inf; 4];
        x[0] = 0.0;
        let lits = [Literal::vec1(&a), Literal::vec1(&x)];
        let out = exe.execute(&lits).unwrap()[0][0]
            .to_literal_sync()
            .unwrap()
            .to_tuple()
            .unwrap();
        let y = out[0].to_vec::<f32>().unwrap();
        assert_eq!(y[0], 0.0);
        assert_eq!(y[1], 1.0);
        assert!(y[2] >= inf / 2.0);
    }

    #[test]
    fn relax_while_reaches_fixpoint() {
        let exe = compile("relax_while_8");
        let inf = 1.5e38f32;
        let n = 8;
        let mut a = vec![inf; n * n];
        for i in 0..n {
            a[i * n + i] = 0.0;
        }
        for i in 0..n - 1 {
            a[i * n + i + 1] = 1.0;
            a[(i + 1) * n + i] = 1.0;
        }
        let mut x = vec![inf; n];
        x[0] = 0.0;
        let lits = [Literal::vec1(&a), Literal::vec1(&x)];
        let out = exe.execute(&lits).unwrap()[0][0]
            .to_literal_sync()
            .unwrap()
            .to_tuple()
            .unwrap();
        let y = out[0].to_vec::<f32>().unwrap();
        for (i, &yi) in y.iter().enumerate() {
            assert_eq!(yi, i as f32);
        }
        let steps = out[1].to_vec::<i32>().unwrap()[0];
        assert!((1..=n as i32 + 1).contains(&steps), "steps {steps}");
    }

    #[test]
    fn unknown_kernel_fails_to_compile() {
        let client = PjRtClient::cpu().unwrap();
        assert!(client
            .compile(&XlaComputation { name: "mystery_kernel".into() })
            .is_err());
    }

    #[test]
    fn funding_step_sells_to_best_bidder_and_conserves_money() {
        // path graph 0-1-2, 2 partitions, vertex 0 funded for part 0 and
        // vertex 2 funded for part 1
        let exe = compile("funding_step_2_4_4");
        let src = vec![0i32, 1, 0, 0]; // last edge is padding
        let dst = vec![1i32, 2, 0, 0];
        let owner = vec![-1i32, -1, -2, -2];
        let mut money = vec![0f32; 2 * 4];
        money[0] = 4.0; // part 0, vertex 0
        money[4 + 2] = 2.0; // part 1, vertex 2
        let lits = [
            Literal::vec1(&src),
            Literal::vec1(&dst),
            Literal::vec1(&owner),
            Literal::vec1(&money),
        ];
        let out = exe.execute(&lits).unwrap()[0][0]
            .to_literal_sync()
            .unwrap()
            .to_tuple()
            .unwrap();
        let new_owner = out[0].to_vec::<i32>().unwrap();
        let new_money = out[1].to_vec::<f32>().unwrap();
        let bought = out[2].to_vec::<f32>().unwrap();
        assert_eq!(new_owner, vec![0, 1, -2, -2]);
        assert_eq!(bought, vec![1.0, 1.0]);
        // money conservation: initial - edges bought
        let total: f32 = new_money.iter().sum();
        assert!((total - (6.0 - 2.0)).abs() < 1e-5, "total {total}");
    }

    #[test]
    fn hlo_header_parsing() {
        let dir = std::env::temp_dir().join("dfep_xla_sim_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("k.hlo.txt");
        std::fs::write(&path, "HloModule minplus_block_256, entry...\n")
            .unwrap();
        let proto =
            HloModuleProto::from_text_file(path.to_str().unwrap()).unwrap();
        assert_eq!(proto.name, "minplus_block_256");
        let bad = dir.join("bad.hlo.txt");
        std::fs::write(&bad, "no header here\n").unwrap();
        assert!(HloModuleProto::from_text_file(bad.to_str().unwrap()).is_err());
    }
}
