//! `artifacts/manifest.json` — shapes/dtypes of every AOT artifact, as
//! written by `python/compile/aot.py`. The runtime validates inputs
//! against this before feeding PJRT (shape bugs surface as rust errors,
//! not XLA aborts).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::error::{Context, Result};
use crate::{anyhow, bail};

use crate::util::json::{self, Json};

/// Element type of a tensor (the subset our kernels use).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    /// 32-bit IEEE float.
    F32,
    /// 32-bit signed integer.
    I32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            other => bail!("unsupported dtype {other}"),
        }
    }

    /// Bytes per element (both supported dtypes are 4-byte).
    pub fn size_bytes(self) -> usize {
        4
    }
}

/// Shape + dtype of one tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    /// Dimension sizes, row-major.
    pub shape: Vec<usize>,
    /// Element type.
    pub dtype: Dtype,
}

impl TensorSpec {
    /// Product of the dimensions.
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One artifact's interface.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    /// Artifact name (the manifest key).
    pub name: String,
    /// HLO text file, resolved relative to the manifest directory.
    pub file: PathBuf,
    /// Input tensor interface, in call order.
    pub inputs: Vec<TensorSpec>,
    /// Output tensor interface (artifacts always return a tuple).
    pub outputs: Vec<TensorSpec>,
}

/// The whole manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    /// Artifacts by name (sorted map keeps listing order stable).
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

fn tensor_spec(v: &Json) -> Result<TensorSpec> {
    let shape = v
        .get("shape")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("missing shape"))?
        .iter()
        .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
        .collect::<Result<Vec<_>>>()?;
    let dtype = Dtype::parse(
        v.get("dtype")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("missing dtype"))?,
    )?;
    Ok(TensorSpec { shape, dtype })
}

impl Manifest {
    /// Load `dir/manifest.json`; artifact files resolve relative to `dir`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {}", path.display()))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest JSON text; artifact files resolve relative to `dir`.
    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let doc = json::parse(text).context("parse manifest.json")?;
        let obj = doc.as_obj().ok_or_else(|| anyhow!("manifest not an object"))?;
        let mut artifacts = BTreeMap::new();
        for (name, entry) in obj {
            let file = entry
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("{name}: missing file"))?;
            let parse_list = |key: &str| -> Result<Vec<TensorSpec>> {
                entry
                    .get(key)
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("{name}: missing {key}"))?
                    .iter()
                    .map(tensor_spec)
                    .collect()
            };
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    file: dir.join(file),
                    inputs: parse_list("inputs")?,
                    outputs: parse_list("outputs")?,
                },
            );
        }
        Ok(Manifest { artifacts })
    }

    /// Look an artifact up by name (error lists it as missing).
    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name} not in manifest"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"{
      "minplus_block_256": {
        "file": "minplus_block_256.hlo.txt",
        "inputs": [
          {"shape": [256, 256], "dtype": "f32"},
          {"shape": [256], "dtype": "f32"}
        ],
        "outputs": [{"shape": [256], "dtype": "f32"}]
      },
      "funding_step_8_1024_4096": {
        "file": "funding_step_8_1024_4096.hlo.txt",
        "inputs": [
          {"shape": [4096], "dtype": "i32"},
          {"shape": [4096], "dtype": "i32"},
          {"shape": [4096], "dtype": "i32"},
          {"shape": [8, 1024], "dtype": "f32"}
        ],
        "outputs": [
          {"shape": [4096], "dtype": "i32"},
          {"shape": [8, 1024], "dtype": "f32"},
          {"shape": [8], "dtype": "f32"}
        ]
      }
    }"#;

    #[test]
    fn parses_specs() {
        let m = Manifest::parse(DOC, Path::new("/tmp/a")).unwrap();
        let a = m.get("minplus_block_256").unwrap();
        assert_eq!(a.inputs[0].shape, vec![256, 256]);
        assert_eq!(a.inputs[0].dtype, Dtype::F32);
        assert_eq!(a.inputs[0].element_count(), 65536);
        assert_eq!(a.file, Path::new("/tmp/a/minplus_block_256.hlo.txt"));
        let f = m.get("funding_step_8_1024_4096").unwrap();
        assert_eq!(f.inputs[2].dtype, Dtype::I32);
        assert_eq!(f.outputs.len(), 3);
    }

    #[test]
    fn missing_artifact_is_error() {
        let m = Manifest::parse(DOC, Path::new("/tmp")).unwrap();
        assert!(m.get("nope").is_err());
    }

    #[test]
    fn malformed_manifest_is_error() {
        assert!(Manifest::parse("[1,2]", Path::new("/tmp")).is_err());
        assert!(Manifest::parse("{\"x\": {}}", Path::new("/tmp")).is_err());
    }
}
