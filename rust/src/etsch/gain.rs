//! Path-compression *gain* (paper §V-A): "the fraction of total
//! iterations avoided by the shortest path algorithm implemented in
//! ETSCH" relative to the vertex-centric baseline.
//!
//! The baseline needs one superstep per hop (`ecc(source)` iterations);
//! ETSCH's local Dijkstra crosses a whole partition per round, so a
//! partitioning that compresses paths needs far fewer rounds.

use super::{sssp::Sssp, vertex_baseline::bsp_sssp, Etsch};
use crate::graph::Graph;
use crate::partition::EdgePartition;
use crate::util::rng::Rng;

/// Gain for one source vertex: `1 - etsch_rounds / baseline_supersteps`
/// (clamped at 0; both engines count their trailing quiescence check).
pub fn gain_for_source(g: &Graph, p: &EdgePartition, source: u32) -> f64 {
    let mut engine = Etsch::new(g, p);
    gain_for_source_with(g, &mut engine, source)
}

/// [`gain_for_source`] on an engine the caller already built — each run
/// resets the engine's stats, so one engine (one `PartitionView` build)
/// serves any number of sources.
pub fn gain_for_source_with(
    g: &Graph,
    engine: &mut Etsch,
    source: u32,
) -> f64 {
    let baseline = bsp_sssp(g, source).supersteps.max(1);
    engine.run(&mut Sssp::new(source));
    let etsch = engine.rounds_executed();
    (1.0 - etsch as f64 / baseline as f64).max(0.0)
}

/// Average gain over `samples` random sources (the paper plots a mean
/// over 100 partition samples; sources add a second averaging dimension).
/// Derives the partition state once for all sources.
pub fn average_gain(
    g: &Graph,
    p: &EdgePartition,
    samples: usize,
    seed: u64,
) -> f64 {
    let mut engine = Etsch::new(g, p);
    average_gain_with(g, &mut engine, samples, seed)
}

/// [`average_gain`] on a caller-built engine (shared view).
pub fn average_gain_with(
    g: &Graph,
    engine: &mut Etsch,
    samples: usize,
    seed: u64,
) -> f64 {
    let mut rng = Rng::new(seed);
    let mut total = 0.0;
    for _ in 0..samples {
        let s = rng.below(g.vertex_count()) as u32;
        total += gain_for_source_with(g, engine, s);
    }
    total / samples as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::GraphKind;
    use crate::partition::{baselines::HashEdge, dfep::Dfep, Partitioner};

    #[test]
    fn gain_in_unit_interval() {
        let g = GraphKind::ErdosRenyi { n: 200, m: 500 }.generate(1);
        let p = Dfep::default().partition_graph(&g, 4, 1).unwrap();
        let gain = average_gain(&g, &p, 3, 7);
        assert!((0.0..=1.0).contains(&gain), "gain {gain}");
    }

    #[test]
    fn dfep_gains_more_than_hash_on_high_diameter() {
        let g = GraphKind::RoadNetwork {
            rows: 12, cols: 12, drop: 0.15, subdiv: 2, shortcuts: 0,
        }
        .generate(2);
        let pd = Dfep::default().partition_graph(&g, 4, 3).unwrap();
        let ph = HashEdge.partition_graph(&g, 4, 3).unwrap();
        let gd = average_gain(&g, &pd, 3, 5);
        let gh = average_gain(&g, &ph, 3, 5);
        assert!(gd > gh, "DFEP gain {gd} should beat hash gain {gh}");
    }

    #[test]
    fn single_partition_has_maximal_gain() {
        let g = GraphKind::RoadNetwork {
            rows: 10, cols: 10, drop: 0.1, subdiv: 2, shortcuts: 0,
        }
        .generate(3);
        let p = Dfep::default().partition_graph(&g, 1, 1).unwrap();
        // k=1: local Dijkstra solves everything in 1 round (+1 quiescence)
        let gain = gain_for_source(&g, &p, 0);
        assert!(gain > 0.8, "gain {gain}");
    }
}
