//! ETSCH — the paper's edge-partition-centric processing framework (§III).
//!
//! A computation is three user functions over vertex states:
//!
//! 1. **init** — run once per vertex;
//! 2. **local computation** — an independent *sequential* algorithm per
//!    partition subgraph (each worker runs one);
//! 3. **aggregation** — frontier vertices collect the distinct states of
//!    their replicas and reconcile them to a single value, copied back.
//!
//! Steps 2 and 3 repeat until no state changes (or the algorithm's round
//! bound). The engine counts rounds and frontier messages — the paper's
//! §V-A metrics — and runs workers on the shared [`crate::util::pool`]
//! (one shard per partition; tokio is not in the vendored crate set, and
//! the local phase is pure CPU anyway).
//!
//! All derived partition state (subgraphs, the replica table, frontier
//! flags) comes from a shared [`PartitionView`]: [`Etsch::new`] builds
//! one, [`Etsch::from_view`] borrows one the caller already built (e.g.
//! for metrics). Aggregation is *change-driven*: the local phase emits
//! per-part dirty-vertex lists and the aggregation visits only the
//! replicas of dirty vertices, instead of re-aggregating every replica
//! of every vertex each round ([`Etsch::run_dense`] keeps the dense
//! reference for the equivalence tests).

pub mod betweenness;
pub mod cc;
pub mod gain;
pub mod kcore;
pub mod labelprop;
pub mod mis;
pub mod pagerank;
pub mod sssp;
pub mod subgraph;
pub mod vertex_baseline;

use std::borrow::Cow;

use crate::graph::Graph;
use crate::partition::view::PartitionView;
use crate::partition::EdgePartition;
pub use subgraph::{build_subgraphs, Subgraph};

/// A computation expressed in the ETSCH model.
pub trait Algorithm: Send + Sync {
    /// Per-vertex state; replicas of frontier vertices are reconciled by
    /// [`aggregate`](Algorithm::aggregate).
    type State: Clone + PartialEq + Send + Sync;

    /// Initialization phase (run once, per vertex, global ids).
    fn init(&self, v: u32, g: &Graph) -> Self::State;

    /// Local computation phase: a sequential algorithm over one partition.
    /// `states[l]` is the state of local vertex `l` (see [`Subgraph`]).
    fn local(&self, sub: &Subgraph, states: &mut [Self::State]);

    /// Aggregation phase: reconcile replica states (called for every
    /// vertex whose state changed during the local phase; non-frontier
    /// vertices pass a single replica).
    ///
    /// Contract for change-driven aggregation: `aggregate` must be a
    /// deterministic function of `replicas`, and a vertex none of whose
    /// replica states moved in the local phase must not need
    /// re-aggregation. All transient accumulator fields (`partial` sums,
    /// vote lists) must be rebuilt from scratch by
    /// [`local`](Algorithm::local), so a skipped aggregation can never
    /// leak a stale accumulator into the next round. If a rule must be
    /// re-applied even when the rebuilt accumulator can collide with the
    /// post-aggregation reset value, reset the accumulator to a marker
    /// `local` can never produce instead (see `kcore::REEVAL`). The
    /// shipped algorithms are pinned to the dense reference by the
    /// equivalence tests in `tests/properties.rs` (betweenness's phases
    /// by the Brandes-oracle tests).
    fn aggregate(&self, replicas: &[Self::State]) -> Self::State;

    /// Round bound (for algorithms without natural quiescence).
    fn max_rounds(&self) -> usize {
        usize::MAX
    }

    /// Hook called at the start of each round (e.g. Luby re-draws).
    fn begin_round(&mut self, _round: usize) {}
}

/// Execution statistics of one ETSCH run.
#[derive(Clone, Debug, Default)]
pub struct RunStats {
    /// Local-computation + aggregation rounds executed.
    pub rounds: usize,
    /// Replica states actually exchanged during aggregations: Σ per round
    /// of Σ_i |F_i ∩ changed| — only frontier vertices whose state moved
    /// in the local phase need their replicas reconciled. (The paper's
    /// MESSAGES counts the per-round ceiling Σ_i |F_i|; we track both.)
    pub messages_exchanged: usize,
    /// Per-round ceiling: Σ_i |F_i| * rounds.
    pub messages_ceiling: usize,
}

/// Per-part working set of one run: the live replica states, the
/// pre-round snapshot the dirty diff compares against, and this round's
/// dirty local-vertex list.
struct PartSlot<'s, S> {
    sub: &'s Subgraph,
    states: Vec<S>,
    /// States as of the start of the round (== the post-aggregation
    /// values; aggregation keeps this in sync so no per-round clone of
    /// the full state vector is needed).
    prev: Vec<S>,
    dirty: Vec<u32>,
}

/// The ETSCH engine bound to one graph + partitioning.
///
/// All derived partition state comes from a [`PartitionView`]:
/// [`new`](Self::new) builds one, [`from_view`](Self::from_view) borrows
/// a caller-built one so metrics and the engine share a single build.
pub struct Etsch<'a> {
    g: &'a Graph,
    view: Cow<'a, PartitionView>,
    stats: RunStats,
}

impl<'a> Etsch<'a> {
    /// Build the engine, deriving a fresh [`PartitionView`].
    pub fn new(g: &'a Graph, p: &EdgePartition) -> Self {
        Etsch {
            g,
            view: Cow::Owned(PartitionView::build(g, p)),
            stats: RunStats::default(),
        }
    }

    /// Build the engine on a view the caller already derived (no extra
    /// pass over the partition).
    pub fn from_view(g: &'a Graph, view: &'a PartitionView) -> Self {
        Etsch { g, view: Cow::Borrowed(view), stats: RunStats::default() }
    }

    /// The shared derived-state view this engine runs on.
    pub fn view(&self) -> &PartitionView {
        &self.view
    }

    /// Partition subgraphs (for inspection / the XLA-backed local phase).
    pub fn subgraphs(&self) -> &[Subgraph] {
        self.view.subgraphs()
    }

    /// Run an algorithm to quiescence; returns the per-vertex final state.
    ///
    /// Aggregation is change-driven: the parallel local phase diffs each
    /// part's states against its pre-round snapshot and emits a dirty
    /// local-vertex list; the aggregation visits only the replicas of
    /// dirty vertices. Final states, round counts and message counts are
    /// identical to the dense reference [`run_dense`](Self::run_dense)
    /// (property-tested), and bit-identical across pool thread counts.
    pub fn run<A: Algorithm>(&mut self, alg: &mut A) -> Vec<A::State> {
        let g = self.g;
        let view: &PartitionView = &self.view;
        let n = g.vertex_count();
        let mut stats = RunStats::default();

        // init (global), then scatter to replicas
        let mut global: Vec<A::State> =
            (0..n as u32).map(|v| alg.init(v, g)).collect();
        let mut slots: Vec<PartSlot<'_, A::State>> = view
            .subgraphs()
            .iter()
            .map(|s| {
                let states: Vec<A::State> = s
                    .global
                    .iter()
                    .map(|&gv| global[gv as usize].clone())
                    .collect();
                PartSlot {
                    sub: s,
                    prev: states.clone(),
                    states,
                    dirty: Vec::new(),
                }
            })
            .collect();

        let max_rounds = alg.max_rounds();
        // round-stamped dedup scratch for the global dirty list
        let mut mark = vec![usize::MAX; n];
        let mut dirty_global: Vec<u32> = Vec::new();
        let mut buf: Vec<A::State> = Vec::with_capacity(4);
        loop {
            if stats.rounds >= max_rounds {
                break;
            }
            alg.begin_round(stats.rounds);
            // ---- local computation phase (parallel over partitions) ----
            // one pool shard per partition; each shard also diffs its
            // states against the pre-round snapshot to emit a dirty list
            {
                let alg_ref: &A = alg;
                crate::util::pool::run_mut(
                    &mut slots,
                    &|_, slot: &mut PartSlot<'_, A::State>| {
                        alg_ref.local(slot.sub, &mut slot.states);
                        slot.dirty.clear();
                        for (l, (now, before)) in slot
                            .states
                            .iter()
                            .zip(slot.prev.iter())
                            .enumerate()
                        {
                            if now != before {
                                slot.dirty.push(l as u32);
                            }
                        }
                    },
                );
            }
            // ---- change-driven aggregation phase ----
            // merge per-part dirty lists into one ascending global list
            // (stamp-deduped; fixed part order keeps this deterministic)
            dirty_global.clear();
            for slot in &slots {
                for &l in &slot.dirty {
                    let gv = slot.sub.global[l as usize] as usize;
                    if mark[gv] != stats.rounds {
                        mark[gv] = stats.rounds;
                        dirty_global.push(gv as u32);
                    }
                }
            }
            dirty_global.sort_unstable();
            let mut changed = false;
            let mut exchanged = 0usize;
            for &v in &dirty_global {
                let reps = view.replicas_of(v);
                buf.clear();
                for &(p, l) in reps {
                    buf.push(
                        slots[p as usize].states[l as usize].clone(),
                    );
                }
                if reps.len() >= 2 {
                    exchanged += reps.len();
                }
                let agg = alg.aggregate(&buf);
                if agg != global[v as usize] {
                    changed = true;
                }
                global[v as usize] = agg.clone();
                for &(p, l) in reps {
                    slots[p as usize].states[l as usize] = agg.clone();
                    slots[p as usize].prev[l as usize] = agg.clone();
                }
            }
            stats.rounds += 1;
            stats.messages_exchanged += exchanged;
            stats.messages_ceiling += view.frontier_total;
            if !changed {
                break;
            }
        }
        self.stats = stats;
        global
    }

    /// Dense reference aggregation: re-aggregates every replicated vertex
    /// each round (the pre-view engine). Kept as the slow-path oracle the
    /// equivalence tests compare [`run`](Self::run) against; message
    /// accounting matches `run` (an exchange is counted only when some
    /// replica actually moved during the local phase).
    pub fn run_dense<A: Algorithm>(&mut self, alg: &mut A) -> Vec<A::State> {
        let g = self.g;
        let view: &PartitionView = &self.view;
        let n = g.vertex_count();
        let mut stats = RunStats::default();

        let mut global: Vec<A::State> =
            (0..n as u32).map(|v| alg.init(v, g)).collect();
        let mut local_states: Vec<Vec<A::State>> = view
            .subgraphs()
            .iter()
            .map(|s| {
                s.global
                    .iter()
                    .map(|&gv| global[gv as usize].clone())
                    .collect()
            })
            .collect();

        let max_rounds = alg.max_rounds();
        let mut buf: Vec<A::State> = Vec::with_capacity(4);
        loop {
            if stats.rounds >= max_rounds {
                break;
            }
            alg.begin_round(stats.rounds);
            {
                let alg_ref: &A = alg;
                let mut tasks: Vec<(&Subgraph, &mut Vec<A::State>)> = view
                    .subgraphs()
                    .iter()
                    .zip(local_states.iter_mut())
                    .collect();
                crate::util::pool::run_mut(
                    &mut tasks,
                    &|_, task: &mut (&Subgraph, &mut Vec<A::State>)| {
                        alg_ref.local(task.0, &mut *task.1);
                    },
                );
            }
            let mut changed = false;
            let mut exchanged = 0usize;
            for v in 0..n {
                let reps = view.replicas_of(v as u32);
                if reps.is_empty() {
                    continue;
                }
                buf.clear();
                let mut moved = false;
                for &(p, l) in reps {
                    let s = &local_states[p as usize][l as usize];
                    if *s != global[v] {
                        moved = true;
                    }
                    buf.push(s.clone());
                }
                if moved && reps.len() >= 2 {
                    exchanged += reps.len();
                }
                let agg = alg.aggregate(&buf);
                if agg != global[v] {
                    changed = true;
                }
                global[v] = agg.clone();
                for &(p, l) in reps {
                    local_states[p as usize][l as usize] = agg.clone();
                }
            }
            stats.rounds += 1;
            stats.messages_exchanged += exchanged;
            stats.messages_ceiling += view.frontier_total;
            if !changed {
                break;
            }
        }
        self.stats = stats;
        global
    }

    /// Rounds executed by the last [`run`](Self::run).
    pub fn rounds_executed(&self) -> usize {
        self.stats.rounds
    }

    /// Stats of the last run.
    pub fn stats(&self) -> &RunStats {
        &self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::GraphKind;
    use crate::partition::{baselines::HashEdge, dfep::Dfep, Partitioner};

    #[test]
    fn sssp_on_dfep_partitions_matches_bfs() {
        let g = GraphKind::PowerlawCluster { n: 300, m: 4, p: 0.3 }
            .generate(1);
        let p = Dfep::default().partition_graph(&g, 4, 1).unwrap();
        let mut engine = Etsch::new(&g, &p);
        let dist = engine.run(&mut sssp::Sssp::new(0));
        let want = crate::graph::stats::bfs_distances(&g, 0);
        for (v, (&got, &w)) in dist.iter().zip(want.iter()).enumerate() {
            let w2 = if w == u32::MAX { sssp::UNREACHED } else { w };
            assert_eq!(got, w2, "vertex {v}");
        }
        assert!(engine.rounds_executed() >= 1);
    }

    #[test]
    fn dirty_aggregation_matches_dense_reference_on_sssp() {
        let g = GraphKind::PowerlawCluster { n: 400, m: 4, p: 0.3 }
            .generate(5);
        let p = Dfep::default().partition_graph(&g, 5, 2).unwrap();
        let view = crate::partition::view::PartitionView::build(&g, &p);
        let (dirty, dirty_stats) = {
            let mut e = Etsch::from_view(&g, &view);
            let out = e.run(&mut sssp::Sssp::new(0));
            (out, e.stats().clone())
        };
        let (dense, dense_stats) = {
            let mut e = Etsch::from_view(&g, &view);
            let out = e.run_dense(&mut sssp::Sssp::new(0));
            (out, e.stats().clone())
        };
        assert_eq!(dirty, dense);
        assert_eq!(dirty_stats.rounds, dense_stats.rounds);
        assert_eq!(
            dirty_stats.messages_exchanged,
            dense_stats.messages_exchanged
        );
        assert_eq!(
            dirty_stats.messages_ceiling,
            dense_stats.messages_ceiling
        );
        // the exchange count is genuinely change-driven: this run's final
        // quiescent round exchanges nothing while the ceiling still adds
        // the full frontier, so strict inequality must hold
        assert!(
            dirty_stats.messages_exchanged < dirty_stats.messages_ceiling,
            "exchanged {} not below ceiling {}",
            dirty_stats.messages_exchanged,
            dirty_stats.messages_ceiling
        );
    }

    #[test]
    fn contiguous_partitions_need_fewer_rounds_than_hash() {
        // path compression: DFEP's connected partitions compress paths,
        // hash partitioning does not
        let g = GraphKind::RoadNetwork {
            rows: 12, cols: 12, drop: 0.15, subdiv: 2, shortcuts: 0,
        }
        .generate(2);
        let k = 4;
        let pd = Dfep::default().partition_graph(&g, k, 3).unwrap();
        let ph = HashEdge.partition_graph(&g, k, 3).unwrap();
        let rd = {
            let mut e = Etsch::new(&g, &pd);
            e.run(&mut sssp::Sssp::new(0));
            e.rounds_executed()
        };
        let rh = {
            let mut e = Etsch::new(&g, &ph);
            e.run(&mut sssp::Sssp::new(0));
            e.rounds_executed()
        };
        assert!(rd < rh, "DFEP rounds {rd} !< hash rounds {rh}");
    }
}
