//! ETSCH — the paper's edge-partition-centric processing framework (§III).
//!
//! A computation is three user functions over vertex states:
//!
//! 1. **init** — run once per vertex;
//! 2. **local computation** — an independent *sequential* algorithm per
//!    partition subgraph (each worker runs one);
//! 3. **aggregation** — frontier vertices collect the distinct states of
//!    their replicas and reconcile them to a single value, copied back.
//!
//! Steps 2 and 3 repeat until no state changes (or the algorithm's round
//! bound). The engine counts rounds and frontier messages — the paper's
//! §V-A metrics — and runs workers on std threads (one per partition;
//! tokio is not in the vendored crate set, and the local phase is pure
//! CPU anyway).

pub mod betweenness;
pub mod cc;
pub mod gain;
pub mod kcore;
pub mod labelprop;
pub mod mis;
pub mod pagerank;
pub mod sssp;
pub mod subgraph;
pub mod vertex_baseline;

use crate::graph::Graph;
use crate::partition::EdgePartition;
pub use subgraph::{build_subgraphs, Subgraph};

/// A computation expressed in the ETSCH model.
pub trait Algorithm: Send + Sync {
    /// Per-vertex state; replicas of frontier vertices are reconciled by
    /// [`aggregate`](Algorithm::aggregate).
    type State: Clone + PartialEq + Send + Sync;

    /// Initialization phase (run once, per vertex, global ids).
    fn init(&self, v: u32, g: &Graph) -> Self::State;

    /// Local computation phase: a sequential algorithm over one partition.
    /// `states[l]` is the state of local vertex `l` (see [`Subgraph`]).
    fn local(&self, sub: &Subgraph, states: &mut [Self::State]);

    /// Aggregation phase: reconcile replica states (called for every
    /// vertex; non-frontier vertices pass a single replica).
    fn aggregate(&self, replicas: &[Self::State]) -> Self::State;

    /// Round bound (for algorithms without natural quiescence).
    fn max_rounds(&self) -> usize {
        usize::MAX
    }

    /// Hook called at the start of each round (e.g. Luby re-draws).
    fn begin_round(&mut self, _round: usize) {}
}

/// Execution statistics of one ETSCH run.
#[derive(Clone, Debug, Default)]
pub struct RunStats {
    /// Local-computation + aggregation rounds executed.
    pub rounds: usize,
    /// Total replica states exchanged during aggregations (Σ per round of
    /// Σ_i |F_i ∩ changed|; the paper's MESSAGES counts the per-round
    /// ceiling Σ_i |F_i| — we track both).
    pub messages_exchanged: usize,
    /// Per-round ceiling: Σ_i |F_i| * rounds.
    pub messages_ceiling: usize,
}

/// The ETSCH engine bound to one graph + partitioning.
pub struct Etsch<'g> {
    g: &'g Graph,
    subs: Vec<Subgraph>,
    /// replica locations per global vertex: (partition, local id)
    replicas: Vec<Vec<(u32, u32)>>,
    frontier_total: usize,
    stats: RunStats,
}

impl<'g> Etsch<'g> {
    pub fn new(g: &'g Graph, p: &EdgePartition) -> Self {
        let subs = build_subgraphs(g, p);
        let mut replicas: Vec<Vec<(u32, u32)>> =
            vec![Vec::new(); g.vertex_count()];
        for s in &subs {
            for (l, &gv) in s.global.iter().enumerate() {
                replicas[gv as usize].push((s.part as u32, l as u32));
            }
        }
        let frontier_total =
            replicas.iter().filter(|r| r.len() >= 2).map(|r| r.len()).sum();
        Etsch { g, subs, replicas, frontier_total, stats: RunStats::default() }
    }

    /// Partition subgraphs (for inspection / the XLA-backed local phase).
    pub fn subgraphs(&self) -> &[Subgraph] {
        &self.subs
    }

    /// Run an algorithm to quiescence; returns the per-vertex final state.
    pub fn run<A: Algorithm>(&mut self, alg: &mut A) -> Vec<A::State> {
        self.stats = RunStats::default();
        // init (global), then scatter to replicas
        let global_init: Vec<A::State> =
            (0..self.g.vertex_count() as u32)
                .map(|v| alg.init(v, self.g))
                .collect();
        let mut local_states: Vec<Vec<A::State>> = self
            .subs
            .iter()
            .map(|s| {
                s.global
                    .iter()
                    .map(|&gv| global_init[gv as usize].clone())
                    .collect()
            })
            .collect();
        let mut global = global_init;

        let max_rounds = alg.max_rounds();
        loop {
            if self.stats.rounds >= max_rounds {
                break;
            }
            alg.begin_round(self.stats.rounds);
            // ---- local computation phase (parallel over partitions) ----
            // one pool shard per partition worker; the pool's reusable
            // threads replace the former per-round std::thread::spawn
            {
                let alg_ref: &A = alg;
                let mut tasks: Vec<(&Subgraph, &mut Vec<A::State>)> = self
                    .subs
                    .iter()
                    .zip(local_states.iter_mut())
                    .collect();
                crate::util::pool::run_mut(
                    &mut tasks,
                    &|_, task: &mut (&Subgraph, &mut Vec<A::State>)| {
                        alg_ref.local(task.0, &mut *task.1);
                    },
                );
            }
            // ---- aggregation phase ----
            let mut changed = false;
            let mut exchanged = 0usize;
            let mut buf: Vec<A::State> = Vec::with_capacity(4);
            for (v, reps) in self.replicas.iter().enumerate() {
                if reps.is_empty() {
                    continue;
                }
                buf.clear();
                for &(p, l) in reps {
                    buf.push(
                        local_states[p as usize][l as usize].clone(),
                    );
                }
                if reps.len() >= 2 {
                    exchanged += reps.len();
                }
                let agg = alg.aggregate(&buf);
                if agg != global[v] {
                    changed = true;
                }
                global[v] = agg.clone();
                for &(p, l) in reps {
                    local_states[p as usize][l as usize] = agg.clone();
                }
            }
            self.stats.rounds += 1;
            self.stats.messages_exchanged += exchanged;
            self.stats.messages_ceiling += self.frontier_total;
            if !changed {
                break;
            }
        }
        global
    }

    /// Rounds executed by the last [`run`](Self::run).
    pub fn rounds_executed(&self) -> usize {
        self.stats.rounds
    }

    /// Stats of the last run.
    pub fn stats(&self) -> &RunStats {
        &self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::GraphKind;
    use crate::partition::{baselines::HashEdge, dfep::Dfep, Partitioner};

    #[test]
    fn sssp_on_dfep_partitions_matches_bfs() {
        let g = GraphKind::PowerlawCluster { n: 300, m: 4, p: 0.3 }
            .generate(1);
        let p = Dfep::default().partition(&g, 4, 1);
        let mut engine = Etsch::new(&g, &p);
        let dist = engine.run(&mut sssp::Sssp::new(0));
        let want = crate::graph::stats::bfs_distances(&g, 0);
        for (v, (&got, &w)) in dist.iter().zip(want.iter()).enumerate() {
            let w2 = if w == u32::MAX { sssp::UNREACHED } else { w };
            assert_eq!(got, w2, "vertex {v}");
        }
        assert!(engine.rounds_executed() >= 1);
    }

    #[test]
    fn contiguous_partitions_need_fewer_rounds_than_hash() {
        // path compression: DFEP's connected partitions compress paths,
        // hash partitioning does not
        let g = GraphKind::RoadNetwork {
            rows: 12, cols: 12, drop: 0.15, subdiv: 2, shortcuts: 0,
        }
        .generate(2);
        let k = 4;
        let pd = Dfep::default().partition(&g, k, 3);
        let ph = HashEdge.partition(&g, k, 3);
        let rd = {
            let mut e = Etsch::new(&g, &pd);
            e.run(&mut sssp::Sssp::new(0));
            e.rounds_executed()
        };
        let rh = {
            let mut e = Etsch::new(&g, &ph);
            e.run(&mut sssp::Sssp::new(0));
            e.rounds_executed()
        };
        assert!(rd < rh, "DFEP rounds {rd} !< hash rounds {rh}");
    }
}
