//! Vertex-centric (Pregel-style) baseline — the comparator of Fig 9.
//!
//! A BSP engine where each vertex is a process that exchanges messages
//! with neighbors under a global synchronization barrier. One superstep =
//! one message hop, so SSSP needs `ecc(source)` supersteps — this is the
//! "standard baseline algorithm" ETSCH's path compression beats.

use crate::graph::Graph;

/// Result of a vertex-centric run.
#[derive(Clone, Debug)]
pub struct BspRun<T> {
    /// Final per-vertex values.
    pub values: Vec<T>,
    /// Supersteps executed (the baseline's round metric).
    pub supersteps: usize,
    /// Total messages sent across the run.
    pub messages: usize,
}

/// BSP SSSP: relax one hop per superstep until quiescent.
pub fn bsp_sssp(g: &Graph, source: u32) -> BspRun<u32> {
    let n = g.vertex_count();
    let mut dist = vec![u32::MAX; n];
    dist[source as usize] = 0;
    let mut active: Vec<u32> = vec![source];
    let mut supersteps = 0;
    let mut messages = 0;
    while !active.is_empty() {
        supersteps += 1;
        let mut next_active = Vec::new();
        // message phase: every active vertex sends dist+1 to neighbors
        for &u in &active {
            let du = dist[u as usize];
            for &w in g.neighbor_vertices(u) {
                messages += 1;
                if du + 1 < dist[w as usize] {
                    dist[w as usize] = du + 1;
                    next_active.push(w);
                }
            }
        }
        next_active.sort_unstable();
        next_active.dedup();
        active = next_active;
    }
    BspRun { values: dist, supersteps, messages }
}

/// BSP connected components: spread min label one hop per superstep.
pub fn bsp_cc(g: &Graph, seed: u64) -> BspRun<u64> {
    let n = g.vertex_count();
    let hash = |v: u32| -> u64 {
        let mut z = seed
            .wrapping_add(0x9E3779B97F4A7C15u64.wrapping_mul(v as u64 + 1));
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    };
    let mut label: Vec<u64> = (0..n as u32).map(hash).collect();
    let mut active: Vec<u32> = (0..n as u32).collect();
    let mut supersteps = 0;
    let mut messages = 0;
    while !active.is_empty() {
        supersteps += 1;
        let mut next = Vec::new();
        for &u in &active {
            let lu = label[u as usize];
            for &w in g.neighbor_vertices(u) {
                messages += 1;
                if lu < label[w as usize] {
                    label[w as usize] = lu;
                    next.push(w);
                }
            }
        }
        next.sort_unstable();
        next.dedup();
        active = next;
    }
    BspRun { values: label, supersteps, messages }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::GraphKind;
    use crate::graph::stats::{bfs_distances, components, eccentricity};

    #[test]
    fn bsp_sssp_matches_bfs() {
        let g = GraphKind::ErdosRenyi { n: 150, m: 400 }.generate(2);
        let run = bsp_sssp(&g, 3);
        assert_eq!(run.values, bfs_distances(&g, 3));
        // supersteps = eccentricity + 1 (final empty wave)
        let ecc = eccentricity(&g, 3) as usize;
        assert!(run.supersteps >= ecc && run.supersteps <= ecc + 1,
                "supersteps {} vs ecc {}", run.supersteps, ecc);
    }

    #[test]
    fn bsp_cc_labels_components() {
        let g = GraphKind::ErdosRenyi { n: 150, m: 200 }.generate(5);
        let run = bsp_cc(&g, 7);
        let (want, _) = components(&g);
        for u in 0..g.vertex_count() {
            for v in 0..g.vertex_count() {
                assert_eq!(
                    run.values[u] == run.values[v],
                    want[u] == want[v]
                );
            }
        }
    }
}
