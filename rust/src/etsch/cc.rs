//! Connected components in ETSCH (paper Algorithm 2).
//!
//! Each vertex gets a random id; the local phase epidemically spreads the
//! minimum id through the partition; aggregation takes the min across
//! replicas. Eventually every component is labeled by its smallest random
//! id.

use super::{Algorithm, Subgraph};
use crate::graph::Graph;

/// Algorithm-2 instance. Random ids are derived from (seed, vertex) so
/// replicas agree without coordination.
#[derive(Clone, Debug)]
pub struct ConnectedComponents {
    /// Seed of the per-vertex random ids.
    pub seed: u64,
}

impl ConnectedComponents {
    /// Label propagation with ids drawn from `seed`.
    pub fn new(seed: u64) -> Self {
        ConnectedComponents { seed }
    }

    fn random_id(&self, v: u32) -> u64 {
        // splitmix-style hash of (seed, v) — the paper's v.id = random()
        let mut z = self
            .seed
            .wrapping_add(0x9E3779B97F4A7C15u64.wrapping_mul(v as u64 + 1));
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

impl Algorithm for ConnectedComponents {
    type State = u64;

    fn init(&self, v: u32, _g: &Graph) -> u64 {
        self.random_id(v)
    }

    fn local(&self, sub: &Subgraph, states: &mut [u64]) {
        // min-label spreading to fixpoint within the partition — the
        // "epidemic" of Algorithm 2 (a worklist makes it near-linear)
        let mut queue: std::collections::VecDeque<u32> =
            (0..states.len() as u32).collect();
        let mut inq = vec![true; states.len()];
        while let Some(u) = queue.pop_front() {
            inq[u as usize] = false;
            let su = states[u as usize];
            for &w in sub.neighbor_vertices(u) {
                if su < states[w as usize] {
                    states[w as usize] = su;
                    if !inq[w as usize] {
                        inq[w as usize] = true;
                        queue.push_back(w);
                    }
                }
            }
        }
    }

    fn aggregate(&self, replicas: &[u64]) -> u64 {
        *replicas.iter().min().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::etsch::Etsch;
    use crate::graph::stats::components;
    use crate::graph::GraphBuilder;
    use crate::partition::{baselines::RandomEdge, dfep::Dfep, Partitioner};
    use crate::graph::generators::GraphKind;

    #[test]
    fn labels_match_true_components() {
        let mut b = GraphBuilder::new();
        // 3 components of different shapes
        for i in 0..10u32 {
            b.push_edge(i, (i + 1) % 10); // cycle 0..10
        }
        b.push_edge(20, 21);
        b.push_edge(21, 22);
        b.push_edge(30, 31);
        let g = b.build();
        let p = RandomEdge.partition_graph(&g, 3, 5).unwrap();
        let mut engine = Etsch::new(&g, &p);
        let labels = engine.run(&mut ConnectedComponents::new(9));
        let (want, _) = components(&g);
        // same label within a component, different across
        for u in 0..g.vertex_count() {
            for v in 0..g.vertex_count() {
                if g.degree(u as u32) == 0 || g.degree(v as u32) == 0 {
                    continue; // isolated ids from buildup gaps
                }
                assert_eq!(
                    labels[u] == labels[v],
                    want[u] == want[v],
                    "vertices {u},{v}"
                );
            }
        }
    }

    #[test]
    fn works_on_dfep_partitions() {
        let g = GraphKind::PowerlawCluster { n: 200, m: 3, p: 0.4 }
            .generate(6);
        let p = Dfep::default().partition_graph(&g, 4, 2).unwrap();
        let mut engine = Etsch::new(&g, &p);
        let labels = engine.run(&mut ConnectedComponents::new(1));
        // generator returns largest component -> all labels equal
        let first = labels[0];
        assert!(labels.iter().all(|&l| l == first));
    }
}
