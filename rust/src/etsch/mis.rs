//! Luby's maximal independent set in ETSCH (paper §III: "It is also
//! possible to implement Luby's maximal independent set algorithm in
//! ETSCH, by spreading the random values in the local phase and choosing
//! if a vertex must be added to the set in the aggregation phase").
//!
//! Per Luby round, every undecided vertex draws a random value (derived
//! from (seed, round, vertex) so replicas agree without messages); the
//! local phase computes, per vertex, the minimum value among its
//! *undecided* neighbors within the partition and whether any neighbor is
//! already in the set; aggregation reconciles replicas (min over neighbor
//! minima, OR over neighbor-in-set) and then applies Luby's rule: a vertex
//! whose value beats every neighbor joins the set; a vertex with a
//! neighbor in the set is excluded.

use super::{Algorithm, Subgraph};
use crate::graph::Graph;

/// Membership progress of one vertex.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Status {
    /// Not yet decided either way.
    Undecided,
    /// In the independent set.
    InSet,
    /// Excluded (a neighbor is in the set).
    Excluded,
}

/// Vertex state for Luby rounds.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MisState {
    /// Membership progress.
    pub status: Status,
    /// This vertex's current draw.
    pub value: u64,
    /// Min draw among undecided neighbors seen so far (this round).
    pub nbr_min: u64,
    /// Whether some neighbor is already in the set.
    pub nbr_in_set: bool,
}

/// Luby's randomized maximal-independent-set algorithm in the ETSCH
/// model (per-round draws derived from (seed, vertex, round) so replicas
/// agree without coordination).
#[derive(Clone, Debug)]
pub struct LubyMis {
    /// Seed of the per-round draws.
    pub seed: u64,
    round: usize,
}

impl LubyMis {
    /// Luby MIS with draws derived from `seed`.
    pub fn new(seed: u64) -> Self {
        LubyMis { seed, round: 0 }
    }

    fn draw(&self, v: u32, round: usize) -> u64 {
        let mut z = self
            .seed
            .wrapping_add((round as u64 + 1).wrapping_mul(0xA24BAED4963EE407))
            .wrapping_add((v as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15));
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        // reserve u64::MAX for "no undecided neighbor"
        (z ^ (z >> 31)).min(u64::MAX - 1)
    }
}

impl Algorithm for LubyMis {
    type State = MisState;

    fn init(&self, v: u32, _g: &Graph) -> MisState {
        MisState {
            status: Status::Undecided,
            value: self.draw(v, 0),
            nbr_min: u64::MAX,
            nbr_in_set: false,
        }
    }

    fn begin_round(&mut self, round: usize) {
        self.round = round;
    }

    fn local(&self, sub: &Subgraph, states: &mut [MisState]) {
        // refresh draws for undecided vertices (deterministic per round)
        for (l, s) in states.iter_mut().enumerate() {
            if s.status == Status::Undecided {
                s.value = self.draw(sub.global[l], self.round);
            }
            s.nbr_min = u64::MAX;
            s.nbr_in_set = false;
        }
        // spread values / set membership across local edges
        for u in 0..states.len() as u32 {
            for &w in sub.neighbor_vertices(u) {
                let sw = states[w as usize];
                let su = &mut states[u as usize];
                if sw.status == Status::Undecided {
                    su.nbr_min = su.nbr_min.min(sw.value);
                }
                if sw.status == Status::InSet {
                    su.nbr_in_set = true;
                }
            }
        }
    }

    fn aggregate(&self, replicas: &[MisState]) -> MisState {
        // reconcile what each replica observed, then apply Luby's rule
        let mut s = replicas[0];
        for r in &replicas[1..] {
            s.nbr_min = s.nbr_min.min(r.nbr_min);
            s.nbr_in_set |= r.nbr_in_set;
            // status escalates monotonically Undecided -> InSet/Excluded
            if r.status != Status::Undecided {
                s.status = r.status;
            }
        }
        if s.status == Status::Undecided {
            if s.nbr_in_set {
                s.status = Status::Excluded;
            } else if s.value < s.nbr_min {
                s.status = Status::InSet;
            }
        }
        s
    }

    fn max_rounds(&self) -> usize {
        10_000
    }
}

/// Validate an MIS: independent (no two set vertices adjacent) and maximal
/// (every excluded vertex has a set neighbor).
pub fn validate_mis(g: &Graph, in_set: &[bool]) -> Result<(), String> {
    for (_, u, v) in g.edge_iter() {
        if in_set[u as usize] && in_set[v as usize] {
            return Err(format!("edge ({u},{v}) inside the set"));
        }
    }
    for v in 0..g.vertex_count() as u32 {
        if !in_set[v as usize] {
            let ok = g
                .neighbor_vertices(v)
                .iter()
                .any(|&w| in_set[w as usize]);
            if !ok && g.degree(v) > 0 {
                return Err(format!("vertex {v} excluded without set neighbor"));
            }
            if !ok && g.degree(v) == 0 {
                return Err(format!("isolated vertex {v} must be in the set"));
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::etsch::Etsch;
    use crate::graph::generators::GraphKind;
    use crate::partition::{baselines::RandomEdge, dfep::Dfep, Partitioner};

    fn run_mis(k: usize, part_seed: u64, alg_seed: u64) -> bool {
        let g = GraphKind::ErdosRenyi { n: 150, m: 400 }.generate(8);
        let p = RandomEdge.partition_graph(&g, k, part_seed).unwrap();
        let mut engine = Etsch::new(&g, &p);
        let states = engine.run(&mut LubyMis::new(alg_seed));
        let in_set: Vec<bool> =
            states.iter().map(|s| s.status == Status::InSet).collect();
        validate_mis(&g, &in_set).is_ok()
    }

    #[test]
    fn produces_valid_mis_across_seeds() {
        for seed in 0..5 {
            assert!(run_mis(4, seed, seed * 3 + 1), "seed {seed}");
        }
    }

    #[test]
    fn works_on_dfep_partitions() {
        let g = GraphKind::PowerlawCluster { n: 250, m: 3, p: 0.4 }
            .generate(9);
        let p = Dfep::default().partition_graph(&g, 5, 4).unwrap();
        let mut engine = Etsch::new(&g, &p);
        let states = engine.run(&mut LubyMis::new(11));
        let in_set: Vec<bool> =
            states.iter().map(|s| s.status == Status::InSet).collect();
        validate_mis(&g, &in_set).unwrap();
        assert!(in_set.iter().any(|&b| b), "set must be nonempty");
    }
}
