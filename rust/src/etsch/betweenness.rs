//! Betweenness centrality on ETSCH — the paper's §III motivation for the
//! distance building block ("the problem of distance computation is
//! needed to compute properties like betweenness centrality [3]").
//!
//! Brandes' algorithm per source s decomposes into three fixpoints, each
//! of which is ETSCH-shaped (partial sums over partition-local edges,
//! summed in aggregation):
//!
//!   1. dist[v]  — ETSCH SSSP (Algorithm 1);
//!   2. sigma[v] — #shortest s-paths: sigma[v] = Σ sigma[u] over
//!      predecessors u (dist[u] = dist[v] - 1);
//!   3. delta[v] — dependency: delta[u] = Σ sigma[u]/sigma[v} (1+delta[v])
//!      over successors v.
//!
//! Exact betweenness sums over all sources; [`etsch_betweenness`] samples
//! sources (the standard approximation) and is validated against the
//! sequential Brandes oracle.

use super::{sssp::Sssp, sssp::UNREACHED, Algorithm, Etsch, Subgraph};
use crate::graph::Graph;
use crate::partition::EdgePartition;
use crate::util::rng::Rng;

/// Forward phase state: fixed dist + accumulating sigma (+ round partial).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SigmaState {
    /// Hop distance from the source (fixed input).
    pub dist: u32,
    /// Shortest-path count accumulated so far.
    pub sigma: f64,
    /// This-round partial contribution from local predecessors.
    pub partial: f64,
}

/// Computes sigma given per-vertex distances (shared immutable).
pub struct SigmaPhase {
    /// The BFS source.
    pub source: u32,
    /// Per-vertex distances from the completed SSSP phase.
    pub dist: std::sync::Arc<Vec<u32>>,
}

impl Algorithm for SigmaPhase {
    type State = SigmaState;

    fn init(&self, v: u32, _g: &Graph) -> SigmaState {
        SigmaState {
            dist: self.dist[v as usize],
            sigma: if v == self.source { 1.0 } else { 0.0 },
            partial: 0.0,
        }
    }

    fn local(&self, sub: &Subgraph, states: &mut [SigmaState]) {
        for s in states.iter_mut() {
            s.partial = 0.0;
        }
        // partial sigma inflow along local edges from predecessors
        for u in 0..states.len() as u32 {
            let su = states[u as usize];
            if su.sigma == 0.0 || su.dist == UNREACHED {
                continue;
            }
            for &w in sub.neighbor_vertices(u) {
                if states[w as usize].dist == su.dist + 1 {
                    states[w as usize].partial += su.sigma;
                }
            }
        }
    }

    fn aggregate(&self, replicas: &[SigmaState]) -> SigmaState {
        let mut s = replicas[0];
        let inflow: f64 = replicas.iter().map(|r| r.partial).sum();
        if s.dist != UNREACHED && s.dist > 0 {
            // fixpoint: sigma is fully determined by predecessors
            s.sigma = inflow;
        }
        s.partial = 0.0;
        s
    }

    fn max_rounds(&self) -> usize {
        100_000
    }
}

/// Backward phase state: fixed dist/sigma + accumulating delta.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DeltaState {
    /// Hop distance from the source (fixed input).
    pub dist: u32,
    /// Shortest-path count (fixed input).
    pub sigma: f64,
    /// Dependency accumulated so far.
    pub delta: f64,
    /// This-round partial contribution from local successors.
    pub partial: f64,
}

/// Computes the Brandes dependency delta given distances and sigma
/// (shared immutable inputs from the earlier phases).
pub struct DeltaPhase {
    /// Per-vertex distances from the SSSP phase.
    pub dist: std::sync::Arc<Vec<u32>>,
    /// Per-vertex shortest-path counts from the sigma phase.
    pub sigma: std::sync::Arc<Vec<f64>>,
}

impl Algorithm for DeltaPhase {
    type State = DeltaState;

    fn init(&self, v: u32, _g: &Graph) -> DeltaState {
        DeltaState {
            dist: self.dist[v as usize],
            sigma: self.sigma[v as usize],
            delta: 0.0,
            partial: 0.0,
        }
    }

    fn local(&self, sub: &Subgraph, states: &mut [DeltaState]) {
        for s in states.iter_mut() {
            s.partial = 0.0;
        }
        // dependency flows from successors (dist + 1) back to predecessors
        for v in 0..states.len() as u32 {
            let sv = states[v as usize];
            if sv.dist == UNREACHED || sv.sigma == 0.0 {
                continue;
            }
            for &u in sub.neighbor_vertices(v) {
                let su = states[u as usize];
                if su.dist != UNREACHED
                    && su.dist + 1 == sv.dist
                    && su.sigma > 0.0
                {
                    states[u as usize].partial +=
                        su.sigma / sv.sigma * (1.0 + sv.delta);
                }
            }
        }
    }

    fn aggregate(&self, replicas: &[DeltaState]) -> DeltaState {
        let mut s = replicas[0];
        let inflow: f64 = replicas.iter().map(|r| r.partial).sum();
        s.delta = inflow;
        s.partial = 0.0;
        s
    }

    fn max_rounds(&self) -> usize {
        100_000
    }
}

/// Source-sampled betweenness via three ETSCH phases per source.
/// `samples = 0` uses every vertex (exact, small graphs only).
pub fn etsch_betweenness(
    g: &Graph,
    p: &EdgePartition,
    samples: usize,
    seed: u64,
) -> Vec<f64> {
    let n = g.vertex_count();
    let sources: Vec<u32> = if samples == 0 || samples >= n {
        (0..n as u32).collect()
    } else {
        Rng::new(seed)
            .sample_indices(n, samples)
            .into_iter()
            .map(|v| v as u32)
            .collect()
    };
    let scale = if sources.len() < n {
        n as f64 / sources.len() as f64
    } else {
        1.0
    };
    let mut bc = vec![0.0f64; n];
    let mut engine = Etsch::new(g, p);
    for &s in &sources {
        let dist = std::sync::Arc::new(engine.run(&mut Sssp::new(s)));
        let sigma_states = engine.run(&mut SigmaPhase {
            source: s,
            dist: dist.clone(),
        });
        let sigma = std::sync::Arc::new(
            sigma_states.iter().map(|x| x.sigma).collect::<Vec<_>>(),
        );
        let delta_states =
            engine.run(&mut DeltaPhase { dist, sigma });
        for v in 0..n {
            if v as u32 != s {
                bc[v] += scale * delta_states[v].delta;
            }
        }
    }
    // undirected graphs count each pair twice
    for x in bc.iter_mut() {
        *x /= 2.0;
    }
    bc
}

/// Sequential Brandes oracle (exact betweenness, unweighted undirected).
pub fn brandes_ref(g: &Graph) -> Vec<f64> {
    let n = g.vertex_count();
    let mut bc = vec![0.0f64; n];
    for s in 0..n as u32 {
        let mut stack: Vec<u32> = Vec::new();
        let mut pred: Vec<Vec<u32>> = vec![Vec::new(); n];
        let mut sigma = vec![0.0f64; n];
        let mut dist = vec![i64::MAX; n];
        sigma[s as usize] = 1.0;
        dist[s as usize] = 0;
        let mut queue = std::collections::VecDeque::from([s]);
        while let Some(v) = queue.pop_front() {
            stack.push(v);
            for &w in g.neighbor_vertices(v) {
                if dist[w as usize] == i64::MAX {
                    dist[w as usize] = dist[v as usize] + 1;
                    queue.push_back(w);
                }
                if dist[w as usize] == dist[v as usize] + 1 {
                    sigma[w as usize] += sigma[v as usize];
                    pred[w as usize].push(v);
                }
            }
        }
        let mut delta = vec![0.0f64; n];
        while let Some(w) = stack.pop() {
            for &v in &pred[w as usize] {
                delta[v as usize] += sigma[v as usize]
                    / sigma[w as usize]
                    * (1.0 + delta[w as usize]);
            }
            if w != s {
                bc[w as usize] += delta[w as usize];
            }
        }
    }
    for x in bc.iter_mut() {
        *x /= 2.0;
    }
    bc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::GraphKind;
    use crate::graph::GraphBuilder;
    use crate::partition::{baselines::RandomEdge, dfep::Dfep, Partitioner};

    #[test]
    fn brandes_on_path() {
        // path 0-1-2-3: bc(1) = bc(2) = 2 (pairs (0,2),(0,3) resp ...)
        let g = GraphBuilder::new()
            .add_edge(0, 1)
            .add_edge(1, 2)
            .add_edge(2, 3)
            .build();
        let bc = brandes_ref(&g);
        assert_eq!(bc, vec![0.0, 2.0, 2.0, 0.0]);
    }

    #[test]
    fn etsch_exact_matches_brandes() {
        let g = GraphKind::ErdosRenyi { n: 60, m: 150 }.generate(2);
        let p = RandomEdge.partition_graph(&g, 4, 1).unwrap();
        let got = etsch_betweenness(&g, &p, 0, 0);
        let want = brandes_ref(&g);
        for v in 0..g.vertex_count() {
            assert!(
                (got[v] - want[v]).abs() < 1e-6 * (1.0 + want[v]),
                "vertex {v}: {} vs {}",
                got[v],
                want[v]
            );
        }
    }

    #[test]
    fn etsch_exact_matches_brandes_on_dfep_partitions() {
        let g = GraphKind::PowerlawCluster { n: 80, m: 3, p: 0.4 }
            .generate(4);
        let p = Dfep::default().partition_graph(&g, 3, 1).unwrap();
        let got = etsch_betweenness(&g, &p, 0, 0);
        let want = brandes_ref(&g);
        for v in 0..g.vertex_count() {
            assert!(
                (got[v] - want[v]).abs() < 1e-6 * (1.0 + want[v]),
                "vertex {v}: {} vs {}",
                got[v],
                want[v]
            );
        }
    }

    #[test]
    fn sampled_estimate_correlates() {
        let g = GraphKind::PowerlawCluster { n: 120, m: 3, p: 0.3 }
            .generate(5);
        let p = RandomEdge.partition_graph(&g, 4, 2).unwrap();
        let est = etsch_betweenness(&g, &p, 40, 7);
        let exact = brandes_ref(&g);
        // the hub with max exact centrality should rank near the top of
        // the estimate
        let hub = exact
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        let better: usize =
            est.iter().filter(|&&x| x > est[hub]).count();
        assert!(better <= 5, "hub rank {better} too low");
    }
}
