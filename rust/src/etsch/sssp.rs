//! Single-source shortest paths in ETSCH (paper Algorithm 1).
//!
//! State = hop distance. Local phase runs Dijkstra (unit weights, so a
//! BFS-flavored priority queue) over the partition subgraph; aggregation
//! takes the min across replicas.

use super::{Algorithm, Subgraph};
use crate::graph::Graph;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Sentinel for "not reached" (the paper's +inf).
pub const UNREACHED: u32 = u32::MAX;

/// Algorithm-1 instance.
#[derive(Clone, Debug)]
pub struct Sssp {
    /// The source vertex (distance 0).
    pub source: u32,
}

impl Sssp {
    /// SSSP from `source`.
    pub fn new(source: u32) -> Self {
        Sssp { source }
    }
}

impl Algorithm for Sssp {
    type State = u32;

    fn init(&self, v: u32, _g: &Graph) -> u32 {
        if v == self.source {
            0
        } else {
            UNREACHED
        }
    }

    fn local(&self, sub: &Subgraph, states: &mut [u32]) {
        // Dijkstra over the local subgraph, seeded with current states
        let mut heap: BinaryHeap<Reverse<(u32, u32)>> = states
            .iter()
            .enumerate()
            .filter(|&(_, &d)| d != UNREACHED)
            .map(|(l, &d)| Reverse((d, l as u32)))
            .collect();
        while let Some(Reverse((d, u))) = heap.pop() {
            if d > states[u as usize] {
                continue; // stale entry
            }
            for &w in sub.neighbor_vertices(u) {
                let nd = d + 1;
                if nd < states[w as usize] {
                    states[w as usize] = nd;
                    heap.push(Reverse((nd, w)));
                }
            }
        }
    }

    fn aggregate(&self, replicas: &[u32]) -> u32 {
        *replicas.iter().min().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::etsch::Etsch;
    use crate::graph::generators::GraphKind;
    use crate::graph::stats::bfs_distances;
    use crate::graph::{Graph, GraphBuilder};
    use crate::partition::{baselines::RandomEdge, Partitioner};

    fn check(g: &Graph, k: usize, source: u32) {
        let p = RandomEdge.partition_graph(g, k, 7).unwrap();
        let mut engine = Etsch::new(g, &p);
        let got = engine.run(&mut Sssp::new(source));
        let want = bfs_distances(g, source);
        for v in 0..g.vertex_count() {
            let w = if want[v] == u32::MAX { UNREACHED } else { want[v] };
            assert_eq!(got[v], w, "vertex {v}");
        }
    }

    #[test]
    fn correct_on_random_partitions() {
        let g = GraphKind::ErdosRenyi { n: 200, m: 500 }.generate(3);
        check(&g, 6, 0);
        check(&g, 2, 10);
    }

    #[test]
    fn unreachable_stays_inf() {
        let g = GraphBuilder::new()
            .add_edge(0, 1)
            .add_edge(2, 3)
            .build();
        check(&g, 2, 0);
    }

    #[test]
    fn single_partition_one_round() {
        // with k=1 everything is local: Dijkstra finishes in round 1 and
        // round 2 detects quiescence
        let g = GraphKind::ErdosRenyi { n: 100, m: 300 }.generate(4);
        let p = RandomEdge.partition_graph(&g, 1, 0).unwrap();
        let mut engine = Etsch::new(&g, &p);
        engine.run(&mut Sssp::new(0));
        assert!(engine.rounds_executed() <= 2);
    }
}
