//! PageRank in ETSCH — an example of a *sum*-reconciled (rather than
//! min-reconciled) computation, showing the aggregation phase is not tied
//! to idempotent reducers.
//!
//! Per round, the local phase computes each vertex's partial incoming mass
//! from the edges of its partition (each edge lives in exactly one
//! partition, so partials add up exactly once); aggregation sums the
//! replicas' partials and applies the damping update. Degrees are global
//! (known at init), so mass pushed along an edge is `rank(u) / deg(u)`.

use super::{Algorithm, Subgraph};
use crate::graph::Graph;

/// Vertex state: current rank, global degree, and this-round partial sum.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PrState {
    /// Current rank.
    pub rank: f64,
    /// Global degree (constant; local phases see only partial degrees).
    pub degree: u32,
    /// This-round partial neighbor-rank sum.
    pub partial: f64,
}

/// Fixed-iteration PageRank in the ETSCH model.
#[derive(Clone, Debug)]
pub struct PageRank {
    /// Damping factor (0.85 = the usual choice).
    pub damping: f64,
    /// Iterations to run (one per ETSCH round).
    pub iterations: usize,
    /// Vertex count (for the teleport term).
    pub n: usize,
}

impl PageRank {
    /// PageRank over `g` for `iterations` rounds at damping 0.85.
    pub fn new(g: &Graph, iterations: usize) -> Self {
        PageRank { damping: 0.85, iterations, n: g.vertex_count() }
    }
}

impl Algorithm for PageRank {
    type State = PrState;

    fn init(&self, v: u32, g: &Graph) -> PrState {
        PrState {
            rank: 1.0 / self.n as f64,
            degree: g.degree(v) as u32,
            partial: 0.0,
        }
    }

    fn local(&self, sub: &Subgraph, states: &mut [PrState]) {
        for s in states.iter_mut() {
            s.partial = 0.0;
        }
        for u in 0..states.len() as u32 {
            let su = states[u as usize];
            if su.degree == 0 {
                continue;
            }
            let push = su.rank / su.degree as f64;
            for &w in sub.neighbor_vertices(u) {
                states[w as usize].partial += push;
            }
        }
    }

    fn aggregate(&self, replicas: &[PrState]) -> PrState {
        let mut s = replicas[0];
        let mut incoming = 0.0;
        for r in replicas {
            incoming += r.partial;
        }
        s.rank = (1.0 - self.damping) / self.n as f64
            + self.damping * incoming;
        s.partial = 0.0;
        s
    }

    fn max_rounds(&self) -> usize {
        self.iterations
    }
}

/// Reference sequential PageRank (same update rule) for tests.
pub fn pagerank_ref(g: &Graph, damping: f64, iterations: usize) -> Vec<f64> {
    let n = g.vertex_count();
    let mut rank = vec![1.0 / n as f64; n];
    for _ in 0..iterations {
        let mut next = vec![(1.0 - damping) / n as f64; n];
        for v in 0..n as u32 {
            let d = g.degree(v);
            if d == 0 {
                continue;
            }
            let push = damping * rank[v as usize] / d as f64;
            for &w in g.neighbor_vertices(v) {
                next[w as usize] += push;
            }
        }
        rank = next;
    }
    rank
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::etsch::Etsch;
    use crate::graph::generators::GraphKind;
    use crate::partition::{baselines::RandomEdge, dfep::Dfep, Partitioner};

    #[test]
    fn matches_sequential_reference() {
        let g = GraphKind::ErdosRenyi { n: 120, m: 360 }.generate(3);
        let iters = 15;
        let p = RandomEdge.partition_graph(&g, 4, 2).unwrap();
        let mut engine = Etsch::new(&g, &p);
        let got = engine.run(&mut PageRank::new(&g, iters));
        let want = pagerank_ref(&g, 0.85, iters);
        for v in 0..g.vertex_count() {
            assert!(
                (got[v].rank - want[v]).abs() < 1e-9,
                "vertex {v}: {} vs {}",
                got[v].rank,
                want[v]
            );
        }
    }

    #[test]
    fn rank_sums_to_one_ish() {
        let g = GraphKind::PowerlawCluster { n: 200, m: 3, p: 0.3 }
            .generate(4);
        let p = Dfep::default().partition_graph(&g, 4, 1).unwrap();
        let mut engine = Etsch::new(&g, &p);
        let got = engine.run(&mut PageRank::new(&g, 20));
        let total: f64 = got.iter().map(|s| s.rank).sum();
        // undirected connected graph, no dangling mass loss
        assert!((total - 1.0).abs() < 1e-6, "total {total}");
    }
}
