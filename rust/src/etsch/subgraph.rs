//! Per-partition local subgraph: the unit of work an ETSCH worker gets.
//!
//! The [`Subgraph`] type itself lives in [`crate::partition::view`] — it
//! is derived partition state, built once by
//! [`PartitionView`](crate::partition::view::PartitionView) alongside the
//! replica table and frontier flags. This module keeps the historical
//! entry point as a thin projection of the view.

use crate::graph::Graph;
use crate::partition::view::PartitionView;
use crate::partition::EdgePartition;

pub use crate::partition::view::Subgraph;

/// Build all K subgraphs for a partitioning — a thin projection of
/// [`PartitionView`]. Callers that also need metrics or an
/// [`Etsch`](crate::etsch::Etsch) engine should build the view once and
/// share it instead.
pub fn build_subgraphs(g: &Graph, p: &EdgePartition) -> Vec<Subgraph> {
    PartitionView::build(g, p).into_subgraphs()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    fn square_partition() -> (Graph, EdgePartition) {
        let g = GraphBuilder::new()
            .add_edge(0, 1)
            .add_edge(1, 2)
            .add_edge(2, 3)
            .add_edge(3, 0)
            .build();
        let p = EdgePartition { k: 2, owner: vec![0, 0, 1, 1], rounds: 1 };
        (g, p)
    }

    #[test]
    fn local_structure() {
        let (g, p) = square_partition();
        // canonical edge order: (0,1),(0,3),(1,2),(2,3)
        let subs = build_subgraphs(&g, &p);
        assert_eq!(subs.len(), 2);
        assert_eq!(subs[0].vertex_count(), 3); // part 0: {0,1,3}
        assert_eq!(subs[0].edge_count, 2);
        // frontier: 1 and 3 live in both partitions
        for s in &subs {
            for (l, &gv) in s.global.iter().enumerate() {
                let expect = gv == 1 || gv == 3;
                assert_eq!(s.frontier[l], expect, "vertex {gv}");
            }
        }
    }

    #[test]
    fn degrees_consistent_with_edges() {
        let (g, p) = square_partition();
        for s in build_subgraphs(&g, &p) {
            let total: usize =
                (0..s.vertex_count() as u32).map(|v| s.degree(v)).sum();
            assert_eq!(total, 2 * s.edge_count);
            // adjacency edge ids belong to this part
            for v in 0..s.vertex_count() as u32 {
                for (w, e) in s.neighbors(v) {
                    assert_eq!(p.owner[e as usize] as usize, s.part);
                    assert!((w as usize) < s.vertex_count());
                }
            }
        }
    }

    #[test]
    fn empty_partition_gives_empty_subgraph() {
        let (g, _) = square_partition();
        let p = EdgePartition { k: 3, owner: vec![0, 0, 1, 1], rounds: 1 };
        let subs = build_subgraphs(&g, &p);
        assert_eq!(subs[2].vertex_count(), 0);
        assert_eq!(subs[2].edge_count, 0);
    }
}
