//! Per-partition local subgraph: the unit of work an ETSCH worker gets.
//!
//! Each partition's edges, their endpoint vertices re-indexed to a dense
//! local id space, plus the frontier flags. Memory is O(|E_i|) per the
//! paper's size argument (§II: |V_i| = O(|E_i|)).

use crate::graph::Graph;
use crate::partition::EdgePartition;

/// A partition's induced subgraph with local vertex ids.
#[derive(Clone, Debug)]
pub struct Subgraph {
    /// Which partition this is.
    pub part: usize,
    /// Global vertex id of each local vertex.
    pub global: Vec<u32>,
    /// Local CSR offsets (length = local vertex count + 1).
    pub offsets: Vec<u32>,
    /// Local adjacency: (local neighbor, global edge id).
    pub adj: Vec<(u32, u32)>,
    /// Frontier flag per local vertex (replicated in >= 2 partitions).
    pub frontier: Vec<bool>,
    /// Number of edges in this partition.
    pub edge_count: usize,
}

impl Subgraph {
    #[inline]
    pub fn vertex_count(&self) -> usize {
        self.global.len()
    }

    #[inline]
    pub fn neighbors(&self, v_local: u32) -> &[(u32, u32)] {
        &self.adj[self.offsets[v_local as usize] as usize
            ..self.offsets[v_local as usize + 1] as usize]
    }

    #[inline]
    pub fn degree(&self, v_local: u32) -> usize {
        (self.offsets[v_local as usize + 1] - self.offsets[v_local as usize])
            as usize
    }
}

/// Build all K subgraphs for a partitioning.
pub fn build_subgraphs(g: &Graph, p: &EdgePartition) -> Vec<Subgraph> {
    let mult = p.vertex_multiplicity(g);
    let edge_sets = p.edge_sets();
    let mut out = Vec::with_capacity(p.k);
    let mut local_of = vec![u32::MAX; g.vertex_count()];
    for (part, edges) in edge_sets.iter().enumerate() {
        // collect local vertices in order of first appearance
        let mut global: Vec<u32> = Vec::new();
        for &e in edges {
            let (u, v) = g.endpoints(e);
            for w in [u, v] {
                if local_of[w as usize] == u32::MAX {
                    local_of[w as usize] = global.len() as u32;
                    global.push(w);
                }
            }
        }
        let nv = global.len();
        // local degree count
        let mut deg = vec![0u32; nv + 1];
        for &e in edges {
            let (u, v) = g.endpoints(e);
            deg[local_of[u as usize] as usize + 1] += 1;
            deg[local_of[v as usize] as usize + 1] += 1;
        }
        let mut offsets = deg;
        for i in 1..offsets.len() {
            offsets[i] += offsets[i - 1];
        }
        let mut adj = vec![(0u32, 0u32); offsets[nv] as usize];
        let mut cursor = offsets.clone();
        for &e in edges {
            let (u, v) = g.endpoints(e);
            let (lu, lv) =
                (local_of[u as usize], local_of[v as usize]);
            adj[cursor[lu as usize] as usize] = (lv, e);
            cursor[lu as usize] += 1;
            adj[cursor[lv as usize] as usize] = (lu, e);
            cursor[lv as usize] += 1;
        }
        let frontier =
            global.iter().map(|&w| mult[w as usize] >= 2).collect();
        // reset the scratch map for the next partition
        for &w in &global {
            local_of[w as usize] = u32::MAX;
        }
        out.push(Subgraph {
            part,
            global,
            offsets,
            adj,
            frontier,
            edge_count: edges.len(),
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    fn square_partition() -> (Graph, EdgePartition) {
        let g = GraphBuilder::new()
            .add_edge(0, 1)
            .add_edge(1, 2)
            .add_edge(2, 3)
            .add_edge(3, 0)
            .build();
        let p = EdgePartition { k: 2, owner: vec![0, 0, 1, 1], rounds: 1 };
        (g, p)
    }

    #[test]
    fn local_structure() {
        let (g, p) = square_partition();
        // canonical edge order: (0,1),(0,3),(1,2),(2,3)
        let subs = build_subgraphs(&g, &p);
        assert_eq!(subs.len(), 2);
        assert_eq!(subs[0].vertex_count(), 3); // part 0: {0,1,3}
        assert_eq!(subs[0].edge_count, 2);
        // frontier: 1 and 3 live in both partitions
        for s in &subs {
            for (l, &gv) in s.global.iter().enumerate() {
                let expect = gv == 1 || gv == 3;
                assert_eq!(s.frontier[l], expect, "vertex {gv}");
            }
        }
    }

    #[test]
    fn degrees_consistent_with_edges() {
        let (g, p) = square_partition();
        for s in build_subgraphs(&g, &p) {
            let total: usize =
                (0..s.vertex_count() as u32).map(|v| s.degree(v)).sum();
            assert_eq!(total, 2 * s.edge_count);
            // adjacency edge ids belong to this part
            for v in 0..s.vertex_count() as u32 {
                for &(w, e) in s.neighbors(v) {
                    assert_eq!(p.owner[e as usize] as usize, s.part);
                    assert!((w as usize) < s.vertex_count());
                }
            }
        }
    }

    #[test]
    fn empty_partition_gives_empty_subgraph() {
        let (g, _) = square_partition();
        let p = EdgePartition { k: 3, owner: vec![0, 0, 1, 1], rounds: 1 };
        let subs = build_subgraphs(&g, &p);
        assert_eq!(subs[2].vertex_count(), 0);
        assert_eq!(subs[2].edge_count, 0);
    }
}
