//! Community detection by synchronous label propagation in ETSCH.
//!
//! Each vertex adopts the most frequent label among its neighbors (ties
//! to the smallest label). Neighbor frequencies are *summable* across
//! partitions — each edge contributes from exactly one partition — so the
//! local phase emits partial (label, count) votes and the aggregation
//! merges them; another demonstration that ETSCH handles non-idempotent
//! reconciliation (the paper's §VII "how flexible is the model" question).

use super::{Algorithm, Subgraph};
use crate::graph::Graph;

/// Vertex state: current label + this-round partial votes from the
/// partition's local edges (kept sorted by label).
#[derive(Clone, Debug, PartialEq)]
pub struct LpaState {
    /// Current community label.
    pub label: u32,
    /// This-round (label, count) votes, sorted by label.
    pub votes: Vec<(u32, u32)>,
}

/// Community detection by label propagation in the ETSCH model.
#[derive(Clone, Debug)]
pub struct LabelPropagation {
    /// Round bound (label propagation has no natural quiescence).
    pub max_rounds: usize,
}

impl Default for LabelPropagation {
    fn default() -> Self {
        LabelPropagation { max_rounds: 30 }
    }
}

impl Algorithm for LabelPropagation {
    type State = LpaState;

    fn init(&self, v: u32, _g: &Graph) -> LpaState {
        LpaState { label: v, votes: Vec::new() }
    }

    fn local(&self, sub: &Subgraph, states: &mut [LpaState]) {
        // gather neighbor labels per vertex over the partition's edges
        let labels: Vec<u32> = states.iter().map(|s| s.label).collect();
        for u in 0..states.len() {
            let mut votes: Vec<(u32, u32)> = Vec::new();
            for &w in sub.neighbor_vertices(u as u32) {
                let l = labels[w as usize];
                match votes.binary_search_by_key(&l, |&(x, _)| x) {
                    Ok(i) => votes[i].1 += 1,
                    Err(i) => votes.insert(i, (l, 1)),
                }
            }
            states[u].votes = votes;
        }
    }

    fn aggregate(&self, replicas: &[LpaState]) -> LpaState {
        // merge partial votes from all replicas
        let mut merged: Vec<(u32, u32)> = Vec::new();
        for r in replicas {
            for &(l, c) in &r.votes {
                match merged.binary_search_by_key(&l, |&(x, _)| x) {
                    Ok(i) => merged[i].1 += c,
                    Err(i) => merged.insert(i, (l, c)),
                }
            }
        }
        // most frequent, smallest label on ties; keep own label if isolated
        let label = merged
            .iter()
            .max_by(|a, b| a.1.cmp(&b.1).then(b.0.cmp(&a.0)))
            .map(|&(l, _)| l)
            .unwrap_or(replicas[0].label);
        LpaState { label, votes: Vec::new() }
    }

    fn max_rounds(&self) -> usize {
        self.max_rounds
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::etsch::Etsch;
    use crate::graph::GraphBuilder;
    use crate::partition::{baselines::RandomEdge, Partitioner};

    fn two_cliques() -> Graph {
        // two K5s joined by a single bridge edge
        let mut b = GraphBuilder::new();
        for u in 0..5u32 {
            for v in (u + 1)..5 {
                b.push_edge(u, v);
                b.push_edge(u + 5, v + 5);
            }
        }
        b.push_edge(4, 5);
        b.build()
    }

    #[test]
    fn separates_two_cliques() {
        let g = two_cliques();
        let p = RandomEdge.partition_graph(&g, 3, 1).unwrap();
        let mut engine = Etsch::new(&g, &p);
        let states = engine.run(&mut LabelPropagation::default());
        let a = states[0].label;
        let b = states[9].label;
        for v in 0..5 {
            assert_eq!(states[v].label, a, "vertex {v}");
        }
        for v in 5..10 {
            assert_eq!(states[v].label, b, "vertex {v}");
        }
        assert_ne!(a, b, "cliques should keep distinct communities");
    }

    #[test]
    fn partitioning_does_not_change_labels() {
        let g = two_cliques();
        let l1 = {
            let p = RandomEdge.partition_graph(&g, 1, 7).unwrap();
            let mut e = Etsch::new(&g, &p);
            e.run(&mut LabelPropagation::default())
        };
        let l4 = {
            let p = RandomEdge.partition_graph(&g, 4, 7).unwrap();
            let mut e = Etsch::new(&g, &p);
            e.run(&mut LabelPropagation::default())
        };
        let labels = |ls: &[LpaState]| -> Vec<u32> {
            ls.iter().map(|s| s.label).collect()
        };
        assert_eq!(labels(&l1), labels(&l4));
    }
}
