//! k-core decomposition in ETSCH.
//!
//! Membership in the k-core (the maximal subgraph where every vertex has
//! degree >= k) is a peeling fixpoint, and it fits the ETSCH mold exactly:
//! a vertex's full degree is the *sum* of its partition-local degrees
//! (every edge lives in exactly one partition), so the local phase counts
//! alive neighbors per partition and the aggregation phase sums the
//! partials and applies the peel rule.

use super::{Algorithm, Subgraph};
use crate::graph::Graph;

/// Vertex state: alive flag + this-round partial alive-degree.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct KCoreState {
    /// Still in the candidate core.
    pub alive: bool,
    /// This-round alive-degree accumulator (see the `REEVAL` marker).
    pub partial_deg: u32,
}

/// Post-aggregation `partial_deg` marker on surviving vertices. The peel
/// rule must be *re-applied* every round while a vertex is alive — even
/// when its recomputed alive-degree lands on the same number (e.g. drops
/// to 0 because the last neighbor died), which a plain reset-to-0 would
/// make invisible to the engine's change-driven aggregation. `local`
/// always overwrites `partial_deg`, so the marker never reaches
/// [`KCore::aggregate`]'s sum.
const REEVAL: u32 = u32::MAX;

/// Iterated k-core peeling in the ETSCH model.
#[derive(Clone, Debug)]
pub struct KCore {
    /// The core order to peel to.
    pub k: u32,
}

impl KCore {
    /// Peel to the `k`-core.
    pub fn new(k: u32) -> Self {
        KCore { k }
    }
}

impl Algorithm for KCore {
    type State = KCoreState;

    fn init(&self, _v: u32, _g: &Graph) -> KCoreState {
        KCoreState { alive: true, partial_deg: 0 }
    }

    fn local(&self, sub: &Subgraph, states: &mut [KCoreState]) {
        for l in 0..states.len() {
            states[l].partial_deg = 0;
        }
        for u in 0..states.len() as u32 {
            if !states[u as usize].alive {
                continue;
            }
            let mut deg = 0;
            for &w in sub.neighbor_vertices(u) {
                if states[w as usize].alive {
                    deg += 1;
                }
            }
            states[u as usize].partial_deg = deg;
        }
    }

    fn aggregate(&self, replicas: &[KCoreState]) -> KCoreState {
        let was_alive = replicas[0].alive; // alive flag replicated equally
        let total: u32 = replicas.iter().map(|r| r.partial_deg).sum();
        let alive = was_alive && total >= self.k;
        KCoreState {
            alive,
            partial_deg: if alive { REEVAL } else { 0 },
        }
    }
}

/// Sequential peeling oracle (tests + CLI).
pub fn kcore_ref(g: &Graph, k: u32) -> Vec<bool> {
    let n = g.vertex_count();
    let mut deg: Vec<u32> = (0..n as u32).map(|v| g.degree(v) as u32).collect();
    let mut alive = vec![true; n];
    let mut queue: std::collections::VecDeque<u32> = (0..n as u32)
        .filter(|&v| deg[v as usize] < k)
        .collect();
    while let Some(v) = queue.pop_front() {
        if !alive[v as usize] {
            continue;
        }
        alive[v as usize] = false;
        for &w in g.neighbor_vertices(v) {
            if alive[w as usize] {
                deg[w as usize] -= 1;
                if deg[w as usize] < k {
                    queue.push_back(w);
                }
            }
        }
    }
    alive
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::etsch::Etsch;
    use crate::graph::generators::GraphKind;
    use crate::graph::GraphBuilder;
    use crate::partition::{baselines::RandomEdge, dfep::Dfep, Partitioner};

    fn run_etsch(g: &Graph, part_k: usize, core_k: u32, seed: u64) -> Vec<bool> {
        let p = RandomEdge.partition_graph(g, part_k, seed).unwrap();
        let mut engine = Etsch::new(g, &p);
        engine
            .run(&mut KCore::new(core_k))
            .into_iter()
            .map(|s| s.alive)
            .collect()
    }

    #[test]
    fn triangle_with_tail() {
        // triangle is a 2-core; the tail vertex is not
        let g = GraphBuilder::new()
            .add_edge(0, 1)
            .add_edge(1, 2)
            .add_edge(0, 2)
            .add_edge(2, 3)
            .build();
        let got = run_etsch(&g, 2, 2, 1);
        assert_eq!(got, vec![true, true, true, false]);
        assert_eq!(got, kcore_ref(&g, 2));
    }

    #[test]
    fn peel_cascade_reaches_vertices_whose_alive_degree_drops_to_zero() {
        // path 0-1-2, k=2: the endpoints die in round 1 and vertex 1's
        // alive-degree then recomputes to 0 — the same value aggregation
        // reset it to. The REEVAL marker keeps vertex 1 dirty so the peel
        // rule is re-applied and it dies too (regression test for the
        // change-driven aggregation).
        let g = GraphBuilder::new().add_edge(0, 1).add_edge(1, 2).build();
        for part_k in [1usize, 2] {
            let got = run_etsch(&g, part_k, 2, 3);
            assert_eq!(got, vec![false, false, false], "part_k={part_k}");
            assert_eq!(got, kcore_ref(&g, 2), "part_k={part_k}");
        }
    }

    #[test]
    fn matches_oracle_on_random_graphs() {
        for seed in 0..4 {
            let g = GraphKind::ErdosRenyi { n: 150, m: 450 }
                .generate(seed);
            for core_k in [2u32, 3, 4, 6] {
                let got = run_etsch(&g, 5, core_k, seed);
                // ETSCH leaves isolated vertices (not in any partition)
                // at their init state; mask them like the oracle does
                let want = kcore_ref(&g, core_k);
                for v in 0..g.vertex_count() {
                    if g.degree(v as u32) > 0 {
                        assert_eq!(
                            got[v], want[v],
                            "k={core_k} seed={seed} vertex {v}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn works_on_dfep_partitions() {
        let g = GraphKind::PowerlawCluster { n: 300, m: 4, p: 0.4 }
            .generate(3);
        let p = Dfep::default().partition_graph(&g, 4, 1).unwrap();
        let mut engine = Etsch::new(&g, &p);
        let got: Vec<bool> = engine
            .run(&mut KCore::new(3))
            .into_iter()
            .map(|s| s.alive)
            .collect();
        let want = kcore_ref(&g, 3);
        assert_eq!(got, want);
        // a PLC graph with m=4 has a nonempty 3-core
        assert!(got.iter().any(|&a| a));
    }
}
