//! DFEP — Distributed Funding-based Edge Partitioning (paper §IV).
//!
//! Each of the `k` partitions starts with an equal amount of *funding*
//! placed on a random vertex. Every round:
//!
//! 1. **Step 1** (per vertex, Alg. 4): each vertex splits each partition's
//!    funding equally among incident edges that are *free or owned by that
//!    partition*.
//! 2. **Step 2** (per edge, Alg. 5): each free edge is sold to the highest
//!    bidder if the bid is >= 1 unit; the winner pays 1 unit, the remainder
//!    returns half/half to the endpoints; losing bids return to the
//!    vertices that contributed them; bids on an edge you already own
//!    return half/half (the funding keeps flowing through the owned
//!    region toward the frontier).
//! 3. **Step 3** (coordinator, Alg. 6): partitions smaller than average
//!    receive `min(cap, avg/|E_i| )` fresh units per vertex they fund —
//!    the catch-up mechanism that makes final sizes balanced.
//!
//! The implementation is single-process but *round-synchronous*: state is
//! updated exactly as the distributed version would (two message-free
//! half-steps per round), so round counts — the paper's synchronization
//! metric — are faithful. The MapReduce-shaped version used for the EC2
//! experiments lives in [`crate::cluster::dfep_mr`], and an XLA-offloaded
//! round (L2 `funding_step` artifact) in [`crate::runtime::xla_engine`].

use super::{check_k, EdgePartition, Partitioner};
use crate::bail;
use crate::graph::Graph;
use crate::util::error::Result;
use crate::util::rng::Rng;

/// Funding ledger for one partition: money on vertices (sparse map would
/// be slower; graphs here fit dense per-partition vectors comfortably).
pub(crate) type Money = Vec<f64>;

/// Tunables (defaults follow the paper's implementation notes).
#[derive(Clone, Debug)]
pub struct Dfep {
    /// Cap on per-round funding for a small partition ("10 in our
    /// implementation") — avoids overfunding during the first rounds.
    pub funding_cap: f64,
    /// Initial funding, as a fraction of the optimal partition size
    /// (`|E|/k`). 1.0 = "what would be needed to buy an amount of edges
    /// equal to the optimal sized partition".
    pub initial_fraction: f64,
    /// Safety bound on rounds (the algorithm converges far earlier).
    pub max_rounds: usize,
    /// Frontier-first funding: a vertex holding an incident *buyable*
    /// edge bids only on buyable edges, instead of also diluting its
    /// funding across edges the partition already owns (the literal
    /// Alg. 4 split). The literal split lets committed money random-walk
    /// the interior, offers at the frontier stagnate below 1 unit and the
    /// end-game livelocks; concentrating at the frontier restores the
    /// wave-like growth the paper's round counts imply. `false` gives the
    /// literal pseudocode (kept as an ablation — see the `hotpath` bench).
    pub frontier_first: bool,
}

impl Default for Dfep {
    fn default() -> Self {
        Dfep {
            funding_cap: 10.0,
            initial_fraction: 1.0,
            max_rounds: 10_000,
            frontier_first: true,
        }
    }
}

/// Full mutable state of a DFEP run (shared with the DFEPC variant).
pub(crate) struct DfepState {
    pub k: usize,
    /// `owner[e]`: `FREE`, or partition id.
    pub owner: Vec<u32>,
    /// Per-partition vertex funding.
    pub money: Vec<Money>,
    /// Edges owned per partition.
    pub sizes: Vec<usize>,
    pub free_edges: usize,
    pub rounds: usize,
    /// Frontier-first funding (see [`Dfep::frontier_first`]).
    pub frontier_first: bool,
    /// Last purchase endpoint per partition — the coordinator's deposit
    /// anchor when a partition's liquid cash is exactly zero.
    pub anchor: Vec<usize>,
    /// Per-partition list of vertices that *may* hold cash (push-only,
    /// may contain stale entries and duplicates; consumers re-check
    /// `money[i][v] > 0`). Keeps every round O(active state), not O(k*n).
    pub holders: Vec<Vec<u32>>,
    /// Number of incident FREE edges per vertex, maintained incrementally
    /// on every purchase (avoids an O(m) scan per round).
    pub free_deg: Vec<u32>,
    /// Vertices with `free_deg > 0` (pruned as they dry up).
    live_vertices: Vec<u32>,
}

pub(crate) const FREE: u32 = u32::MAX;

impl DfepState {
    /// Initialize per Alg. 3: each partition starts on a random vertex
    /// holding the full initial funding.
    pub fn new(g: &Graph, k: usize, initial: f64, rng: &mut Rng) -> Self {
        let n = g.vertex_count();
        let mut money = vec![vec![0.0; n]; k];
        let mut anchors = Vec::with_capacity(k);
        let mut holders = Vec::with_capacity(k);
        // paper Alg. 3: each partition starts on a random vertex with the
        // full initial funding
        for part in money.iter_mut() {
            let v = rng.below(n);
            part[v] = initial;
            anchors.push(v);
            holders.push(vec![v as u32]);
        }
        let mut free_deg = vec![0u32; n];
        for (_, u, v) in g.edge_iter() {
            free_deg[u as usize] += 1;
            free_deg[v as usize] += 1;
        }
        let live_vertices =
            (0..n as u32).filter(|&v| free_deg[v as usize] > 0).collect();
        DfepState {
            k,
            owner: vec![FREE; g.edge_count()],
            money,
            sizes: vec![0; k],
            free_edges: g.edge_count(),
            rounds: 0,
            frontier_first: true,
            anchor: anchors,
            holders,
            free_deg,
            live_vertices,
        }
    }

    /// Steps 1 + 2 for one round. `poor`/`rich` enable the DFEPC
    /// dynamic: partitions listed in `poor` may also bid on edges owned by
    /// partitions listed in `rich`, stealing them on a strictly higher bid.
    ///
    /// Both steps run data-parallel on the shared [`crate::util::pool`]:
    /// step 1 over fixed-size holder chunks per partition, step 2 over
    /// fixed-size runs of bid-receiving edges. Every shard computes a pure
    /// function of its input slice; mutations (money zeroing, ownership,
    /// refunds) are applied serially in fixed shard order afterwards, so
    /// the round trajectory — including every `f64` accumulation order —
    /// is bit-identical to the sequential execution for any thread count.
    pub fn funding_round(
        &mut self,
        g: &Graph,
        poor: Option<&[bool]>,
        rich: Option<&[bool]>,
    ) {
        // Step 1: bids per (partition, edge). Sparse hot path: only
        // vertices in the holder lists are visited, and only edges that
        // actually receive a bid are touched in step 2 — every round is
        // O(active frontier), not O(k * m).
        //
        // bid = (edge, partition, offer, contribution-from-lower-endpoint)
        let mut holder_lists: Vec<Vec<u32>> = Vec::with_capacity(self.k);
        for i in 0..self.k {
            let mut hs = std::mem::take(&mut self.holders[i]);
            hs.sort_unstable();
            hs.dedup();
            holder_lists.push(hs);
        }
        // shard = one holder chunk of one partition, in (partition,
        // holder-order) order; chunk size is a constant so the shard list
        // does not depend on the thread count
        const HOLDER_CHUNK: usize = 512;
        let mut shards: Vec<(usize, usize, usize)> = Vec::new();
        for (i, hs) in holder_lists.iter().enumerate() {
            let mut lo = 0;
            while lo < hs.len() {
                let hi = (lo + HOLDER_CHUNK).min(hs.len());
                shards.push((i, lo, hi));
                lo = hi;
            }
        }
        #[derive(Default)]
        struct Shard1Out {
            bids: Vec<(u32, u32, f64, f64)>,
            /// holders with cash but no eligible edge (stay funded)
            stranded: Vec<u32>,
            /// holders whose cash became bids (zeroed in apply)
            spent: Vec<u32>,
        }
        let mut outs: Vec<Shard1Out> = Vec::new();
        outs.resize_with(shards.len(), Shard1Out::default);
        {
            let money = &self.money;
            let owner = &self.owner;
            let frontier_first = self.frontier_first;
            let shards = &shards;
            let holder_lists = &holder_lists;
            crate::util::pool::run_mut(&mut outs, &|s, out: &mut Shard1Out| {
                let (i, lo, hi) = shards[s];
                let money_i = &money[i];
                let poor_i = poor.map(|p| p[i]).unwrap_or(false);
                let mut eligible: Vec<u32> = Vec::with_capacity(64);
                for &v in &holder_lists[i][lo..hi] {
                    let cash = money_i[v as usize];
                    if cash <= 0.0 {
                        continue; // stale/duplicate holder entry
                    }
                    eligible.clear();
                    let mut has_buyable = false;
                    for &(_, e) in g.neighbors(v) {
                        let o = owner[e as usize];
                        let buyable = o == FREE
                            || (poor_i
                                && o != i as u32
                                && rich
                                    .map(|r| r[o as usize])
                                    .unwrap_or(false));
                        if buyable && !has_buyable && frontier_first {
                            // first buyable edge seen: drop own edges
                            // collected so far, fund the frontier only
                            has_buyable = true;
                            eligible.clear();
                        }
                        let can = buyable
                            || (o == i as u32
                                && !(frontier_first && has_buyable));
                        if can {
                            eligible.push(e);
                        }
                    }
                    if eligible.is_empty() {
                        // stranded funding stays on the vertex
                        out.stranded.push(v);
                        continue;
                    }
                    let share = cash / eligible.len() as f64;
                    for &e in &eligible {
                        let (u, _) = g.endpoints(e);
                        let from_lo = if u == v { share } else { 0.0 };
                        out.bids.push((e, i as u32, share, from_lo));
                    }
                    out.spent.push(v);
                }
            });
        }
        // apply step-1 effects and concatenate bids in shard order (equal
        // to the sequential per-partition, per-holder order)
        let mut bids: Vec<(u32, u32, f64, f64)> =
            Vec::with_capacity(outs.iter().map(|o| o.bids.len()).sum());
        for (s, out) in outs.iter_mut().enumerate() {
            let i = shards[s].0;
            for &v in &out.stranded {
                self.holders[i].push(v);
            }
            for &v in &out.spent {
                self.money[i][v as usize] = 0.0;
            }
            bids.append(&mut out.bids);
        }

        // Step 2: auction — only over edges that received bids. Merge the
        // per-(edge, partition) contributions by sorting, then compute
        // every edge's outcome in parallel (outcomes only read the
        // pre-auction state: each edge is decided by its own bids) and
        // apply ownership changes + refunds serially in edge order.
        bids.sort_unstable_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
        let mut groups: Vec<(usize, usize)> = Vec::new();
        {
            let mut idx = 0usize;
            while idx < bids.len() {
                let e = bids[idx].0;
                let start = idx;
                while idx < bids.len() && bids[idx].0 == e {
                    idx += 1;
                }
                groups.push((start, idx));
            }
        }
        const GROUP_CHUNK: usize = 256;
        #[derive(Default)]
        struct Shard2Out {
            /// (edge, winner-or-FREE, number of credit entries)
            sales: Vec<(u32, u32, u32)>,
            /// (partition, vertex, amount) in sequential credit order
            credits: Vec<(u32, u32, f64)>,
        }
        let mut outs2: Vec<Shard2Out> = Vec::new();
        outs2.resize_with(
            groups.len().div_ceil(GROUP_CHUNK),
            Shard2Out::default,
        );
        {
            let owner = &self.owner;
            let bids = &bids;
            let groups = &groups;
            crate::util::pool::run_mut(&mut outs2, &|c, out: &mut Shard2Out| {
                let lo = c * GROUP_CHUNK;
                let hi = ((c + 1) * GROUP_CHUNK).min(groups.len());
                let mut merged: Vec<(u32, f64, f64)> = Vec::with_capacity(8);
                for &(start, end) in &groups[lo..hi] {
                    let e = bids[start].0;
                    merged.clear();
                    for &(_, i, offer, from_lo) in &bids[start..end] {
                        if let Some(last) = merged.last_mut() {
                            if last.0 == i {
                                last.1 += offer;
                                last.2 += from_lo;
                                continue;
                            }
                        }
                        merged.push((i, offer, from_lo));
                    }
                    let (u, v) = g.endpoints(e);
                    // find best bidder (lowest partition id wins ties, as
                    // the dense argmax did)
                    let mut best = u32::MAX;
                    let mut best_offer = 0.0f64;
                    for &(i, offer, _) in &merged {
                        if offer > best_offer {
                            best_offer = offer;
                            best = i;
                        }
                    }
                    let cur = owner[e as usize];
                    let cur_offer = merged
                        .iter()
                        .find(|&&(i, _, _)| i == cur)
                        .map(|&(_, o, _)| o)
                        .unwrap_or(0.0);
                    let sold = if cur == FREE {
                        best != u32::MAX && best_offer >= 1.0
                    } else {
                        // DFEPC raid: a poor bidder can buy an owned
                        // (rich) edge by strictly outbidding the owner's
                        // committed funding.
                        best != u32::MAX
                            && best != cur
                            && best_offer >= 1.0
                            && poor
                                .map(|p| p[best as usize])
                                .unwrap_or(false)
                            && rich.map(|r| r[cur as usize]).unwrap_or(false)
                            && best_offer > cur_offer
                    };
                    let new_owner = if sold { best } else { cur };
                    let before = out.credits.len();
                    for &(i, offer, from_lo) in &merged {
                        if offer <= 0.0 {
                            continue;
                        }
                        if sold && i == best {
                            // winner pays 1, remainder split half/half
                            let rem = (offer - 1.0) * 0.5;
                            out.credits.push((i, u, rem));
                            out.credits.push((i, v, rem));
                        } else if !sold && i == new_owner {
                            // own-edge circulation: half/half
                            out.credits.push((i, u, offer * 0.5));
                            out.credits.push((i, v, offer * 0.5));
                        } else {
                            // exact refund to contributors
                            out.credits.push((i, u, from_lo));
                            out.credits.push((i, v, offer - from_lo));
                        }
                    }
                    let n_credits = (out.credits.len() - before) as u32;
                    out.sales.push((
                        e,
                        if sold { best } else { FREE },
                        n_credits,
                    ));
                }
            });
        }
        // serial apply in edge order: ownership first, then that edge's
        // credits — exactly the sequential interleaving
        for out in &outs2 {
            let mut credit_idx = 0usize;
            for &(e, winner, n_credits) in &out.sales {
                if winner != FREE {
                    let (u, v) = g.endpoints(e);
                    let (u, v) = (u as usize, v as usize);
                    let cur = self.owner[e as usize];
                    if cur != FREE {
                        self.sizes[cur as usize] -= 1;
                    } else {
                        self.free_edges -= 1;
                        self.free_deg[u] -= 1;
                        self.free_deg[v] -= 1;
                    }
                    self.owner[e as usize] = winner;
                    self.sizes[winner as usize] += 1;
                    self.anchor[winner as usize] = u;
                }
                for &(i, w, amount) in
                    &out.credits[credit_idx..credit_idx + n_credits as usize]
                {
                    self.credit(i as usize, w as usize, amount);
                }
                credit_idx += n_credits as usize;
            }
        }
        if self.frontier_first {
            self.pool_at_frontier(g);
        }
        self.rounds += 1;
    }

    /// Add funds to (partition, vertex), registering the holder.
    #[inline]
    pub(crate) fn credit(&mut self, i: usize, v: usize, amount: f64) {
        if amount <= 0.0 {
            return;
        }
        let cell = &mut self.money[i][v];
        if *cell <= 0.0 {
            self.holders[i].push(v as u32);
        }
        *cell += amount;
    }

    /// Intra-partition money transport: collect funding stuck on interior
    /// vertices (no incident free edge) and re-park it on the partition's
    /// frontier vertices. Conservation-exact.
    ///
    /// Justification: each partition is one worker — in the ETSCH/Hadoop
    /// deployment the partition's vertex ledger is local state, so moving
    /// money within the region costs nothing and needs no network round.
    /// Without this, interior funding random-walks the owned region
    /// (Alg. 4 splits across owned edges) and the end-game livelocks with
    /// frontier offers stuck below 1 unit. Disabled in the literal-Alg.4
    /// ablation (`frontier_first = false`).
    fn pool_at_frontier(&mut self, g: &Graph) {
        // Each partition's TRUE frontier: region vertices (incident to an
        // owned edge) that also touch a free edge. Cash must be routed
        // there even if the partition's refunds parked it elsewhere in the
        // region — the worker owns the whole ledger locally, so this costs
        // no communication. Driven by the incrementally-maintained live
        // vertex list, so the scan is O(live frontier * deg), shrinking
        // as coverage grows. The scan runs in parallel chunks; duplicate
        // (vertex, partition) discoveries are canonicalized by the
        // sort+dedup below, so no shared visit-stamp state is needed and
        // the outcome is independent of chunking and thread count.
        let free_deg = &self.free_deg;
        self.live_vertices.retain(|&w| free_deg[w as usize] > 0);
        const LIVE_CHUNK: usize = 2048;
        let mut found: Vec<Vec<(u32, u32)>> = Vec::new();
        found.resize_with(
            self.live_vertices.len().div_ceil(LIVE_CHUNK),
            Vec::new,
        );
        {
            let live = &self.live_vertices;
            let owner = &self.owner;
            crate::util::pool::run_mut(
                &mut found,
                &|c, out: &mut Vec<(u32, u32)>| {
                    let lo = c * LIVE_CHUNK;
                    let hi = ((c + 1) * LIVE_CHUNK).min(live.len());
                    for &w in &live[lo..hi] {
                        // cheap adjacent-duplicate filter; exact dedup
                        // happens in the per-partition sort below
                        let mut last = FREE;
                        for &(_, e2) in g.neighbors(w) {
                            let p = owner[e2 as usize];
                            if p != FREE && p != last {
                                last = p;
                                out.push((p, w));
                            }
                        }
                    }
                },
            );
        }
        let mut frontier_of: Vec<Vec<usize>> = vec![Vec::new(); self.k];
        for chunk in &found {
            for &(p, w) in chunk {
                frontier_of[p as usize].push(w as usize);
            }
        }
        // per-partition distribution: each task owns its partition's
        // ledger (money + holders are disjoint across partitions)
        let mut tasks: Vec<(&mut Money, &mut Vec<u32>, Vec<usize>)> = self
            .money
            .iter_mut()
            .zip(self.holders.iter_mut())
            .zip(frontier_of)
            .map(|((m, h), f)| (m, h, f))
            .collect();
        crate::util::pool::run_mut(
            &mut tasks,
            &|_, task: &mut (&mut Money, &mut Vec<u32>, Vec<usize>)| {
                let money_i: &mut Vec<f64> = &mut *task.0;
                let holders_i: &mut Vec<u32> = &mut *task.1;
                let frontier: &mut Vec<usize> = &mut task.2;
                // collect the partition's entire liquid cash (region
                // locality: money of partition i only ever sits on V_i)
                let mut pool = 0.0f64;
                let mut first_holder: Option<usize> = None;
                let mut hs = std::mem::take(holders_i);
                hs.sort_unstable();
                hs.dedup();
                for &hv in &hs {
                    let v = hv as usize;
                    let c = money_i[v];
                    if c <= 0.0 {
                        continue;
                    }
                    first_holder = first_holder.or(Some(v));
                    pool += c;
                    money_i[v] = 0.0;
                }
                if pool <= 0.0 {
                    return;
                }
                if frontier.is_empty() {
                    // boxed in: re-deposit on the first holder — stays
                    // inside the region; the DFEPC raid dynamic is what
                    // unboxes it
                    let fh = first_holder.unwrap();
                    money_i[fh] += pool;
                    holders_i.push(fh as u32);
                    return;
                }
                // greedy concentration: fund vertices with the cheapest
                // frontier first — each gets exactly enough to bid 1 unit
                // per free incident edge; leftovers spread equally as
                // headroom. Interleaved owners can record a vertex twice —
                // dedup before the greedy fill.
                frontier.sort_unstable();
                frontier.dedup();
                frontier.sort_unstable_by_key(|&v| free_deg[v]);
                let mut remaining = pool;
                let mut funded = 0usize;
                for &v in frontier.iter() {
                    let need = free_deg[v] as f64 * 1.0001;
                    if remaining < need {
                        break;
                    }
                    money_i[v] += need;
                    holders_i.push(v as u32);
                    remaining -= need;
                    funded += 1;
                }
                if funded == 0 {
                    // cannot cover even the cheapest vertex: concentrate
                    // all on it so accumulation crosses the threshold
                    money_i[frontier[0]] += remaining;
                    holders_i.push(frontier[0] as u32);
                } else {
                    let per = remaining / funded as f64;
                    for &v in &frontier[..funded] {
                        money_i[v] += per;
                    }
                }
            },
        );
    }

    /// Step 3 (Alg. 6): the coordinator injects funding inversely
    /// proportional to current size, spread across the vertices where the
    /// partition already has a presence.
    pub fn coordinator_step(&mut self, cap: f64) {
        let avg = self.sizes.iter().sum::<usize>() as f64 / self.k as f64;
        for i in 0..self.k {
            let size = self.sizes[i] as f64;
            // inversely proportional to size, plus one base unit per round
            // so end-game purchases (1-unit edges at exhausted frontiers)
            // stay injection-paced at ~k edges/round rather than ~1
            let units = if size < 1.0 {
                cap
            } else {
                (avg / size + 1.0).min(cap)
            };
            if units <= 0.0 {
                continue;
            }
            // distribute between all vertices with positive committed funds
            let mut hs = std::mem::take(&mut self.holders[i]);
            hs.sort_unstable();
            hs.dedup();
            let money_i = &mut self.money[i];
            let mut live = 0usize;
            for &v in &hs {
                if money_i[v as usize] > 0.0 {
                    live += 1;
                }
            }
            if live == 0 {
                // partition spent everything: deposit on its last
                // purchase's endpoint so it keeps receiving funding
                // (skipping here would freeze the partition for good)
                let a = self.anchor[i];
                self.holders[i] = hs;
                self.credit(i, a, units);
                continue;
            }
            let per = units / live as f64;
            for &v in &hs {
                if money_i[v as usize] > 0.0 {
                    money_i[v as usize] += per;
                }
            }
            self.holders[i] = hs;
        }
    }

    /// Total money across all partitions (the conservation invariant).
    #[allow(dead_code)] // exercised by the conservation tests
    pub fn total_money(&self) -> f64 {
        self.money.iter().map(|mv| mv.iter().sum::<f64>()).sum()
    }
}

impl Dfep {
    /// Run DFEP, returning the partition plus the per-round trace of free
    /// edges (used by tests and the bench harness).
    pub fn run_traced(
        &self,
        g: &Graph,
        k: usize,
        seed: u64,
    ) -> (EdgePartition, Vec<usize>) {
        assert!(k >= 1 && g.edge_count() > 0);
        let mut rng = Rng::new(seed);
        let initial =
            self.initial_fraction * g.edge_count() as f64 / k as f64;
        let mut st = DfepState::new(g, k, initial.max(1.0), &mut rng);
        st.frontier_first = self.frontier_first;
        let mut trace = Vec::new();
        let mut stall = 0usize;
        while st.free_edges > 0 && st.rounds < self.max_rounds {
            let before = st.free_edges;
            st.funding_round(g, None, None);
            st.coordinator_step(self.funding_cap);
            trace.push(st.free_edges);
            if st.free_edges == before {
                stall += 1;
                // a component can be unreachable from every start vertex
                // (or funding got stranded): reseed the smallest partition
                // on a free edge, as any practical deployment would.
                if stall >= 3 {
                    reseed_on_free_edge(g, &mut st, &mut rng);
                    stall = 0;
                }
            } else {
                stall = 0;
            }
        }
        let owner = finalize(g, st.owner, k);
        (
            EdgePartition { k, owner, rounds: st.rounds },
            trace,
        )
    }
}

/// Stall recovery. First choice: top up funding *at the frontier* — for
/// each free edge, find a partition owning an adjacent edge and grant it
/// 2 units on the shared endpoint (preserves connectedness: money only
/// lands inside an owned region). Only if some free edges have no owned
/// neighbor at all (disconnected component never reached by any start
/// vertex) does the smallest partition get reseeded there — the one case
/// where a disconnected partition is unavoidable.
pub(crate) fn reseed_on_free_edge(g: &Graph, st: &mut DfepState, rng: &mut Rng) {
    let m = g.edge_count();
    // ONE bounded top-up per invocation (injecting per free edge would
    // counterfeit money and wreck balance): scan free edges from a random
    // offset, boost the smallest adjacent owner at the shared endpoint.
    let start = rng.below(m);
    let mut orphan: Option<u32> = None;
    for off in 0..m {
        let e = ((start + off) % m) as u32;
        if st.owner[e as usize] != FREE {
            continue;
        }
        let (u, v) = g.endpoints(e);
        let mut best: Option<(usize, u32)> = None; // (partition, endpoint)
        for w in [u, v] {
            for &(_, e2) in g.neighbors(w) {
                let o = st.owner[e2 as usize];
                if o != FREE {
                    let i = o as usize;
                    if best
                        .map(|(b, _)| st.sizes[i] < st.sizes[b])
                        .unwrap_or(true)
                    {
                        best = Some((i, w));
                    }
                }
            }
        }
        if let Some((i, w)) = best {
            st.credit(i, w as usize, 2.0);
            return;
        }
        orphan = orphan.or(Some(e));
    }
    if let Some(e) = orphan {
        // free edges exist but none touches an owned region: an
        // unreachable component — reseed the smallest partition there
        // (the one unavoidable connectedness exception; disconnected
        // inputs only)
        let smallest = (0..st.k).min_by_key(|&i| st.sizes[i]).unwrap();
        let (u, v) = g.endpoints(e);
        let w = if rng.chance(0.5) { u } else { v };
        st.credit(smallest, w as usize, 2.0);
    }
}

/// Assign any still-free edges (max_rounds hit) to the smaller adjacent
/// partition so the result is always a complete partitioning.
pub(crate) fn finalize(g: &Graph, owner: Vec<u32>, k: usize) -> Vec<u32> {
    let mut owner = owner;
    let mut sizes = vec![0usize; k];
    for &p in &owner {
        if p != FREE {
            sizes[p as usize] += 1;
        }
    }
    loop {
        let mut changed = false;
        let mut remaining = false;
        for e in 0..owner.len() {
            if owner[e] != FREE {
                continue;
            }
            let (u, v) = g.endpoints(e as u32);
            // smallest partition among those owning an adjacent edge
            let mut best: Option<u32> = None;
            for w in [u, v] {
                for &(_, e2) in g.neighbors(w) {
                    let p = owner[e2 as usize];
                    if p != FREE
                        && best.map(|b| sizes[p as usize] < sizes[b as usize])
                            .unwrap_or(true)
                    {
                        best = Some(p);
                    }
                }
            }
            if let Some(p) = best {
                owner[e] = p;
                sizes[p as usize] += 1;
                changed = true;
            } else {
                remaining = true;
            }
        }
        if !remaining {
            break;
        }
        if !changed {
            // isolated free component with no partitioned neighbor at all:
            // give it to the globally smallest partition
            let smallest =
                (0..k).min_by_key(|&i| sizes[i]).unwrap() as u32;
            for o in owner.iter_mut() {
                if *o == FREE {
                    *o = smallest;
                    sizes[smallest as usize] += 1;
                }
            }
            break;
        }
    }
    owner
}


/// Instrumented run for development (prints round diagnostics).
pub fn debug_run(g: &Graph, k: usize, seed: u64) {
    let cfg = Dfep::default();
    let mut rng = Rng::new(seed);
    let initial = cfg.initial_fraction * g.edge_count() as f64 / k as f64;
    let mut st = DfepState::new(g, k, initial.max(1.0), &mut rng);
    let mut stall = 0usize;
    while st.free_edges > 0 && st.rounds < 400 {
        let before = st.free_edges;
        st.funding_round(g, None, None);
        st.coordinator_step(cfg.funding_cap);
        if st.rounds % 10 == 0 || st.free_edges < 30 {
            let money: Vec<i64> = st.money.iter().map(|m| m.iter().sum::<f64>() as i64).collect();
            println!("round {} free {} sizes {:?} money {:?}", st.rounds, st.free_edges, st.sizes, money);
        }
        if st.free_edges == before { stall += 1; if stall >= 3 { reseed_on_free_edge(g, &mut st, &mut rng); stall = 0; } } else { stall = 0; }
    }
}

impl Partitioner for Dfep {
    fn partition_graph(
        &self,
        g: &Graph,
        k: usize,
        seed: u64,
    ) -> Result<EdgePartition> {
        check_k(k)?;
        if g.edge_count() == 0 {
            bail!("DFEP cannot partition an empty graph (0 edges)");
        }
        Ok(self.run_traced(g, k, seed).0)
    }

    fn name(&self) -> &'static str {
        "DFEP"
    }
}

#[cfg(test)]
mod tests {
#[test]
fn money_audit_per_partition() {
    use crate::graph::generators::GraphKind;
    use crate::partition::dfep::DfepState;
    use crate::util::rng::Rng;
    let g = GraphKind::PowerlawCluster { n: 5000, m: 8, p: 0.4 }.generate(42);
    let k = 8;
    let mut rng = Rng::new(1);
    let initial = g.edge_count() as f64 / k as f64;
    let mut st = DfepState::new(&g, k, initial, &mut rng);
    let mut injected = vec![0.0; k];
    for round in 0..80 {
        st.funding_round(&g, None, None);
        let before: Vec<f64> = st.money.iter().map(|m| m.iter().sum()).collect();
        st.coordinator_step(10.0);
        let after: Vec<f64> = st.money.iter().map(|m| m.iter().sum()).collect();
        for i in 0..k { injected[i] += after[i] - before[i]; }
        for i in 0..k {
            let expect = initial + injected[i] - st.sizes[i] as f64;
            let actual: f64 = st.money[i].iter().sum();
            if (expect - actual).abs() > 1.0 {
                println!("round {} part {}: expect {:.1} actual {:.1}", round, i, expect, actual);
                return;
            }
        }
        if st.free_edges == 0 { println!("done round {} sizes {:?} injected {:?}", round, st.sizes, injected.iter().map(|x| *x as i64).collect::<Vec<_>>()); return; }
    }
    panic!("did not converge: free={} sizes={:?}", st.free_edges, st.sizes);
}

#[test]
fn money_audit() {
    use crate::graph::generators::GraphKind;
    use crate::partition::dfep::DfepState;
    use crate::util::rng::Rng;
    let g = GraphKind::PowerlawCluster { n: 5000, m: 8, p: 0.4 }.generate(42);
    let k = 8;
    let mut rng = Rng::new(1);
    let initial = g.edge_count() as f64 / k as f64;
    let mut st = DfepState::new(&g, k, initial, &mut rng);
    let mut injected = 0.0;
    for round in 0..60 {
        st.funding_round(&g, None, None);
        let before = st.total_money();
        st.coordinator_step(10.0);
        injected += st.total_money() - before;
        let bought: usize = st.sizes.iter().sum();
        let expect = initial * k as f64 + injected - bought as f64;
        let actual = st.total_money();
        if (expect - actual).abs() > 1.0 {
            println!("round {}: expect {:.1} actual {:.1} diff {:.1}", round, expect, actual, actual-expect);
        }
        if st.free_edges == 0 { println!("done at {} sizes {:?}", round, st.sizes); break; }
    }
}

    use super::*;
    use crate::graph::generators::GraphKind;
    use crate::partition::metrics;

    fn small_world() -> Graph {
        GraphKind::PowerlawCluster { n: 400, m: 4, p: 0.3 }.generate(5)
    }

    #[test]
    fn produces_complete_partitioning() {
        let g = small_world();
        let p = Dfep::default().partition_graph(&g, 8, 1).unwrap();
        p.validate(&g).unwrap();
        assert!(p.owner.iter().all(|&o| (o as usize) < 8));
        assert_eq!(p.owner.len(), g.edge_count());
    }

    #[test]
    fn deterministic_per_seed() {
        let g = small_world();
        let a = Dfep::default().partition_graph(&g, 4, 9).unwrap();
        let b = Dfep::default().partition_graph(&g, 4, 9).unwrap();
        assert_eq!(a.owner, b.owner);
        let c = Dfep::default().partition_graph(&g, 4, 10).unwrap();
        assert_ne!(a.owner, c.owner);
    }

    #[test]
    fn partitions_are_reasonably_balanced() {
        let g = small_world();
        let p = Dfep::default().partition_graph(&g, 4, 2).unwrap();
        let report = metrics::evaluate(&g, &p);
        assert!(
            report.nstdev < 0.6,
            "nstdev {} too high (sizes {:?})",
            report.nstdev,
            p.sizes()
        );
    }

    #[test]
    fn partitions_are_connected() {
        let g = small_world();
        let p = Dfep::default().partition_graph(&g, 6, 3).unwrap();
        let disc = metrics::disconnected_fraction(&g, &p);
        assert_eq!(disc, 0.0, "plain DFEP must give connected partitions");
    }

    #[test]
    fn funding_is_conserved_per_round() {
        let g = small_world();
        let mut rng = Rng::new(4);
        let mut st = DfepState::new(&g, 4, 100.0, &mut rng);
        let before = st.total_money();
        st.funding_round(&g, None, None);
        let bought: usize = st.sizes.iter().sum();
        let after = st.total_money() + bought as f64;
        assert!(
            (before - after).abs() < 1e-6 * before.max(1.0),
            "money leaked: {before} -> {after}"
        );
    }

    #[test]
    fn free_edges_monotone_decreasing() {
        let g = small_world();
        let (_, trace) = Dfep::default().run_traced(&g, 4, 6);
        for w in trace.windows(2) {
            assert!(w[1] <= w[0], "free edges increased: {trace:?}");
        }
        assert_eq!(*trace.last().unwrap(), 0);
    }

    #[test]
    fn single_partition_takes_everything() {
        let g = small_world();
        let p = Dfep::default().partition_graph(&g, 1, 1).unwrap();
        assert!(p.owner.iter().all(|&o| o == 0));
    }

    #[test]
    fn rounds_grow_with_diameter() {
        // Fig 6d shape: rounds rise with diameter. Single runs are noisy
        // (the end-game is injection-paced on both graphs), so compare
        // means over several seeds with a strong diameter contrast.
        let road = GraphKind::RoadNetwork {
            rows: 14, cols: 14, drop: 0.2, subdiv: 5, shortcuts: 0,
        }
        .generate(8);
        let ball = GraphKind::ErdosRenyi {
            n: road.vertex_count(),
            m: road.edge_count(),
        }
        .generate(8);
        let mean = |g: &Graph| -> f64 {
            (1u64..=5)
                .map(|s| {
                    Dfep::default()
                        .partition_graph(g, 4, s)
                        .unwrap()
                        .rounds as f64
                })
                .sum::<f64>()
                / 5.0
        };
        let r_road = mean(&road);
        let r_ball = mean(&ball);
        assert!(
            r_road > r_ball * 1.3,
            "road rounds {r_road} should clearly exceed ER rounds {r_ball}"
        );
    }
}
