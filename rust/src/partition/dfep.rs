//! DFEP — Distributed Funding-based Edge Partitioning (paper §IV).
//!
//! Each of the `k` partitions starts with an equal amount of *funding*
//! placed on a random vertex. Every round:
//!
//! 1. **Step 1** (per vertex, Alg. 4): each vertex splits each partition's
//!    funding equally among incident edges that are *free or owned by that
//!    partition*.
//! 2. **Step 2** (per edge, Alg. 5): each free edge is sold to the highest
//!    bidder if the bid is >= 1 unit; the winner pays 1 unit, the remainder
//!    returns half/half to the endpoints; losing bids return to the
//!    vertices that contributed them; bids on an edge you already own
//!    return half/half (the funding keeps flowing through the owned
//!    region toward the frontier).
//! 3. **Step 3** (coordinator, Alg. 6): partitions smaller than average
//!    receive `min(cap, avg/|E_i| )` fresh units per vertex they fund —
//!    the catch-up mechanism that makes final sizes balanced.
//!
//! The implementation is single-process but *round-synchronous*: state is
//! updated exactly as the distributed version would (two message-free
//! half-steps per round), so round counts — the paper's synchronization
//! metric — are faithful. The MapReduce-shaped version used for the EC2
//! experiments lives in [`crate::cluster::dfep_mr`], and an XLA-offloaded
//! round (L2 `funding_step` artifact) in [`crate::runtime::xla_engine`].
//!
//! # Round engine memory model
//!
//! The round loop is the crate's hottest path and runs **allocation-free
//! in steady state** (pinned by `tests/alloc_budget.rs`):
//!
//! - every per-round buffer — step-1 shard outputs, the bid buffer, the
//!   per-edge group index, step-2 auction outputs, the frontier-scan
//!   chunks and the per-partition frontier lists — lives in a persistent
//!   `RoundScratch` owned by [`DfepState`] and is cleared, never freed,
//!   between rounds;
//! - bids are ordered by a **stable two-pass LSD counting sort** on the
//!   edge id (`radix_sort_bids_by_edge`) instead of a comparison sort:
//!   the canonical bid order (edge asc, then partition asc, then holder
//!   registration order) pins every `f64` accumulation in step 2;
//! - the old `sort_unstable` + `dedup` canonicalizations of holder and
//!   frontier lists are replaced by epoch-stamped `u32` visit arrays: the
//!   canonical holder order is **registration order** (first time a
//!   vertex received cash since the last canonicalization) and the
//!   canonical frontier fill order is `(free_deg, vertex id)` ascending —
//!   both total orders, independent of thread count;
//! - the money ledger is one flat stride-`n` allocation
//!   ([`super::money::MoneyLedger`]) shared with the DFEPC variant, the
//!   cluster simulator and the XLA engine.

use super::money::MoneyLedger;
use super::{check_k, EdgePartition, Partitioner};
use crate::bail;
use crate::graph::Graph;
use crate::util::error::Result;
use crate::util::rng::Rng;

/// One step-1 bid: `(edge, partition, offer, contribution from the
/// edge's lower endpoint)`.
pub(crate) type Bid = (u32, u32, f64, f64);

/// Distinct values per LSD digit (16 bits — at most two passes cover any
/// `u32` edge id).
const RADIX: usize = 1 << 16;

/// Tunables (defaults follow the paper's implementation notes).
#[derive(Clone, Debug)]
pub struct Dfep {
    /// Cap on per-round funding for a small partition ("10 in our
    /// implementation") — avoids overfunding during the first rounds.
    pub funding_cap: f64,
    /// Initial funding, as a fraction of the optimal partition size
    /// (`|E|/k`). 1.0 = "what would be needed to buy an amount of edges
    /// equal to the optimal sized partition".
    pub initial_fraction: f64,
    /// Safety bound on rounds (the algorithm converges far earlier).
    pub max_rounds: usize,
    /// Frontier-first funding: a vertex holding an incident *buyable*
    /// edge bids only on buyable edges, instead of also diluting its
    /// funding across edges the partition already owns (the literal
    /// Alg. 4 split). The literal split lets committed money random-walk
    /// the interior, offers at the frontier stagnate below 1 unit and the
    /// end-game livelocks; concentrating at the frontier restores the
    /// wave-like growth the paper's round counts imply. `false` gives the
    /// literal pseudocode (kept as an ablation — see the `hotpath` bench).
    pub frontier_first: bool,
}

impl Default for Dfep {
    fn default() -> Self {
        Dfep {
            funding_cap: 10.0,
            initial_fraction: 1.0,
            max_rounds: 10_000,
            frontier_first: true,
        }
    }
}

/// Step-1 shard output (one holder chunk of one partition). Reused
/// across rounds via [`RoundScratch`]; `clear` keeps every capacity.
#[derive(Default)]
struct Shard1Out {
    /// Bids emitted by this chunk's holders.
    bids: Vec<Bid>,
    /// Holders with cash but no eligible edge (stay funded).
    stranded: Vec<u32>,
    /// Holders whose cash became bids (zeroed in apply).
    spent: Vec<u32>,
    /// Per-holder eligible-edge workspace.
    eligible: Vec<u32>,
}

impl Shard1Out {
    fn clear(&mut self) {
        self.bids.clear();
        self.stranded.clear();
        self.spent.clear();
        self.eligible.clear();
    }

    fn bytes(&self) -> usize {
        self.bids.capacity() * std::mem::size_of::<Bid>()
            + (self.stranded.capacity()
                + self.spent.capacity()
                + self.eligible.capacity())
                * std::mem::size_of::<u32>()
    }
}

/// Step-2 shard output (one run of bid-receiving edges). Reused across
/// rounds via [`RoundScratch`].
#[derive(Default)]
struct Shard2Out {
    /// (edge, winner-or-FREE, number of credit entries).
    sales: Vec<(u32, u32, u32)>,
    /// (partition, vertex, amount) in sequential credit order.
    credits: Vec<(u32, u32, f64)>,
    /// Per-edge merged-bid workspace: (partition, offer, from_lo).
    merged: Vec<(u32, f64, f64)>,
}

impl Shard2Out {
    fn clear(&mut self) {
        self.sales.clear();
        self.credits.clear();
        self.merged.clear();
    }

    fn bytes(&self) -> usize {
        self.sales.capacity() * std::mem::size_of::<(u32, u32, u32)>()
            + self.credits.capacity()
                * std::mem::size_of::<(u32, u32, f64)>()
            + self.merged.capacity()
                * std::mem::size_of::<(u32, f64, f64)>()
    }
}

/// Persistent scratch for [`DfepState::funding_round`]: every buffer the
/// round loop needs, allocated once and grown to its high-water mark, so
/// steady-state rounds perform **zero** heap allocations (asserted by
/// the counting-allocator test in `tests/alloc_budget.rs`).
pub(crate) struct RoundScratch {
    /// Canonicalized per-partition holder lists (registration order).
    holder_lists: Vec<Vec<u32>>,
    /// Step-1 shards: (partition, chunk lo, chunk hi) into
    /// `holder_lists[partition]`.
    shards: Vec<(u32, u32, u32)>,
    /// Step-1 shard outputs (`shards.len()` used per round).
    outs1: Vec<Shard1Out>,
    /// Concatenated bids, later radix-sorted by edge id.
    bids: Vec<Bid>,
    /// Radix scatter buffer.
    bids_tmp: Vec<Bid>,
    /// Radix histogram — `min(|E|, RADIX)` entries: small graphs only
    /// ever touch digits below their edge count, and two-pass graphs
    /// (|E| > `RADIX`) need exactly `RADIX` slots.
    counts: Vec<u32>,
    /// Per-edge `[start, end)` ranges into `bids`.
    groups: Vec<(u32, u32)>,
    /// Step-2 shard outputs.
    outs2: Vec<Shard2Out>,
    /// How many `outs2` entries the current round filled.
    outs2_used: usize,
    /// Per-vertex visit stamps for holder canonicalization (a vertex is
    /// recorded for lane `p` of a pass iff `stamp[v] == base + p`).
    stamp: Vec<u32>,
    /// Next unissued stamp value (wraps by re-zeroing `stamp`).
    epoch: u32,
    /// Per-partition visit stamp for the frontier merge: `seen_parts[p]`
    /// is the last vertex recorded as partition `p`'s frontier. Sound
    /// because the scan emits each live vertex's discoveries
    /// consecutively and live vertices are distinct.
    seen_parts: Vec<u32>,
    /// Frontier-scan chunk outputs: (partition, vertex) discoveries.
    found: Vec<Vec<(u32, u32)>>,
    /// Per-partition frontier vertex lists (first-discovery order).
    frontier_of: Vec<Vec<u32>>,
    /// High-water heap footprint of all scratch element buffers.
    peak_bytes: usize,
}

impl RoundScratch {
    fn new(n: usize, k: usize, m: usize) -> RoundScratch {
        let mut holder_lists = Vec::with_capacity(k);
        holder_lists.resize_with(k, Vec::new);
        let mut frontier_of = Vec::with_capacity(k);
        frontier_of.resize_with(k, Vec::new);
        RoundScratch {
            holder_lists,
            shards: Vec::new(),
            outs1: Vec::new(),
            bids: Vec::new(),
            bids_tmp: Vec::new(),
            counts: vec![0; m.clamp(1, RADIX)],
            groups: Vec::new(),
            outs2: Vec::new(),
            outs2_used: 0,
            stamp: vec![0; n],
            epoch: 0,
            seen_parts: vec![u32::MAX; k],
            found: Vec::new(),
            frontier_of,
            peak_bytes: 0,
        }
    }

    /// Element-buffer bytes currently held (excludes the fixed spines).
    fn current_bytes(&self) -> usize {
        use std::mem::size_of;
        fn nested<T>(v: &[Vec<T>]) -> usize {
            v.iter()
                .map(|x| x.capacity() * size_of::<T>())
                .sum::<usize>()
        }
        nested(&self.holder_lists)
            + self.shards.capacity() * size_of::<(u32, u32, u32)>()
            + self.outs1.iter().map(Shard1Out::bytes).sum::<usize>()
            + self.bids.capacity() * size_of::<Bid>()
            + self.bids_tmp.capacity() * size_of::<Bid>()
            + self.counts.capacity() * size_of::<u32>()
            + self.groups.capacity() * size_of::<(u32, u32)>()
            + self.outs2.iter().map(Shard2Out::bytes).sum::<usize>()
            + self.stamp.capacity() * size_of::<u32>()
            + self.seen_parts.capacity() * size_of::<u32>()
            + nested(&self.found)
            + nested(&self.frontier_of)
    }

    fn note_peak(&mut self) {
        self.peak_bytes = self.peak_bytes.max(self.current_bytes());
    }

    /// Reshape for a run on a graph with `n` vertices, `m` edges and `k`
    /// partitions, reusing every buffer (grow-only capacities). After
    /// this the scratch is observably equivalent to
    /// `RoundScratch::new(n, k, m)`: the stamp/epoch pair keeps counting
    /// monotonically (the [`begin_pass`] contract only needs
    /// `stamp[v] <= epoch`, re-zeroing when `n` changes), `seen_parts`
    /// and the radix histogram are re-filled at use time, and
    /// `peak_bytes` deliberately carries across runs — it is the
    /// high-water mark the batch engine reports per lane.
    fn reset(&mut self, n: usize, k: usize, m: usize) {
        self.holder_lists.truncate(k);
        for l in &mut self.holder_lists {
            l.clear();
        }
        self.holder_lists.resize_with(k, Vec::new);
        self.shards.clear();
        // outs1/outs2 entries are cleared at use; keep their capacities
        self.bids.clear();
        self.bids_tmp.clear();
        self.counts.clear();
        self.counts.resize(m.clamp(1, RADIX), 0);
        self.groups.clear();
        self.outs2_used = 0;
        if self.stamp.len() != n {
            self.stamp.clear();
            self.stamp.resize(n, 0);
            self.epoch = 0;
        }
        self.seen_parts.clear();
        self.seen_parts.resize(k, u32::MAX);
        for f in &mut self.found {
            f.clear();
        }
        self.frontier_of.truncate(k);
        for f in &mut self.frontier_of {
            f.clear();
        }
        self.frontier_of.resize_with(k, Vec::new);
    }
}

/// Reserve `span` fresh stamp values, returning the base id: vertex `v`
/// counts as visited for lane `p` of this pass iff
/// `stamp[v] == base + p`. Handles `u32` wrap-around by re-zeroing the
/// stamp array (base is always >= 1, so zeroed entries never collide).
fn begin_pass(stamp: &mut [u32], epoch: &mut u32, span: u32) -> u32 {
    if *epoch > u32::MAX - span {
        stamp.fill(0);
        *epoch = 0;
    }
    let base = *epoch + 1;
    *epoch += span;
    base
}

/// Stable two-pass LSD counting sort of `bids` by edge id.
///
/// This pins **the** canonical bid order that fixes every `f64`
/// accumulation in step 2: edge id ascending; within one edge, partition
/// id ascending; within one (edge, partition) key, holder registration
/// order. The sort keys only on the edge id — the partition and holder
/// sub-orders are inherited from the input sequence, which step 1 emits
/// partition-major in holder order, and stability preserves them.
///
/// `tmp` and `counts` are caller-owned scratch; `counts` needs
/// `min(edge_bound, RADIX)` slots (which also covers the high-digit
/// pass: its range never exceeds `RADIX`, and two-pass inputs imply
/// `edge_bound > RADIX`). Steady-state calls allocate nothing beyond
/// `tmp`'s high-water mark. Graphs with at most 2^16 edges finish after
/// the low-digit pass.
///
/// Positions are `u32` (like the group index built on top of the sorted
/// order), which caps one round at 2^32 bids — asserted below rather
/// than wrapping silently.
pub(crate) fn radix_sort_bids_by_edge(
    bids: &mut Vec<Bid>,
    tmp: &mut Vec<Bid>,
    counts: &mut [u32],
    edge_bound: u32,
) {
    // a real assert, not debug_assert: the ceiling is only reachable in
    // release-scale runs, exactly where debug asserts compile out
    assert!(
        bids.len() <= u32::MAX as usize,
        "a round emitted {} bids, above the u32 position ceiling",
        bids.len()
    );
    if bids.len() <= 1 {
        return;
    }
    tmp.resize(bids.len(), (0, 0, 0.0, 0.0));
    // pass 1: low 16 bits, bids -> tmp
    let lo_range = (edge_bound as usize).min(RADIX);
    assert!(
        counts.len() >= lo_range,
        "radix histogram has {} slots, need {lo_range}",
        counts.len()
    );
    counts[..lo_range].fill(0);
    for b in bids.iter() {
        counts[(b.0 & 0xFFFF) as usize] += 1;
    }
    let mut sum = 0u32;
    for c in counts[..lo_range].iter_mut() {
        let t = *c;
        *c = sum;
        sum += t;
    }
    for &b in bids.iter() {
        let d = (b.0 & 0xFFFF) as usize;
        tmp[counts[d] as usize] = b;
        counts[d] += 1;
    }
    if edge_bound as usize <= RADIX {
        // every edge id fits one digit: tmp is fully sorted
        std::mem::swap(bids, tmp);
        return;
    }
    // pass 2: high 16 bits, tmp -> bids (stable, so the low-digit order
    // within each high digit is preserved)
    let hi_range = ((edge_bound - 1) >> 16) as usize + 1;
    counts[..hi_range].fill(0);
    for b in tmp.iter() {
        counts[(b.0 >> 16) as usize] += 1;
    }
    let mut sum = 0u32;
    for c in counts[..hi_range].iter_mut() {
        let t = *c;
        *c = sum;
        sum += t;
    }
    for &b in tmp.iter() {
        let d = (b.0 >> 16) as usize;
        bids[counts[d] as usize] = b;
        counts[d] += 1;
    }
}

/// Full mutable state of a DFEP run. Shared with the DFEPC variant, the
/// MapReduce-shaped cluster simulator and the engine-level tests and
/// benches (`tests/alloc_budget.rs`, the `dfep_round` series in the
/// `hotpath` bench).
pub struct DfepState {
    /// Number of partitions.
    pub k: usize,
    /// `owner[e]`: `u32::MAX` (free), or partition id.
    pub owner: Vec<u32>,
    /// Flat per-(partition, vertex) funding ledger (stride = |V|).
    pub money: MoneyLedger,
    /// Edges owned per partition.
    pub sizes: Vec<usize>,
    /// Edges not yet sold.
    pub free_edges: usize,
    /// Rounds executed so far.
    pub rounds: usize,
    /// Frontier-first funding (see [`Dfep::frontier_first`]).
    pub frontier_first: bool,
    /// Last purchase endpoint per partition — the coordinator's deposit
    /// anchor when a partition's liquid cash is exactly zero.
    pub anchor: Vec<usize>,
    /// Per-partition list of vertices that *may* hold cash (push-only,
    /// may contain stale entries and duplicates; consumers re-check the
    /// ledger cell). Keeps every round O(active state), not O(k*n).
    pub holders: Vec<Vec<u32>>,
    /// Number of incident FREE edges per vertex, maintained incrementally
    /// on every purchase (avoids an O(m) scan per round).
    pub free_deg: Vec<u32>,
    /// Vertices with `free_deg > 0` (pruned as they dry up).
    live_vertices: Vec<u32>,
    /// Reusable round buffers (see [`RoundScratch`]).
    scratch: RoundScratch,
}

pub(crate) const FREE: u32 = u32::MAX;

impl DfepState {
    /// Initialize per Alg. 3: each partition starts on a random vertex
    /// holding the full initial funding.
    pub fn new(g: &Graph, k: usize, initial: f64, rng: &mut Rng) -> Self {
        let n = g.vertex_count();
        let mut money = MoneyLedger::new(k, n);
        let mut anchors = Vec::with_capacity(k);
        let mut holders = Vec::with_capacity(k);
        // paper Alg. 3: each partition starts on a random vertex with the
        // full initial funding
        for i in 0..k {
            let v = rng.below(n);
            *money.cell_mut(i, v) = initial;
            anchors.push(v);
            holders.push(vec![v as u32]);
        }
        let mut free_deg = vec![0u32; n];
        for (_, u, v) in g.edge_iter() {
            free_deg[u as usize] += 1;
            free_deg[v as usize] += 1;
        }
        let live_vertices =
            (0..n as u32).filter(|&v| free_deg[v as usize] > 0).collect();
        DfepState {
            k,
            owner: vec![FREE; g.edge_count()],
            money,
            sizes: vec![0; k],
            free_edges: g.edge_count(),
            rounds: 0,
            frontier_first: true,
            anchor: anchors,
            holders,
            free_deg,
            live_vertices,
            scratch: RoundScratch::new(n, k, g.edge_count()),
        }
    }

    /// Re-initialize in place for a fresh run, reusing every buffer —
    /// the ledger, the ownership vector, the degree/holder/frontier
    /// lists and the whole round scratch keep their allocations
    /// (grow-only capacities).
    ///
    /// The post-state is *observably identical* to
    /// [`DfepState::new(g, k, initial, rng)`](Self::new) — including the
    /// `rng` draw sequence (exactly `k` calls to `below(n)`) — which is
    /// what lets the run loops recycle states unconditionally without
    /// perturbing the bit-exact trajectory pinned by
    /// `tests/pool_invariants.rs`. This is the engine half of the batch
    /// facade's steady-state story: after the first variant on a lane,
    /// later same-shape variants run their rounds without a single heap
    /// allocation (`tests/batch.rs`).
    pub fn reset(&mut self, g: &Graph, k: usize, initial: f64, rng: &mut Rng) {
        let n = g.vertex_count();
        let m = g.edge_count();
        self.k = k;
        self.money.reset(k, n);
        self.anchor.clear();
        self.holders.truncate(k);
        for h in &mut self.holders {
            h.clear();
        }
        self.holders.resize_with(k, Vec::new);
        for i in 0..k {
            let v = rng.below(n);
            *self.money.cell_mut(i, v) = initial;
            self.anchor.push(v);
            self.holders[i].push(v as u32);
        }
        self.free_deg.clear();
        self.free_deg.resize(n, 0);
        for (_, u, v) in g.edge_iter() {
            self.free_deg[u as usize] += 1;
            self.free_deg[v as usize] += 1;
        }
        self.live_vertices.clear();
        self.live_vertices
            .extend((0..n as u32).filter(|&v| self.free_deg[v as usize] > 0));
        self.owner.clear();
        self.owner.resize(m, FREE);
        self.sizes.clear();
        self.sizes.resize(k, 0);
        self.free_edges = m;
        self.rounds = 0;
        self.frontier_first = true;
        self.scratch.reset(n, k, m);
    }

    /// Steps 1 + 2 for one round. `poor`/`rich` enable the DFEPC
    /// dynamic: partitions listed in `poor` may also bid on edges owned by
    /// partitions listed in `rich`, stealing them on a strictly higher bid.
    ///
    /// Both steps run data-parallel on the shared [`crate::util::pool`]:
    /// step 1 over fixed-size holder chunks per partition, step 2 over
    /// fixed-size runs of bid-receiving edges. Every shard computes a pure
    /// function of its input slice; mutations (money zeroing, ownership,
    /// refunds) are applied serially in fixed shard order afterwards, so
    /// the round trajectory — including every `f64` accumulation order —
    /// is bit-identical to the sequential execution for any thread count.
    /// All buffers come from the persistent `RoundScratch`; steady-state
    /// rounds allocate nothing.
    ///
    /// Implemented as [`round_bids`](Self::round_bids) (step 1) followed
    /// by [`round_auction`](Self::round_auction) (step 2) with no
    /// ownership mask — the distributed runtime calls the two halves
    /// separately, exchanging the bid list between them.
    pub fn funding_round(
        &mut self,
        g: &Graph,
        poor: Option<&[bool]>,
        rich: Option<&[bool]>,
    ) {
        self.round_bids(g, poor, rich, None);
        self.round_auction(g, poor, rich, None);
    }

    /// Step 1 of one round: emit bids from every partition the caller
    /// owns, leaving them (pre-sort, in the canonical partition-major
    /// order) in the internal bid buffer exposed by
    /// [`pending_bids`](Self::pending_bids).
    ///
    /// `owned` masks the computation to a subset of partitions: a
    /// distributed worker passes its ownership mask (partition `i` owned
    /// by worker `i % W`) so only its partitions' holder lists, ledger
    /// rows and bids are touched; `None` means "owns everything" and is
    /// byte-identical to the historical single-process step 1. The
    /// replicated read-only inputs (`owner`, `free_deg`) are the same on
    /// every worker, so the union of all workers' masked bid lists,
    /// stitched in partition order, equals the unmasked list exactly.
    pub fn round_bids(
        &mut self,
        g: &Graph,
        poor: Option<&[bool]>,
        rich: Option<&[bool]>,
        owned: Option<&[bool]>,
    ) {
        let k = self.k;
        // Step 1 canonicalization: stamp-dedup each partition's holder
        // list, keeping only vertices that still hold cash, in
        // registration order (the documented canonical holder order).
        // Non-owned partitions get an empty list (their holders/ledger
        // live on another worker) and therefore produce no shards below.
        {
            let RoundScratch { holder_lists, stamp, epoch, .. } =
                &mut self.scratch;
            let base = begin_pass(stamp.as_mut_slice(), epoch, k as u32);
            for i in 0..k {
                let tag = base + i as u32;
                let hl = &mut holder_lists[i];
                hl.clear();
                if !owned.map(|o| o[i]).unwrap_or(true) {
                    continue;
                }
                let row = self.money.part(i);
                for &v in &self.holders[i] {
                    let vu = v as usize;
                    if row[vu] > 0.0 && stamp[vu] != tag {
                        stamp[vu] = tag;
                        hl.push(v);
                    }
                }
                self.holders[i].clear();
            }
        }
        // shard = one holder chunk of one partition, in (partition,
        // holder-order) order; chunk size is a constant so the shard list
        // does not depend on the thread count
        const HOLDER_CHUNK: usize = 512;
        {
            let RoundScratch { holder_lists, shards, .. } = &mut self.scratch;
            shards.clear();
            for (i, hs) in holder_lists.iter().enumerate() {
                let mut lo = 0;
                while lo < hs.len() {
                    let hi = (lo + HOLDER_CHUNK).min(hs.len());
                    shards.push((i as u32, lo as u32, hi as u32));
                    lo = hi;
                }
            }
        }
        // Step 1: bids per (partition, edge). Sparse hot path: only
        // vertices in the holder lists are visited, and only edges that
        // actually receive a bid are touched in step 2 — every round is
        // O(active frontier), not O(k * m).
        {
            let RoundScratch { holder_lists, shards, outs1, .. } =
                &mut self.scratch;
            let used = shards.len();
            if outs1.len() < used {
                outs1.resize_with(used, Shard1Out::default);
            }
            for o in &mut outs1[..used] {
                o.clear();
            }
            let money = &self.money;
            let owner = &self.owner;
            let frontier_first = self.frontier_first;
            let shards = &*shards;
            let holder_lists = &*holder_lists;
            crate::util::pool::run_mut(
                &mut outs1[..used],
                &|s, out: &mut Shard1Out| {
                    let (i, lo, hi) = shards[s];
                    let i = i as usize;
                    let money_i = money.part(i);
                    let poor_i = poor.map(|p| p[i]).unwrap_or(false);
                    for &v in &holder_lists[i][lo as usize..hi as usize] {
                        // canonicalization kept only cash-holding vertices
                        let cash = money_i[v as usize];
                        out.eligible.clear();
                        let mut has_buyable = false;
                        for &e in g.neighbor_edges(v) {
                            let o = owner[e as usize];
                            let buyable = o == FREE
                                || (poor_i
                                    && o != i as u32
                                    && rich
                                        .map(|r| r[o as usize])
                                        .unwrap_or(false));
                            if buyable && !has_buyable && frontier_first {
                                // first buyable edge seen: drop own edges
                                // collected so far, fund the frontier only
                                has_buyable = true;
                                out.eligible.clear();
                            }
                            let can = buyable
                                || (o == i as u32
                                    && !(frontier_first && has_buyable));
                            if can {
                                out.eligible.push(e);
                            }
                        }
                        if out.eligible.is_empty() {
                            // stranded funding stays on the vertex
                            out.stranded.push(v);
                            continue;
                        }
                        let share = cash / out.eligible.len() as f64;
                        for &e in &out.eligible {
                            let (u, _) = g.endpoints(e);
                            let from_lo = if u == v { share } else { 0.0 };
                            out.bids.push((e, i as u32, share, from_lo));
                        }
                        out.spent.push(v);
                    }
                },
            );
        }
        // apply step-1 effects and concatenate bids in shard order (equal
        // to the sequential per-partition, per-holder order)
        {
            let RoundScratch { shards, outs1, bids, .. } = &mut self.scratch;
            bids.clear();
            for (s, out) in outs1[..shards.len()].iter_mut().enumerate() {
                let i = shards[s].0 as usize;
                for &v in &out.stranded {
                    self.holders[i].push(v);
                }
                let row = self.money.part_mut(i);
                for &v in &out.spent {
                    row[v as usize] = 0.0;
                }
                bids.append(&mut out.bids);
            }
        }
    }

    /// Step 2 of one round: auction the bids currently in the internal
    /// bid buffer (either left there by [`round_bids`](Self::round_bids)
    /// or installed via [`set_pending_bids`](Self::set_pending_bids)),
    /// then run the frontier pool and advance the round counter.
    ///
    /// The auction itself is a pure function of the replicated state
    /// (`owner`, bid list), so under a mask every worker computes
    /// identical sales and applies identical updates to the replicated
    /// fields (`owner`, `sizes`, `free_edges`, `free_deg`, `anchor`).
    /// Only the ledger writes — credits and the frontier pool — are
    /// masked to owned partitions, because those rows are authoritative
    /// on exactly one worker. With `owned = None` this is byte-identical
    /// to the historical single-process step 2.
    pub fn round_auction(
        &mut self,
        g: &Graph,
        poor: Option<&[bool]>,
        rich: Option<&[bool]>,
        owned: Option<&[bool]>,
    ) {
        // Step 2: auction — only over edges that received bids. Order the
        // per-(edge, partition) contributions with the stable radix sort
        // (canonical order documented there), then compute every edge's
        // outcome in parallel (outcomes only read the pre-auction state:
        // each edge is decided by its own bids) and apply ownership
        // changes + refunds serially in edge order.
        {
            let RoundScratch { bids, bids_tmp, counts, .. } =
                &mut self.scratch;
            radix_sort_bids_by_edge(
                bids,
                bids_tmp,
                counts,
                g.edge_count() as u32,
            );
        }
        {
            let RoundScratch { bids, groups, .. } = &mut self.scratch;
            groups.clear();
            let mut idx = 0usize;
            while idx < bids.len() {
                let e = bids[idx].0;
                let start = idx;
                while idx < bids.len() && bids[idx].0 == e {
                    idx += 1;
                }
                groups.push((start as u32, idx as u32));
            }
        }
        const GROUP_CHUNK: usize = 256;
        {
            let RoundScratch { bids, groups, outs2, outs2_used, .. } =
                &mut self.scratch;
            let used = groups.len().div_ceil(GROUP_CHUNK);
            *outs2_used = used;
            if outs2.len() < used {
                outs2.resize_with(used, Shard2Out::default);
            }
            for o in &mut outs2[..used] {
                o.clear();
            }
            let owner = &self.owner;
            let bids = &*bids;
            let groups = &*groups;
            crate::util::pool::run_mut(
                &mut outs2[..used],
                &|c, out: &mut Shard2Out| {
                    let lo = c * GROUP_CHUNK;
                    let hi = ((c + 1) * GROUP_CHUNK).min(groups.len());
                    for &(start, end) in &groups[lo..hi] {
                        let (start, end) = (start as usize, end as usize);
                        let e = bids[start].0;
                        out.merged.clear();
                        for &(_, i, offer, from_lo) in &bids[start..end] {
                            if let Some(last) = out.merged.last_mut() {
                                if last.0 == i {
                                    last.1 += offer;
                                    last.2 += from_lo;
                                    continue;
                                }
                            }
                            out.merged.push((i, offer, from_lo));
                        }
                        let (u, v) = g.endpoints(e);
                        // find best bidder (lowest partition id wins ties,
                        // as the dense argmax did)
                        let mut best = u32::MAX;
                        let mut best_offer = 0.0f64;
                        for &(i, offer, _) in &out.merged {
                            if offer > best_offer {
                                best_offer = offer;
                                best = i;
                            }
                        }
                        let cur = owner[e as usize];
                        let cur_offer = out
                            .merged
                            .iter()
                            .find(|&&(i, _, _)| i == cur)
                            .map(|&(_, o, _)| o)
                            .unwrap_or(0.0);
                        let sold = if cur == FREE {
                            best != u32::MAX && best_offer >= 1.0
                        } else {
                            // DFEPC raid: a poor bidder can buy an owned
                            // (rich) edge by strictly outbidding the
                            // owner's committed funding.
                            best != u32::MAX
                                && best != cur
                                && best_offer >= 1.0
                                && poor
                                    .map(|p| p[best as usize])
                                    .unwrap_or(false)
                                && rich
                                    .map(|r| r[cur as usize])
                                    .unwrap_or(false)
                                && best_offer > cur_offer
                        };
                        let new_owner = if sold { best } else { cur };
                        let before = out.credits.len();
                        for &(i, offer, from_lo) in &out.merged {
                            if offer <= 0.0 {
                                continue;
                            }
                            if sold && i == best {
                                // winner pays 1, remainder split half/half
                                let rem = (offer - 1.0) * 0.5;
                                out.credits.push((i, u, rem));
                                out.credits.push((i, v, rem));
                            } else if !sold && i == new_owner {
                                // own-edge circulation: half/half
                                out.credits.push((i, u, offer * 0.5));
                                out.credits.push((i, v, offer * 0.5));
                            } else {
                                // exact refund to contributors
                                out.credits.push((i, u, from_lo));
                                out.credits.push((i, v, offer - from_lo));
                            }
                        }
                        let n_credits = (out.credits.len() - before) as u32;
                        out.sales.push((
                            e,
                            if sold { best } else { FREE },
                            n_credits,
                        ));
                    }
                },
            );
        }
        // serial apply in edge order: ownership first, then that edge's
        // credits — exactly the sequential interleaving
        {
            let outs2 = std::mem::take(&mut self.scratch.outs2);
            for out in &outs2[..self.scratch.outs2_used] {
                let mut credit_idx = 0usize;
                for &(e, winner, n_credits) in &out.sales {
                    if winner != FREE {
                        let (u, v) = g.endpoints(e);
                        let (u, v) = (u as usize, v as usize);
                        let cur = self.owner[e as usize];
                        if cur != FREE {
                            self.sizes[cur as usize] -= 1;
                        } else {
                            self.free_edges -= 1;
                            self.free_deg[u] -= 1;
                            self.free_deg[v] -= 1;
                        }
                        self.owner[e as usize] = winner;
                        self.sizes[winner as usize] += 1;
                        self.anchor[winner as usize] = u;
                    }
                    for &(i, w, amount) in &out.credits
                        [credit_idx..credit_idx + n_credits as usize]
                    {
                        if owned.map(|o| o[i as usize]).unwrap_or(true) {
                            self.credit(i as usize, w as usize, amount);
                        }
                    }
                    credit_idx += n_credits as usize;
                }
            }
            self.scratch.outs2 = outs2;
        }
        if self.frontier_first {
            self.pool_at_frontier(g, owned);
        }
        self.rounds += 1;
        self.scratch.note_peak();
    }

    /// The bids emitted by the last [`round_bids`](Self::round_bids)
    /// call, pre-sort, in canonical partition-major order (ascending
    /// partition id; holder registration order within a partition). The
    /// distributed runtime ships these to the coordinator.
    pub(crate) fn pending_bids(&self) -> &[Bid] {
        &self.scratch.bids
    }

    /// Install a bid list (the coordinator's stitched global list) to be
    /// auctioned by the next [`round_auction`](Self::round_auction) call.
    /// Must be in the same canonical order `round_bids` produces — the
    /// stable radix sort then reproduces the exact single-process auction
    /// input order.
    pub(crate) fn set_pending_bids(&mut self, bids: &[Bid]) {
        self.scratch.bids.clear();
        self.scratch.bids.extend_from_slice(bids);
    }

    /// Rebuild the live-vertex list from `free_deg` after a checkpoint
    /// restore. Equivalent to the incrementally-maintained list at any
    /// consumer: the list starts as the ascending `free_deg > 0` filter
    /// and is only ever `retain`ed (free degrees never grow), and every
    /// consumer re-applies the retain before reading — so rebuilding the
    /// ascending filter restores the exact observable sequence.
    pub(crate) fn rebuild_live(&mut self) {
        let n = self.free_deg.len();
        self.live_vertices.clear();
        self.live_vertices
            .extend((0..n as u32).filter(|&v| self.free_deg[v as usize] > 0));
    }

    /// Add funds to (partition, vertex), registering the holder.
    #[inline]
    pub(crate) fn credit(&mut self, i: usize, v: usize, amount: f64) {
        if amount <= 0.0 {
            return;
        }
        let cell = self.money.cell_mut(i, v);
        if *cell <= 0.0 {
            self.holders[i].push(v as u32);
        }
        *cell += amount;
    }

    /// Intra-partition money transport: collect funding stuck on interior
    /// vertices (no incident free edge) and re-park it on the partition's
    /// frontier vertices. Conservation-exact.
    ///
    /// Justification: each partition is one worker — in the ETSCH/Hadoop
    /// deployment the partition's vertex ledger is local state, so moving
    /// money within the region costs nothing and needs no network round.
    /// Without this, interior funding random-walks the owned region
    /// (Alg. 4 splits across owned edges) and the end-game livelocks with
    /// frontier offers stuck below 1 unit. Disabled in the literal-Alg.4
    /// ablation (`frontier_first = false`).
    ///
    /// `owned` restricts the per-partition ledger redistribution to the
    /// caller's partitions (distributed mode); the frontier *discovery*
    /// scan reads only replicated state and runs unmasked everywhere.
    fn pool_at_frontier(&mut self, g: &Graph, owned: Option<&[bool]>) {
        // Each partition's TRUE frontier: region vertices (incident to an
        // owned edge) that also touch a free edge. Cash must be routed
        // there even if the partition's refunds parked it elsewhere in the
        // region — the worker owns the whole ledger locally, so this costs
        // no communication. Driven by the incrementally-maintained live
        // vertex list, so the scan is O(live frontier * deg), shrinking
        // as coverage grows. The scan runs in parallel chunks; duplicate
        // (vertex, partition) discoveries are removed in the serial merge
        // by the `seen_parts` visit stamps, so the outcome is independent
        // of chunking and thread count.
        {
            let free_deg = &self.free_deg;
            self.live_vertices.retain(|&w| free_deg[w as usize] > 0);
        }
        const LIVE_CHUNK: usize = 2048;
        let n_chunks = self.live_vertices.len().div_ceil(LIVE_CHUNK);
        {
            let RoundScratch { found, .. } = &mut self.scratch;
            if found.len() < n_chunks {
                found.resize_with(n_chunks, Vec::new);
            }
            for f in &mut found[..n_chunks] {
                f.clear();
            }
            let live = &self.live_vertices;
            let owner = &self.owner;
            crate::util::pool::run_mut(
                &mut found[..n_chunks],
                &|c, out: &mut Vec<(u32, u32)>| {
                    let lo = c * LIVE_CHUNK;
                    let hi = ((c + 1) * LIVE_CHUNK).min(live.len());
                    for &w in &live[lo..hi] {
                        // cheap adjacent-duplicate filter; exact dedup
                        // happens in the stamped serial merge below
                        let mut last = FREE;
                        for &e2 in g.neighbor_edges(w) {
                            let p = owner[e2 as usize];
                            if p != FREE && p != last {
                                last = p;
                                out.push((p, w));
                            }
                        }
                    }
                },
            );
        }
        // serial merge with visit stamps: frontier_of[p] gets each
        // frontier vertex exactly once, in first-discovery order (chunk
        // order == live order, so the result is thread-count independent)
        {
            let RoundScratch { found, frontier_of, seen_parts, .. } =
                &mut self.scratch;
            seen_parts.fill(u32::MAX);
            for fl in frontier_of.iter_mut() {
                fl.clear();
            }
            for chunk in &found[..n_chunks] {
                for &(p, w) in chunk {
                    let pu = p as usize;
                    if seen_parts[pu] != w {
                        seen_parts[pu] = w;
                        frontier_of[pu].push(w);
                    }
                }
            }
        }
        // per-partition distribution: each shard owns its partition's
        // ledger row, holder list and frontier list (disjoint state)
        struct Dist {
            money: *mut f64,
            stride: usize,
            holders: *mut Vec<u32>,
            frontier: *mut Vec<u32>,
        }
        // SAFETY: shard i touches only partition i's money row, holder
        // list and frontier list — disjoint across shard indices (the
        // same pattern as `pool::run_mut`).
        unsafe impl Sync for Dist {}
        let dist = Dist {
            stride: self.money.stride(),
            money: self.money.as_mut_ptr(),
            holders: self.holders.as_mut_ptr(),
            frontier: self.scratch.frontier_of.as_mut_ptr(),
        };
        let free_deg = &self.free_deg;
        crate::util::pool::run(self.k, &|i| {
            if !owned.map(|o| o[i]).unwrap_or(true) {
                return; // this partition's ledger lives on another worker
            }
            // SAFETY: see `Dist` — every dereference is indexed by the
            // shard's own partition id, so the borrows are disjoint.
            let money_i = unsafe {
                std::slice::from_raw_parts_mut(
                    dist.money.add(i * dist.stride),
                    dist.stride,
                )
            };
            let holders_i = unsafe { &mut *dist.holders.add(i) };
            let frontier = unsafe { &mut *dist.frontier.add(i) };
            distribute_to_frontier(money_i, holders_i, frontier, free_deg);
        });
    }

    /// Step 3 (Alg. 6): the coordinator injects funding inversely
    /// proportional to current size, spread across the vertices where the
    /// partition already has a presence.
    pub fn coordinator_step(&mut self, cap: f64) {
        self.coordinator_step_masked(cap, None);
    }

    /// [`coordinator_step`](Self::coordinator_step) restricted to owned
    /// partitions (distributed mode). The injection amounts depend only
    /// on the replicated `sizes`/`anchor`, so each worker funding its own
    /// partitions reproduces the single-process ledger writes exactly.
    pub(crate) fn coordinator_step_masked(
        &mut self,
        cap: f64,
        owned: Option<&[bool]>,
    ) {
        let avg = self.sizes.iter().sum::<usize>() as f64 / self.k as f64;
        let k = self.k;
        let RoundScratch { stamp, epoch, .. } = &mut self.scratch;
        let base = begin_pass(stamp.as_mut_slice(), epoch, k as u32);
        for i in 0..k {
            if !owned.map(|o| o[i]).unwrap_or(true) {
                continue;
            }
            let size = self.sizes[i] as f64;
            // inversely proportional to size, plus one base unit per round
            // so end-game purchases (1-unit edges at exhausted frontiers)
            // stay injection-paced at ~k edges/round rather than ~1
            let units = if size < 1.0 {
                cap
            } else {
                (avg / size + 1.0).min(cap)
            };
            // in-place stamped canonicalization: keep the first appearance
            // of every vertex that still holds cash (registration order)
            let tag = base + i as u32;
            let row = self.money.part_mut(i);
            let hs = &mut self.holders[i];
            let mut live = 0usize;
            let mut r = 0usize;
            while r < hs.len() {
                let v = hs[r];
                let vu = v as usize;
                if row[vu] > 0.0 && stamp[vu] != tag {
                    stamp[vu] = tag;
                    hs[live] = v;
                    live += 1;
                }
                r += 1;
            }
            hs.truncate(live);
            if units <= 0.0 {
                continue;
            }
            if live == 0 {
                // partition spent everything: deposit on its last
                // purchase's endpoint so it keeps receiving funding
                // (skipping here would freeze the partition for good)
                let a = self.anchor[i];
                row[a] += units;
                hs.push(a as u32);
                continue;
            }
            // distribute between all vertices with positive committed funds
            let per = units / live as f64;
            for &v in hs.iter() {
                row[v as usize] += per;
            }
        }
    }

    /// Total money across all partitions (the conservation invariant).
    pub fn total_money(&self) -> f64 {
        self.money.total()
    }

    /// High-water heap footprint of the reusable round scratch, in bytes
    /// (reported by the `dfep_round` bench series).
    pub fn scratch_peak_bytes(&self) -> usize {
        self.scratch.peak_bytes
    }
}

std::thread_local! {
    /// Per-thread parking slot for a finished run's [`DfepState`]: the
    /// run loops park their state here instead of dropping it, and the
    /// next run on the same thread resurrects it via
    /// [`DfepState::reset`]. One slot is enough — runs on a thread are
    /// strictly sequential, and the batch engine's lanes each execute on
    /// one pool worker, so a lane's variants chain through this slot and
    /// the big per-run allocations (the `k x n` ledger, the scratch, the
    /// degree/holder lists) are paid once per lane, not once per variant.
    static PARKED: std::cell::RefCell<Option<DfepState>> =
        const { std::cell::RefCell::new(None) };
}

/// A run-ready state: the thread's parked state reset in place when one
/// is available, else a freshly allocated [`DfepState::new`]. The two
/// are observably identical (see [`DfepState::reset`]).
pub(crate) fn acquire_state(
    g: &Graph,
    k: usize,
    initial: f64,
    rng: &mut Rng,
) -> DfepState {
    match PARKED.with(|c| c.borrow_mut().take()) {
        Some(mut st) => {
            st.reset(g, k, initial, rng);
            st
        }
        None => DfepState::new(g, k, initial, rng),
    }
}

/// Park `st` for reuse by the next DFEP/DFEPC run on this thread.
pub(crate) fn park_state(st: DfepState) {
    PARKED.with(|c| *c.borrow_mut() = Some(st));
}

/// High-water round-scratch bytes of the state parked on this thread
/// (0 when none) — how a batch lane reports its reuse footprint after
/// its variants finish.
pub fn parked_scratch_peak_bytes() -> usize {
    PARKED.with(|c| {
        c.borrow().as_ref().map_or(0, DfepState::scratch_peak_bytes)
    })
}

/// Per-partition half of [`DfepState::pool_at_frontier`]: drain the
/// partition's liquid cash (in holder registration order — the canonical
/// order that pins the `f64` pool sum) and re-park it on the frontier,
/// cheapest vertices first in `(free_deg, vertex id)` ascending order — a
/// total order, so the fill is independent of discovery order.
fn distribute_to_frontier(
    money_i: &mut [f64],
    holders_i: &mut Vec<u32>,
    frontier: &mut Vec<u32>,
    free_deg: &[u32],
) {
    // collect the partition's entire liquid cash (region locality: money
    // of partition i only ever sits on V_i); duplicate holder entries
    // contribute once because cells are zeroed as they drain
    let mut pool = 0.0f64;
    let mut first_holder: Option<usize> = None;
    for &hv in holders_i.iter() {
        let v = hv as usize;
        let c = money_i[v];
        if c <= 0.0 {
            continue;
        }
        first_holder = first_holder.or(Some(v));
        pool += c;
        money_i[v] = 0.0;
    }
    holders_i.clear();
    if pool <= 0.0 {
        return;
    }
    if frontier.is_empty() {
        // boxed in: re-deposit on the first holder — stays inside the
        // region; the DFEPC raid dynamic is what unboxes it
        let fh = first_holder.unwrap();
        money_i[fh] += pool;
        holders_i.push(fh as u32);
        return;
    }
    greedy_fund_frontier(money_i, frontier, free_deg, pool, |v| {
        holders_i.push(v)
    });
}

/// The greedy frontier fill shared by the reference engine and the XLA
/// engine (one implementation, so the two cannot silently diverge):
/// fund vertices with the cheapest frontier first, in `(free_deg,
/// vertex id)` ascending order — a total order, so ties cannot depend
/// on discovery order. Each funded vertex gets exactly enough to bid 1
/// unit per free incident edge; leftovers spread equally as headroom;
/// if even the cheapest vertex cannot be covered, everything
/// concentrates on it so accumulation crosses the threshold.
/// Conservation-exact: exactly `pool` is added to `row`.
///
/// `frontier` must be non-empty and deduplicated; `funded_sink` is
/// called once per vertex that received the full `need` grant (the
/// reference engine registers holders through it).
pub(crate) fn greedy_fund_frontier(
    row: &mut [f64],
    frontier: &mut Vec<u32>,
    free_deg: &[u32],
    pool: f64,
    mut funded_sink: impl FnMut(u32),
) {
    frontier.sort_unstable_by_key(|&v| (free_deg[v as usize], v));
    let mut remaining = pool;
    let mut funded = 0usize;
    for &v in frontier.iter() {
        let need = free_deg[v as usize] as f64 * 1.0001;
        if remaining < need {
            break;
        }
        row[v as usize] += need;
        funded_sink(v);
        remaining -= need;
        funded += 1;
    }
    if funded == 0 {
        row[frontier[0] as usize] += remaining;
        funded_sink(frontier[0]);
    } else {
        let per = remaining / funded as f64;
        for &v in &frontier[..funded] {
            row[v as usize] += per;
        }
    }
}

impl Dfep {
    /// Run DFEP, returning the partition plus the per-round trace of free
    /// edges (used by tests and the bench harness).
    pub fn run_traced(
        &self,
        g: &Graph,
        k: usize,
        seed: u64,
    ) -> (EdgePartition, Vec<usize>) {
        self.run_inner(g, k, seed, false)
    }

    /// The one round loop behind [`run_traced`](Self::run_traced) and
    /// [`debug_run`] (`debug` prints per-round diagnostics).
    fn run_inner(
        &self,
        g: &Graph,
        k: usize,
        seed: u64,
        debug: bool,
    ) -> (EdgePartition, Vec<usize>) {
        assert!(k >= 1 && g.edge_count() > 0);
        let mut rng = Rng::new(seed);
        let initial =
            self.initial_fraction * g.edge_count() as f64 / k as f64;
        let mut st = acquire_state(g, k, initial.max(1.0), &mut rng);
        st.frontier_first = self.frontier_first;
        let mut trace = Vec::new();
        let mut stall = 0usize;
        while st.free_edges > 0 && st.rounds < self.max_rounds {
            let before = st.free_edges;
            st.funding_round(g, None, None);
            st.coordinator_step(self.funding_cap);
            trace.push(st.free_edges);
            if debug && (st.rounds % 10 == 0 || st.free_edges < 30) {
                let money: Vec<i64> = (0..k)
                    .map(|i| st.money.part_total(i) as i64)
                    .collect();
                println!(
                    "round {} free {} sizes {:?} money {:?}",
                    st.rounds, st.free_edges, st.sizes, money
                );
            }
            if st.free_edges == before {
                stall += 1;
                // a component can be unreachable from every start vertex
                // (or funding got stranded): reseed the smallest partition
                // on a free edge, as any practical deployment would.
                if stall >= 3 {
                    reseed_on_free_edge(g, &mut st, &mut rng);
                    stall = 0;
                }
            } else {
                stall = 0;
            }
        }
        let rounds = st.rounds;
        let owner = finalize(g, std::mem::take(&mut st.owner), k);
        park_state(st);
        (EdgePartition { k, owner, rounds }, trace)
    }
}

/// Stall recovery, driven by the engine's maintained
/// `live_vertices`/`free_deg` state (not an O(m) full-edge sweep: only
/// vertices that still touch a free edge are walked, so late-run stalls —
/// when almost everything is owned — cost O(live frontier), not O(m)).
///
/// First choice: top up funding *at the frontier* — walk the live
/// vertices from a random offset; for the first free edge found whose
/// endpoints touch an owned edge, grant the smallest adjacent owner 2
/// units on the shared endpoint (preserves connectedness: money only
/// lands inside an owned region). Only if no free edge has an owned
/// neighbor at all (disconnected component never reached by any start
/// vertex) does the smallest partition get reseeded there — the one case
/// where a disconnected partition is unavoidable. One bounded top-up per
/// invocation (injecting per free edge would counterfeit money and wreck
/// balance).
pub fn reseed_on_free_edge(g: &Graph, st: &mut DfepState, rng: &mut Rng) {
    reseed_on_free_edge_masked(g, st, rng, None);
}

/// [`reseed_on_free_edge`] with the distributed ownership mask: the walk
/// and the `rng` draws run identically on every worker (they read only
/// replicated state and keep the streams in lockstep); the final credit
/// lands in the ledger only on the worker that owns the granted
/// partition.
pub(crate) fn reseed_on_free_edge_masked(
    g: &Graph,
    st: &mut DfepState,
    rng: &mut Rng,
    owned: Option<&[bool]>,
) {
    // prune stale live entries here too: the literal-Alg4 ablation skips
    // pool_at_frontier, which otherwise maintains the list
    {
        let free_deg = &st.free_deg;
        st.live_vertices.retain(|&w| free_deg[w as usize] > 0);
    }
    if st.live_vertices.is_empty() {
        return; // no free edges left at all
    }
    let len = st.live_vertices.len();
    let start = rng.below(len);
    let mut grant: Option<(usize, u32)> = None; // (partition, endpoint)
    let mut orphan: Option<u32> = None;
    'walk: for off in 0..len {
        let w = st.live_vertices[(start + off) % len];
        for &e in g.neighbor_edges(w) {
            if st.owner[e as usize] != FREE {
                continue;
            }
            let (u, v) = g.endpoints(e);
            let mut best: Option<(usize, u32)> = None;
            for x in [u, v] {
                for &e2 in g.neighbor_edges(x) {
                    let o = st.owner[e2 as usize];
                    if o != FREE {
                        let i = o as usize;
                        if best
                            .map(|(b, _)| st.sizes[i] < st.sizes[b])
                            .unwrap_or(true)
                        {
                            best = Some((i, x));
                        }
                    }
                }
            }
            if best.is_some() {
                grant = best;
                break 'walk;
            }
            orphan = orphan.or(Some(e));
        }
    }
    if let Some((i, x)) = grant {
        if owned.map(|o| o[i]).unwrap_or(true) {
            st.credit(i, x as usize, 2.0);
        }
        return;
    }
    if let Some(e) = orphan {
        // free edges exist but none touches an owned region: an
        // unreachable component — reseed the smallest partition there
        // (the one unavoidable connectedness exception; disconnected
        // inputs only)
        let smallest = (0..st.k).min_by_key(|&i| st.sizes[i]).unwrap();
        let (u, v) = g.endpoints(e);
        let x = if rng.chance(0.5) { u } else { v };
        if owned.map(|o| o[smallest]).unwrap_or(true) {
            st.credit(smallest, x as usize, 2.0);
        }
    }
}

/// Assign any still-free edges (max_rounds hit) to the smaller adjacent
/// partition so the result is always a complete partitioning.
pub(crate) fn finalize(g: &Graph, owner: Vec<u32>, k: usize) -> Vec<u32> {
    let mut owner = owner;
    let mut sizes = vec![0usize; k];
    for &p in &owner {
        if p != FREE {
            sizes[p as usize] += 1;
        }
    }
    loop {
        let mut changed = false;
        let mut remaining = false;
        for e in 0..owner.len() {
            if owner[e] != FREE {
                continue;
            }
            let (u, v) = g.endpoints(e as u32);
            // smallest partition among those owning an adjacent edge
            let mut best: Option<u32> = None;
            for w in [u, v] {
                for &e2 in g.neighbor_edges(w) {
                    let p = owner[e2 as usize];
                    if p != FREE
                        && best.map(|b| sizes[p as usize] < sizes[b as usize])
                            .unwrap_or(true)
                    {
                        best = Some(p);
                    }
                }
            }
            if let Some(p) = best {
                owner[e] = p;
                sizes[p as usize] += 1;
                changed = true;
            } else {
                remaining = true;
            }
        }
        if !remaining {
            break;
        }
        if !changed {
            // isolated free component with no partitioned neighbor at all:
            // give it to the globally smallest partition
            let smallest =
                (0..k).min_by_key(|&i| sizes[i]).unwrap() as u32;
            for o in owner.iter_mut() {
                if *o == FREE {
                    *o = smallest;
                    sizes[smallest as usize] += 1;
                }
            }
            break;
        }
    }
    owner
}

/// Instrumented run for development: the traced runner with per-round
/// diagnostics printed (shares [`Dfep::run_traced`]'s loop instead of
/// carrying its own copy).
pub fn debug_run(g: &Graph, k: usize, seed: u64) {
    let cfg = Dfep { max_rounds: 400, ..Dfep::default() };
    let _ = cfg.run_inner(g, k, seed, true);
}

impl Partitioner for Dfep {
    fn partition_graph(
        &self,
        g: &Graph,
        k: usize,
        seed: u64,
    ) -> Result<EdgePartition> {
        check_k(k)?;
        if g.edge_count() == 0 {
            bail!("DFEP cannot partition an empty graph (0 edges)");
        }
        Ok(self.run_traced(g, k, seed).0)
    }

    fn name(&self) -> &'static str {
        "DFEP"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::GraphKind;
    use crate::graph::GraphBuilder;
    use crate::partition::metrics;

    fn small_world() -> Graph {
        GraphKind::PowerlawCluster { n: 400, m: 4, p: 0.3 }.generate(5)
    }

    #[test]
    fn money_audit_per_partition() {
        let g = GraphKind::PowerlawCluster { n: 5000, m: 8, p: 0.4 }
            .generate(42);
        let k = 8;
        let mut rng = Rng::new(1);
        let initial = g.edge_count() as f64 / k as f64;
        let mut st = DfepState::new(&g, k, initial, &mut rng);
        let mut injected = vec![0.0; k];
        for round in 0..120 {
            st.funding_round(&g, None, None);
            let before: Vec<f64> =
                (0..k).map(|i| st.money.part_total(i)).collect();
            st.coordinator_step(10.0);
            for (i, inj) in injected.iter_mut().enumerate() {
                *inj += st.money.part_total(i) - before[i];
            }
            for (i, inj) in injected.iter().enumerate() {
                let expect = initial + inj - st.sizes[i] as f64;
                let actual = st.money.part_total(i);
                assert!(
                    (expect - actual).abs() <= 1.0,
                    "round {round} part {i}: expect {expect:.1} \
                     actual {actual:.1}"
                );
            }
            if st.free_edges == 0 {
                return;
            }
        }
        panic!(
            "did not converge: free={} sizes={:?}",
            st.free_edges, st.sizes
        );
    }

    #[test]
    fn money_audit() {
        let g = GraphKind::PowerlawCluster { n: 5000, m: 8, p: 0.4 }
            .generate(42);
        let k = 8;
        let mut rng = Rng::new(1);
        let initial = g.edge_count() as f64 / k as f64;
        let mut st = DfepState::new(&g, k, initial, &mut rng);
        let mut injected = 0.0;
        for round in 0..60 {
            st.funding_round(&g, None, None);
            let before = st.total_money();
            st.coordinator_step(10.0);
            injected += st.total_money() - before;
            let bought: usize = st.sizes.iter().sum();
            let expect = initial * k as f64 + injected - bought as f64;
            let actual = st.total_money();
            assert!(
                (expect - actual).abs() <= 1.0,
                "round {round}: expect {expect:.1} actual {actual:.1} \
                 diff {:.1}",
                actual - expect
            );
            if st.free_edges == 0 {
                break;
            }
        }
    }

    /// The distributed decomposition: per-worker masked `round_bids`,
    /// bids stitched in partition order, redundant masked `round_auction`
    /// + `coordinator_step_masked` on every replica — must reproduce the
    /// single-process trajectory bit-exactly, including every owned
    /// ledger row. This is the in-memory half of the `cluster::runtime`
    /// determinism story (tests/cluster.rs pins the socket half).
    #[test]
    fn masked_phases_compose_to_single_process() {
        let g = small_world();
        let k = 5usize;
        let workers = 2usize;
        let initial = g.edge_count() as f64 / k as f64;
        let mut rng_ref = Rng::new(9);
        let mut reference = DfepState::new(&g, k, initial, &mut rng_ref);
        let mut rngs: Vec<Rng> = (0..workers).map(|_| Rng::new(9)).collect();
        let mut reps: Vec<DfepState> = rngs
            .iter_mut()
            .map(|r| DfepState::new(&g, k, initial, r))
            .collect();
        let masks: Vec<Vec<bool>> = (0..workers)
            .map(|w| (0..k).map(|i| i % workers == w).collect())
            .collect();
        for _ in 0..120 {
            reference.funding_round(&g, None, None);
            reference.coordinator_step(10.0);
            // workers bid on their own partitions only; the stitched
            // global list is partition-major, like the unmasked one
            let mut per_part: Vec<Vec<Bid>> = vec![Vec::new(); k];
            for (w, rep) in reps.iter_mut().enumerate() {
                rep.round_bids(&g, None, None, Some(&masks[w]));
                for &b in rep.pending_bids() {
                    assert_eq!(b.1 as usize % workers, w, "foreign bid");
                    per_part[b.1 as usize].push(b);
                }
            }
            let merged: Vec<Bid> = per_part.into_iter().flatten().collect();
            for (w, rep) in reps.iter_mut().enumerate() {
                rep.set_pending_bids(&merged);
                rep.round_auction(&g, None, None, Some(&masks[w]));
                rep.coordinator_step_masked(10.0, Some(&masks[w]));
            }
            for rep in &reps {
                assert_eq!(rep.owner, reference.owner);
                assert_eq!(rep.free_edges, reference.free_edges);
                assert_eq!(rep.sizes, reference.sizes);
                assert_eq!(rep.free_deg, reference.free_deg);
                assert_eq!(rep.anchor, reference.anchor);
            }
            for (w, rep) in reps.iter().enumerate() {
                for i in 0..k {
                    if i % workers == w {
                        assert_eq!(
                            rep.money.part(i),
                            reference.money.part(i),
                            "round {} part {i} ledger row diverged",
                            reference.rounds
                        );
                    }
                }
            }
            if reference.free_edges == 0 {
                break;
            }
        }
        // no reseeds in this loop, so a late-run stall is possible on an
        // unlucky graph; substantial progress is what the test needs
        assert!(reference.free_edges < g.edge_count() / 2);
    }

    #[test]
    fn radix_bid_sort_matches_stable_reference_on_random_bid_sets() {
        // property: on random bid sets — including duplicate
        // (edge, partition) keys, as both endpoints of an edge produce —
        // the radix sort equals a stable sort by edge id, i.e. the
        // documented canonical order (edge asc, input order within)
        let mut rng = Rng::new(77);
        let mut tmp: Vec<Bid> = Vec::new();
        let mut counts = vec![0u32; RADIX];
        for case in 0..60u64 {
            // alternate small (single-pass) and large (two-pass) edge
            // id spaces
            let edge_bound = if case % 2 == 0 {
                1 + rng.below(50_000) as u32
            } else {
                (1 << 16) + 1 + rng.below(200_000) as u32
            };
            let len = rng.below(2_000);
            let mut bids: Vec<Bid> = (0..len)
                .map(|j| {
                    let e = rng.below(edge_bound as usize) as u32;
                    let p = rng.below(8) as u32;
                    // offer tags the input position so stability is
                    // observable even for duplicate (edge, partition) keys
                    (e, p, j as f64, rng.f64())
                })
                .collect();
            // force some exact duplicate keys (two-endpoint bids)
            for j in (0..len / 4).step_by(2) {
                let (e, p, _, _) = bids[j];
                bids[len - 1 - j].0 = e;
                bids[len - 1 - j].1 = p;
            }
            let mut reference = bids.clone();
            reference.sort_by_key(|b| b.0); // stable
            radix_sort_bids_by_edge(
                &mut bids,
                &mut tmp,
                &mut counts,
                edge_bound,
            );
            assert_eq!(bids, reference, "case {case}");
        }
    }

    #[test]
    fn radix_sorted_bids_group_partitions_in_order() {
        // the engine feeds the sort partition-major bids; the output must
        // then be (edge asc, partition asc) with duplicates adjacent —
        // the contract the adjacent-merge in step 2 relies on
        let mut rng = Rng::new(9);
        let mut bids: Vec<Bid> = Vec::new();
        for p in 0..6u32 {
            for _ in 0..500 {
                bids.push((rng.below(70_000) as u32, p, 1.0, 0.5));
            }
        }
        let mut tmp = Vec::new();
        let mut counts = vec![0u32; RADIX];
        radix_sort_bids_by_edge(&mut bids, &mut tmp, &mut counts, 70_000);
        for w in bids.windows(2) {
            assert!(
                (w[0].0, w[0].1) <= (w[1].0, w[1].1),
                "not (edge, partition) ordered: {w:?}"
            );
        }
    }

    #[test]
    fn reseed_completes_disconnected_multi_component_graphs() {
        // regression for the stall path: many components unreachable from
        // the k start vertices previously forced repeated O(m) full-edge
        // scans; the live-vertex walk must still find and seed every
        // orphan component, and the run must converge (not fall through
        // to the max_rounds finalize bail-out)
        let mut b = GraphBuilder::new();
        for c in 0..8u32 {
            let base = c * 12;
            for i in 0..12u32 {
                b.push_edge(base + i, base + (i + 1) % 12);
            }
        }
        let g = b.build();
        let p = Dfep::default().partition_graph(&g, 3, 4).unwrap();
        p.validate(&g).unwrap();
        assert!(
            p.rounds < Dfep::default().max_rounds,
            "run hit max_rounds instead of converging via reseeds"
        );
        assert_eq!(p.sizes().iter().sum::<usize>(), g.edge_count());
        // deterministic per seed through the reseed path as well
        let q = Dfep::default().partition_graph(&g, 3, 4).unwrap();
        assert_eq!(p.owner, q.owner);
    }

    #[test]
    fn produces_complete_partitioning() {
        let g = small_world();
        let p = Dfep::default().partition_graph(&g, 8, 1).unwrap();
        p.validate(&g).unwrap();
        assert!(p.owner.iter().all(|&o| (o as usize) < 8));
        assert_eq!(p.owner.len(), g.edge_count());
    }

    #[test]
    fn deterministic_per_seed() {
        let g = small_world();
        let a = Dfep::default().partition_graph(&g, 4, 9).unwrap();
        let b = Dfep::default().partition_graph(&g, 4, 9).unwrap();
        assert_eq!(a.owner, b.owner);
        let c = Dfep::default().partition_graph(&g, 4, 10).unwrap();
        assert_ne!(a.owner, c.owner);
    }

    #[test]
    fn partitions_are_reasonably_balanced() {
        let g = small_world();
        let p = Dfep::default().partition_graph(&g, 4, 2).unwrap();
        let report = metrics::evaluate(&g, &p);
        assert!(
            report.nstdev < 0.6,
            "nstdev {} too high (sizes {:?})",
            report.nstdev,
            p.sizes()
        );
    }

    #[test]
    fn partitions_are_connected() {
        let g = small_world();
        let p = Dfep::default().partition_graph(&g, 6, 3).unwrap();
        let disc = metrics::disconnected_fraction(&g, &p);
        assert_eq!(disc, 0.0, "plain DFEP must give connected partitions");
    }

    #[test]
    fn funding_is_conserved_per_round() {
        let g = small_world();
        let mut rng = Rng::new(4);
        let mut st = DfepState::new(&g, 4, 100.0, &mut rng);
        let before = st.total_money();
        st.funding_round(&g, None, None);
        let bought: usize = st.sizes.iter().sum();
        let after = st.total_money() + bought as f64;
        assert!(
            (before - after).abs() < 1e-6 * before.max(1.0),
            "money leaked: {before} -> {after}"
        );
    }

    #[test]
    fn free_edges_monotone_decreasing() {
        let g = small_world();
        let (_, trace) = Dfep::default().run_traced(&g, 4, 6);
        for w in trace.windows(2) {
            assert!(w[1] <= w[0], "free edges increased: {trace:?}");
        }
        assert_eq!(*trace.last().unwrap(), 0);
    }

    #[test]
    fn single_partition_takes_everything() {
        let g = small_world();
        let p = Dfep::default().partition_graph(&g, 1, 1).unwrap();
        assert!(p.owner.iter().all(|&o| o == 0));
    }

    #[test]
    fn scratch_peak_is_reported_after_rounds() {
        let g = small_world();
        let mut rng = Rng::new(2);
        let mut st = DfepState::new(&g, 4, 100.0, &mut rng);
        assert_eq!(st.scratch_peak_bytes(), 0);
        st.funding_round(&g, None, None);
        assert!(st.scratch_peak_bytes() > 0);
    }

    #[test]
    fn rounds_grow_with_diameter() {
        // Fig 6d shape: rounds rise with diameter. Single runs are noisy
        // (the end-game is injection-paced on both graphs), so compare
        // means over several seeds with a strong diameter contrast.
        let road = GraphKind::RoadNetwork {
            rows: 14, cols: 14, drop: 0.2, subdiv: 5, shortcuts: 0,
        }
        .generate(8);
        let ball = GraphKind::ErdosRenyi {
            n: road.vertex_count(),
            m: road.edge_count(),
        }
        .generate(8);
        let mean = |g: &Graph| -> f64 {
            (1u64..=5)
                .map(|s| {
                    Dfep::default()
                        .partition_graph(g, 4, s)
                        .unwrap()
                        .rounds as f64
                })
                .sum::<f64>()
                / 5.0
        };
        let r_road = mean(&road);
        let r_ball = mean(&ball);
        assert!(
            r_road > r_ball * 1.3,
            "road rounds {r_road} should clearly exceed ER rounds {r_ball}"
        );
    }
}
