//! Edge partitioning: the paper's core abstraction plus all partitioners.
//!
//! An [`EdgePartition`] assigns every edge to exactly one of `k` parts;
//! vertex sets `V_i` (and the frontier `F_i`) are derived — hot paths
//! derive them exactly once through [`view::PartitionView`]. Partitioners:
//! [`dfep::Dfep`] (the paper's contribution), [`dfepc::Dfepc`] (the
//! variant of §IV-A), [`jabeja::JaBeJa`] (the comparison baseline), the
//! trivial [`baselines`], and the ingest-time [`streaming`] partitioners
//! (HDRF / DBH / restreaming refinement) that place edges straight off a
//! bounded-memory [`crate::graph::stream::EdgeStream`].

pub mod baselines;
pub mod dfep;
pub mod dfepc;
pub mod fennel;
pub mod jabeja;
pub mod multilevel;
pub mod metrics;
pub mod streaming;
pub mod view;

use crate::graph::Graph;

/// A complete edge partitioning of a graph into `k` parts.
#[derive(Clone, Debug)]
pub struct EdgePartition {
    /// Number of parts.
    pub k: usize,
    /// `owner[e]` = partition of edge `e` (always in `0..k` once complete).
    pub owner: Vec<u32>,
    /// Rounds the partitioner took (paper metric).
    pub rounds: usize,
}

impl EdgePartition {
    /// Edge ids of each part.
    ///
    /// Slow reference derivation: hot paths go through
    /// [`view::PartitionView`], which derives all of this state in one
    /// build; `edge_sets`/[`vertex_sets`](Self::vertex_sets) survive as
    /// the independent oracles the equivalence tests compare against.
    pub fn edge_sets(&self) -> Vec<Vec<u32>> {
        let mut sets = vec![Vec::new(); self.k];
        for (e, &p) in self.owner.iter().enumerate() {
            sets[p as usize].push(e as u32);
        }
        sets
    }

    /// `|E_i|` for each part.
    pub fn sizes(&self) -> Vec<usize> {
        let mut sizes = vec![0usize; self.k];
        for &p in &self.owner {
            sizes[p as usize] += 1;
        }
        sizes
    }

    /// Vertex sets `V_i` (endpoints of each part's edges), de-duplicated.
    pub fn vertex_sets(&self, g: &Graph) -> Vec<Vec<u32>> {
        // iterate one part at a time so a single stamp array stays correct
        // (stamp[w] == p  <=>  w already recorded for the current part)
        let mut sets = vec![Vec::new(); self.k];
        let mut stamp = vec![u32::MAX; g.vertex_count()];
        for (p, edges) in self.edge_sets().into_iter().enumerate() {
            for e in edges {
                let (u, v) = g.endpoints(e);
                for w in [u, v] {
                    if stamp[w as usize] != p as u32 {
                        stamp[w as usize] = p as u32;
                        sets[p].push(w);
                    }
                }
            }
        }
        sets
    }

    /// For every vertex, the number of distinct partitions it appears in.
    /// (Frontier vertices are those with multiplicity >= 2.)
    ///
    /// Single stamp-array pass over the adjacency: no vertex sets are
    /// materialized. The old derivation survives as
    /// [`vertex_sets`](Self::vertex_sets), which the equivalence tests
    /// recount against this.
    pub fn vertex_multiplicity(&self, g: &Graph) -> Vec<u32> {
        let mut mult = vec![0u32; g.vertex_count()];
        // seen[p] == v  <=>  part p already counted for vertex v
        let mut seen = vec![u32::MAX; self.k];
        for v in 0..g.vertex_count() as u32 {
            for &(_, e) in g.neighbors(v) {
                let p = self.owner[e as usize] as usize;
                if seen[p] != v {
                    seen[p] = v;
                    mult[v as usize] += 1;
                }
            }
        }
        mult
    }

    /// Check this is a valid complete partitioning of `g`'s edges.
    pub fn validate(&self, g: &Graph) -> Result<(), String> {
        if self.owner.len() != g.edge_count() {
            return Err(format!(
                "owner len {} != edge count {}",
                self.owner.len(),
                g.edge_count()
            ));
        }
        if let Some((e, &p)) =
            self.owner.iter().enumerate().find(|&(_, &p)| p as usize >= self.k)
        {
            return Err(format!("edge {e} has invalid owner {p}"));
        }
        Ok(())
    }
}

/// Common interface for all edge partitioners.
pub trait Partitioner {
    /// Partition `g` into `k` parts; `seed` controls all randomness.
    fn partition(&self, g: &Graph, k: usize, seed: u64) -> EdgePartition;
    /// Short display name for benches/tables.
    fn name(&self) -> &'static str;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    fn square() -> Graph {
        GraphBuilder::new()
            .add_edge(0, 1)
            .add_edge(1, 2)
            .add_edge(2, 3)
            .add_edge(3, 0)
            .build()
    }

    #[test]
    fn sizes_and_sets() {
        let g = square();
        let p = EdgePartition { k: 2, owner: vec![0, 0, 1, 1], rounds: 1 };
        p.validate(&g).unwrap();
        assert_eq!(p.sizes(), vec![2, 2]);
        let es = p.edge_sets();
        assert_eq!(es[0], vec![0, 1]);
        assert_eq!(es[1], vec![2, 3]);
    }

    #[test]
    fn vertex_sets_and_frontier() {
        let g = square();
        // canonical edge order after build: (0,1),(0,3),(1,2),(2,3)
        assert_eq!(g.edges(), &[(0, 1), (0, 3), (1, 2), (2, 3)]);
        let p = EdgePartition { k: 2, owner: vec![0, 0, 1, 1], rounds: 1 };
        let vs = p.vertex_sets(&g);
        // part 0 owns edges (0,1),(0,3) -> vertices {0,1,3}
        let mut v0 = vs[0].clone();
        v0.sort_unstable();
        assert_eq!(v0, vec![0, 1, 3]);
        let mult = p.vertex_multiplicity(&g);
        // vertices 1 and 3 are frontier (in both parts)
        assert_eq!(mult, vec![1, 2, 1, 2]);
    }

    #[test]
    fn validate_catches_bad_owner() {
        let g = square();
        let p = EdgePartition { k: 2, owner: vec![0, 0, 5, 1], rounds: 0 };
        assert!(p.validate(&g).is_err());
        let p2 = EdgePartition { k: 2, owner: vec![0, 0], rounds: 0 };
        assert!(p2.validate(&g).is_err());
    }
}
