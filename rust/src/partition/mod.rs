//! Edge partitioning: the paper's core abstraction plus all partitioners.
//!
//! An [`EdgePartition`] assigns every edge to exactly one of `k` parts;
//! vertex sets `V_i` (and the frontier `F_i`) are derived — hot paths
//! derive them exactly once through [`view::PartitionView`]. Partitioners:
//! [`dfep::Dfep`] (the paper's contribution), [`dfepc::Dfepc`] (the
//! variant of §IV-A), [`jabeja::JaBeJa`] (the comparison baseline), the
//! trivial [`baselines`], and the ingest-time [`streaming`] partitioners
//! (HDRF / DBH / restreaming refinement) that place edges straight off a
//! bounded-memory [`crate::graph::stream::EdgeStream`].
//!
//! The DFEP engines share the flat per-(partition, vertex) funding
//! ledger in [`money`] and a persistent round scratch (see
//! `dfep::RoundScratch`), so the hottest loop — the funding round — runs
//! allocation-free in steady state.
//!
//! All of them dispatch through the one fallible [`Partitioner`] trait:
//! [`Partitioner::partition`] takes a [`PartitionInput`] — either a
//! materialized [`Graph`] or a replayable edge stream — so streaming
//! partitioners run streaming-native and graph partitioners materialize,
//! behind the same interface. Partitioners are constructed by name and
//! parameters through [`spec::PartitionerSpec`] and the [`registry`].
//!
//! The output of *any* of them can be post-processed by the
//! [`refine`] local-search pass (`refine:base=<spec>`), which strictly
//! never worsens the replication factor.

pub mod baselines;
pub mod dfep;
pub mod dfepc;
pub mod fennel;
pub mod jabeja;
pub mod money;
pub mod multilevel;
pub mod metrics;
pub mod refine;
pub mod registry;
pub mod spec;
pub mod streaming;
pub mod view;

use crate::graph::stream::EdgeStream;
use crate::graph::{Graph, GraphBuilder};
use crate::bail;
use crate::util::error::Result;

/// A complete edge partitioning of a graph into `k` parts.
#[derive(Clone, Debug)]
pub struct EdgePartition {
    /// Number of parts.
    pub k: usize,
    /// `owner[e]` = partition of edge `e` (always in `0..k` once complete).
    pub owner: Vec<u32>,
    /// Rounds the partitioner took (paper metric).
    pub rounds: usize,
}

impl EdgePartition {
    /// Edge ids of each part.
    ///
    /// Slow reference derivation: hot paths go through
    /// [`view::PartitionView`], which derives all of this state in one
    /// build; `edge_sets`/[`vertex_sets`](Self::vertex_sets) survive as
    /// the independent oracles the equivalence tests compare against.
    pub fn edge_sets(&self) -> Vec<Vec<u32>> {
        let mut sets = vec![Vec::new(); self.k];
        for (e, &p) in self.owner.iter().enumerate() {
            sets[p as usize].push(e as u32);
        }
        sets
    }

    /// `|E_i|` for each part.
    pub fn sizes(&self) -> Vec<usize> {
        let mut sizes = vec![0usize; self.k];
        for &p in &self.owner {
            sizes[p as usize] += 1;
        }
        sizes
    }

    /// Vertex sets `V_i` (endpoints of each part's edges), de-duplicated.
    pub fn vertex_sets(&self, g: &Graph) -> Vec<Vec<u32>> {
        // iterate one part at a time so a single stamp array stays correct
        // (stamp[w] == p  <=>  w already recorded for the current part)
        let mut sets = vec![Vec::new(); self.k];
        let mut stamp = vec![u32::MAX; g.vertex_count()];
        for (p, edges) in self.edge_sets().into_iter().enumerate() {
            for e in edges {
                let (u, v) = g.endpoints(e);
                for w in [u, v] {
                    if stamp[w as usize] != p as u32 {
                        stamp[w as usize] = p as u32;
                        sets[p].push(w);
                    }
                }
            }
        }
        sets
    }

    /// For every vertex, the number of distinct partitions it appears in.
    /// (Frontier vertices are those with multiplicity >= 2.)
    ///
    /// Single stamp-array pass over the adjacency: no vertex sets are
    /// materialized. The old derivation survives as
    /// [`vertex_sets`](Self::vertex_sets), which the equivalence tests
    /// recount against this.
    pub fn vertex_multiplicity(&self, g: &Graph) -> Vec<u32> {
        let mut mult = vec![0u32; g.vertex_count()];
        // seen[p] == v  <=>  part p already counted for vertex v
        let mut seen = vec![u32::MAX; self.k];
        for v in 0..g.vertex_count() as u32 {
            for &e in g.neighbor_edges(v) {
                let p = self.owner[e as usize] as usize;
                if seen[p] != v {
                    seen[p] = v;
                    mult[v as usize] += 1;
                }
            }
        }
        mult
    }

    /// Check this is a valid complete partitioning of `g`'s edges:
    /// `k >= 1`, one owner per edge, every owner in `0..k`. The error
    /// reports *how many* owners are out of range (and the first
    /// offender), not just the first edge found.
    pub fn validate(&self, g: &Graph) -> Result<()> {
        if self.k == 0 {
            bail!("partition has k=0 (k must be >= 1)");
        }
        if self.owner.len() != g.edge_count() {
            bail!(
                "owner len {} != edge count {}",
                self.owner.len(),
                g.edge_count()
            );
        }
        let mut bad = 0usize;
        let mut first: Option<(usize, u32)> = None;
        for (e, &p) in self.owner.iter().enumerate() {
            if p as usize >= self.k {
                bad += 1;
                if first.is_none() {
                    first = Some((e, p));
                }
            }
        }
        if let Some((e, p)) = first {
            bail!(
                "{bad} edge(s) have owners outside 0..{} (first: edge {e} \
                 with owner {p})",
                self.k
            );
        }
        Ok(())
    }
}

/// Reject `k == 0` with the one shared message (every partitioner's
/// entry-point check).
pub(crate) fn check_k(k: usize) -> Result<()> {
    if k == 0 {
        bail!("k must be >= 1 (got 0)");
    }
    Ok(())
}

/// A replayable edge stream plus optional size hints. The stream follows
/// the [`EdgeStream`](crate::graph::stream) contract: cleaned `(u, v)`
/// pairs with `u < v`, identical sequence on every replay, stream
/// position == edge identity.
///
/// The hints are advisory pre-sizing information only — correctness
/// never depends on them. [`materialize`](Self::materialize) uses
/// `edges` to pre-allocate; the streaming-native partitioners grow
/// their O(|V|) tables incrementally and currently ignore both.
pub struct StreamInput<'a> {
    /// The replayable edge source.
    pub stream: &'a mut dyn EdgeStream,
    /// Number of distinct vertices, when known (pre-sizing hint only).
    pub vertices: Option<usize>,
    /// Number of edges the stream yields, when known (pre-sizing hint
    /// only).
    pub edges: Option<usize>,
}

impl<'a> StreamInput<'a> {
    /// Wrap a stream with no size hints.
    pub fn new(stream: &'a mut dyn EdgeStream) -> StreamInput<'a> {
        StreamInput { stream, vertices: None, edges: None }
    }

    /// Materialize the stream into a [`Graph`] — the fallback path for
    /// partitioners that are not streaming-native (`algo` names the
    /// requester in errors). This forfeits the bounded-memory property,
    /// and it requires the stream to be *canonical* (sorted, deduplicated,
    /// as written by [`crate::graph::io::write_edge_list`]): otherwise the
    /// built graph's edge ids would not line up with stream positions and
    /// the returned owner vector would pair parts with the wrong edges.
    pub fn materialize(self, algo: &str) -> Result<Graph> {
        self.stream.reset()?;
        let mut edges = Vec::with_capacity(self.edges.unwrap_or(0));
        let mut buf = Vec::new();
        loop {
            if self.stream.fill(4096, &mut buf)? == 0 {
                break;
            }
            edges.extend_from_slice(&buf);
        }
        let mut b = GraphBuilder::new();
        for &(u, v) in &edges {
            b.push_edge(u, v);
        }
        let g = b.build();
        if g.edges() != &edges[..] {
            bail!(
                "'{algo}' needs a materialized graph, which requires a \
                 canonical edge list (sorted, deduplicated, as written by \
                 write_edge_list): the stream's edge sequence does not \
                 match the built graph's edge ids"
            );
        }
        Ok(g)
    }
}

/// The source a partitioner runs on: a materialized graph, or a
/// replayable stream of edges that never has to fit in memory.
pub enum PartitionInput<'a> {
    /// A fully materialized graph (the fast path for every partitioner).
    Graph(&'a Graph),
    /// A replayable edge stream + size hints. Streaming-native
    /// partitioners ([`streaming::Hdrf`], [`streaming::Dbh`],
    /// [`streaming::Restream`]) ingest it in bounded memory; the rest
    /// materialize it via [`StreamInput::materialize`].
    Stream(StreamInput<'a>),
}

impl<'a> From<&'a Graph> for PartitionInput<'a> {
    fn from(g: &'a Graph) -> PartitionInput<'a> {
        PartitionInput::Graph(g)
    }
}

impl<'a> From<StreamInput<'a>> for PartitionInput<'a> {
    fn from(s: StreamInput<'a>) -> PartitionInput<'a> {
        PartitionInput::Stream(s)
    }
}

/// Common interface for all edge partitioners.
///
/// The one entry point is [`partition`](Self::partition): fallible, and
/// source-aware through [`PartitionInput`] — bad `k`, empty inputs and
/// ingest I/O failures surface as `Err`, never panics. Implementors
/// provide the in-memory path ([`partition_graph`](Self::partition_graph));
/// streaming-native partitioners additionally override
/// [`partition`](Self::partition) to ingest the stream arm directly
/// instead of materializing it.
pub trait Partitioner {
    /// Partition the input into `k` parts; `seed` controls all
    /// randomness. The default implementation dispatches the graph arm to
    /// [`partition_graph`](Self::partition_graph) and materializes the
    /// stream arm first.
    fn partition(
        &self,
        input: PartitionInput<'_>,
        k: usize,
        seed: u64,
    ) -> Result<EdgePartition> {
        match input {
            PartitionInput::Graph(g) => self.partition_graph(g, k, seed),
            PartitionInput::Stream(s) => {
                let g = s.materialize(self.name())?;
                self.partition_graph(&g, k, seed)
            }
        }
    }

    /// Partition a materialized graph into `k` parts (the in-memory fast
    /// path; [`partition`](Self::partition) routes here).
    fn partition_graph(
        &self,
        g: &Graph,
        k: usize,
        seed: u64,
    ) -> Result<EdgePartition>;

    /// Short display name for benches/tables.
    fn name(&self) -> &'static str;

    /// True when the stream arm of [`partition`](Self::partition) ingests
    /// in bounded memory instead of materializing the graph.
    fn streaming_native(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::stream::MemoryEdgeStream;
    use crate::graph::GraphBuilder;
    use crate::partition::dfep::Dfep;

    fn square() -> Graph {
        GraphBuilder::new()
            .add_edge(0, 1)
            .add_edge(1, 2)
            .add_edge(2, 3)
            .add_edge(3, 0)
            .build()
    }

    #[test]
    fn sizes_and_sets() {
        let g = square();
        let p = EdgePartition { k: 2, owner: vec![0, 0, 1, 1], rounds: 1 };
        p.validate(&g).unwrap();
        assert_eq!(p.sizes(), vec![2, 2]);
        let es = p.edge_sets();
        assert_eq!(es[0], vec![0, 1]);
        assert_eq!(es[1], vec![2, 3]);
    }

    #[test]
    fn vertex_sets_and_frontier() {
        let g = square();
        // canonical edge order after build: (0,1),(0,3),(1,2),(2,3)
        assert_eq!(g.edges(), &[(0, 1), (0, 3), (1, 2), (2, 3)]);
        let p = EdgePartition { k: 2, owner: vec![0, 0, 1, 1], rounds: 1 };
        let vs = p.vertex_sets(&g);
        // part 0 owns edges (0,1),(0,3) -> vertices {0,1,3}
        let mut v0 = vs[0].clone();
        v0.sort_unstable();
        assert_eq!(v0, vec![0, 1, 3]);
        let mult = p.vertex_multiplicity(&g);
        // vertices 1 and 3 are frontier (in both parts)
        assert_eq!(mult, vec![1, 2, 1, 2]);
    }

    #[test]
    fn validate_catches_bad_owner_with_count() {
        let g = square();
        let p = EdgePartition { k: 2, owner: vec![0, 7, 5, 1], rounds: 0 };
        let e = p.validate(&g).unwrap_err().to_string();
        assert!(e.contains("2 edge(s)"), "{e}");
        assert!(e.contains("edge 1"), "{e}");
        let p2 = EdgePartition { k: 2, owner: vec![0, 0], rounds: 0 };
        assert!(p2.validate(&g).is_err());
    }

    #[test]
    fn validate_rejects_k_zero() {
        let g = square();
        let p = EdgePartition { k: 0, owner: vec![0; 4], rounds: 0 };
        let e = p.validate(&g).unwrap_err().to_string();
        assert!(e.contains("k=0"), "{e}");
    }

    #[test]
    fn partition_rejects_k_zero() {
        let g = square();
        let e = Dfep::default()
            .partition_graph(&g, 0, 1)
            .unwrap_err()
            .to_string();
        assert!(e.contains("k must be >= 1"), "{e}");
    }

    #[test]
    fn graph_partitioner_accepts_canonical_stream() {
        let g = square();
        let mut s = MemoryEdgeStream::from_graph(&g);
        let p = Dfep::default()
            .partition(PartitionInput::Stream(StreamInput::new(&mut s)), 2, 1)
            .unwrap();
        p.validate(&g).unwrap();
        // same input, same seed -> identical to the in-memory path
        let q = Dfep::default().partition_graph(&g, 2, 1).unwrap();
        assert_eq!(p.owner, q.owner);
    }

    #[test]
    fn graph_partitioner_rejects_noncanonical_stream() {
        // duplicate edge: the built graph dedups, so ids shift
        let mut s = MemoryEdgeStream::from_edges(vec![(0, 1), (0, 1), (1, 2)]);
        let err = Dfep::default()
            .partition(PartitionInput::Stream(StreamInput::new(&mut s)), 2, 1)
            .unwrap_err()
            .to_string();
        assert!(err.contains("canonical"), "{err}");
    }
}
