//! `name:key=val,...` partitioner specs: the one string grammar the CLI,
//! the facade, the benches and the tests use to name a configured
//! partitioner.
//!
//! ## Grammar
//!
//! ```text
//! spec   := name [ ":" param ("," param)* ]
//! param  := key "=" value
//! ```
//!
//! `name` is a canonical registry name or alias (case-insensitive; see
//! [`registry::all`]); keys and values are validated at parse time
//! against the registry's typed [`registry::ParamSpec`]s, so
//! [`PartitionerSpec::build`] is infallible. Examples:
//!
//! ```
//! use dfep::partition::spec::PartitionerSpec;
//!
//! let s: PartitionerSpec = "hdrf:lambda=1.5".parse().unwrap();
//! assert_eq!(s.to_string(), "hdrf:lambda=1.5");
//! assert_eq!(s.algo().name, "hdrf");
//! assert!("hdrf:lambda=abc".parse::<PartitionerSpec>().is_err());
//! assert!("nosuch".parse::<PartitionerSpec>().is_err());
//! ```
//!
//! ## Nested specs
//!
//! A [`registry::ParamKind::Spec`] parameter takes a whole partitioner
//! spec as its value. The nested spec keeps its own `:` but writes its
//! comma separators as `+` (the outer comma would otherwise end the
//! parameter), so
//!
//! ```text
//! refine:base=hdrf:lambda=1.5+group=512,rounds=2
//! ```
//!
//! nests `hdrf:lambda=1.5,group=512` under `refine`'s `base` key. The
//! nested value is parsed and validated recursively at parse time (and
//! re-canonicalized inside [`PartitionerSpec::canonical`], so
//! `refine:base=hdrf` and `refine:base=hdrf:lambda=1.1` share a cache
//! key); a spec may not nest its own entry (`refine:base=refine` is
//! rejected).
//!
//! ## Documented errors
//!
//! - unknown algorithm: `unknown partitioner 'nosuch' (known: dfep, ...)`
//! - unknown key: `hdrf: unknown parameter 'foo' (available: lambda,
//!   epsilon, group, chunk)`
//! - unparsable value: `hdrf: parameter 'lambda': expected a float, got
//!   'abc'`
//! - out-of-range value: `hdrf: parameter 'group' must be >= 1 (got 0)`
//! - malformed pair: `hdrf: bad parameter 'lambda' (expected key=value)`
//! - duplicate key: `hdrf: duplicate parameter 'lambda'`
//! - bad nested spec: `refine: parameter 'base': unknown partitioner
//!   'nosuch' (known: dfep, ...)` — the inner parse error, prefixed
//! - self-nesting: `refine: parameter 'base' must not name 'refine'
//!   itself`

use std::fmt;
use std::str::FromStr;

use crate::anyhow;
use crate::util::error::{Error, ErrorKind, Result};

use super::registry::{self, AlgoEntry, ParamKind};
use super::Partitioner;

/// A parsed, validated partitioner spec: a registry entry plus `key=val`
/// overrides in input order. Round-trips through [`fmt::Display`]
/// (`parse(s).to_string()` re-parses to an equal spec, with the name
/// canonicalized).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PartitionerSpec {
    name: &'static str,
    overrides: Vec<(String, String)>,
}

impl PartitionerSpec {
    /// Parse `name[:key=val,...]`; every error message is documented in
    /// the [module docs](self). All errors carry
    /// [`ErrorKind::InvalidSpec`].
    pub fn parse(s: &str) -> Result<PartitionerSpec> {
        Self::parse_inner(s).map_err(|e| e.with_kind(ErrorKind::InvalidSpec))
    }

    fn parse_inner(s: &str) -> Result<PartitionerSpec> {
        let s = s.trim();
        let (name, params) = match s.split_once(':') {
            Some((n, p)) => (n.trim(), Some(p)),
            None => (s, None),
        };
        let entry = registry::find(name).ok_or_else(|| {
            anyhow!(
                "unknown partitioner '{name}' (known: {})",
                registry::known_names()
            )
        })?;
        let mut overrides: Vec<(String, String)> = Vec::new();
        for pair in params.into_iter().flat_map(|p| p.split(',')) {
            let pair = pair.trim();
            if pair.is_empty() {
                // "hdrf:" (and stray commas) read as "no parameter here"
                continue;
            }
            let Some((key, value)) = pair.split_once('=') else {
                return Err(anyhow!(
                    "{}: bad parameter '{pair}' (expected key=value)",
                    entry.name
                ));
            };
            let (key, value) = (key.trim(), value.trim());
            let Some(spec) = entry.param(key) else {
                return Err(anyhow!(
                    "{}: unknown parameter '{key}' (available: {})",
                    entry.name,
                    available(entry)
                ));
            };
            if overrides.iter().any(|(k, _)| k == key) {
                return Err(anyhow!(
                    "{}: duplicate parameter '{key}'",
                    entry.name
                ));
            }
            let canonical = check_value(entry, spec, value)?;
            overrides.push((key.to_string(), canonical));
        }
        Ok(PartitionerSpec { name: entry.name, overrides })
    }

    /// The registry entry this spec names.
    pub fn algo(&self) -> &'static AlgoEntry {
        registry::find(self.name).expect("spec names a registered algo")
    }

    /// Construct the configured partitioner. Infallible: keys and values
    /// were validated by [`parse`](Self::parse).
    pub fn build(&self) -> Box<dyn Partitioner> {
        self.algo().build(&self.overrides)
    }

    /// The canonical algorithm name (no parameters).
    pub fn name(&self) -> &'static str {
        self.name
    }

    /// The `key=val` overrides, in input order.
    pub fn overrides(&self) -> &[(String, String)] {
        &self.overrides
    }

    /// The fully-elaborated canonical form: the registry name plus
    /// *every* parameter in registry order at its effective value
    /// (override if present, default otherwise). Unlike [`fmt::Display`]
    /// — which echoes only the explicit overrides, in input order — this
    /// form is identical for every spelling of the same configuration:
    /// `hdrf`, `HDRF:`, and `hdrf:lambda=1.1` (the default λ) all
    /// canonicalize to `hdrf:lambda=1.1,epsilon=1,group=1024,chunk=4096`.
    /// The serving layer's result cache keys on this string.
    pub fn canonical(&self) -> String {
        let entry = self.algo();
        if entry.params.is_empty() {
            return entry.name.to_string();
        }
        let cells: Vec<String> = entry
            .params
            .iter()
            .map(|p| {
                let v = match self.overrides.iter().find(|(k, _)| k == p.key)
                {
                    // nested specs re-canonicalize recursively, so the
                    // default-elided and default-explicit spellings of
                    // the inner spec collide too
                    Some((_, v)) if p.kind == ParamKind::Spec => {
                        canonical_spec_value(v)
                    }
                    Some((_, v)) => v.clone(),
                    None => canonical_default(p),
                };
                format!("{}={v}", p.key)
            })
            .collect();
        format!("{}:{}", entry.name, cells.join(","))
    }
}

/// The fully-elaborated canonical form of a stored nested-spec value
/// (`+`-separated), rendered back in the `+`-separated embedding.
fn canonical_spec_value(stored: &str) -> String {
    let inner = PartitionerSpec::parse(&stored.replace('+', ","))
        .expect("stored nested spec re-parses");
    inner.canonical().replace(',', "+")
}

/// Render a parameter's default through the same canonicalization as
/// explicit values (`"1.50"` would become `"1.5"`), so defaults and
/// default-valued overrides compare equal in [`PartitionerSpec::canonical`].
fn canonical_default(p: &super::registry::ParamSpec) -> String {
    match p.kind {
        ParamKind::Float => {
            let v: f64 = p.default.parse().expect("registry default parses");
            format!("{v}")
        }
        ParamKind::Int => {
            let v: usize = p.default.parse().expect("registry default parses");
            format!("{v}")
        }
        ParamKind::Bool => {
            let v = super::registry::parse_bool(p.default)
                .expect("registry default parses");
            format!("{v}")
        }
        ParamKind::Spec => canonical_spec_value(p.default),
    }
}

/// A spec with no parameter overrides for `entry` — the programmatic
/// counterpart of parsing the bare name.
pub fn default_spec(entry: &'static AlgoEntry) -> PartitionerSpec {
    PartitionerSpec { name: entry.name, overrides: Vec::new() }
}

impl fmt::Display for PartitionerSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name)?;
        for (i, (k, v)) in self.overrides.iter().enumerate() {
            f.write_str(if i == 0 { ":" } else { "," })?;
            write!(f, "{k}={v}")?;
        }
        Ok(())
    }
}

impl FromStr for PartitionerSpec {
    type Err = Error;

    fn from_str(s: &str) -> Result<PartitionerSpec> {
        PartitionerSpec::parse(s)
    }
}

fn available(entry: &AlgoEntry) -> String {
    if entry.params.is_empty() {
        return "no parameters".to_string();
    }
    let keys: Vec<&str> = entry.params.iter().map(|p| p.key).collect();
    keys.join(", ")
}

/// Validate `value` against `spec`, returning the canonical rendering
/// (so `Display` round-trips bit-identically: `1.50` becomes `1.5`).
fn check_value(
    entry: &AlgoEntry,
    spec: &super::registry::ParamSpec,
    value: &str,
) -> Result<String> {
    let bad = || {
        anyhow!(
            "{}: parameter '{}': expected {}, got '{value}'",
            entry.name,
            spec.key,
            spec.kind.article()
        )
    };
    let out_of_range = |got: f64| {
        anyhow!(
            "{}: parameter '{}' must be >= {} (got {got})",
            entry.name,
            spec.key,
            spec.min
        )
    };
    match spec.kind {
        ParamKind::Float => {
            let v: f64 = value.parse().map_err(|_| bad())?;
            if !v.is_finite() {
                return Err(bad());
            }
            if v < spec.min {
                return Err(out_of_range(v));
            }
            Ok(format!("{v}"))
        }
        ParamKind::Int => {
            let v: usize = value.parse().map_err(|_| bad())?;
            if (v as f64) < spec.min {
                return Err(out_of_range(v as f64));
            }
            Ok(format!("{v}"))
        }
        ParamKind::Bool => {
            let v = super::registry::parse_bool(value).ok_or_else(bad)?;
            Ok(format!("{v}"))
        }
        ParamKind::Spec => {
            // the nested spec writes its commas as '+'; recurse through
            // the full parser so every inner error surfaces, prefixed
            let inner = PartitionerSpec::parse(&value.replace('+', ","))
                .map_err(|e| {
                    anyhow!(
                        "{}: parameter '{}': {e}",
                        entry.name,
                        spec.key
                    )
                })?;
            if inner.name() == entry.name {
                return Err(anyhow!(
                    "{}: parameter '{}' must not name '{}' itself",
                    entry.name,
                    spec.key,
                    entry.name
                ));
            }
            // store in the embedded ('+'-separated) rendering
            Ok(inner.to_string().replace(',', "+"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bare_names_and_aliases_round_trip() {
        for e in registry::all() {
            let s = PartitionerSpec::parse(e.name).unwrap();
            assert_eq!(s.to_string(), e.name);
            assert_eq!(s, s.to_string().parse().unwrap());
            for a in e.aliases {
                // aliases canonicalize
                let s = PartitionerSpec::parse(a).unwrap();
                assert_eq!(s.name(), e.name);
            }
        }
    }

    #[test]
    fn params_round_trip_and_canonicalize() {
        let s = PartitionerSpec::parse("hdrf:lambda=1.50,group=512").unwrap();
        assert_eq!(s.to_string(), "hdrf:lambda=1.5,group=512");
        let again: PartitionerSpec = s.to_string().parse().unwrap();
        assert_eq!(s, again);
        // case-insensitive name, whitespace-tolerant names and pairs
        let s = PartitionerSpec::parse("HDRF: lambda = 2").unwrap();
        assert_eq!(s.to_string(), "hdrf:lambda=2");
        let s = PartitionerSpec::parse("hdrf : lambda=2").unwrap();
        assert_eq!(s.to_string(), "hdrf:lambda=2");
        // a bare trailing colon is the bare name, not an error
        assert_eq!(
            PartitionerSpec::parse("hdrf:").unwrap().to_string(),
            "hdrf"
        );
    }

    #[test]
    fn documented_error_messages() {
        let err = |s: &str| PartitionerSpec::parse(s).unwrap_err().to_string();
        assert!(
            err("nosuch").starts_with("unknown partitioner 'nosuch' (known: dfep,"),
            "{}",
            err("nosuch")
        );
        assert_eq!(
            err("hdrf:lambda=abc"),
            "hdrf: parameter 'lambda': expected a float, got 'abc'"
        );
        assert_eq!(
            err("hdrf:foo=1"),
            "hdrf: unknown parameter 'foo' (available: lambda, epsilon, \
             group, chunk)"
        );
        assert_eq!(
            err("random:x=1"),
            "random: unknown parameter 'x' (available: no parameters)"
        );
        assert_eq!(
            err("hdrf:lambda"),
            "hdrf: bad parameter 'lambda' (expected key=value)"
        );
        assert_eq!(
            err("hdrf:lambda=1,lambda=2"),
            "hdrf: duplicate parameter 'lambda'"
        );
        assert_eq!(
            err("hdrf:group=0"),
            "hdrf: parameter 'group' must be >= 1 (got 0)"
        );
        assert_eq!(
            err("fennel:shuffle=maybe"),
            "fennel: parameter 'shuffle': expected a bool (true|false|1|0), \
             got 'maybe'"
        );
        // nested-spec errors: the inner parse error, prefixed
        assert!(
            err("refine:base=nosuch").starts_with(
                "refine: parameter 'base': unknown partitioner 'nosuch' \
                 (known: dfep,"
            ),
            "{}",
            err("refine:base=nosuch")
        );
        assert_eq!(
            err("refine:base=hdrf:lambda=abc"),
            "refine: parameter 'base': hdrf: parameter 'lambda': \
             expected a float, got 'abc'"
        );
        assert_eq!(
            err("refine:base=refine"),
            "refine: parameter 'base' must not name 'refine' itself"
        );
        assert_eq!(
            err("refine:rounds=0"),
            "refine: parameter 'rounds' must be >= 1 (got 0)"
        );
    }

    #[test]
    fn nested_specs_round_trip_and_canonicalize() {
        // the inner spec keeps its ':' and writes its commas as '+'
        let s = PartitionerSpec::parse(
            "refine:base=hdrf:lambda=1.50+group=512,rounds=2",
        )
        .unwrap();
        assert_eq!(
            s.to_string(),
            "refine:base=hdrf:lambda=1.5+group=512,rounds=2"
        );
        let again: PartitionerSpec = s.to_string().parse().unwrap();
        assert_eq!(s, again);
        // inner default-elided / default-explicit spellings share a
        // cache key: the nested value re-canonicalizes recursively
        let bare = PartitionerSpec::parse("refine").unwrap();
        let explicit =
            PartitionerSpec::parse("refine:base=hdrf:lambda=1.1").unwrap();
        assert_eq!(bare.canonical(), explicit.canonical());
        assert_eq!(
            bare.canonical(),
            "refine:base=hdrf:lambda=1.1+epsilon=1+group=1024+chunk=4096,\
             rounds=4,eps=0.05"
        );
        // a genuinely tuned inner spec gets its own cache key
        let tuned =
            PartitionerSpec::parse("refine:base=hdrf:lambda=1.5").unwrap();
        assert_ne!(tuned.canonical(), bare.canonical());
        // a parameterless inner spec stays bare
        let s = PartitionerSpec::parse("refine:base=random").unwrap();
        assert_eq!(s.to_string(), "refine:base=random");
        assert_eq!(s, s.to_string().parse().unwrap());
    }

    #[test]
    fn canonical_elaborates_defaults_and_collides_spellings() {
        // the default-elided / alias / explicit-default spellings of one
        // configuration share a single canonical form (the serving
        // layer's cache-key regression: DESIGN.md "Serving layer")
        let bare = PartitionerSpec::parse("hdrf").unwrap();
        let explicit = PartitionerSpec::parse("hdrf:lambda=1.1").unwrap();
        assert_ne!(bare.to_string(), explicit.to_string());
        assert_eq!(bare.canonical(), explicit.canonical());
        assert_eq!(
            bare.canonical(),
            "hdrf:lambda=1.1,epsilon=1,group=1024,chunk=4096"
        );
        // a real override shows up in canonical form
        let tuned = PartitionerSpec::parse("hdrf:lambda=1.5").unwrap();
        assert_ne!(tuned.canonical(), bare.canonical());
        // value canonicalization applies ("1.10" == default 1.1)
        let padded = PartitionerSpec::parse("hdrf:lambda=1.10").unwrap();
        assert_eq!(padded.canonical(), bare.canonical());
        // aliases collide with their registry name
        for e in registry::all() {
            let c = default_spec(e).canonical();
            for a in e.aliases {
                assert_eq!(PartitionerSpec::parse(a).unwrap().canonical(), c);
            }
            // canonical form is itself a parsable spec that round-trips
            let re = PartitionerSpec::parse(&c).unwrap();
            assert_eq!(re.canonical(), c, "{}", e.name);
        }
    }

    #[test]
    fn parse_errors_carry_invalid_spec_kind() {
        for s in ["nosuch", "hdrf:lambda=abc", "hdrf:nope=3", "dfep:cap"] {
            let e = PartitionerSpec::parse(s).unwrap_err();
            assert_eq!(e.kind(), ErrorKind::InvalidSpec, "{s}");
        }
    }

    #[test]
    fn built_partitioner_reflects_overrides() {
        use crate::graph::generators::GraphKind;
        use crate::partition::metrics;
        let g = GraphKind::PowerlawCluster { n: 400, m: 4, p: 0.3 }
            .generate(5);
        // a huge lambda forces near-perfect balance vs the default
        let tuned = PartitionerSpec::parse("hdrf:lambda=1000")
            .unwrap()
            .build()
            .partition_graph(&g, 8, 1)
            .unwrap();
        let default = PartitionerSpec::parse("hdrf")
            .unwrap()
            .build()
            .partition_graph(&g, 8, 1)
            .unwrap();
        assert!(
            metrics::largest(&g, &tuned) <= metrics::largest(&g, &default)
        );
        assert_ne!(tuned.owner, default.owner);
    }
}
