//! Flat per-(partition, vertex) funding ledger for the DFEP engines.
//!
//! DFEP's money state is conceptually a `k x n` matrix: partition `i`'s
//! cash on vertex `v`. The old representation (`Vec<Vec<f64>>`) paid one
//! heap allocation per partition and scattered the rows across the heap;
//! [`MoneyLedger`] flattens it into **one** `k * stride` allocation with
//! partition `i`'s row at cells `[i * stride, (i + 1) * stride)`, so a
//! per-partition sweep is a cache-linear slice walk and the whole ledger
//! can be snapshotted, cleared or converted in a single pass.
//!
//! The ledger is shared by the reference engine
//! ([`crate::partition::dfep::DfepState`]), the DFEPC variant, the
//! MapReduce-shaped cluster run ([`crate::cluster::dfep_mr`]) and the
//! XLA-offloaded engine ([`crate::runtime::xla_engine`]), which packs it
//! to / unpacks it from the `f32` tensors of the `funding_step` artifact
//! via [`MoneyLedger::fill_f32`] / [`MoneyLedger::load_f32`].

/// Dense `k x stride` funding ledger in one flat `f64` allocation.
///
/// `stride` is normally the vertex count; the XLA engine uses the
/// artifact's padded vertex capacity instead so rows line up with the
/// compiled tensor layout.
#[derive(Clone, Debug, PartialEq)]
pub struct MoneyLedger {
    /// Cells per partition row (>= 1).
    stride: usize,
    /// Row-major cells: `cells[i * stride + v]` = partition `i`'s cash on
    /// vertex `v`.
    cells: Vec<f64>,
}

impl MoneyLedger {
    /// Zero-filled ledger for `k` partitions with `stride` cells each.
    pub fn new(k: usize, stride: usize) -> MoneyLedger {
        let stride = stride.max(1);
        MoneyLedger { stride, cells: vec![0.0; k * stride] }
    }

    /// Number of partition rows.
    pub fn parts(&self) -> usize {
        self.cells.len() / self.stride
    }

    /// Cells per partition row.
    pub fn stride(&self) -> usize {
        self.stride
    }

    /// Partition `i`'s cash on vertex `v`.
    #[inline]
    pub fn get(&self, i: usize, v: usize) -> f64 {
        self.cells[i * self.stride + v]
    }

    /// Mutable cell for partition `i`, vertex `v`.
    #[inline]
    pub fn cell_mut(&mut self, i: usize, v: usize) -> &mut f64 {
        &mut self.cells[i * self.stride + v]
    }

    /// Partition `i`'s row (cache-linear slice of `stride` cells).
    #[inline]
    pub fn part(&self, i: usize) -> &[f64] {
        &self.cells[i * self.stride..(i + 1) * self.stride]
    }

    /// Mutable row for partition `i`.
    #[inline]
    pub fn part_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.cells[i * self.stride..(i + 1) * self.stride]
    }

    /// All rows as disjoint mutable slices, in partition order (for
    /// per-partition parallel phases).
    pub fn rows_mut(
        &mut self,
    ) -> std::slice::ChunksExactMut<'_, f64> {
        self.cells.chunks_exact_mut(self.stride)
    }

    /// The raw row-major cells (e.g. for bit-exact trajectory pinning).
    pub fn cells(&self) -> &[f64] {
        &self.cells
    }

    /// Raw mutable pointer to the row-major cells — for the engine's
    /// disjoint per-partition parallel phases (each shard slices its own
    /// row, exactly like `pool::run_mut` hands out disjoint `&mut`s).
    pub(crate) fn as_mut_ptr(&mut self) -> *mut f64 {
        self.cells.as_mut_ptr()
    }

    /// Sum of partition `i`'s row.
    pub fn part_total(&self, i: usize) -> f64 {
        self.part(i).iter().sum()
    }

    /// Sum over the whole ledger (the conservation invariant's left side).
    pub fn total(&self) -> f64 {
        self.cells.iter().sum()
    }

    /// Zero every cell (keeps the allocation).
    pub fn clear(&mut self) {
        self.cells.fill(0.0);
    }

    /// Reshape to `k` zeroed rows of `stride` cells, reusing the existing
    /// allocation (grow-only capacity). Equivalent to
    /// `*self = MoneyLedger::new(k, stride)` without the fresh heap
    /// allocation — the reuse hook behind
    /// [`crate::partition::dfep::DfepState::reset`].
    pub fn reset(&mut self, k: usize, stride: usize) {
        self.stride = stride.max(1);
        self.cells.clear();
        self.cells.resize(k * self.stride, 0.0);
    }

    /// Pack the ledger into an `f32` buffer of the same layout (the XLA
    /// `funding_step` artifact's money tensor). `out.len()` must equal
    /// `parts() * stride()`.
    pub fn fill_f32(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.cells.len(), "f32 buffer shape mismatch");
        for (o, &c) in out.iter_mut().zip(&self.cells) {
            *o = c as f32;
        }
    }

    /// Load the ledger from an `f32` buffer of the same layout (the money
    /// tensor the artifact returns).
    pub fn load_f32(&mut self, src: &[f32]) {
        assert_eq!(src.len(), self.cells.len(), "f32 buffer shape mismatch");
        for (c, &s) in self.cells.iter_mut().zip(src) {
            *c = s as f64;
        }
    }

    /// Heap footprint of the ledger in bytes.
    pub fn bytes(&self) -> usize {
        self.cells.capacity() * std::mem::size_of::<f64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_are_disjoint_and_strided() {
        let mut m = MoneyLedger::new(3, 4);
        *m.cell_mut(0, 1) = 1.5;
        *m.cell_mut(2, 3) = 2.5;
        assert_eq!(m.get(0, 1), 1.5);
        assert_eq!(m.part(2), &[0.0, 0.0, 0.0, 2.5]);
        assert_eq!(m.part_total(0), 1.5);
        assert_eq!(m.total(), 4.0);
        assert_eq!(m.parts(), 3);
        let rows: Vec<Vec<f64>> =
            m.rows_mut().map(|r| r.to_vec()).collect();
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[2][3], 2.5);
        m.clear();
        assert_eq!(m.total(), 0.0);
    }

    #[test]
    fn reset_matches_fresh_ledger() {
        let mut m = MoneyLedger::new(3, 4);
        *m.cell_mut(2, 3) = 9.0;
        m.reset(2, 6);
        assert_eq!(m, MoneyLedger::new(2, 6));
        m.reset(4, 0);
        assert_eq!(m, MoneyLedger::new(4, 0));
    }

    #[test]
    fn f32_roundtrip_matches_layout() {
        let mut m = MoneyLedger::new(2, 3);
        *m.cell_mut(1, 2) = 7.0;
        let mut buf = vec![0f32; 6];
        m.fill_f32(&mut buf);
        assert_eq!(buf, vec![0.0, 0.0, 0.0, 0.0, 0.0, 7.0]);
        buf[0] = 3.0;
        m.load_f32(&buf);
        assert_eq!(m.get(0, 0), 3.0);
        assert_eq!(m.get(1, 2), 7.0);
    }

    #[test]
    fn zero_stride_is_clamped() {
        let m = MoneyLedger::new(2, 0);
        assert_eq!(m.stride(), 1);
        assert_eq!(m.parts(), 2);
    }
}
