//! Local-search refinement: the `refine:` meta-spec (registry entry #12).
//!
//! Every other registry entry is one-shot — once HDRF/DFEP/DBH emit
//! owners, nothing improves them. Guo et al. 2021 (*Enhancing Balanced
//! Graph Edge Partition with Effective Local Search*) show a cheap
//! edge-move/swap post-pass cuts the replication factor of **any**
//! initial partition. [`Refine`] wraps that post-pass as a composable
//! partitioner: `refine:base=<spec>,rounds=N,eps=E` runs the `base` spec
//! first (any registry entry, its own parameters separated by `+`
//! instead of `,` — `refine:base=hdrf:lambda=1.5+group=512,rounds=4`),
//! then drives [`RefineEngine`] for up to `rounds` local-search rounds.
//! Because it is an ordinary registry entry, the CLI, the batch engine
//! and the serve layer all compose with it with zero new plumbing.
//!
//! ## Neighborhoods and acceptance rule
//!
//! The engine maintains a live per-(vertex, part) incident-edge count
//! table (a fixed-capacity CSR sized `min(k, deg(v))` per vertex — the
//! distinct-part count can never exceed either bound). For an edge
//! `e = (u, v)` owned by part `a`, moving it to part `b` changes the
//! total replica count by
//!
//! ```text
//! gain(e, b) = [cnt(u,b) == 0] + [cnt(v,b) == 0]      // new replicas
//!            - [cnt(u,a) == 1] - [cnt(v,a) == 1]      // freed replicas
//! ```
//!
//! - **Edge move**: accepted only when the *live* gain is strictly
//!   negative and `|E_b| + 1` stays within the balance cap
//!   `⌊(1 + eps) · ⌈m/k⌉⌋`.
//! - **Pairwise swap**: negative-gain moves that fail only the balance
//!   cap are collected, sorted by their unordered part pair, and paired
//!   `a→b` with `b→a`; both edges move together (sizes net unchanged)
//!   and the pair is reverted unless the combined live gain is strictly
//!   negative.
//!
//! Every accepted change strictly decreases the total replica count, so
//! the replication factor is *never* worse after refinement (the
//! Restream invariant, re-proved per move instead of per pass), and the
//! count is bounded below — refinement always terminates.
//!
//! ## Determinism
//!
//! Each round is a frozen parallel scan + a sequential apply. The scan
//! shards the edge range into fixed [`SHARD_EDGES`]-sized slices on
//! [`crate::util::pool`]; each shard writes its proposals into its own
//! persistent buffer as a pure function of the frozen round state, and
//! the apply phase walks shards in index order, re-validating every
//! proposal against the live counts (gain buckets: −2 moves before −1).
//! Owners are therefore bit-identical for every pool thread count.
//!
//! ## Memory model
//!
//! All round state lives in [`RefineScratch`] and the count CSR,
//! allocated once and grown to high-water capacity during warm-up — in
//! steady state (and in particular once the engine reaches its fixed
//! point) a round allocates **zero** heap memory, pinned by
//! `tests/refine_alloc.rs` exactly like the PR5 DFEP budget contract.

use crate::graph::Graph;
use crate::util::error::Result;
use crate::util::pool;

use super::spec::PartitionerSpec;
use super::view::PartitionView;
use super::{check_k, EdgePartition, Partitioner};

/// Edges per frozen-scan shard. Fixed (never derived from the thread
/// count) so shard boundaries — and thus proposal order — are identical
/// for every pool width.
pub const SHARD_EDGES: usize = 1024;

/// One candidate relocation of `edge` from part `from` to part `to`,
/// with the gain (replica-count delta) computed against the state it
/// was scanned or re-validated under.
#[derive(Clone, Copy, Debug)]
struct Proposal {
    edge: u32,
    from: u32,
    to: u32,
}

/// Persistent round buffers (the PR5 zero-alloc pattern): per-shard
/// proposal buffers for the frozen scan, gain buckets for the apply
/// order, and the balance-blocked queue that feeds the swap phase. All
/// buffers are cleared — never dropped — between rounds, so steady-state
/// rounds allocate nothing.
pub struct RefineScratch {
    /// One proposal buffer per scan shard (index = shard index).
    shards: Vec<Vec<Proposal>>,
    /// Apply-order buckets: gain −2 proposals, then gain −1.
    buckets: [Vec<Proposal>; 2],
    /// Negative-gain moves rejected only by the balance cap — the swap
    /// phase pairs these across opposite directions of one part pair.
    blocked: Vec<Proposal>,
}

impl RefineScratch {
    fn new() -> RefineScratch {
        RefineScratch {
            shards: Vec::new(),
            buckets: [Vec::new(), Vec::new()],
            blocked: Vec::new(),
        }
    }

    /// High-water footprint of the persistent buffers in bytes (for the
    /// hotpath bench, like `DfepState::scratch_peak_bytes`).
    pub fn peak_bytes(&self) -> usize {
        let slot = std::mem::size_of::<Proposal>();
        let shards: usize = self.shards.iter().map(|b| b.capacity()).sum();
        let buckets: usize =
            self.buckets.iter().map(|b| b.capacity()).sum();
        (shards + buckets + self.blocked.capacity()) * slot
    }
}

/// The local-search engine: live owner array, part sizes, the
/// per-(vertex, part) count table and the persistent [`RefineScratch`].
///
/// [`round`](Self::round) runs one scan + apply round and returns the
/// number of accepted changes; [`Refine`] drives it to `rounds` or to
/// the first round that applies nothing, and the invariant tests drive
/// it round-by-round (validating owners after every round).
pub struct RefineEngine {
    k: usize,
    /// Balance cap: moves may not push any part past this edge count.
    cap: usize,
    owner: Vec<u32>,
    sizes: Vec<u32>,
    /// Count-table CSR offsets per vertex (capacity `min(k, deg(v))`).
    cnt_off: Vec<u32>,
    /// Live entry count per vertex (`<=` its CSR capacity).
    cnt_len: Vec<u32>,
    /// Part id per live entry.
    cnt_part: Vec<u32>,
    /// Incident-edge count per live entry (always `>= 1`).
    cnt_val: Vec<u32>,
    total_replicas: usize,
    scratch: RefineScratch,
    /// Rounds executed so far (including the terminating no-op round).
    pub rounds: usize,
    /// Single edge moves accepted so far.
    pub moves_applied: usize,
    /// Pairwise swaps accepted so far (each relocates two edges).
    pub swaps_applied: usize,
}

impl RefineEngine {
    /// Build the engine for `part`, deriving a fresh [`PartitionView`]
    /// internally. `eps` is the balance slack: the cap is
    /// `⌊(1 + eps) · ⌈m/k⌉⌋`.
    pub fn new(g: &Graph, part: &EdgePartition, eps: f64) -> RefineEngine {
        let view = PartitionView::build(g, part);
        RefineEngine::from_view(g, part, &view, eps)
    }

    /// Build the engine from a prebuilt view of the same `(g, part)`
    /// pair: the replica table seeds each vertex's part list (parts
    /// ascending — the view's canonical order) and the multiplicity
    /// column seeds the frontier filter; one adjacency pass fills in the
    /// per-part incident counts.
    pub fn from_view(
        g: &Graph,
        part: &EdgePartition,
        view: &PartitionView,
        eps: f64,
    ) -> RefineEngine {
        let k = part.k;
        let n = g.vertex_count();
        let m = g.edge_count();
        let ideal = if k == 0 { 0 } else { (m + k - 1) / k };
        let cap_f = (1.0 + eps.max(0.0)) * ideal as f64;
        let cap = if cap_f >= m as f64 { m } else { cap_f as usize };

        let mut cnt_off = vec![0u32; n + 1];
        for v in 0..n {
            let slots = g.neighbor_edges(v as u32).len().min(k);
            cnt_off[v + 1] = cnt_off[v] + slots as u32;
        }
        let mut cnt_len = vec![0u32; n];
        let mut cnt_part = vec![0u32; cnt_off[n] as usize];
        let mut cnt_val = vec![0u32; cnt_off[n] as usize];
        for v in 0..n {
            let lo = cnt_off[v] as usize;
            let reps = view.replicas_of(v as u32);
            for (i, &(p, _)) in reps.iter().enumerate() {
                cnt_part[lo + i] = p;
            }
            cnt_len[v] = reps.len() as u32;
            for &e in g.neighbor_edges(v as u32) {
                let p = part.owner[e as usize];
                let len = cnt_len[v] as usize;
                let slot = cnt_part[lo..lo + len]
                    .iter()
                    .position(|&q| q == p)
                    .expect("owner part is in the vertex's replica list");
                cnt_val[lo + slot] += 1;
            }
        }

        RefineEngine {
            k,
            cap,
            owner: part.owner.clone(),
            sizes: view.sizes().iter().map(|&s| s as u32).collect(),
            cnt_off,
            cnt_len,
            cnt_part,
            cnt_val,
            total_replicas: view.replica_total(),
            scratch: RefineScratch::new(),
            rounds: 0,
            moves_applied: 0,
            swaps_applied: 0,
        }
    }

    /// The live owner array (valid and complete after every round).
    pub fn owner(&self) -> &[u32] {
        &self.owner
    }

    /// The live total replica count Σ_v |{parts containing v}| — the
    /// replication factor's numerator. Strictly decreases with every
    /// accepted change.
    pub fn total_replicas(&self) -> usize {
        self.total_replicas
    }

    /// The balance cap `⌊(1 + eps) · ⌈m/k⌉⌋` moves are checked against.
    pub fn cap(&self) -> usize {
        self.cap
    }

    /// High-water footprint of the persistent round buffers in bytes.
    pub fn scratch_peak_bytes(&self) -> usize {
        self.scratch.peak_bytes()
    }

    /// Run up to `rounds` rounds, stopping early at the first round that
    /// applies nothing. Returns the total number of accepted changes.
    pub fn run(&mut self, g: &Graph, rounds: usize) -> usize {
        let mut applied = 0usize;
        for _ in 0..rounds {
            let got = self.round(g);
            applied += got;
            if got == 0 {
                break;
            }
        }
        applied
    }

    /// One refinement round: frozen parallel scan, then sequential apply
    /// (moves in gain order, then pairwise swaps). Returns the number of
    /// accepted changes (moves + swaps); `0` means the engine reached a
    /// fixed point and further rounds are no-ops.
    pub fn round(&mut self, g: &Graph) -> usize {
        self.rounds += 1;
        let m = self.owner.len();
        if m == 0 || self.k < 2 {
            return 0;
        }
        let shard_count = (m + SHARD_EDGES - 1) / SHARD_EDGES;
        if self.scratch.shards.len() < shard_count {
            self.scratch.shards.resize_with(shard_count, Vec::new);
        }

        // ---- frozen scan: each shard is a pure function of the round's
        // starting state, writing into its own persistent buffer ----
        {
            let owner = &self.owner;
            let cnt_off = &self.cnt_off;
            let cnt_len = &self.cnt_len;
            let cnt_part = &self.cnt_part;
            let cnt_val = &self.cnt_val;
            pool::run_mut(
                &mut self.scratch.shards[..shard_count],
                &|s, buf: &mut Vec<Proposal>| {
                    buf.clear();
                    let lo = s * SHARD_EDGES;
                    let hi = (lo + SHARD_EDGES).min(m);
                    for e in lo..hi {
                        let a = owner[e];
                        let (u, v) = g.endpoints(e as u32);
                        if let Some(to) = best_target(
                            cnt_off, cnt_len, cnt_part, cnt_val, u, v, a,
                        ) {
                            buf.push(Proposal { edge: e as u32, from: a, to });
                        }
                    }
                },
            );
        }

        // ---- bucket the proposals in fixed shard order: gain −2 moves
        // apply before gain −1 (bigger wins first, so a −1 move cannot
        // consume balance headroom a −2 move needed) ----
        {
            let RefineScratch { shards, buckets, blocked } = &mut self.scratch;
            buckets[0].clear();
            buckets[1].clear();
            blocked.clear();
            for buf in shards[..shard_count].iter() {
                for &p in buf.iter() {
                    let gain = frozen_gain(
                        &self.cnt_off,
                        &self.cnt_len,
                        &self.cnt_part,
                        &self.cnt_val,
                        g,
                        p,
                    );
                    buckets[if gain <= -2 { 0 } else { 1 }].push(p);
                }
            }
        }

        // ---- sequential apply with live re-validation (the Restream
        // idiom: the acceptance rule is re-proved against the counts as
        // they are *now*, not as the scan froze them) ----
        let mut applied = 0usize;
        for bucket in 0..2 {
            let mut i = 0usize;
            while i < self.scratch.buckets[bucket].len() {
                let p = self.scratch.buckets[bucket][i];
                i += 1;
                let (u, v) = g.endpoints(p.edge);
                let a = self.owner[p.edge as usize];
                debug_assert_eq!(a, p.from, "blocked edges never moved");
                let gain = self.live_gain(u, v, a, p.to);
                if gain >= 0 {
                    continue;
                }
                if self.sizes[p.to as usize] as usize + 1 > self.cap {
                    self.scratch.blocked.push(Proposal {
                        edge: p.edge,
                        from: a,
                        to: p.to,
                    });
                    continue;
                }
                self.apply(g, p.edge, p.to);
                self.moves_applied += 1;
                applied += 1;
            }
        }

        applied += self.swap_phase(g);
        applied
    }

    /// Pair balance-blocked moves across opposite directions of one part
    /// pair and apply both together (sizes net unchanged); revert unless
    /// the combined live gain is strictly negative.
    fn swap_phase(&mut self, g: &Graph) -> usize {
        // deterministic total order: unordered part pair, then direction,
        // then edge id (unique per proposal)
        self.scratch.blocked.sort_unstable_by_key(|p| {
            (p.from.min(p.to), p.from.max(p.to), p.from, p.edge)
        });
        let mut applied = 0usize;
        let total = self.scratch.blocked.len();
        let mut i = 0usize;
        while i < total {
            let head = self.scratch.blocked[i];
            let (lo, hi) = (head.from.min(head.to), head.from.max(head.to));
            let mut j = i + 1;
            while j < total {
                let q = self.scratch.blocked[j];
                if (q.from.min(q.to), q.from.max(q.to)) != (lo, hi) {
                    break;
                }
                j += 1;
            }
            // within the group entries sort by `from`: lo→hi first
            let mut split = i;
            while split < j && self.scratch.blocked[split].from == lo {
                split += 1;
            }
            let pairs = (split - i).min(j - split);
            for t in 0..pairs {
                let p = self.scratch.blocked[i + t];
                let q = self.scratch.blocked[split + t];
                if self.try_swap(g, p, q) {
                    applied += 1;
                }
            }
            i = j;
        }
        applied
    }

    /// Apply `p` (lo→hi) and `q` (hi→lo) together; keep iff the combined
    /// live gain is strictly negative, else revert both exactly.
    fn try_swap(&mut self, g: &Graph, p: Proposal, q: Proposal) -> bool {
        debug_assert_eq!(self.owner[p.edge as usize], p.from);
        debug_assert_eq!(self.owner[q.edge as usize], q.from);
        debug_assert_eq!((p.from, p.to), (q.to, q.from));
        let before = self.total_replicas;
        self.apply(g, p.edge, p.to);
        self.apply(g, q.edge, q.to);
        if self.total_replicas < before {
            self.swaps_applied += 1;
            true
        } else {
            self.apply(g, q.edge, q.from);
            self.apply(g, p.edge, p.from);
            debug_assert_eq!(self.total_replicas, before);
            false
        }
    }

    /// Replica-count delta of moving `(u, v)` from `a` to `b` under the
    /// live counts.
    fn live_gain(&self, u: u32, v: u32, a: u32, b: u32) -> i32 {
        let mut gain = 0i32;
        for x in [u, v] {
            let lo = self.cnt_off[x as usize] as usize;
            let len = self.cnt_len[x as usize] as usize;
            let parts = &self.cnt_part[lo..lo + len];
            let vals = &self.cnt_val[lo..lo + len];
            if count_in(parts, vals, a) == 1 {
                gain -= 1;
            }
            if count_in(parts, vals, b) == 0 {
                gain += 1;
            }
        }
        gain
    }

    /// Move one edge and maintain sizes, counts and the replica total.
    /// The vacated part is decremented *before* the target is
    /// incremented so the per-vertex entry count never exceeds the CSR
    /// capacity `min(k, deg)`.
    fn apply(&mut self, g: &Graph, e: u32, b: u32) {
        let a = self.owner[e as usize];
        debug_assert_ne!(a, b);
        let (u, v) = g.endpoints(e);
        self.owner[e as usize] = b;
        self.sizes[a as usize] -= 1;
        self.sizes[b as usize] += 1;
        for x in [u, v] {
            if self.dec(x, a) {
                self.total_replicas -= 1;
            }
            if self.inc(x, b) {
                self.total_replicas += 1;
            }
        }
    }

    /// Decrement `v`'s count in part `p`; swap-remove the entry when it
    /// reaches zero. Returns true when the vertex left the part.
    fn dec(&mut self, v: u32, p: u32) -> bool {
        let lo = self.cnt_off[v as usize] as usize;
        let len = self.cnt_len[v as usize] as usize;
        let slot = self.cnt_part[lo..lo + len]
            .iter()
            .position(|&q| q == p)
            .expect("decrement of a part the vertex is not in");
        let i = lo + slot;
        self.cnt_val[i] -= 1;
        if self.cnt_val[i] == 0 {
            let last = lo + len - 1;
            self.cnt_part[i] = self.cnt_part[last];
            self.cnt_val[i] = self.cnt_val[last];
            self.cnt_len[v as usize] -= 1;
            true
        } else {
            false
        }
    }

    /// Increment `v`'s count in part `p`, appending a fresh entry on
    /// first contact. Returns true when the vertex entered the part.
    fn inc(&mut self, v: u32, p: u32) -> bool {
        let lo = self.cnt_off[v as usize] as usize;
        let len = self.cnt_len[v as usize] as usize;
        if let Some(slot) =
            self.cnt_part[lo..lo + len].iter().position(|&q| q == p)
        {
            self.cnt_val[lo + slot] += 1;
            false
        } else {
            debug_assert!(
                lo + len < self.cnt_off[v as usize + 1] as usize,
                "count CSR capacity min(k, deg) overflowed"
            );
            self.cnt_part[lo + len] = p;
            self.cnt_val[lo + len] = 1;
            self.cnt_len[v as usize] += 1;
            true
        }
    }
}

/// Incident-edge count of part `p` in one vertex's live entry list
/// (`0` when the vertex has no edge in `p`).
#[inline]
fn count_in(parts: &[u32], vals: &[u32], p: u32) -> u32 {
    parts
        .iter()
        .position(|&q| q == p)
        .map(|i| vals[i])
        .unwrap_or(0)
}

/// Frozen-state gain of a scanned proposal (used only to bucket the
/// apply order; acceptance always re-checks the live gain).
fn frozen_gain(
    cnt_off: &[u32],
    cnt_len: &[u32],
    cnt_part: &[u32],
    cnt_val: &[u32],
    g: &Graph,
    p: Proposal,
) -> i32 {
    let (u, v) = g.endpoints(p.edge);
    let mut gain = 0i32;
    for x in [u, v] {
        let lo = cnt_off[x as usize] as usize;
        let len = cnt_len[x as usize] as usize;
        let parts = &cnt_part[lo..lo + len];
        let vals = &cnt_val[lo..lo + len];
        if count_in(parts, vals, p.from) == 1 {
            gain -= 1;
        }
        if count_in(parts, vals, p.to) == 0 {
            gain += 1;
        }
    }
    gain
}

/// The best strictly-negative-gain target for edge `(u, v)` currently in
/// part `a`, minimizing `(gain, part id)` — order-independent, so the
/// result does not depend on entry order inside the count lists.
/// Candidates are the parts either endpoint already lives in (any other
/// target only adds replicas); an edge with neither endpoint replicated
/// is skipped by the `free == 0` frontier filter.
fn best_target(
    cnt_off: &[u32],
    cnt_len: &[u32],
    cnt_part: &[u32],
    cnt_val: &[u32],
    u: u32,
    v: u32,
    a: u32,
) -> Option<u32> {
    let lou = cnt_off[u as usize] as usize;
    let lenu = cnt_len[u as usize] as usize;
    let (pu, vu) =
        (&cnt_part[lou..lou + lenu], &cnt_val[lou..lou + lenu]);
    let lov = cnt_off[v as usize] as usize;
    let lenv = cnt_len[v as usize] as usize;
    let (pv, vv) =
        (&cnt_part[lov..lov + lenv], &cnt_val[lov..lov + lenv]);
    let free = (count_in(pu, vu, a) == 1) as i32
        + (count_in(pv, vv, a) == 1) as i32;
    if free == 0 {
        // interior edge: vacating `a` frees nothing, gain can't go
        // negative
        return None;
    }
    let mut best: Option<(i32, u32)> = None;
    for &b in pu.iter().chain(pv.iter()) {
        if b == a {
            continue;
        }
        // a part in both lists is visited twice; the (gain, part)
        // minimum is idempotent so the repeat is harmless
        let cost = (count_in(pu, vu, b) == 0) as i32
            + (count_in(pv, vv, b) == 0) as i32;
        let gain = cost - free;
        if gain >= 0 {
            continue;
        }
        let cand = (gain, b);
        if best.is_none_or(|x| cand < x) {
            best = Some(cand);
        }
    }
    best.map(|(_, b)| b)
}

/// The `refine:` meta-partitioner: run `base`, then local-search it.
pub struct Refine {
    /// The initial partitioner (any registry spec except `refine`
    /// itself; its parameters use `+` as the separator inside the
    /// `base=` value).
    pub base: PartitionerSpec,
    /// Maximum local-search rounds (early-stops at a fixed point).
    pub rounds: usize,
    /// Balance slack: parts may grow to `(1 + eps) · ⌈m/k⌉` edges.
    pub eps: f64,
}

impl Default for Refine {
    fn default() -> Refine {
        Refine {
            base: "hdrf".parse().expect("hdrf is registered"),
            rounds: 4,
            eps: 0.05,
        }
    }
}

impl Partitioner for Refine {
    fn partition_graph(
        &self,
        g: &Graph,
        k: usize,
        seed: u64,
    ) -> Result<EdgePartition> {
        check_k(k)?;
        let base = self.base.build();
        let mut part = base.partition_graph(g, k, seed)?;
        part.validate(g)?;
        let mut engine = RefineEngine::new(g, &part, self.eps);
        engine.run(g, self.rounds);
        part.owner.copy_from_slice(engine.owner());
        part.rounds += engine.rounds;
        Ok(part)
    }

    fn name(&self) -> &'static str {
        "refine"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    fn replicas(g: &Graph, p: &EdgePartition) -> usize {
        p.vertex_multiplicity(g).iter().map(|&m| m as usize).sum()
    }

    #[test]
    fn forced_move_is_found_and_applied() {
        // star 0-{1,2,3,4}; canonical edges (0,1),(0,2),(0,3),(0,4).
        // Edge (0,4) in part 1 frees a replica of vertex 0 by joining
        // part 0 (gain −1); eps=1 makes the move balance-admissible.
        let g = GraphBuilder::new()
            .add_edge(0, 1)
            .add_edge(0, 2)
            .add_edge(0, 3)
            .add_edge(0, 4)
            .build();
        let part =
            EdgePartition { k: 2, owner: vec![0, 0, 0, 1], rounds: 0 };
        let mut eng = RefineEngine::new(&g, &part, 1.0);
        assert_eq!(eng.total_replicas(), 6);
        assert_eq!(eng.cap(), 4);
        let applied = eng.round(&g);
        assert_eq!(applied, 1);
        assert_eq!(eng.moves_applied, 1);
        assert_eq!(eng.owner(), &[0, 0, 0, 0]);
        assert_eq!(eng.total_replicas(), 5);
        let fixed =
            EdgePartition { k: 2, owner: eng.owner().to_vec(), rounds: 0 };
        assert_eq!(replicas(&g, &fixed), 5);
        // fixed point: a second round applies nothing
        assert_eq!(eng.round(&g), 0);
    }

    #[test]
    fn blocked_moves_pair_into_a_swap() {
        // two triangles with one edge each stranded in the other's part;
        // eps=0 blocks both single moves (every part is at the cap), the
        // swap phase exchanges them (combined gain −4)
        let g = GraphBuilder::new()
            .add_edge(0, 1)
            .add_edge(0, 2)
            .add_edge(1, 2)
            .add_edge(3, 4)
            .add_edge(3, 5)
            .add_edge(4, 5)
            .build();
        // canonical: (0,1),(0,2),(1,2),(3,4),(3,5),(4,5)
        let part = EdgePartition {
            k: 2,
            owner: vec![0, 0, 1, 1, 1, 0],
            rounds: 0,
        };
        let mut eng = RefineEngine::new(&g, &part, 0.0);
        assert_eq!(eng.cap(), 3);
        assert_eq!(eng.total_replicas(), 10);
        let applied = eng.round(&g);
        assert_eq!(applied, 1);
        assert_eq!(eng.moves_applied, 0);
        assert_eq!(eng.swaps_applied, 1);
        assert_eq!(eng.owner(), &[0, 0, 0, 1, 1, 1]);
        assert_eq!(eng.total_replicas(), 6);
        // sizes unchanged by the swap: still 3 + 3
        let fixed =
            EdgePartition { k: 2, owner: eng.owner().to_vec(), rounds: 0 };
        assert_eq!(fixed.sizes(), vec![3, 3]);
        assert_eq!(eng.round(&g), 0);
    }

    #[test]
    fn losing_swaps_are_reverted_exactly() {
        // a single edge with k=2 and eps=0: its move is blocked (cap 1,
        // both parts size <= cap... construct instead a 2-edge path where
        // nothing can improve) — the engine must be a no-op and leave
        // every ledger untouched
        let g = GraphBuilder::new().add_edge(0, 1).add_edge(1, 2).build();
        let part = EdgePartition { k: 2, owner: vec![0, 1], rounds: 0 };
        let mut eng = RefineEngine::new(&g, &part, 0.0);
        let before = eng.total_replicas();
        for _ in 0..3 {
            assert_eq!(eng.round(&g), 0);
            assert_eq!(eng.total_replicas(), before);
            assert_eq!(eng.owner(), &[0, 1]);
        }
    }

    #[test]
    fn k1_and_empty_graph_are_noops() {
        let g = GraphBuilder::new().add_edge(0, 1).add_edge(1, 2).build();
        let part = EdgePartition { k: 1, owner: vec![0, 0], rounds: 0 };
        let mut eng = RefineEngine::new(&g, &part, 0.05);
        assert_eq!(eng.round(&g), 0);
        assert_eq!(eng.owner(), &[0, 0]);
        let refined = Refine::default().partition_graph(&g, 1, 7).unwrap();
        refined.validate(&g).unwrap();
        assert!(Refine::default().partition_graph(&g, 0, 7).is_err());
        let empty = GraphBuilder::new().build();
        let p0 = EdgePartition { k: 2, owner: Vec::new(), rounds: 0 };
        let mut e0 = RefineEngine::new(&empty, &p0, 0.05);
        assert_eq!(e0.round(&empty), 0);
    }
}
