//! Ingest-time (streaming) edge partitioners: place every edge as it
//! arrives off an [`EdgeStream`], with bounded memory and no materialized
//! [`Graph`].
//!
//! The paper's premise is that graphs outgrow single-machine memory, yet
//! every other partitioner in this crate — including the "streaming"
//! [`crate::partition::fennel::StreamingGreedy`] — needs the full CSR
//! before it can place one edge. This module provides the workload that
//! makes edge partitioning matter at scale (cf. Hybrid Edge Partitioner,
//! Mayer & Jacobsen 2021; Scalable Edge Partitioning, Schlag et al.
//! 2018):
//!
//! - [`Hdrf`] — High-Degree Replicated First greedy (Petroni et al.,
//!   CIKM 2015). For edge `(u, v)` with partial degrees `δ(u), δ(v)`
//!   and `θ(u) = δ(u) / (δ(u) + δ(v))`, part `i` scores
//!
//!   ```text
//!   C_REP(i) = g(u, i) + g(v, i),   g(x, i) = 1 + (1 - θ(x))  if x ∈ A(i)
//!                                             0                otherwise
//!   C_BAL(i) = λ · (maxsize - |E_i|) / (ε + maxsize - minsize)
//!   score(i) = C_REP(i) + C_BAL(i)
//!   ```
//!
//!   and the edge goes to the argmax: replicas of *low*-degree endpoints
//!   are favored, so the inevitable cuts land on high-degree hubs.
//! - [`Dbh`] — Degree-Based Hashing (Xie et al., NIPS 2014): two passes;
//!   the first builds the degree table, the second sends each edge to
//!   `hash(lower-degree endpoint) mod k`.
//! - [`Restream`] — restreaming refinement (after Nishimura & Ugander,
//!   KDD 2013): replay the stream against a previous assignment and move
//!   an edge only when the move cannot increase the replica count
//!   (re-validated against live state, so the replication factor is
//!   non-increasing *by construction*).
//!
//! ## Determinism: chunks vs scoring groups
//!
//! Ingestion chunk sizes are presentation only. Each partitioner
//! re-buffers the stream into fixed **scoring groups** of `group` edges
//! (boundaries at multiples of the global stream index, so they cannot
//! depend on how the source chunked the data). A group is scored in
//! parallel on [`crate::util::pool`] — fixed-size shards of
//! [`SCORE_SHARD`] edges, each a pure function of the state *frozen at
//! group start* — and shard outputs are merged in fixed shard order by a
//! sequential apply pass that updates the degree/presence/size tables in
//! stream order. Results are therefore bit-identical across pool thread
//! counts, ingestion chunk sizes, and in-memory vs from-disk sources
//! (pinned by `tests/streaming.rs`).
//!
//! ## Memory
//!
//! O(|V|) degree and presence state (`k <= 64`: one `u64` mask per
//! vertex; beyond: a row-major table), O(group + chunk) edge buffers —
//! never O(|E|). The owner vector itself (one `u32` per stream edge) is
//! the output.

use crate::bail;
use crate::graph::stream::{EdgeStream, MemoryEdgeStream};
use crate::graph::Graph;
use crate::util::error::Result;
use crate::util::pool;

use super::{check_k, EdgePartition, PartitionInput, Partitioner};

/// Edges per parallel scoring shard. A fixed constant (never derived from
/// the thread count), so shard boundaries — and therefore the merged
/// result — are identical for every pool width.
pub const SCORE_SHARD: usize = 128;

// The partitioners here dispatch through the one [`Partitioner`] trait:
// their `partition` override ingests the [`PartitionInput::Stream`] arm
// directly (bounded memory, `owner[i]` = part of the `i`-th stream edge),
// and `partition_graph` replays the materialized graph's canonical edge
// list through the same `partition_stream` inherent method, so the two
// paths cannot drift.

// ---------------------------------------------------------------------
// shared state tables
// ---------------------------------------------------------------------

/// Per-(vertex, part) membership bits — one `u64` mask per vertex for
/// `k <= 64`, a row-major bool table beyond — plus running replica and
/// vertex counts (the replication factor's numerator and denominator).
struct Presence {
    k: usize,
    mask: Vec<u64>,
    table: Vec<bool>,
    per_vertex: Vec<u32>,
    replicas: usize,
    vertices: usize,
}

impl Presence {
    fn new(k: usize) -> Presence {
        Presence {
            k,
            mask: Vec::new(),
            table: Vec::new(),
            per_vertex: Vec::new(),
            replicas: 0,
            vertices: 0,
        }
    }

    fn wide(&self) -> bool {
        self.k > 64
    }

    /// Grow the tables to cover vertex `v` (new rows read as absent, so
    /// growing never changes observable state).
    fn ensure(&mut self, v: u32) {
        let need = v as usize + 1;
        if self.per_vertex.len() < need {
            if self.wide() {
                self.table.resize(need * self.k, false);
            } else {
                self.mask.resize(need, 0);
            }
            self.per_vertex.resize(need, 0);
        }
    }

    /// Membership test; never-seen vertices read as absent.
    fn contains(&self, v: u32, part: usize) -> bool {
        let vi = v as usize;
        if self.wide() {
            self.table.get(vi * self.k + part).copied().unwrap_or(false)
        } else {
            self.mask.get(vi).is_some_and(|m| (m >> part) & 1 == 1)
        }
    }

    fn insert(&mut self, v: u32, part: usize) {
        self.ensure(v);
        let vi = v as usize;
        let fresh = if self.wide() {
            let slot = &mut self.table[vi * self.k + part];
            let fresh = !*slot;
            *slot = true;
            fresh
        } else {
            let bit = 1u64 << part;
            let fresh = self.mask[vi] & bit == 0;
            self.mask[vi] |= bit;
            fresh
        };
        if fresh {
            if self.per_vertex[vi] == 0 {
                self.vertices += 1;
            }
            self.per_vertex[vi] += 1;
            self.replicas += 1;
        }
    }
}

/// SplitMix64 finalizer — the one hash DBH and tie-breaking use.
fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

// ---------------------------------------------------------------------
// HDRF
// ---------------------------------------------------------------------

/// High-Degree Replicated First streaming partitioner (Petroni et al.,
/// CIKM 2015), batched for deterministic parallel scoring: groups of
/// [`group`](Self::group) edges are scored against the state frozen at
/// group start (see the [module docs](self) for the formulas and the
/// determinism story).
#[derive(Clone, Debug)]
pub struct Hdrf {
    /// Balance weight λ of `C_BAL` (1.0 = the paper's default; higher
    /// favors balance over replication).
    pub lambda: f64,
    /// Denominator offset ε of `C_BAL` (keeps it finite when all parts
    /// are equal; sizes are integers, so 1.0 is a natural scale).
    pub epsilon: f64,
    /// Scoring-group size: edges per frozen-state batch. Smaller tracks
    /// the sequential algorithm more closely; larger exposes more
    /// parallelism. The first groups ramp up (64, 128, 256, ... up to
    /// this cap) so the cold-start stream — where a whole frozen group
    /// would otherwise tie on empty state — stays close to the
    /// sequential algorithm. Group boundaries are a pure function of the
    /// global stream index, so the result is independent of ingestion
    /// chunk sizes.
    pub group: usize,
    /// Edges requested per [`EdgeStream::fill`] call (ingestion buffer
    /// size; has no effect on the result).
    pub chunk: usize,
}

impl Default for Hdrf {
    fn default() -> Self {
        Hdrf { lambda: 1.1, epsilon: 1.0, group: 1024, chunk: 4096 }
    }
}

/// One HDRF placement: pure function of the frozen tables and the global
/// stream index `idx` (exact ties rotate by `idx % k`, which spreads the
/// cold-start ties without breaking replay determinism).
#[allow(clippy::too_many_arguments)]
fn hdrf_choice(
    u: u32,
    v: u32,
    idx: usize,
    k: usize,
    lambda: f64,
    epsilon: f64,
    deg: &[u32],
    presence: &Presence,
    sizes: &[usize],
    maxsize: usize,
    minsize: usize,
) -> u32 {
    let du = deg[u as usize] as f64;
    let dv = deg[v as usize] as f64;
    // partial degrees counted as if this edge were already attached
    let theta_u = (du + 1.0) / (du + dv + 2.0);
    let theta_v = 1.0 - theta_u;
    let spread = epsilon + (maxsize - minsize) as f64;
    let rot = idx % k;
    let mut best = 0u32;
    let mut best_score = f64::NEG_INFINITY;
    for step in 0..k {
        let i = (rot + step) % k;
        let mut score = lambda * (maxsize - sizes[i]) as f64 / spread;
        if presence.contains(u, i) {
            score += 1.0 + (1.0 - theta_u);
        }
        if presence.contains(v, i) {
            score += 1.0 + (1.0 - theta_v);
        }
        if score > best_score {
            best_score = score;
            best = i as u32;
        }
    }
    best
}

impl Hdrf {
    /// Score one group in parallel against the frozen state, then apply
    /// the choices sequentially in stream order.
    fn place_group(
        &self,
        group: &[(u32, u32)],
        k: usize,
        deg: &mut Vec<u32>,
        presence: &mut Presence,
        sizes: &mut [usize],
        owner: &mut Vec<u32>,
    ) {
        // grow tables to cover the group (values unchanged: the state
        // the scorers see is exactly the group-start state)
        for &(u, v) in group {
            let top = u.max(v) as usize + 1;
            if deg.len() < top {
                deg.resize(top, 0);
            }
            presence.ensure(u.max(v));
        }
        let base = owner.len();
        let maxsize = sizes.iter().copied().max().unwrap_or(0);
        let minsize = sizes.iter().copied().min().unwrap_or(0);
        let (lambda, epsilon) = (self.lambda, self.epsilon);
        let shards = group.len().div_ceil(SCORE_SHARD);
        let mut choices: Vec<Vec<u32>> = vec![Vec::new(); shards];
        {
            let deg_r: &[u32] = deg;
            let presence_r: &Presence = presence;
            let sizes_r: &[usize] = sizes;
            pool::run_mut(&mut choices, &|s, out: &mut Vec<u32>| {
                let lo = s * SCORE_SHARD;
                let hi = (lo + SCORE_SHARD).min(group.len());
                out.reserve(hi - lo);
                for j in lo..hi {
                    let (u, v) = group[j];
                    out.push(hdrf_choice(
                        u, v, base + j, k, lambda, epsilon, deg_r,
                        presence_r, sizes_r, maxsize, minsize,
                    ));
                }
            });
        }
        // sequential apply in stream order (fixed shard-order merge)
        let mut j = 0usize;
        for shard in &choices {
            for &q in shard {
                let (u, v) = group[j];
                owner.push(q);
                sizes[q as usize] += 1;
                presence.insert(u, q as usize);
                presence.insert(v, q as usize);
                deg[u as usize] += 1;
                deg[v as usize] += 1;
                j += 1;
            }
        }
        debug_assert_eq!(j, group.len());
    }
}

impl Hdrf {
    /// Partition the stream into `k` parts in bounded memory; `owner[i]`
    /// is the part of the `i`-th stream edge (for canonical streams,
    /// stream position == edge id). HDRF is deterministic: the seed is
    /// unused.
    pub fn partition_stream(
        &self,
        stream: &mut dyn EdgeStream,
        k: usize,
        _seed: u64,
    ) -> Result<EdgePartition> {
        check_k(k)?;
        check_knobs(self.group, self.chunk)?;
        if self.epsilon <= 0.0 {
            bail!("HDRF epsilon must be positive (got {})", self.epsilon);
        }
        stream.reset()?;
        let mut deg: Vec<u32> = Vec::new();
        let mut presence = Presence::new(k);
        let mut sizes = vec![0usize; k];
        let mut owner: Vec<u32> = Vec::new();
        let mut buf: Vec<(u32, u32)> = Vec::new();
        let mut group: Vec<(u32, u32)> = Vec::with_capacity(self.group);
        // deterministic ramp: early groups are small so the cold-start
        // frozen state tracks the sequential algorithm; a pure function
        // of the global stream index, so chunking cannot shift it
        let mut target = self.group.min(64);
        loop {
            if stream.fill(self.chunk, &mut buf)? == 0 {
                break;
            }
            for &e in &buf {
                group.push(e);
                if group.len() == target {
                    self.place_group(
                        &group, k, &mut deg, &mut presence, &mut sizes,
                        &mut owner,
                    );
                    group.clear();
                    target = (target * 2).min(self.group);
                }
            }
        }
        if !group.is_empty() {
            self.place_group(
                &group, k, &mut deg, &mut presence, &mut sizes, &mut owner,
            );
        }
        Ok(EdgePartition { k, owner, rounds: 1 })
    }
}

impl Partitioner for Hdrf {
    fn partition(
        &self,
        input: PartitionInput<'_>,
        k: usize,
        seed: u64,
    ) -> Result<EdgePartition> {
        match input {
            PartitionInput::Graph(g) => self.partition_graph(g, k, seed),
            PartitionInput::Stream(s) => {
                self.partition_stream(s.stream, k, seed)
            }
        }
    }

    fn partition_graph(
        &self,
        g: &Graph,
        k: usize,
        seed: u64,
    ) -> Result<EdgePartition> {
        let mut s = MemoryEdgeStream::from_graph(g);
        self.partition_stream(&mut s, k, seed)
    }

    fn name(&self) -> &'static str {
        "HDRF"
    }

    fn streaming_native(&self) -> bool {
        true
    }
}

// ---------------------------------------------------------------------
// DBH
// ---------------------------------------------------------------------

/// Degree-Based Hashing (Xie et al., NIPS 2014): pass 1 builds the full
/// degree table, pass 2 hashes each edge's lower-degree endpoint to a
/// part. Placement is a pure per-edge function of the degree table and
/// the seed, so pass 2 parallelizes with no frozen-state caveats at all.
#[derive(Clone, Debug)]
pub struct Dbh {
    /// Edges requested per [`EdgeStream::fill`] call (ingestion buffer
    /// size; has no effect on the result).
    pub chunk: usize,
}

impl Default for Dbh {
    fn default() -> Self {
        Dbh { chunk: 4096 }
    }
}

/// The DBH placement rule: hash the lower-degree endpoint (ties: the
/// lower vertex id) mixed with the seed.
fn dbh_choice(u: u32, v: u32, deg: &[u32], k: usize, seed: u64) -> u32 {
    let (du, dv) = (deg[u as usize], deg[v as usize]);
    let target = if du < dv {
        u
    } else if dv < du {
        v
    } else {
        u.min(v)
    };
    (mix64(target as u64 ^ seed.wrapping_mul(0x9E3779B97F4A7C15))
        % k as u64) as u32
}

impl Dbh {
    /// Partition the stream into `k` parts in two bounded-memory passes;
    /// `owner[i]` is the part of the `i`-th stream edge.
    pub fn partition_stream(
        &self,
        stream: &mut dyn EdgeStream,
        k: usize,
        seed: u64,
    ) -> Result<EdgePartition> {
        check_k(k)?;
        check_knobs(1, self.chunk)?;
        // pass 1: full degree table (sums commute; order-independent)
        stream.reset()?;
        let mut deg: Vec<u32> = Vec::new();
        let mut buf: Vec<(u32, u32)> = Vec::new();
        loop {
            if stream.fill(self.chunk, &mut buf)? == 0 {
                break;
            }
            for &(u, v) in &buf {
                let top = u.max(v) as usize + 1;
                if deg.len() < top {
                    deg.resize(top, 0);
                }
                deg[u as usize] += 1;
                deg[v as usize] += 1;
            }
        }
        // pass 2: per-edge hashing, parallel over fixed-size shards
        stream.reset()?;
        let mut owner: Vec<u32> = Vec::new();
        loop {
            let got = stream.fill(self.chunk, &mut buf)?;
            if got == 0 {
                break;
            }
            let shards = got.div_ceil(SCORE_SHARD);
            let mut outs: Vec<Vec<u32>> = vec![Vec::new(); shards];
            {
                let deg_r: &[u32] = &deg;
                let buf_r: &[(u32, u32)] = &buf;
                pool::run_mut(&mut outs, &|s, out: &mut Vec<u32>| {
                    let lo = s * SCORE_SHARD;
                    let hi = (lo + SCORE_SHARD).min(buf_r.len());
                    out.reserve(hi - lo);
                    for j in lo..hi {
                        let (u, v) = buf_r[j];
                        out.push(dbh_choice(u, v, deg_r, k, seed));
                    }
                });
            }
            for out in &outs {
                owner.extend_from_slice(out);
            }
        }
        Ok(EdgePartition { k, owner, rounds: 2 })
    }
}

impl Partitioner for Dbh {
    fn partition(
        &self,
        input: PartitionInput<'_>,
        k: usize,
        seed: u64,
    ) -> Result<EdgePartition> {
        match input {
            PartitionInput::Graph(g) => self.partition_graph(g, k, seed),
            PartitionInput::Stream(s) => {
                self.partition_stream(s.stream, k, seed)
            }
        }
    }

    fn partition_graph(
        &self,
        g: &Graph,
        k: usize,
        seed: u64,
    ) -> Result<EdgePartition> {
        let mut s = MemoryEdgeStream::from_graph(g);
        self.partition_stream(&mut s, k, seed)
    }

    fn name(&self) -> &'static str {
        "DBH"
    }

    fn streaming_native(&self) -> bool {
        true
    }
}

// ---------------------------------------------------------------------
// restreaming refinement
// ---------------------------------------------------------------------

/// Restreaming refinement (after Nishimura & Ugander, KDD 2013): an
/// initial [`Hdrf`] pass, then [`passes`](Self::passes) replays of the
/// stream that move an edge only when the move cannot increase the
/// replica count — re-validated against the live per-(vertex, part)
/// incident-edge counts at apply time, so the replication factor is
/// non-increasing by construction (property-tested). Candidate selection
/// runs in parallel against the group-start snapshot, exactly like
/// [`Hdrf`]'s scoring.
#[derive(Clone, Debug)]
pub struct Restream {
    /// The partitioner that produces the initial assignment.
    pub inner: Hdrf,
    /// Refinement replays after the initial pass.
    pub passes: usize,
    /// Scoring-group size of the refinement replay (same contract as
    /// [`Hdrf::group`]).
    pub group: usize,
    /// Edges requested per [`EdgeStream::fill`] call.
    pub chunk: usize,
}

impl Default for Restream {
    fn default() -> Self {
        Restream {
            inner: Hdrf::default(),
            passes: 1,
            group: 1024,
            chunk: 4096,
        }
    }
}

/// One refinement candidate: the best strictly-improving move for edge
/// `(u, v)` currently in `p0`, judged against the frozen counts/sizes —
/// minimize the replica delta, then the target size, then the part id.
/// Returns `p0` when no move qualifies.
fn restream_choice(
    u: u32,
    v: u32,
    p0: u32,
    k: usize,
    counts: &[u32],
    sizes: &[usize],
) -> u32 {
    let (ub, vb) = (u as usize * k, v as usize * k);
    let p0u = p0 as usize;
    let removed = (counts[ub + p0u] == 1) as i32
        + (counts[vb + p0u] == 1) as i32;
    let mut best: Option<(i32, usize, usize)> = None;
    for q in 0..k {
        if q == p0u {
            continue;
        }
        let added =
            (counts[ub + q] == 0) as i32 + (counts[vb + q] == 0) as i32;
        let delta = added - removed;
        if delta < 0 || (delta == 0 && sizes[q] + 1 < sizes[p0u]) {
            let key = (delta, sizes[q], q);
            if best.is_none_or(|b| key < b) {
                best = Some(key);
            }
        }
    }
    best.map_or(p0, |(_, _, q)| q as u32)
}

/// Candidate-score one group in parallel against the frozen counts, then
/// re-validate and apply sequentially: a move is taken only if, against
/// the *live* counts, it still cannot increase the replica total.
fn apply_restream_group(
    group: &[(u32, u32)],
    base: usize,
    k: usize,
    cur: &mut [u32],
    counts: &mut [u32],
    sizes: &mut [usize],
) {
    let shards = group.len().div_ceil(SCORE_SHARD);
    let mut cand: Vec<Vec<u32>> = vec![Vec::new(); shards];
    {
        let cur_r: &[u32] = cur;
        let counts_r: &[u32] = counts;
        let sizes_r: &[usize] = sizes;
        pool::run_mut(&mut cand, &|s, out: &mut Vec<u32>| {
            let lo = s * SCORE_SHARD;
            let hi = (lo + SCORE_SHARD).min(group.len());
            out.reserve(hi - lo);
            for j in lo..hi {
                let (u, v) = group[j];
                out.push(restream_choice(
                    u,
                    v,
                    cur_r[base + j],
                    k,
                    counts_r,
                    sizes_r,
                ));
            }
        });
    }
    let mut j = 0usize;
    for shard in &cand {
        for &q in shard {
            let (u, v) = group[j];
            let p0 = cur[base + j];
            let at = base + j;
            j += 1;
            if q == p0 {
                continue;
            }
            let (ub, vb) = (u as usize * k, v as usize * k);
            let removed = (counts[ub + p0 as usize] == 1) as i32
                + (counts[vb + p0 as usize] == 1) as i32;
            let added = (counts[ub + q as usize] == 0) as i32
                + (counts[vb + q as usize] == 0) as i32;
            let delta = added - removed;
            if delta < 0
                || (delta == 0
                    && sizes[q as usize] + 1 < sizes[p0 as usize])
            {
                cur[at] = q;
                counts[ub + p0 as usize] -= 1;
                counts[vb + p0 as usize] -= 1;
                counts[ub + q as usize] += 1;
                counts[vb + q as usize] += 1;
                sizes[p0 as usize] -= 1;
                sizes[q as usize] += 1;
            }
        }
    }
    debug_assert_eq!(j, group.len());
}

impl Restream {
    /// Refine an existing assignment (`prev[i]` = part of the `i`-th
    /// stream edge) with [`passes`](Self::passes) replays (at least one).
    /// The returned assignment's replication factor never exceeds
    /// `prev`'s.
    pub fn refine(
        &self,
        stream: &mut dyn EdgeStream,
        k: usize,
        prev: &[u32],
    ) -> Result<EdgePartition> {
        check_k(k)?;
        if let Some(&p) = prev.iter().find(|&&p| p as usize >= k) {
            return Err(crate::anyhow!(
                "previous owner {p} out of range for k={k}"
            ));
        }
        let mut cur = prev.to_vec();
        let passes = self.passes.max(1);
        for _ in 0..passes {
            self.refine_pass(stream, k, &mut cur)?;
        }
        Ok(EdgePartition { k, owner: cur, rounds: passes })
    }

    /// One replay: rebuild the per-(vertex, part) incident-edge counts,
    /// then stream the edges through grouped candidate scoring + apply.
    fn refine_pass(
        &self,
        stream: &mut dyn EdgeStream,
        k: usize,
        cur: &mut [u32],
    ) -> Result<()> {
        check_knobs(self.group, self.chunk)?;
        // pass A: counts[v*k + p] = v's incident edges currently in p
        stream.reset()?;
        let mut counts: Vec<u32> = Vec::new();
        let mut buf: Vec<(u32, u32)> = Vec::new();
        let mut idx = 0usize;
        loop {
            if stream.fill(self.chunk, &mut buf)? == 0 {
                break;
            }
            for &(u, v) in &buf {
                if idx >= cur.len() {
                    return Err(crate::anyhow!(
                        "stream yields more than the {} assigned edges",
                        cur.len()
                    ));
                }
                let p = cur[idx] as usize;
                let top = (u.max(v) as usize + 1) * k;
                if counts.len() < top {
                    counts.resize(top, 0);
                }
                counts[u as usize * k + p] += 1;
                counts[v as usize * k + p] += 1;
                idx += 1;
            }
        }
        if idx != cur.len() {
            return Err(crate::anyhow!(
                "stream yields {idx} edges, assignment covers {}",
                cur.len()
            ));
        }
        let mut sizes = vec![0usize; k];
        for &p in cur.iter() {
            sizes[p as usize] += 1;
        }
        // pass B: grouped replay
        stream.reset()?;
        let mut group: Vec<(u32, u32)> = Vec::with_capacity(self.group);
        let mut base = 0usize;
        loop {
            if stream.fill(self.chunk, &mut buf)? == 0 {
                break;
            }
            for &e in &buf {
                group.push(e);
                if group.len() == self.group {
                    apply_restream_group(
                        &group, base, k, cur, &mut counts, &mut sizes,
                    );
                    base += group.len();
                    group.clear();
                }
            }
        }
        if !group.is_empty() {
            apply_restream_group(
                &group, base, k, cur, &mut counts, &mut sizes,
            );
        }
        Ok(())
    }
}

impl Restream {
    /// Partition the stream into `k` parts in bounded memory: the inner
    /// [`Hdrf`] pass followed by [`passes`](Self::passes) refinement
    /// replays; `owner[i]` is the part of the `i`-th stream edge.
    pub fn partition_stream(
        &self,
        stream: &mut dyn EdgeStream,
        k: usize,
        seed: u64,
    ) -> Result<EdgePartition> {
        let first = self.inner.partition_stream(stream, k, seed)?;
        let mut cur = first.owner;
        for _ in 0..self.passes {
            self.refine_pass(stream, k, &mut cur)?;
        }
        Ok(EdgePartition {
            k,
            owner: cur,
            rounds: first.rounds + self.passes,
        })
    }
}

impl Partitioner for Restream {
    fn partition(
        &self,
        input: PartitionInput<'_>,
        k: usize,
        seed: u64,
    ) -> Result<EdgePartition> {
        match input {
            PartitionInput::Graph(g) => self.partition_graph(g, k, seed),
            PartitionInput::Stream(s) => {
                self.partition_stream(s.stream, k, seed)
            }
        }
    }

    fn partition_graph(
        &self,
        g: &Graph,
        k: usize,
        seed: u64,
    ) -> Result<EdgePartition> {
        let mut s = MemoryEdgeStream::from_graph(g);
        self.partition_stream(&mut s, k, seed)
    }

    fn name(&self) -> &'static str {
        "ReStream"
    }

    fn streaming_native(&self) -> bool {
        true
    }
}

/// Shared knob validation for the streaming partitioners.
fn check_knobs(group: usize, chunk: usize) -> Result<()> {
    if group < 1 || chunk < 1 {
        bail!("group and chunk sizes must be >= 1");
    }
    Ok(())
}

// ---------------------------------------------------------------------
// streaming-native quality stats
// ---------------------------------------------------------------------

/// Partition quality computable during ingestion with no materialized
/// graph: balance from the part sizes, replication from a presence
/// table — the out-of-core counterpart of
/// [`crate::partition::metrics::Report`].
#[derive(Clone, Debug)]
pub struct StreamStats {
    /// Total stream edges.
    pub edges: usize,
    /// Distinct vertices seen.
    pub vertices: usize,
    /// Total (vertex, part) replicas.
    pub replicas: usize,
    /// `|E_i|` per part.
    pub sizes: Vec<usize>,
}

impl StreamStats {
    /// Mean replicas per vertex (1.0 = no replication).
    pub fn replication_factor(&self) -> f64 {
        self.replicas as f64 / self.vertices.max(1) as f64
    }

    /// Largest part size normalized so `1.0 == |E|/k`.
    pub fn largest_normalized(&self) -> f64 {
        if self.edges == 0 {
            return 0.0;
        }
        let ideal = self.edges as f64 / self.sizes.len().max(1) as f64;
        self.sizes.iter().copied().max().unwrap_or(0) as f64 / ideal
    }
}

/// Replay `stream` against an owner vector (stream position == index),
/// accumulating [`StreamStats`] in bounded memory.
pub fn stream_stats(
    stream: &mut dyn EdgeStream,
    owner: &[u32],
    k: usize,
    chunk: usize,
) -> Result<StreamStats> {
    stream.reset()?;
    let mut presence = Presence::new(k);
    let mut sizes = vec![0usize; k];
    let mut buf: Vec<(u32, u32)> = Vec::new();
    let mut idx = 0usize;
    loop {
        if stream.fill(chunk.max(1), &mut buf)? == 0 {
            break;
        }
        for &(u, v) in &buf {
            let Some(&p) = owner.get(idx) else {
                return Err(crate::anyhow!(
                    "stream yields more than the {} assigned edges",
                    owner.len()
                ));
            };
            let p = p as usize;
            if p >= k {
                return Err(crate::anyhow!(
                    "owner {p} out of range for k={k}"
                ));
            }
            sizes[p] += 1;
            presence.insert(u, p);
            presence.insert(v, p);
            idx += 1;
        }
    }
    if idx != owner.len() {
        return Err(crate::anyhow!(
            "stream yields {idx} edges, assignment covers {}",
            owner.len()
        ));
    }
    Ok(StreamStats {
        edges: idx,
        vertices: presence.vertices,
        replicas: presence.replicas,
        sizes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::GraphKind;
    use crate::partition::spec::PartitionerSpec;
    use crate::partition::{metrics, StreamInput};

    fn g() -> Graph {
        GraphKind::PowerlawCluster { n: 600, m: 4, p: 0.3 }.generate(7)
    }

    fn streamers() -> Vec<(&'static str, Box<dyn Partitioner>)> {
        vec![
            ("hdrf", Box::new(Hdrf::default())),
            ("dbh", Box::new(Dbh::default())),
            ("restream", Box::new(Restream::default())),
        ]
    }

    /// Run the unified trait's stream arm.
    fn stream_partition(
        p: &dyn Partitioner,
        s: &mut dyn EdgeStream,
        k: usize,
        seed: u64,
    ) -> Result<EdgePartition> {
        p.partition(PartitionInput::Stream(StreamInput::new(s)), k, seed)
    }

    #[test]
    fn all_streamers_yield_valid_covers() {
        let g = g();
        for (name, p) in streamers() {
            let mut s = MemoryEdgeStream::from_graph(&g);
            let part = stream_partition(p.as_ref(), &mut s, 8, 3).unwrap();
            part.validate(&g).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(
                part.sizes().iter().sum::<usize>(),
                g.edge_count(),
                "{name}"
            );
        }
    }

    #[test]
    fn results_independent_of_chunk_and_group_interleaving() {
        // chunk size is presentation only; the scoring group is a fixed
        // partitioner parameter, so any chunking gives the same owners
        let g = g();
        let m = g.edge_count();
        for (name, p) in streamers() {
            let mut s = MemoryEdgeStream::from_graph(&g);
            let base = stream_partition(p.as_ref(), &mut s, 8, 3).unwrap();
            for chunk in [1usize, 64, 1000, m.max(1)] {
                let retuned = PartitionerSpec::parse(&format!(
                    "{name}:chunk={chunk}"
                ))
                .unwrap()
                .build();
                let mut s = MemoryEdgeStream::from_graph(&g);
                let got =
                    stream_partition(retuned.as_ref(), &mut s, 8, 3).unwrap();
                assert_eq!(
                    got.owner, base.owner,
                    "{name}: chunk {chunk} changed the result"
                );
            }
        }
    }

    #[test]
    fn hdrf_beats_dbh_on_replication_here() {
        // not a universal law, but on a clustered power-law graph the
        // degree-aware greedy should replicate less than pure hashing
        let g = g();
        let h = Hdrf::default().partition_graph(&g, 8, 1).unwrap();
        let d = Dbh::default().partition_graph(&g, 8, 1).unwrap();
        let reps = |p: &EdgePartition| -> usize {
            p.vertex_multiplicity(&g).iter().map(|&m| m as usize).sum()
        };
        assert!(
            reps(&h) < reps(&d),
            "hdrf {} !< dbh {}",
            reps(&h),
            reps(&d)
        );
    }

    #[test]
    fn hdrf_is_reasonably_balanced() {
        let g = g();
        let p = Hdrf::default().partition_graph(&g, 8, 1).unwrap();
        let largest = metrics::largest(&g, &p);
        assert!(largest < 1.8, "largest {largest}");
    }

    #[test]
    fn restream_never_raises_replication_and_validates() {
        let g = g();
        let prev = crate::partition::baselines::RandomEdge
            .partition_graph(&g, 6, 9)
            .unwrap();
        let mut s = MemoryEdgeStream::from_graph(&g);
        let refined =
            Restream::default().refine(&mut s, 6, &prev.owner).unwrap();
        refined.validate(&g).unwrap();
        let reps = |p: &EdgePartition| -> usize {
            p.vertex_multiplicity(&g).iter().map(|&m| m as usize).sum()
        };
        assert!(
            reps(&refined) <= reps(&prev),
            "refined {} > prev {}",
            reps(&refined),
            reps(&prev)
        );
    }

    #[test]
    fn wide_k_path_works() {
        let g = g();
        for (name, p) in streamers() {
            let mut s = MemoryEdgeStream::from_graph(&g);
            let part = stream_partition(p.as_ref(), &mut s, 80, 2).unwrap();
            part.validate(&g).unwrap_or_else(|e| panic!("{name}: {e}"));
        }
    }

    #[test]
    fn stream_stats_match_view_derivations() {
        let g = g();
        let p = Hdrf::default().partition_graph(&g, 5, 4).unwrap();
        let mut s = MemoryEdgeStream::from_graph(&g);
        let st = stream_stats(&mut s, &p.owner, 5, 512).unwrap();
        assert_eq!(st.edges, g.edge_count());
        assert_eq!(&st.sizes[..], &p.sizes()[..]);
        let mult = p.vertex_multiplicity(&g);
        let replicas: usize = mult.iter().map(|&m| m as usize).sum();
        let vertices = mult.iter().filter(|&&m| m > 0).count();
        assert_eq!(st.replicas, replicas);
        assert_eq!(st.vertices, vertices);
        assert!(st.replication_factor() >= 1.0);
        assert!(st.largest_normalized() >= 1.0);
    }

    #[test]
    fn seed_changes_dbh_but_not_hdrf() {
        let g = g();
        let h1 = Hdrf::default().partition_graph(&g, 8, 1).unwrap();
        let h2 = Hdrf::default().partition_graph(&g, 8, 2).unwrap();
        assert_eq!(h1.owner, h2.owner, "HDRF should ignore the seed");
        let d1 = Dbh::default().partition_graph(&g, 8, 1).unwrap();
        let d2 = Dbh::default().partition_graph(&g, 8, 2).unwrap();
        assert_ne!(d1.owner, d2.owner, "DBH should be seed-sensitive");
    }
}
