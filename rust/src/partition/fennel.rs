//! Streaming greedy edge partitioner — the Fennel [18] idea (the paper's
//! related work: "in the streaming scenario it is unfeasible to use the
//! classical partitioning algorithm, since the data is continuously
//! arriving. A greedy algorithm that assigns each incoming vertex to a
//! partition has been proposed") adapted from vertices to edges.
//!
//! Edges arrive in a stream (random order); each is assigned greedily to
//! the partition maximizing
//!
//! ```text
//! score(i) = locality(i) - gamma * |E_i| / (|E|/K)
//! ```
//!
//! where `locality(i)` counts how many of the edge's endpoints are already
//! present in partition i (0, 1 or 2) — the degree-of-presence heuristic —
//! and the second term is the Fennel-style load penalty. One pass, O(1)
//! state per (vertex, partition) presence bit, which is what makes it a
//! streaming algorithm.

use super::{check_k, EdgePartition, Partitioner};
use crate::graph::Graph;
use crate::util::error::Result;
use crate::util::rng::Rng;

/// Fennel-style streaming greedy edge partitioner (requires the
/// materialized [`Graph`]; the bounded-memory ingest-time counterparts
/// live in [`crate::partition::streaming`]).
#[derive(Clone, Debug)]
pub struct StreamingGreedy {
    /// Load-balance penalty weight (Fennel's gamma).
    pub gamma: f64,
    /// Shuffle the stream (true = random arrival, matching the streaming
    /// setting; false = canonical edge order, deterministic).
    pub shuffle: bool,
}

impl Default for StreamingGreedy {
    fn default() -> Self {
        StreamingGreedy { gamma: 1.5, shuffle: true }
    }
}

impl Partitioner for StreamingGreedy {
    fn partition_graph(
        &self,
        g: &Graph,
        k: usize,
        seed: u64,
    ) -> Result<EdgePartition> {
        check_k(k)?;
        let m = g.edge_count();
        let n = g.vertex_count();
        let mut order: Vec<u32> = (0..m as u32).collect();
        if self.shuffle {
            Rng::new(seed).shuffle(&mut order);
        }
        // presence[v] = bitmask of partitions containing v (k <= 64 fast
        // path; beyond that a per-vertex stamp table)
        let wide = k > 64;
        let mut mask = if wide { Vec::new() } else { vec![0u64; n] };
        let mut table = if wide {
            vec![false; n * k]
        } else {
            Vec::new()
        };
        let mut sizes = vec![0usize; k];
        let ideal = m as f64 / k as f64;
        let mut owner = vec![0u32; m];
        for &e in &order {
            let (u, v) = g.endpoints(e);
            let (u, v) = (u as usize, v as usize);
            let mut best = 0usize;
            let mut best_score = f64::NEG_INFINITY;
            for i in 0..k {
                let loc = if wide {
                    table[u * k + i] as u32 + table[v * k + i] as u32
                } else {
                    ((mask[u] >> i) & 1) as u32 + ((mask[v] >> i) & 1) as u32
                };
                let score =
                    loc as f64 - self.gamma * sizes[i] as f64 / ideal;
                if score > best_score {
                    best_score = score;
                    best = i;
                }
            }
            owner[e as usize] = best as u32;
            sizes[best] += 1;
            if wide {
                table[u * k + best] = true;
                table[v * k + best] = true;
            } else {
                mask[u] |= 1 << best;
                mask[v] |= 1 << best;
            }
        }
        Ok(EdgePartition { k, owner, rounds: 1 })
    }

    fn name(&self) -> &'static str {
        "Streaming"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::GraphKind;
    use crate::partition::{baselines::RandomEdge, metrics};

    fn g() -> Graph {
        GraphKind::PowerlawCluster { n: 500, m: 4, p: 0.3 }.generate(7)
    }

    #[test]
    fn complete_and_roughly_balanced() {
        let g = g();
        let p = StreamingGreedy::default().partition_graph(&g, 8, 1).unwrap();
        p.validate(&g).unwrap();
        assert!(
            metrics::nstdev(&g, &p) < 0.25,
            "nstdev {}",
            metrics::nstdev(&g, &p)
        );
    }

    #[test]
    fn beats_random_on_messages() {
        let g = g();
        let s = StreamingGreedy::default().partition_graph(&g, 8, 1).unwrap();
        let r = RandomEdge.partition_graph(&g, 8, 1).unwrap();
        assert!(
            metrics::messages(&g, &s) < metrics::messages(&g, &r),
            "streaming {} !< random {}",
            metrics::messages(&g, &s),
            metrics::messages(&g, &r)
        );
    }

    #[test]
    fn wide_k_path_works() {
        let g = g();
        let p = StreamingGreedy::default().partition_graph(&g, 80, 2).unwrap();
        p.validate(&g).unwrap();
    }

    #[test]
    fn higher_gamma_is_more_balanced() {
        let g = g();
        let loose = StreamingGreedy { gamma: 0.1, shuffle: false }
            .partition_graph(&g, 8, 3).unwrap();
        let tight = StreamingGreedy { gamma: 8.0, shuffle: false }
            .partition_graph(&g, 8, 3).unwrap();
        assert!(
            metrics::nstdev(&g, &tight) <= metrics::nstdev(&g, &loose),
            "tight {} loose {}",
            metrics::nstdev(&g, &tight),
            metrics::nstdev(&g, &loose)
        );
    }
}
