//! The partitioner registry: every algorithm the crate ships, addressable
//! by name, with its typed, documented, defaulted parameters and a
//! factory that builds the configured [`Partitioner`].
//!
//! [`spec::PartitionerSpec`](super::spec::PartitionerSpec) parses
//! `name:key=val,...` strings against this registry; the CLI, the
//! benches, the facade in [`crate::coordinator::runs`] and the property
//! tests all enumerate [`all`] instead of hard-coding algorithm lists.
//! The registry table in `DESIGN.md` is enforced against [`all`] by a
//! unit test in this module, so the docs cannot drift from the code.

use super::baselines::{GreedyBfs, HashEdge, RandomEdge};
use super::dfep::Dfep;
use super::dfepc::Dfepc;
use super::fennel::StreamingGreedy;
use super::jabeja::JaBeJa;
use super::multilevel::Multilevel;
use super::refine::Refine;
use super::spec::PartitionerSpec;
use super::streaming::{Dbh, Hdrf, Restream};
use super::Partitioner;

/// The type of one spec parameter.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ParamKind {
    /// An `f64` (`lambda=1.5`).
    Float,
    /// A `usize` (`rounds=30`).
    Int,
    /// A `bool` (`shuffle=false`; accepts `true`/`false`/`1`/`0`).
    Bool,
    /// A whole nested partitioner spec (`base=hdrf:lambda=1.5+group=512`
    /// — the nested spec writes its commas as `+`; see the nested-specs
    /// section of [`super::spec`]).
    Spec,
}

impl ParamKind {
    /// Human name used in error messages ("a float", "an integer", ...).
    pub fn article(&self) -> &'static str {
        match self {
            ParamKind::Float => "a float",
            ParamKind::Int => "an integer",
            ParamKind::Bool => "a bool (true|false|1|0)",
            ParamKind::Spec => "a partitioner spec",
        }
    }
}

/// One tunable parameter of a registered partitioner.
#[derive(Clone, Copy, Debug)]
pub struct ParamSpec {
    /// The spec key (`lambda` in `hdrf:lambda=1.5`).
    pub key: &'static str,
    /// Value type (drives parse-time validation).
    pub kind: ParamKind,
    /// Default value, rendered exactly as a spec string would write it.
    pub default: &'static str,
    /// Inclusive lower bound for numeric kinds (`f64::NEG_INFINITY` =
    /// unconstrained; ignored for [`ParamKind::Bool`]).
    pub min: f64,
    /// One-line description for `repro help` / DESIGN.md.
    pub doc: &'static str,
}

/// Resolved parameter values for one spec: defaults from the
/// [`AlgoEntry`], overridden by the parsed `key=val` pairs. Lookups are
/// infallible because [`super::spec::PartitionerSpec::parse`] validated
/// every key and value against the entry.
pub struct Resolved<'a> {
    entry: &'a AlgoEntry,
    overrides: &'a [(String, String)],
}

impl<'a> Resolved<'a> {
    /// Resolved view over an already-parsed spec, for callers that need
    /// parameter values without building the boxed partitioner (the
    /// cluster runtime drives the DFEP phases directly).
    pub(crate) fn of(spec: &'a super::spec::PartitionerSpec) -> Resolved<'a> {
        Resolved { entry: spec.algo(), overrides: spec.overrides() }
    }

    fn raw(&self, key: &str) -> &str {
        self.overrides
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
            .unwrap_or_else(|| {
                self.entry
                    .params
                    .iter()
                    .find(|p| p.key == key)
                    .unwrap_or_else(|| {
                        panic!("{}: no such param '{key}'", self.entry.name)
                    })
                    .default
            })
    }

    /// The resolved `f64` value of `key`.
    pub fn f64(&self, key: &str) -> f64 {
        self.raw(key).parse().expect("validated at parse time")
    }

    /// The resolved `usize` value of `key`.
    pub fn usize(&self, key: &str) -> usize {
        self.raw(key).parse().expect("validated at parse time")
    }

    /// The resolved `bool` value of `key`.
    pub fn bool(&self, key: &str) -> bool {
        parse_bool(self.raw(key)).expect("validated at parse time")
    }

    /// The resolved nested-spec value of `key` (stored `+`-separated;
    /// see [`ParamKind::Spec`]).
    pub fn spec(&self, key: &str) -> PartitionerSpec {
        PartitionerSpec::parse(&self.raw(key).replace('+', ","))
            .expect("validated at parse time")
    }
}

/// Parse a spec bool (`true`/`false`/`1`/`0`).
pub(super) fn parse_bool(s: &str) -> Option<bool> {
    match s {
        "true" | "1" => Some(true),
        "false" | "0" => Some(false),
        _ => None,
    }
}

/// One registered partitioner.
pub struct AlgoEntry {
    /// Canonical name (what [`Display`](super::spec::PartitionerSpec)
    /// prints).
    pub name: &'static str,
    /// Accepted aliases (parse-time only).
    pub aliases: &'static [&'static str],
    /// One-line description.
    pub summary: &'static str,
    /// Paper the algorithm follows.
    pub citation: &'static str,
    /// Tunable parameters (empty = the algorithm has none).
    pub params: &'static [ParamSpec],
    /// True when the built partitioner ingests streams in bounded memory
    /// (see [`Partitioner::streaming_native`]).
    pub streaming_native: bool,
    factory: fn(&Resolved<'_>) -> Box<dyn Partitioner>,
}

impl AlgoEntry {
    /// Build the partitioner from parse-validated overrides.
    pub(super) fn build(
        &self,
        overrides: &[(String, String)],
    ) -> Box<dyn Partitioner> {
        (self.factory)(&Resolved { entry: self, overrides })
    }

    /// The parameter spec for `key`, if the algorithm has one.
    pub fn param(&self, key: &str) -> Option<&'static ParamSpec> {
        self.params.iter().find(|p| p.key == key)
    }
}

const NO_MIN: f64 = f64::NEG_INFINITY;

macro_rules! p {
    ($key:literal, $kind:ident, $default:literal, $min:expr, $doc:literal) => {
        ParamSpec {
            key: $key,
            kind: ParamKind::$kind,
            default: $default,
            min: $min,
            doc: $doc,
        }
    };
}

static DFEP_PARAMS: &[ParamSpec] = &[
    p!("cap", Float, "10", 1e-9, "per-round funding cap for small parts"),
    p!("init", Float, "1", 1e-9, "initial funding as a fraction of |E|/k"),
    p!("max_rounds", Int, "10000", 1.0, "safety bound on rounds"),
    p!("frontier_first", Bool, "true", NO_MIN, "concentrate funding at the frontier"),
];

static DFEPC_PARAMS: &[ParamSpec] = &[
    p!("p", Float, "2", 1e-9, "poverty divisor (poor if size < avg/p)"),
    p!("cap", Float, "10", 1e-9, "per-round funding cap for small parts"),
    p!("init", Float, "1", 1e-9, "initial funding as a fraction of |E|/k"),
    p!("max_rounds", Int, "10000", 1.0, "safety bound on rounds"),
    p!("rebalance", Int, "16", 0.0, "raid rounds after full coverage"),
];

static JABEJA_PARAMS: &[ParamSpec] = &[
    p!("rounds", Int, "200", 1.0, "swap rounds"),
    p!("temp", Float, "2", 1e-9, "initial simulated-annealing temperature"),
    p!("delta", Float, "0.01", 0.0, "temperature decrement per round"),
    p!("sample", Int, "3", 0.0, "random peers sampled per vertex per round"),
    p!("alpha", Float, "2", 1e-9, "energy-function exponent"),
];

static FENNEL_PARAMS: &[ParamSpec] = &[
    p!("gamma", Float, "1.5", 0.0, "load-balance penalty weight"),
    p!("shuffle", Bool, "true", NO_MIN, "randomize the arrival order"),
];

static MULTILEVEL_PARAMS: &[ParamSpec] = &[
    p!("coarsest", Int, "256", 1.0, "stop coarsening at this many vertices"),
    p!("balance_cap", Float, "1.08", 1e-9, "refinement balance cap on |E_i|/(|E|/k)"),
    p!("refine_passes", Int, "2", 0.0, "refinement passes per level"),
];

static HDRF_PARAMS: &[ParamSpec] = &[
    p!("lambda", Float, "1.1", 0.0, "balance weight of C_BAL"),
    p!("epsilon", Float, "1", 1e-9, "C_BAL denominator offset"),
    p!("group", Int, "1024", 1.0, "edges per frozen-state scoring group"),
    p!("chunk", Int, "4096", 1.0, "edges per ingestion fill"),
];

static DBH_PARAMS: &[ParamSpec] =
    &[p!("chunk", Int, "4096", 1.0, "edges per ingestion fill")];

static RESTREAM_PARAMS: &[ParamSpec] = &[
    p!("lambda", Float, "1.1", 0.0, "balance weight of the initial HDRF pass"),
    p!("epsilon", Float, "1", 1e-9, "C_BAL denominator offset of the HDRF pass"),
    p!("passes", Int, "1", 1.0, "refinement replays after the initial pass"),
    p!("group", Int, "1024", 1.0, "scoring-group size (HDRF pass and replays)"),
    p!("chunk", Int, "4096", 1.0, "edges per ingestion fill"),
];

static REFINE_PARAMS: &[ParamSpec] = &[
    p!("base", Spec, "hdrf", NO_MIN, "initial partitioner to refine"),
    p!("rounds", Int, "4", 1.0, "max local-search rounds (early-stops)"),
    p!("eps", Float, "0.05", 0.0, "balance slack over the ideal part size"),
];

static ENTRIES: &[AlgoEntry] = &[
    AlgoEntry {
        name: "dfep",
        aliases: &[],
        summary: "the paper's funding-based edge partitioner",
        citation: "Guerrieri & Montresor 2014, \u{a7}IV",
        params: DFEP_PARAMS,
        streaming_native: false,
        factory: |r| {
            Box::new(Dfep {
                funding_cap: r.f64("cap"),
                initial_fraction: r.f64("init"),
                max_rounds: r.usize("max_rounds"),
                frontier_first: r.bool("frontier_first"),
            })
        },
    },
    AlgoEntry {
        name: "dfepc",
        aliases: &[],
        summary: "DFEP plus poor-partition raids on rich neighbors",
        citation: "Guerrieri & Montresor 2014, \u{a7}IV-A",
        params: DFEPC_PARAMS,
        streaming_native: false,
        factory: |r| {
            Box::new(Dfepc {
                poverty_divisor: r.f64("p"),
                funding_cap: r.f64("cap"),
                initial_fraction: r.f64("init"),
                max_rounds: r.usize("max_rounds"),
                rebalance_rounds: r.usize("rebalance"),
            })
        },
    },
    AlgoEntry {
        name: "jabeja",
        aliases: &["ja-be-ja"],
        summary: "simulated-annealing swap baseline, vertex-to-edge",
        citation: "Rahimian et al. 2013",
        params: JABEJA_PARAMS,
        streaming_native: false,
        factory: |r| {
            Box::new(JaBeJa {
                rounds: r.usize("rounds"),
                t0: r.f64("temp"),
                delta: r.f64("delta"),
                sample: r.usize("sample"),
                alpha: r.f64("alpha"),
            })
        },
    },
    AlgoEntry {
        name: "random",
        aliases: &[],
        summary: "uniform random edge assignment",
        citation: "Guerrieri & Montresor 2014, \u{a7}IV (strawman)",
        params: &[],
        streaming_native: false,
        factory: |_| Box::new(RandomEdge),
    },
    AlgoEntry {
        name: "hash",
        aliases: &[],
        summary: "round-robin edge assignment",
        citation: "Guerrieri & Montresor 2014, \u{a7}IV (strawman)",
        params: &[],
        streaming_native: false,
        factory: |_| Box::new(HashEdge),
    },
    AlgoEntry {
        name: "greedy",
        aliases: &["greedybfs"],
        summary: "lockstep greedy BFS growth",
        citation: "Guerrieri & Montresor 2014, \u{a7}IV (sketch)",
        params: &[],
        streaming_native: false,
        factory: |_| Box::new(GreedyBfs),
    },
    AlgoEntry {
        name: "fennel",
        aliases: &["streaming"],
        summary: "Fennel-style greedy over a shuffled edge order",
        citation: "Tsourakakis et al. 2014",
        params: FENNEL_PARAMS,
        streaming_native: false,
        factory: |r| {
            Box::new(StreamingGreedy {
                gamma: r.f64("gamma"),
                shuffle: r.bool("shuffle"),
            })
        },
    },
    AlgoEntry {
        name: "multilevel",
        aliases: &["metis"],
        summary: "METIS-style coarsen / partition / refine",
        citation: "Karypis & Kumar 1998",
        params: MULTILEVEL_PARAMS,
        streaming_native: false,
        factory: |r| {
            Box::new(Multilevel {
                coarsest: r.usize("coarsest"),
                balance_cap: r.f64("balance_cap"),
                refine_passes: r.usize("refine_passes"),
            })
        },
    },
    AlgoEntry {
        name: "hdrf",
        aliases: &[],
        summary: "High-Degree Replicated First ingest-time greedy",
        citation: "Petroni et al. 2015",
        params: HDRF_PARAMS,
        streaming_native: true,
        factory: |r| {
            Box::new(Hdrf {
                lambda: r.f64("lambda"),
                epsilon: r.f64("epsilon"),
                group: r.usize("group"),
                chunk: r.usize("chunk"),
            })
        },
    },
    AlgoEntry {
        name: "dbh",
        aliases: &[],
        summary: "degree-based hashing, two bounded-memory passes",
        citation: "Xie et al. 2014",
        params: DBH_PARAMS,
        streaming_native: true,
        factory: |r| Box::new(Dbh { chunk: r.usize("chunk") }),
    },
    AlgoEntry {
        name: "restream",
        aliases: &["re-stream"],
        summary: "HDRF plus restreaming refinement replays",
        citation: "Nishimura & Ugander 2013",
        params: RESTREAM_PARAMS,
        streaming_native: true,
        factory: |r| {
            Box::new(Restream {
                inner: Hdrf {
                    lambda: r.f64("lambda"),
                    epsilon: r.f64("epsilon"),
                    group: r.usize("group"),
                    chunk: r.usize("chunk"),
                },
                passes: r.usize("passes"),
                group: r.usize("group"),
                chunk: r.usize("chunk"),
            })
        },
    },
    AlgoEntry {
        name: "refine",
        aliases: &["local-search"],
        summary: "local-search edge-move/swap refinement of any base spec",
        citation: "Guo et al. 2021",
        params: REFINE_PARAMS,
        streaming_native: false,
        factory: |r| {
            Box::new(Refine {
                base: r.spec("base"),
                rounds: r.usize("rounds"),
                eps: r.f64("eps"),
            })
        },
    },
];

/// Every registered partitioner, in display order (the ablation sweep and
/// the property tests iterate this).
pub fn all() -> &'static [AlgoEntry] {
    ENTRIES
}

/// Look an entry up by canonical name or alias (case-insensitive).
pub fn find(name: &str) -> Option<&'static AlgoEntry> {
    let lower = name.to_lowercase();
    ENTRIES
        .iter()
        .find(|e| e.name == lower || e.aliases.contains(&lower.as_str()))
}

/// The comma-separated canonical name list (for error messages / help).
pub fn known_names() -> String {
    let names: Vec<&str> = ENTRIES.iter().map(|e| e.name).collect();
    names.join(", ")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_unique_and_aliases_resolve() {
        let mut seen = std::collections::HashSet::new();
        for e in all() {
            assert!(seen.insert(e.name), "duplicate name {}", e.name);
            for a in e.aliases {
                assert!(seen.insert(*a), "alias {a} collides");
                assert_eq!(find(a).unwrap().name, e.name);
            }
            assert_eq!(find(e.name).unwrap().name, e.name);
            assert_eq!(find(&e.name.to_uppercase()).unwrap().name, e.name);
        }
        assert!(find("nosuch").is_none());
    }

    #[test]
    fn defaults_parse_as_their_kind() {
        for e in all() {
            for p in e.params {
                match p.kind {
                    ParamKind::Float => {
                        let v: f64 = p.default.parse().unwrap();
                        assert!(v >= p.min, "{}:{}", e.name, p.key);
                    }
                    ParamKind::Int => {
                        let v: usize = p.default.parse().unwrap();
                        assert!(v as f64 >= p.min, "{}:{}", e.name, p.key);
                    }
                    ParamKind::Bool => {
                        parse_bool(p.default).unwrap();
                    }
                    ParamKind::Spec => {
                        let inner = PartitionerSpec::parse(
                            &p.default.replace('+', ","),
                        )
                        .unwrap();
                        assert_ne!(
                            inner.name(),
                            e.name,
                            "{}:{} defaults to itself",
                            e.name,
                            p.key
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn default_factories_match_struct_defaults() {
        // the registry's documented defaults must be the struct defaults
        // the rest of the crate uses
        use crate::graph::generators::GraphKind;
        let g = GraphKind::ErdosRenyi { n: 120, m: 360 }.generate(3);
        for e in all() {
            let built = e.build(&[]);
            assert_eq!(
                built.streaming_native(),
                e.streaming_native,
                "{}",
                e.name
            );
            let a = built.partition_graph(&g, 4, 9).unwrap();
            let reference: Box<dyn Partitioner> = match e.name {
                "dfep" => Box::new(Dfep::default()),
                "dfepc" => Box::new(Dfepc::default()),
                "jabeja" => Box::new(JaBeJa::default()),
                "random" => Box::new(RandomEdge),
                "hash" => Box::new(HashEdge),
                "greedy" => Box::new(GreedyBfs),
                "fennel" => Box::new(StreamingGreedy::default()),
                "multilevel" => Box::new(Multilevel::default()),
                "hdrf" => Box::new(Hdrf::default()),
                "dbh" => Box::new(Dbh::default()),
                "restream" => Box::new(Restream::default()),
                "refine" => Box::new(Refine::default()),
                other => panic!("entry {other} missing a reference default"),
            };
            let b = reference.partition_graph(&g, 4, 9).unwrap();
            assert_eq!(a.owner, b.owner, "{}: defaults drifted", e.name);
        }
    }

    /// DESIGN.md's registry table is generated from this same data; the
    /// test fails (with the expected rows) whenever the table and
    /// `registry::all()` disagree on names, keys or defaults.
    #[test]
    fn design_md_registry_table_matches() {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("../DESIGN.md");
        let text = std::fs::read_to_string(&path).unwrap_or_else(|e| {
            panic!("cannot read {}: {e}", path.display())
        });
        let mut documented = Vec::new();
        for line in text.lines() {
            let Some(rest) = line.strip_prefix("| `") else { continue };
            let Some((name, rest)) = rest.split_once("` | ") else {
                continue;
            };
            // only rows of the registry table: the second cell is the
            // parameter list (`—` when the algorithm has none)
            let Some((params_cell, _)) = rest.split_once(" |") else {
                continue;
            };
            if find(name).is_none() {
                continue;
            }
            documented.push((name.to_string(), params_cell.to_string()));
        }
        let expected: Vec<(String, String)> = all()
            .iter()
            .map(|e| (e.name.to_string(), params_cell(e)))
            .collect();
        assert_eq!(
            documented, expected,
            "DESIGN.md registry table is out of sync with \
             registry::all(); regenerate the rows as `| `name` | params \
             | ... |` using the expected list above"
        );
    }

    /// Render one entry's parameter cell exactly as DESIGN.md writes it.
    fn params_cell(e: &AlgoEntry) -> String {
        if e.params.is_empty() {
            return "\u{2014}".to_string();
        }
        let cells: Vec<String> = e
            .params
            .iter()
            .map(|p| format!("`{}={}`", p.key, p.default))
            .collect();
        cells.join(", ")
    }
}
