//! Multilevel edge partitioner — a METIS-flavored ablation baseline
//! (paper related work §VI-B: "METIS... uses a multilevel partitioning
//! approach... the graph is coarsened into a smaller graph, which is then
//! partitioned and the solution is then refined").
//!
//! Pipeline, adapted to *edge* partitioning:
//!
//! 1. **Coarsen** — repeated heavy-edge matching merges matched vertex
//!    pairs until the graph is small; merged edges carry multiplicities.
//! 2. **Initial partition** — greedy BFS edge growth on the coarsest
//!    graph (balanced by construction).
//! 3. **Uncoarsen + refine** — project the edge assignment back level by
//!    level; at each level a boundary-edge refinement pass moves edges to
//!    the neighboring partition when that reduces frontier replicas
//!    without breaking the balance cap.

use super::{baselines::GreedyBfs, check_k, EdgePartition, Partitioner};
use crate::graph::{Graph, GraphBuilder};
use crate::util::error::Result;
use crate::util::rng::Rng;

/// METIS-style multilevel partitioner: coarsen, partition the coarsest
/// graph, then uncoarsen with balance-capped refinement.
#[derive(Clone, Debug)]
pub struct Multilevel {
    /// Stop coarsening when the graph has at most this many vertices
    /// (also bounded below by 4k so the initial partition has room).
    pub coarsest: usize,
    /// Balance cap for refinement: a move may not push a partition above
    /// `cap * |E|/K`.
    pub balance_cap: f64,
    /// Refinement passes per level.
    pub refine_passes: usize,
}

impl Default for Multilevel {
    fn default() -> Self {
        Multilevel { coarsest: 256, balance_cap: 1.08, refine_passes: 2 }
    }
}

/// One coarsening level: the coarser graph plus the vertex mapping
/// fine -> coarse and, per coarse edge, the list of fine edges it bundles.
struct Level {
    graph: Graph,
    /// fine edge id -> coarse edge id (or u32::MAX for edges collapsed
    /// inside a merged vertex pair — those are assigned in projection).
    fine_to_coarse_edge: Vec<u32>,
}

fn coarsen(g: &Graph, rng: &mut Rng) -> Option<Level> {
    let n = g.vertex_count();
    // heavy-edge matching on multiplicity (unweighted level 0: random
    // maximal matching)
    let mut matched = vec![u32::MAX; n];
    let mut order: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut order);
    for &v in &order {
        if matched[v as usize] != u32::MAX {
            continue;
        }
        // first unmatched neighbor (random order would need a shuffle per
        // vertex; first-fit on a shuffled vertex order is standard)
        let mut pick = None;
        for &w in g.neighbor_vertices(v) {
            if w != v && matched[w as usize] == u32::MAX {
                pick = Some(w);
                break;
            }
        }
        match pick {
            Some(w) => {
                matched[v as usize] = w;
                matched[w as usize] = v;
            }
            None => matched[v as usize] = v, // self-matched
        }
    }
    // coarse ids
    let mut map = vec![u32::MAX; n];
    let mut next = 0u32;
    for v in 0..n as u32 {
        if map[v as usize] != u32::MAX {
            continue;
        }
        let w = matched[v as usize];
        map[v as usize] = next;
        if w != v && w != u32::MAX {
            map[w as usize] = next;
        }
        next += 1;
    }
    if (next as usize) as f64 > 0.95 * n as f64 {
        return None; // matching stopped making progress
    }
    // build coarse graph; remember which coarse edge each fine edge maps to
    let mut builder = GraphBuilder::new();
    if next > 0 {
        builder.touch_vertex(next - 1);
    }
    let mut coarse_pairs: Vec<(u32, u32)> = Vec::new();
    for (_, u, v) in g.edge_iter() {
        let (cu, cv) = (map[u as usize], map[v as usize]);
        if cu != cv {
            coarse_pairs.push((cu.min(cv), cu.max(cv)));
            builder.push_edge(cu, cv);
        } else {
            coarse_pairs.push((u32::MAX, u32::MAX));
        }
    }
    let graph = builder.build();
    // canonical coarse edge ids are positions in the sorted-dedup edge
    // list; binary-search each fine edge's pair
    let fine_to_coarse_edge = coarse_pairs
        .iter()
        .map(|&(a, b)| {
            if a == u32::MAX {
                u32::MAX
            } else {
                graph
                    .edges()
                    .binary_search(&(a, b))
                    .map(|i| i as u32)
                    .unwrap_or(u32::MAX)
            }
        })
        .collect();
    let _ = map;
    Some(Level { graph, fine_to_coarse_edge })
}

/// Refinement: move boundary edges to the adjacent partition when the
/// frontier-replica count drops and balance stays within the cap.
fn refine(
    g: &Graph,
    owner: &mut [u32],
    k: usize,
    cap: f64,
    passes: usize,
) {
    let ideal = g.edge_count() as f64 / k as f64;
    let max_size = (cap * ideal).ceil() as usize;
    let mut sizes = vec![0usize; k];
    for &o in owner.iter() {
        sizes[o as usize] += 1;
    }
    // count, per vertex, how many incident edges each partition owns —
    // a vertex is replicated in every partition with count > 0
    let n = g.vertex_count();
    let mut incident: Vec<std::collections::HashMap<u32, u32>> =
        vec![Default::default(); n];
    for (e, u, v) in g.edge_iter() {
        let o = owner[e as usize];
        *incident[u as usize].entry(o).or_insert(0) += 1;
        *incident[v as usize].entry(o).or_insert(0) += 1;
    }
    let replica_delta = |incident: &[std::collections::HashMap<u32, u32>],
                         vert: usize,
                         from: u32,
                         to: u32|
     -> i64 {
        let mut d = 0i64;
        if incident[vert].get(&from).copied().unwrap_or(0) == 1 {
            d -= 1; // last `from` edge at this vertex leaves
        }
        if incident[vert].get(&to).copied().unwrap_or(0) == 0 {
            d += 1; // first `to` edge arrives
        }
        d
    };
    let mut cands: Vec<u32> = Vec::with_capacity(8);
    for _ in 0..passes {
        let mut moved = 0usize;
        for (e, u, v) in g.edge_iter() {
            let from = owner[e as usize];
            // candidate targets: partitions already present on u or v —
            // collected into a sorted list so the scan order (and the
            // tie-break on equal deltas) never depends on HashMap
            // iteration order
            cands.clear();
            for vert in [u as usize, v as usize] {
                cands.extend(incident[vert].keys().copied());
            }
            cands.sort_unstable();
            cands.dedup();
            let mut best: Option<(u32, i64)> = None;
            for &cand in &cands {
                if cand == from || sizes[cand as usize] + 1 > max_size {
                    continue;
                }
                let d = replica_delta(&incident, u as usize, from, cand)
                    + replica_delta(&incident, v as usize, from, cand);
                if d < 0 && best.map(|(_, bd)| d < bd).unwrap_or(true) {
                    best = Some((cand, d));
                }
            }
            if let Some((to, _)) = best {
                owner[e as usize] = to;
                sizes[from as usize] -= 1;
                sizes[to as usize] += 1;
                for vert in [u as usize, v as usize] {
                    let c = incident[vert].get_mut(&from).unwrap();
                    *c -= 1;
                    if *c == 0 {
                        incident[vert].remove(&from);
                    }
                    *incident[vert].entry(to).or_insert(0) += 1;
                }
                moved += 1;
            }
        }
        if moved == 0 {
            break;
        }
    }
}

impl Partitioner for Multilevel {
    fn partition_graph(
        &self,
        g: &Graph,
        k: usize,
        seed: u64,
    ) -> Result<EdgePartition> {
        check_k(k)?;
        let mut rng = Rng::new(seed);
        // ---- coarsen ----
        let mut levels: Vec<Level> = Vec::new();
        let mut current = g.clone();
        let coarsest = self.coarsest.max(4 * k);
        let mut rounds = 0usize;
        while current.vertex_count() > coarsest {
            match coarsen(&current, &mut rng) {
                Some(level) => {
                    rounds += 1;
                    current = level.graph.clone();
                    levels.push(level);
                }
                None => break,
            }
        }
        // ---- initial partition on the coarsest graph ----
        let mut owner = if current.edge_count() > 0 {
            GreedyBfs.partition_graph(&current, k, rng.next_u64())?.owner
        } else {
            Vec::new()
        };
        refine(&current, &mut owner, k, self.balance_cap, self.refine_passes);
        // ---- uncoarsen + refine ----
        for li in (0..levels.len()).rev() {
            let fine = if li == 0 { g } else { &levels[li - 1].graph };
            let level = &levels[li];
            let mut fine_owner = vec![u32::MAX; fine.edge_count()];
            for (e, _, _) in fine.edge_iter() {
                let ce = level.fine_to_coarse_edge[e as usize];
                if ce != u32::MAX {
                    fine_owner[e as usize] = owner[ce as usize];
                }
                // edges collapsed inside a merged pair stay MAX and
                // inherit from an adjacent assigned edge via finalize()
            }
            // collapsed edges inherit from an adjacent assigned edge
            fine_owner = super::dfep::finalize(fine, fine_owner, k);
            refine(
                fine,
                &mut fine_owner,
                k,
                self.balance_cap,
                self.refine_passes,
            );
            owner = fine_owner;
            rounds += 1;
        }
        if levels.is_empty() {
            // graph was already small: owner is for `current == g` clone
            let mut o = owner;
            refine(g, &mut o, k, self.balance_cap, self.refine_passes);
            return Ok(EdgePartition {
                k,
                owner: o,
                rounds: rounds.max(1),
            });
        }
        Ok(EdgePartition { k, owner, rounds: rounds.max(1) })
    }

    fn name(&self) -> &'static str {
        "Multilevel"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::GraphKind;
    use crate::partition::{baselines::RandomEdge, metrics};

    fn g() -> Graph {
        GraphKind::PowerlawCluster { n: 800, m: 4, p: 0.3 }.generate(5)
    }

    #[test]
    fn complete_and_valid() {
        let g = g();
        let p = Multilevel::default().partition_graph(&g, 8, 1).unwrap();
        p.validate(&g).unwrap();
    }

    #[test]
    fn balance_within_cap_margin() {
        let g = g();
        let p = Multilevel::default().partition_graph(&g, 8, 2).unwrap();
        // finalize() of collapsed edges can exceed the refine cap slightly
        assert!(
            metrics::largest(&g, &p) < 1.5,
            "largest {}",
            metrics::largest(&g, &p)
        );
    }

    #[test]
    fn fewer_messages_than_random() {
        let g = g();
        let p = Multilevel::default().partition_graph(&g, 8, 3).unwrap();
        let r = RandomEdge.partition_graph(&g, 8, 3).unwrap();
        assert!(
            metrics::messages(&g, &p) < metrics::messages(&g, &r),
            "multilevel {} !< random {}",
            metrics::messages(&g, &p),
            metrics::messages(&g, &r)
        );
    }

    #[test]
    fn handles_tiny_graph_without_coarsening() {
        let g = GraphKind::ErdosRenyi { n: 40, m: 80 }.generate(1);
        let p = Multilevel::default().partition_graph(&g, 4, 1).unwrap();
        p.validate(&g).unwrap();
    }

    #[test]
    fn refinement_reduces_messages() {
        let g = g();
        let mut owner = RandomEdge.partition_graph(&g, 6, 4).unwrap().owner;
        let before = metrics::messages(
            &g,
            &EdgePartition { k: 6, owner: owner.clone(), rounds: 1 },
        );
        refine(&g, &mut owner, 6, 1.3, 3);
        let after = metrics::messages(
            &g,
            &EdgePartition { k: 6, owner, rounds: 1 },
        );
        assert!(after < before, "{after} !< {before}");
    }
}
