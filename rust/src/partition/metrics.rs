//! Partition quality metrics (paper §V-A): balance (largest normalized
//! size, NSTDEV), communication cost (MESSAGES = Σ|F_i|), connectedness,
//! and path-compression *gain* (computed by the ETSCH engine, re-exported
//! here for the report struct).

use super::EdgePartition;
use crate::graph::Graph;

/// One row of the paper's simulation plots.
#[derive(Clone, Debug)]
pub struct Report {
    pub k: usize,
    /// Size of the largest partition, normalized so 1.0 == |E|/K.
    pub largest: f64,
    /// NSTDEV as defined in §V-A.
    pub nstdev: f64,
    /// MESSAGES = Σ_i |F_i| (frontier vertices, counted with multiplicity
    /// of partitions they appear in).
    pub messages: usize,
    /// Rounds the partitioner needed.
    pub rounds: usize,
    /// Fraction of partitions whose induced subgraph is disconnected.
    pub disconnected: f64,
}

/// Normalized sizes: `|E_i| / (|E|/K)`.
pub fn normalized_sizes(g: &Graph, p: &EdgePartition) -> Vec<f64> {
    let ideal = g.edge_count() as f64 / p.k as f64;
    p.sizes().iter().map(|&s| s as f64 / ideal).collect()
}

/// NSTDEV = sqrt( Σ (|E_i|/(E/K) - 1)^2 / K ).
pub fn nstdev(g: &Graph, p: &EdgePartition) -> f64 {
    let norm = normalized_sizes(g, p);
    (norm.iter().map(|&x| (x - 1.0) * (x - 1.0)).sum::<f64>()
        / p.k as f64)
        .sqrt()
}

/// Largest normalized partition size.
pub fn largest(g: &Graph, p: &EdgePartition) -> f64 {
    normalized_sizes(g, p)
        .into_iter()
        .fold(0.0f64, f64::max)
}

/// MESSAGES = Σ_i |F_i|: every replica of a frontier vertex must exchange
/// its state each aggregation, so a vertex appearing in `r >= 2` partitions
/// contributes `r` (a vertex in one partition contributes 0).
pub fn messages(g: &Graph, p: &EdgePartition) -> usize {
    p.vertex_multiplicity(g)
        .into_iter()
        .filter(|&r| r >= 2)
        .map(|r| r as usize)
        .sum()
}

/// Fraction of partitions whose induced subgraph is disconnected
/// (Fig 6e). Plain DFEP is always 0; DFEPC and JaBeJa-derived partitions
/// may not be.
pub fn disconnected_fraction(g: &Graph, p: &EdgePartition) -> f64 {
    let sets = p.edge_sets();
    let mut disconnected = 0usize;
    let mut nonempty = 0usize;
    // reusable scratch keyed by vertex
    let mut mark = vec![u32::MAX; g.vertex_count()];
    let mut edge_of: std::collections::HashMap<u32, Vec<(u32, u32)>> =
        std::collections::HashMap::new();
    for (i, edges) in sets.iter().enumerate() {
        if edges.is_empty() {
            continue;
        }
        nonempty += 1;
        // local adjacency over this part's edges
        edge_of.clear();
        for &e in edges {
            let (u, v) = g.endpoints(e);
            edge_of.entry(u).or_default().push((v, e));
            edge_of.entry(v).or_default().push((u, e));
        }
        // BFS from the first edge's endpoint, over this part only
        let stamp = i as u32;
        let (start, _) = g.endpoints(edges[0]);
        let mut stack = vec![start];
        mark[start as usize] = stamp;
        let mut seen_vertices = 1usize;
        while let Some(u) = stack.pop() {
            if let Some(nbrs) = edge_of.get(&u) {
                for &(w, _) in nbrs {
                    if mark[w as usize] != stamp {
                        mark[w as usize] = stamp;
                        seen_vertices += 1;
                        stack.push(w);
                    }
                }
            }
        }
        if seen_vertices != edge_of.len() {
            disconnected += 1;
        }
    }
    if nonempty == 0 {
        0.0
    } else {
        disconnected as f64 / nonempty as f64
    }
}

/// Evaluate everything but gain (gain needs an ETSCH run; see
/// [`crate::etsch::gain`]).
pub fn evaluate(g: &Graph, p: &EdgePartition) -> Report {
    Report {
        k: p.k,
        largest: largest(g, p),
        nstdev: nstdev(g, p),
        messages: messages(g, p),
        rounds: p.rounds,
        disconnected: disconnected_fraction(g, p),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    fn path4() -> Graph {
        // 0-1-2-3-4 (4 edges)
        GraphBuilder::new()
            .add_edge(0, 1)
            .add_edge(1, 2)
            .add_edge(2, 3)
            .add_edge(3, 4)
            .build()
    }

    #[test]
    fn perfect_balance() {
        let g = path4();
        let p = EdgePartition { k: 2, owner: vec![0, 0, 1, 1], rounds: 1 };
        assert_eq!(nstdev(&g, &p), 0.0);
        assert_eq!(largest(&g, &p), 1.0);
    }

    #[test]
    fn imbalance_measured() {
        let g = path4();
        let p = EdgePartition { k: 2, owner: vec![0, 0, 0, 1], rounds: 1 };
        // sizes 3,1; ideal 2 -> normalized 1.5, 0.5 -> nstdev = 0.5
        assert!((nstdev(&g, &p) - 0.5).abs() < 1e-12);
        assert!((largest(&g, &p) - 1.5).abs() < 1e-12);
    }

    #[test]
    fn messages_counts_frontier_multiplicity() {
        let g = path4();
        // alternate ownership: every interior vertex is frontier
        let p = EdgePartition { k: 2, owner: vec![0, 1, 0, 1], rounds: 1 };
        // vertices 1,2,3 appear in both parts -> 3 * 2 = 6
        assert_eq!(messages(&g, &p), 6);
        // contiguous split: only vertex 2 is frontier -> 2
        let p2 = EdgePartition { k: 2, owner: vec![0, 0, 1, 1], rounds: 1 };
        assert_eq!(messages(&g, &p2), 2);
    }

    #[test]
    fn disconnection_detected() {
        let g = path4();
        // part 0 owns edges 0 and 3 (disconnected), part 1 owns 1,2
        let p = EdgePartition { k: 2, owner: vec![0, 1, 1, 0], rounds: 1 };
        assert!((disconnected_fraction(&g, &p) - 0.5).abs() < 1e-12);
        let p2 = EdgePartition { k: 2, owner: vec![0, 0, 1, 1], rounds: 1 };
        assert_eq!(disconnected_fraction(&g, &p2), 0.0);
    }

    #[test]
    fn empty_partitions_ignored_in_disconnection() {
        let g = path4();
        let p = EdgePartition { k: 3, owner: vec![0, 0, 1, 1], rounds: 1 };
        assert_eq!(disconnected_fraction(&g, &p), 0.0);
    }
}
