//! Partition quality metrics (paper §V-A): balance (largest normalized
//! size, NSTDEV), communication cost (MESSAGES = Σ|F_i|), connectedness,
//! and path-compression *gain* (computed by the ETSCH engine, re-exported
//! here for the report struct).
//!
//! Everything derived is read off a single [`PartitionView`] build —
//! callers that also construct an ETSCH engine should build the view
//! once and pass it to [`evaluate_with`] and
//! [`Etsch::from_view`](crate::etsch::Etsch::from_view).

use super::view::PartitionView;
use super::EdgePartition;
use crate::graph::Graph;

/// One row of the paper's simulation plots.
#[derive(Clone, Debug)]
pub struct Report {
    /// Number of parts.
    pub k: usize,
    /// Size of the largest partition, normalized so 1.0 == |E|/K.
    pub largest: f64,
    /// NSTDEV as defined in §V-A.
    pub nstdev: f64,
    /// MESSAGES = Σ_i |F_i| (frontier vertices, counted with multiplicity
    /// of partitions they appear in).
    pub messages: usize,
    /// Rounds the partitioner needed.
    pub rounds: usize,
    /// Fraction of partitions whose induced subgraph is disconnected.
    pub disconnected: f64,
}

/// Normalized sizes: `|E_i| / (|E|/K)`.
pub fn normalized_sizes(g: &Graph, p: &EdgePartition) -> Vec<f64> {
    let ideal = g.edge_count() as f64 / p.k as f64;
    p.sizes().iter().map(|&s| s as f64 / ideal).collect()
}

/// (largest, NSTDEV) over part sizes — the one copy of the §V-A balance
/// formulas, shared by the standalone functions and [`evaluate_with`].
fn balance(sizes: &[usize], edge_count: usize, k: usize) -> (f64, f64) {
    let ideal = edge_count as f64 / k as f64;
    let norm = sizes.iter().map(|&s| s as f64 / ideal);
    let largest = norm.clone().fold(0.0f64, f64::max);
    let nstdev = (norm.map(|x| (x - 1.0) * (x - 1.0)).sum::<f64>()
        / k as f64)
        .sqrt();
    (largest, nstdev)
}

/// NSTDEV = sqrt( Σ (|E_i|/(E/K) - 1)^2 / K ).
pub fn nstdev(g: &Graph, p: &EdgePartition) -> f64 {
    balance(&p.sizes(), g.edge_count(), p.k).1
}

/// Largest normalized partition size.
pub fn largest(g: &Graph, p: &EdgePartition) -> f64 {
    balance(&p.sizes(), g.edge_count(), p.k).0
}

/// MESSAGES = Σ_i |F_i|: every replica of a frontier vertex must exchange
/// its state each aggregation, so a vertex appearing in `r >= 2` partitions
/// contributes `r` (a vertex in one partition contributes 0).
pub fn messages(g: &Graph, p: &EdgePartition) -> usize {
    p.vertex_multiplicity(g)
        .into_iter()
        .filter(|&r| r >= 2)
        .map(|r| r as usize)
        .sum()
}

/// Fraction of partitions whose induced subgraph is disconnected
/// (Fig 6e). Plain DFEP is always 0; DFEPC and JaBeJa-derived partitions
/// may not be. Standalone convenience over one view build; callers with
/// a view in hand use [`PartitionView::disconnected_fraction`].
pub fn disconnected_fraction(g: &Graph, p: &EdgePartition) -> f64 {
    PartitionView::build(g, p).disconnected_fraction()
}

/// Evaluate everything but gain (gain needs an ETSCH run; see
/// [`crate::etsch::gain`]) — one [`PartitionView`] build serves every
/// derived metric.
pub fn evaluate(g: &Graph, p: &EdgePartition) -> Report {
    let view = PartitionView::build(g, p);
    evaluate_with(g, p, &view)
}

/// [`evaluate`] on a view the caller already built (no extra derivation
/// pass over the owner array).
pub fn evaluate_with(
    g: &Graph,
    p: &EdgePartition,
    view: &PartitionView,
) -> Report {
    let (largest, nstdev) = balance(view.sizes(), g.edge_count(), p.k);
    Report {
        k: p.k,
        largest,
        nstdev,
        messages: view.messages(),
        rounds: p.rounds,
        disconnected: view.disconnected_fraction(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    fn path4() -> Graph {
        // 0-1-2-3-4 (4 edges)
        GraphBuilder::new()
            .add_edge(0, 1)
            .add_edge(1, 2)
            .add_edge(2, 3)
            .add_edge(3, 4)
            .build()
    }

    #[test]
    fn perfect_balance() {
        let g = path4();
        let p = EdgePartition { k: 2, owner: vec![0, 0, 1, 1], rounds: 1 };
        assert_eq!(nstdev(&g, &p), 0.0);
        assert_eq!(largest(&g, &p), 1.0);
    }

    #[test]
    fn imbalance_measured() {
        let g = path4();
        let p = EdgePartition { k: 2, owner: vec![0, 0, 0, 1], rounds: 1 };
        // sizes 3,1; ideal 2 -> normalized 1.5, 0.5 -> nstdev = 0.5
        assert!((nstdev(&g, &p) - 0.5).abs() < 1e-12);
        assert!((largest(&g, &p) - 1.5).abs() < 1e-12);
    }

    #[test]
    fn messages_counts_frontier_multiplicity() {
        let g = path4();
        // alternate ownership: every interior vertex is frontier
        let p = EdgePartition { k: 2, owner: vec![0, 1, 0, 1], rounds: 1 };
        // vertices 1,2,3 appear in both parts -> 3 * 2 = 6
        assert_eq!(messages(&g, &p), 6);
        // contiguous split: only vertex 2 is frontier -> 2
        let p2 = EdgePartition { k: 2, owner: vec![0, 0, 1, 1], rounds: 1 };
        assert_eq!(messages(&g, &p2), 2);
    }

    #[test]
    fn disconnection_detected() {
        let g = path4();
        // part 0 owns edges 0 and 3 (disconnected), part 1 owns 1,2
        let p = EdgePartition { k: 2, owner: vec![0, 1, 1, 0], rounds: 1 };
        assert!((disconnected_fraction(&g, &p) - 0.5).abs() < 1e-12);
        let p2 = EdgePartition { k: 2, owner: vec![0, 0, 1, 1], rounds: 1 };
        assert_eq!(disconnected_fraction(&g, &p2), 0.0);
    }

    #[test]
    fn empty_partitions_ignored_in_disconnection() {
        let g = path4();
        let p = EdgePartition { k: 3, owner: vec![0, 0, 1, 1], rounds: 1 };
        assert_eq!(disconnected_fraction(&g, &p), 0.0);
    }
}
