//! Trivial edge partitioners: lower/upper reference points for the benches
//! ("it would be simple to just split the edges in K sets of size |E|/K,
//! but this could have severe implications on communication efficiency,
//! connectedness and path compression" — §IV).

use super::{check_k, EdgePartition, Partitioner};
use crate::graph::Graph;
use crate::util::error::Result;
use crate::util::rng::Rng;

/// Uniform random edge assignment — perfectly balanced in expectation,
/// terrible communication cost and path compression.
#[derive(Clone, Debug, Default)]
pub struct RandomEdge;

impl Partitioner for RandomEdge {
    fn partition_graph(
        &self,
        g: &Graph,
        k: usize,
        seed: u64,
    ) -> Result<EdgePartition> {
        check_k(k)?;
        let mut rng = Rng::new(seed);
        let owner =
            (0..g.edge_count()).map(|_| rng.below(k) as u32).collect();
        Ok(EdgePartition { k, owner, rounds: 1 })
    }

    fn name(&self) -> &'static str {
        "Random"
    }
}

/// Round-robin over canonically sorted edges — exactly balanced (±1),
/// deterministic, no locality whatsoever.
#[derive(Clone, Debug, Default)]
pub struct HashEdge;

impl Partitioner for HashEdge {
    fn partition_graph(
        &self,
        g: &Graph,
        k: usize,
        _seed: u64,
    ) -> Result<EdgePartition> {
        check_k(k)?;
        let owner = (0..g.edge_count()).map(|e| (e % k) as u32).collect();
        Ok(EdgePartition { k, owner, rounds: 1 })
    }

    fn name(&self) -> &'static str {
        "Hash"
    }
}

/// Greedy BFS growth: K random seed edges expand in lockstep, each taking
/// the lowest-id free neighboring edge first — the "simple solution" the
/// paper sketches (and rejects) at the start of §IV. Kept as an ablation:
/// it shows why funding (not just growth) is needed for balance.
#[derive(Clone, Debug, Default)]
pub struct GreedyBfs;

impl Partitioner for GreedyBfs {
    fn partition_graph(
        &self,
        g: &Graph,
        k: usize,
        seed: u64,
    ) -> Result<EdgePartition> {
        check_k(k)?;
        let m = g.edge_count();
        let mut rng = Rng::new(seed);
        let mut owner = vec![u32::MAX; m];
        let mut frontier: Vec<std::collections::VecDeque<u32>> =
            vec![Default::default(); k];
        for (i, e) in rng.sample_indices(m, k.min(m)).into_iter().enumerate()
        {
            owner[e] = i as u32;
            frontier[i].push_back(e as u32);
        }
        let mut remaining = m - k.min(m);
        let mut rounds = 0usize;
        while remaining > 0 {
            rounds += 1;
            let mut progressed = false;
            for i in 0..k {
                // take one new edge per partition per round (lockstep)
                let mut taken = false;
                while let Some(&e) = frontier[i].front() {
                    let (u, v) = g.endpoints(e);
                    let mut advanced = false;
                    for w in [u, v] {
                        for &e2 in g.neighbor_edges(w) {
                            if owner[e2 as usize] == u32::MAX {
                                owner[e2 as usize] = i as u32;
                                frontier[i].push_back(e2);
                                remaining -= 1;
                                taken = true;
                                advanced = true;
                                progressed = true;
                                break;
                            }
                        }
                        if advanced {
                            break;
                        }
                    }
                    if taken {
                        break;
                    }
                    frontier[i].pop_front(); // exhausted edge
                }
                if taken {
                    continue;
                }
            }
            if !progressed {
                // free edges unreachable from any frontier (other
                // component): seed the smallest partition there
                if let Some(e) =
                    (0..m).find(|&e| owner[e] == u32::MAX)
                {
                    let mut sizes = vec![0usize; k];
                    for &o in &owner {
                        if o != u32::MAX {
                            sizes[o as usize] += 1;
                        }
                    }
                    let i = (0..k).min_by_key(|&i| sizes[i]).unwrap();
                    owner[e] = i as u32;
                    frontier[i].push_back(e as u32);
                    remaining -= 1;
                }
            }
        }
        Ok(EdgePartition { k, owner, rounds })
    }

    fn name(&self) -> &'static str {
        "GreedyBFS"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::GraphKind;
    use crate::partition::metrics;

    fn g() -> Graph {
        GraphKind::ErdosRenyi { n: 200, m: 600 }.generate(7)
    }

    #[test]
    fn all_baselines_complete() {
        let g = g();
        for p in [
            RandomEdge.partition_graph(&g, 5, 1).unwrap(),
            HashEdge.partition_graph(&g, 5, 1).unwrap(),
            GreedyBfs.partition_graph(&g, 5, 1).unwrap(),
        ] {
            p.validate(&g).unwrap();
        }
    }

    #[test]
    fn hash_is_perfectly_balanced() {
        let g = g();
        let p = HashEdge.partition_graph(&g, 7, 0).unwrap();
        let sizes = p.sizes();
        let (mn, mx) =
            (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
        assert!(mx - mn <= 1);
    }

    #[test]
    fn random_has_high_messages_vs_greedy() {
        let g = g();
        let mr = metrics::messages(&g, &RandomEdge.partition_graph(&g, 8, 1).unwrap());
        let mg = metrics::messages(&g, &GreedyBfs.partition_graph(&g, 8, 1).unwrap());
        assert!(
            mr > mg,
            "random messages {mr} should exceed greedy {mg}"
        );
    }

    #[test]
    fn greedy_covers_disconnected_graphs() {
        use crate::graph::GraphBuilder;
        let mut b = GraphBuilder::new();
        for i in 0..10u32 {
            b.push_edge(i, i + 1);
        }
        for i in 20..30u32 {
            b.push_edge(i, i + 1);
        }
        let g = b.build();
        let p = GreedyBfs.partition_graph(&g, 3, 2).unwrap();
        p.validate(&g).unwrap();
    }
}
