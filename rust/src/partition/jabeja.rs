//! JaBeJa baseline (Rahimian et al., 2013) + vertex-to-edge conversion.
//!
//! JaBeJa is a decentralized *vertex* partitioner: every vertex starts
//! with a random color; at each round it tries to swap colors with a
//! neighbor or a random peer when the swap reduces (degree-weighted) edge
//! cut, with simulated annealing to escape local minima. The paper
//! converts its output to an edge partitioning by coloring each edge with
//! its endpoints' common color, assigning each *cut* edge uniformly at
//! random to one of its two endpoint partitions (§V-C: the line-graph
//! alternative "can be orders of magnitude bigger").

use super::{check_k, EdgePartition, Partitioner};
use crate::graph::Graph;
use crate::util::error::Result;
use crate::util::rng::Rng;

/// The JaBeJa comparison baseline: simulated-annealing edge swaps.
#[derive(Clone, Debug)]
pub struct JaBeJa {
    /// Number of swap rounds (the paper notes JaBeJa's round count is
    /// mostly independent of the graph; its cost model is per-round).
    pub rounds: usize,
    /// Initial simulated-annealing temperature.
    pub t0: f64,
    /// Temperature decrement per round (T -> max(1, T - delta)).
    pub delta: f64,
    /// Per-vertex random-peer sample size per round.
    pub sample: usize,
    /// Alpha exponent of the JaBeJa energy function.
    pub alpha: f64,
}

impl Default for JaBeJa {
    fn default() -> Self {
        JaBeJa { rounds: 200, t0: 2.0, delta: 0.01, sample: 3, alpha: 2.0 }
    }
}

impl JaBeJa {
    /// Vertex-partitioning phase; returns per-vertex colors.
    pub fn vertex_partition(
        &self,
        g: &Graph,
        k: usize,
        seed: u64,
    ) -> Vec<u32> {
        let n = g.vertex_count();
        let mut rng = Rng::new(seed);
        // balanced random init: shuffled round-robin (JaBeJa swaps preserve
        // the color histogram, so init balance = final balance)
        let mut color: Vec<u32> =
            (0..n).map(|i| (i % k) as u32).collect();
        rng.shuffle(&mut color);

        let mut temp = self.t0;
        // degree of same-color neighbors, recomputed on the fly
        let same = |color: &[u32], v: u32, c: u32| -> f64 {
            g.neighbor_vertices(v).iter().filter(|&&w| color[w as usize] == c).count()
                as f64
        };
        for _ in 0..self.rounds {
            for v in 0..n as u32 {
                let cv = color[v as usize];
                // candidate set: neighbors then random peers
                let mut best: Option<(u32, f64)> = None;
                let dv_old = same(&color, v, cv);
                let consider = |w: u32,
                                    color: &[u32],
                                    best: &mut Option<(u32, f64)>| {
                    let cw = color[w as usize];
                    if cw == cv || w == v {
                        return;
                    }
                    let dw_old = same(color, w, cw);
                    let old = dv_old.powf(self.alpha) + dw_old.powf(self.alpha);
                    // degrees if swapped (ignore the v-w edge adjustment;
                    // JaBeJa's published heuristic does the same)
                    let dv_new = same(color, v, cw);
                    let dw_new = same(color, w, cv);
                    let new =
                        dv_new.powf(self.alpha) + dw_new.powf(self.alpha);
                    if new * temp > old {
                        let gain = new - old;
                        if best.map(|(_, bg)| gain > bg).unwrap_or(true) {
                            *best = Some((w, gain));
                        }
                    }
                };
                for &w in g.neighbor_vertices(v) {
                    consider(w, &color, &mut best);
                }
                for _ in 0..self.sample {
                    let w = rng.below(n) as u32;
                    consider(w, &color, &mut best);
                }
                if let Some((w, _)) = best {
                    color.swap(v as usize, w as usize);
                }
            }
            temp = (temp - self.delta).max(1.0);
        }
        color
    }

    /// The paper's conversion: inner edges take the endpoints' color, cut
    /// edges go to a uniformly random endpoint's partition.
    pub fn edges_from_colors(
        g: &Graph,
        color: &[u32],
        seed: u64,
    ) -> Vec<u32> {
        let mut rng = Rng::new(seed ^ 0x9E37);
        g.edge_iter()
            .map(|(_, u, v)| {
                let (cu, cv) = (color[u as usize], color[v as usize]);
                if cu == cv || rng.chance(0.5) {
                    cu
                } else {
                    cv
                }
            })
            .collect()
    }
}

impl Partitioner for JaBeJa {
    fn partition_graph(
        &self,
        g: &Graph,
        k: usize,
        seed: u64,
    ) -> Result<EdgePartition> {
        check_k(k)?;
        let color = self.vertex_partition(g, k, seed);
        let owner = Self::edges_from_colors(g, &color, seed);
        Ok(EdgePartition { k, owner, rounds: self.rounds })
    }

    fn name(&self) -> &'static str {
        "JaBeJa"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::GraphKind;
    use crate::partition::metrics;

    #[test]
    fn complete_and_valid() {
        let g = GraphKind::ErdosRenyi { n: 200, m: 600 }.generate(1);
        let p = JaBeJa { rounds: 30, ..Default::default() }
            .partition_graph(&g, 4, 2).unwrap();
        p.validate(&g).unwrap();
    }

    #[test]
    fn vertex_histogram_preserved() {
        let g = GraphKind::ErdosRenyi { n: 200, m: 600 }.generate(1);
        let jb = JaBeJa { rounds: 20, ..Default::default() };
        let color = jb.vertex_partition(&g, 4, 3);
        let mut hist = [0usize; 4];
        for &c in &color {
            hist[c as usize] += 1;
        }
        // swaps preserve the histogram exactly
        let expect = g.vertex_count() / 4;
        assert!(hist.iter().all(|&h| (h as i64 - expect as i64).abs() <= 1),
                "{hist:?}");
    }

    #[test]
    fn optimization_reduces_cut() {
        let g = GraphKind::PowerlawCluster { n: 300, m: 4, p: 0.5 }
            .generate(2);
        let jb = JaBeJa { rounds: 60, ..Default::default() };
        let cut = |color: &[u32]| {
            g.edge_iter()
                .filter(|&(_, u, v)| color[u as usize] != color[v as usize])
                .count()
        };
        // initial = shuffled round robin (reconstruct the same way)
        let mut rng = crate::util::rng::Rng::new(5);
        let mut init: Vec<u32> =
            (0..g.vertex_count()).map(|i| (i % 4) as u32).collect();
        rng.shuffle(&mut init);
        let optimized = jb.vertex_partition(&g, 4, 5);
        assert!(
            cut(&optimized) < cut(&init),
            "JaBeJa failed to reduce cut: {} -> {}",
            cut(&init),
            cut(&optimized)
        );
    }

    #[test]
    fn jabeja_more_balanced_on_road_but_more_messages() {
        // the Fig-7 USROADS pattern: JaBeJa balances better but costs far
        // more messages than DFEP on a high-diameter graph
        use crate::partition::dfep::Dfep;
        let g = GraphKind::RoadNetwork {
            rows: 16, cols: 16, drop: 0.2, subdiv: 2, shortcuts: 0,
        }
        .generate(3);
        let jb = JaBeJa { rounds: 60, ..Default::default() }
            .partition_graph(&g, 8, 1).unwrap();
        let df = Dfep::default().partition_graph(&g, 8, 1).unwrap();
        let m_jb = metrics::messages(&g, &jb);
        let m_df = metrics::messages(&g, &df);
        assert!(
            m_jb > m_df,
            "expected JaBeJa messages {m_jb} > DFEP {m_df}"
        );
    }
}
