//! DFEPC — the DFEP variant of paper §IV-A.
//!
//! A partition is *poor* at a round if its size is below `mu / p` (mu =
//! average size, `p` = the variant's parameter); otherwise *rich*. Poor
//! partitions may additionally commit funding on edges already owned by
//! rich partitions and buy them on a strictly higher bid. This lets a
//! partition that got boxed in catch up — better balance, at the cost of
//! the connectedness guarantee.
//!
//! The variant reuses the reference engine's
//! [`DfepState`](super::dfep::DfepState) wholesale —
//! including its persistent round scratch and flat
//! [`crate::partition::money::MoneyLedger`] — so DFEPC rounds are just
//! DFEP rounds with the poor/rich raid masks supplied, and inherit the
//! zero-allocation steady state and thread-count-independent trajectory.

use super::dfep::{
    acquire_state, finalize, park_state, reseed_on_free_edge,
};
use super::{check_k, EdgePartition, Partitioner};
use crate::bail;
use crate::graph::Graph;
use crate::util::error::Result;
use crate::util::rng::Rng;

/// The DFEPC variant (§IV-A): DFEP plus poor-partition raids on rich
/// neighbors once coverage completes.
#[derive(Clone, Debug)]
pub struct Dfepc {
    /// Poverty threshold divisor `p` (a partition is poor if
    /// `size < avg / p`).
    pub poverty_divisor: f64,
    /// Per-edge funding cap (same semantics as [`super::dfep::Dfep`]).
    pub funding_cap: f64,
    /// Initial funding multiplier on `|E|/k`.
    pub initial_fraction: f64,
    /// Round bound.
    pub max_rounds: usize,
    /// Extra rounds after full coverage during which poor partitions may
    /// keep raiding (lets balance improve once every edge is owned).
    pub rebalance_rounds: usize,
}

impl Default for Dfepc {
    fn default() -> Self {
        Dfepc {
            poverty_divisor: 2.0,
            funding_cap: 10.0,
            initial_fraction: 1.0,
            max_rounds: 10_000,
            rebalance_rounds: 16,
        }
    }
}

impl Dfepc {
    /// Recompute the poor/rich masks in place (the two buffers are
    /// hoisted out of the round loop, so DFEPC rounds stay
    /// allocation-free in steady state like plain DFEP rounds).
    fn poor_rich_into(
        &self,
        sizes: &[usize],
        poor: &mut Vec<bool>,
        rich: &mut Vec<bool>,
    ) {
        let avg =
            sizes.iter().sum::<usize>() as f64 / sizes.len() as f64;
        let thresh = avg / self.poverty_divisor;
        poor.clear();
        poor.extend(sizes.iter().map(|&s| (s as f64) < thresh));
        rich.clear();
        rich.extend(sizes.iter().map(|&s| (s as f64) >= avg));
    }
}

impl Partitioner for Dfepc {
    fn partition_graph(
        &self,
        g: &Graph,
        k: usize,
        seed: u64,
    ) -> Result<EdgePartition> {
        check_k(k)?;
        if g.edge_count() == 0 {
            bail!("DFEPC cannot partition an empty graph (0 edges)");
        }
        let mut rng = Rng::new(seed);
        let initial =
            self.initial_fraction * g.edge_count() as f64 / k as f64;
        let mut st = acquire_state(g, k, initial.max(1.0), &mut rng);
        let mut stall = 0usize;
        let mut poor: Vec<bool> = Vec::with_capacity(k);
        let mut rich: Vec<bool> = Vec::with_capacity(k);
        while st.free_edges > 0 && st.rounds < self.max_rounds {
            let before = st.free_edges;
            self.poor_rich_into(&st.sizes, &mut poor, &mut rich);
            st.funding_round(g, Some(&poor), Some(&rich));
            st.coordinator_step(self.funding_cap);
            if st.free_edges == before {
                stall += 1;
                if stall >= 3 {
                    reseed_on_free_edge(g, &mut st, &mut rng);
                    stall = 0;
                }
            } else {
                stall = 0;
            }
        }
        // post-coverage rebalancing: poor partitions raid rich ones
        for _ in 0..self.rebalance_rounds {
            self.poor_rich_into(&st.sizes, &mut poor, &mut rich);
            if !poor.iter().any(|&b| b) {
                break;
            }
            st.funding_round(g, Some(&poor), Some(&rich));
            st.coordinator_step(self.funding_cap);
        }
        let rounds = st.rounds;
        let owner = finalize(g, std::mem::take(&mut st.owner), k);
        park_state(st);
        Ok(EdgePartition { k, owner, rounds })
    }

    fn name(&self) -> &'static str {
        "DFEPC"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::GraphKind;
    use crate::partition::dfep::Dfep;
    use crate::partition::metrics;
    use crate::util::stats::mean;

    #[test]
    fn complete_and_valid() {
        let g = GraphKind::PowerlawCluster { n: 400, m: 4, p: 0.3 }
            .generate(5);
        let p = Dfepc::default().partition_graph(&g, 8, 1).unwrap();
        p.validate(&g).unwrap();
    }

    #[test]
    fn deterministic() {
        let g = GraphKind::ErdosRenyi { n: 300, m: 900 }.generate(2);
        let a = Dfepc::default().partition_graph(&g, 4, 3).unwrap();
        let b = Dfepc::default().partition_graph(&g, 4, 3).unwrap();
        assert_eq!(a.owner, b.owner);
    }

    #[test]
    fn balances_at_least_as_well_as_dfep_on_road_graphs() {
        // the variant exists precisely for high-diameter graphs where a
        // poor starting vertex boxes a partition in (paper §IV-A)
        let g = GraphKind::RoadNetwork {
            rows: 18, cols: 18, drop: 0.2, subdiv: 2, shortcuts: 0,
        }
        .generate(4);
        let k = 8;
        let seeds = [1u64, 2, 3, 4, 5];
        let nst_c: Vec<f64> = seeds
            .iter()
            .map(|&s| metrics::nstdev(&g, &Dfepc::default().partition_graph(&g, k, s).unwrap()))
            .collect();
        let nst_d: Vec<f64> = seeds
            .iter()
            .map(|&s| metrics::nstdev(&g, &Dfep::default().partition_graph(&g, k, s).unwrap()))
            .collect();
        assert!(
            mean(&nst_c) <= mean(&nst_d) * 1.10,
            "DFEPC should balance at least comparably: {:?} vs {:?}",
            nst_c,
            nst_d
        );
    }
}
