//! `PartitionView` — the one shared derived-state layer for partitions.
//!
//! An [`EdgePartition`] is just an owner array; everything else the system
//! needs (per-part edge lists, per-part local CSRs, the replica table,
//! frontier flags, part sizes) is *derived*. Before this module existed,
//! every consumer re-derived that state independently — the metrics walked
//! the owner array three times, the ETSCH engine twice more. The view
//! builds all of it exactly once, in parallel over partitions on
//! [`crate::util::pool`], and every consumer (metrics, ETSCH, the cluster
//! simulators, benches, the CLI) shares the result.
//!
//! Determinism (see DESIGN.md "Determinism contract"): the only passes
//! over the owner array are a sequential counting sort; each per-part
//! local CSR is a pure function of that part's (ascending) edge-id slice;
//! and all cross-part merges (multiplicity, the replica table) walk parts
//! in fixed ascending order. The view is bit-identical for every pool
//! thread count.

use crate::graph::{Graph, NeighborIter};
use crate::partition::EdgePartition;
use crate::util::pool;

/// A partition's induced subgraph with dense local vertex ids.
///
/// Local ids are assigned in order of first appearance over the part's
/// edges (ascending edge id), so local vertex 0 is the first endpoint of
/// the part's lowest-numbered edge. Memory is O(|E_i|) per the paper's
/// size argument (§II: |V_i| = O(|E_i|)). Like [`Graph`], the local
/// adjacency is struct-of-arrays: neighbor ids and edge ids in two
/// parallel `Vec<u32>`s, so the ETSCH local phase (which only reads
/// neighbors) streams half the bytes.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Subgraph {
    /// Which partition this is.
    pub part: usize,
    /// Global vertex id of each local vertex.
    pub global: Vec<u32>,
    /// Local CSR offsets (length = local vertex count + 1).
    pub offsets: Vec<u32>,
    /// Local neighbor id per adjacency slot.
    pub adj_nbr: Vec<u32>,
    /// Global edge id per adjacency slot (parallel to
    /// [`adj_nbr`](Self::adj_nbr)).
    pub adj_eid: Vec<u32>,
    /// Frontier flag per local vertex (replicated in >= 2 partitions).
    pub frontier: Vec<bool>,
    /// Number of edges in this partition.
    pub edge_count: usize,
}

impl Subgraph {
    /// Number of local vertices.
    #[inline]
    pub fn vertex_count(&self) -> usize {
        self.global.len()
    }

    /// `(local neighbor, global edge id)` pairs incident on `v_local`.
    #[inline]
    pub fn neighbors(&self, v_local: u32) -> NeighborIter<'_> {
        let (lo, hi) = self.adj_range(v_local);
        self.adj_nbr[lo..hi]
            .iter()
            .copied()
            .zip(self.adj_eid[lo..hi].iter().copied())
    }

    /// Local neighbor ids of `v_local` as a slice — what the
    /// neighbor-only local phases scan.
    #[inline]
    pub fn neighbor_vertices(&self, v_local: u32) -> &[u32] {
        let (lo, hi) = self.adj_range(v_local);
        &self.adj_nbr[lo..hi]
    }

    /// Global edge ids incident on `v_local`, parallel to
    /// [`neighbor_vertices`](Self::neighbor_vertices).
    #[inline]
    pub fn neighbor_edges(&self, v_local: u32) -> &[u32] {
        let (lo, hi) = self.adj_range(v_local);
        &self.adj_eid[lo..hi]
    }

    #[inline]
    fn adj_range(&self, v_local: u32) -> (usize, usize) {
        (
            self.offsets[v_local as usize] as usize,
            self.offsets[v_local as usize + 1] as usize,
        )
    }

    /// Local degree of `v_local`.
    #[inline]
    pub fn degree(&self, v_local: u32) -> usize {
        (self.offsets[v_local as usize + 1] - self.offsets[v_local as usize])
            as usize
    }
}

/// All derived state of one (graph, partition) pair, built once.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PartitionView {
    /// Number of parts.
    pub k: usize,
    /// `|E_i|` per part.
    pub sizes: Vec<usize>,
    /// Per-part edge CSR offsets into [`part_edges`](Self::part_edges)
    /// (length k + 1).
    pub part_starts: Vec<u32>,
    /// Edge ids grouped by part, ascending within each part.
    pub part_edges: Vec<u32>,
    /// Number of distinct parts each vertex appears in (frontier
    /// vertices have multiplicity >= 2; isolated vertices 0).
    pub multiplicity: Vec<u32>,
    /// Per-part local subgraphs (dense local ids + CSR + frontier flags).
    pub subs: Vec<Subgraph>,
    /// Replica-table CSR offsets per global vertex (length |V| + 1).
    pub rep_offsets: Vec<u32>,
    /// Replica locations, parts ascending per vertex: (part, local id).
    pub replicas: Vec<(u32, u32)>,
    /// MESSAGES = Σ over frontier vertices of their replica count.
    pub frontier_total: usize,
}

impl PartitionView {
    /// Derive everything from the owner array in one build.
    pub fn build(g: &Graph, p: &EdgePartition) -> PartitionView {
        let k = p.k;
        let n = g.vertex_count();

        // ---- the derivation pass over the owner array: counting sort of
        // edge ids into the per-part edge CSR (ascending within parts) ----
        let mut sizes = vec![0usize; k];
        for &o in &p.owner {
            sizes[o as usize] += 1;
        }
        let mut part_starts = vec![0u32; k + 1];
        for i in 0..k {
            part_starts[i + 1] = part_starts[i] + sizes[i] as u32;
        }
        let mut part_edges = vec![0u32; p.owner.len()];
        let mut cursor: Vec<u32> = part_starts[..k].to_vec();
        for (e, &o) in p.owner.iter().enumerate() {
            part_edges[cursor[o as usize] as usize] = e as u32;
            cursor[o as usize] += 1;
        }

        // ---- per-part local CSRs, one pool shard per part (each a pure
        // function of its edge slice; merged in fixed part order below) ----
        let mut subs: Vec<Subgraph> = (0..k)
            .map(|part| Subgraph {
                part,
                global: Vec::new(),
                offsets: vec![0],
                adj_nbr: Vec::new(),
                adj_eid: Vec::new(),
                frontier: Vec::new(),
                edge_count: 0,
            })
            .collect();
        {
            let part_starts = &part_starts;
            let part_edges = &part_edges;
            pool::run_mut(&mut subs, &|part, sub: &mut Subgraph| {
                let edges = &part_edges[part_starts[part] as usize
                    ..part_starts[part + 1] as usize];
                build_local_csr(g, edges, sub);
            });
        }

        // ---- vertex multiplicity: fixed ascending part order ----
        let mut multiplicity = vec![0u32; n];
        for sub in &subs {
            for &gv in &sub.global {
                multiplicity[gv as usize] += 1;
            }
        }

        // ---- frontier flags (read-only fan-out over the shared mult) ----
        {
            let mult = &multiplicity;
            pool::run_mut(&mut subs, &|_, sub: &mut Subgraph| {
                sub.frontier = sub
                    .global
                    .iter()
                    .map(|&gv| mult[gv as usize] >= 2)
                    .collect();
            });
        }

        // ---- replica table: vertex -> (part, local), parts ascending ----
        let mut rep_offsets = vec![0u32; n + 1];
        for v in 0..n {
            rep_offsets[v + 1] = rep_offsets[v] + multiplicity[v];
        }
        let mut replicas = vec![(0u32, 0u32); rep_offsets[n] as usize];
        let mut rcursor: Vec<u32> = rep_offsets[..n].to_vec();
        for sub in &subs {
            for (l, &gv) in sub.global.iter().enumerate() {
                replicas[rcursor[gv as usize] as usize] =
                    (sub.part as u32, l as u32);
                rcursor[gv as usize] += 1;
            }
        }

        let frontier_total = multiplicity
            .iter()
            .filter(|&&m| m >= 2)
            .map(|&m| m as usize)
            .sum();

        PartitionView {
            k,
            sizes,
            part_starts,
            part_edges,
            multiplicity,
            subs,
            rep_offsets,
            replicas,
            frontier_total,
        }
    }

    /// `|E_i|` per part.
    pub fn sizes(&self) -> &[usize] {
        &self.sizes
    }

    /// Edge ids of one part, ascending.
    pub fn edges_of(&self, part: usize) -> &[u32] {
        &self.part_edges[self.part_starts[part] as usize
            ..self.part_starts[part + 1] as usize]
    }

    /// Replica locations of a global vertex: (part, local id), parts
    /// ascending. Empty for isolated vertices.
    pub fn replicas_of(&self, v: u32) -> &[(u32, u32)] {
        &self.replicas[self.rep_offsets[v as usize] as usize
            ..self.rep_offsets[v as usize + 1] as usize]
    }

    /// The per-part local subgraphs.
    pub fn subgraphs(&self) -> &[Subgraph] {
        &self.subs
    }

    /// Consume the view, keeping only the subgraphs (the thin projection
    /// behind [`crate::etsch::build_subgraphs`]).
    pub fn into_subgraphs(self) -> Vec<Subgraph> {
        self.subs
    }

    /// MESSAGES = Σ_i |F_i| (paper §V-A): every replica of a frontier
    /// vertex exchanges state each aggregation.
    pub fn messages(&self) -> usize {
        self.frontier_total
    }

    /// Total replica count Σ_v |{parts containing v}| — the replication
    /// factor's numerator (RF = this / |V|). The quantity the
    /// [`crate::partition::refine`] pass strictly decreases.
    pub fn replica_total(&self) -> usize {
        self.replicas.len()
    }

    /// Fraction of nonempty parts whose induced subgraph is disconnected
    /// (Fig 6e), computed on the per-part local CSRs — no per-part hash
    /// adjacency. Parallel over parts; the verdict per part is a pure
    /// function of its local CSR.
    pub fn disconnected_fraction(&self) -> f64 {
        // 0 = empty part, 1 = connected, 2 = disconnected
        let mut flags: Vec<u8> = vec![0; self.k];
        {
            let subs = &self.subs;
            pool::run_mut(&mut flags, &|part, flag: &mut u8| {
                let sub = &subs[part];
                if sub.edge_count == 0 {
                    *flag = 0;
                    return;
                }
                // DFS from local vertex 0 == the first endpoint of the
                // part's lowest-numbered edge (first-appearance order)
                let nv = sub.vertex_count();
                let mut seen = vec![false; nv];
                seen[0] = true;
                let mut reached = 1usize;
                let mut stack = vec![0u32];
                while let Some(u) = stack.pop() {
                    for &w in sub.neighbor_vertices(u) {
                        if !seen[w as usize] {
                            seen[w as usize] = true;
                            reached += 1;
                            stack.push(w);
                        }
                    }
                }
                *flag = if reached == nv { 1 } else { 2 };
            });
        }
        let nonempty = flags.iter().filter(|&&f| f != 0).count();
        let disconnected = flags.iter().filter(|&&f| f == 2).count();
        if nonempty == 0 {
            0.0
        } else {
            disconnected as f64 / nonempty as f64
        }
    }
}

/// Per-part global->local vertex id scratch: a stamp array (the PR5
/// round-engine pattern), replacing the old dense-array / HashMap split.
/// `stamp[w] == mark` says `local[w]` is valid for the current part;
/// [`begin_part`](Self::begin_part) retires every entry by bumping the
/// mark, so reuse across parts costs O(1) instead of an O(|V|) clear or
/// a HashMap rebuild. Both arrays are allocated zeroed (untouched pages
/// never materialize) and only ever *looked up*, never iterated, so the
/// built CSR is identical to what the old scheme produced.
pub(crate) struct LocalIds {
    local: Vec<u32>,
    stamp: Vec<u32>,
    mark: u32,
}

impl LocalIds {
    pub(crate) fn new(vertex_count: usize) -> LocalIds {
        LocalIds {
            local: vec![0; vertex_count],
            stamp: vec![0; vertex_count],
            mark: 0,
        }
    }

    /// Start assigning ids for a new part: one mark bump invalidates all
    /// previous entries. On (astronomically unlikely) mark wraparound the
    /// stamp array is hard-cleared so stale marks can never collide.
    pub(crate) fn begin_part(&mut self) {
        self.mark = self.mark.wrapping_add(1);
        if self.mark == 0 {
            self.stamp.fill(0);
            self.mark = 1;
        }
    }

    /// Local id of `w`, assigning the next one on first sight.
    fn get_or_insert(&mut self, w: u32, next: u32) -> (u32, bool) {
        if self.stamp[w as usize] == self.mark {
            (self.local[w as usize], false)
        } else {
            self.stamp[w as usize] = self.mark;
            self.local[w as usize] = next;
            (next, true)
        }
    }

    #[inline]
    fn get(&self, w: u32) -> u32 {
        debug_assert_eq!(self.stamp[w as usize], self.mark);
        self.local[w as usize]
    }
}

/// Build one part's local CSR from its (ascending) edge-id slice. Local
/// ids are assigned in order of first appearance, exactly like the
/// pre-view `build_subgraphs`, so the result is a pure function of the
/// edge slice.
fn build_local_csr(g: &Graph, edges: &[u32], sub: &mut Subgraph) {
    let mut local_of = LocalIds::new(g.vertex_count());
    local_of.begin_part();
    let mut global: Vec<u32> = Vec::new();
    for &e in edges {
        let (u, v) = g.endpoints(e);
        for w in [u, v] {
            let (_, fresh) =
                local_of.get_or_insert(w, global.len() as u32);
            if fresh {
                global.push(w);
            }
        }
    }
    let nv = global.len();
    let mut offsets = vec![0u32; nv + 1];
    for &e in edges {
        let (u, v) = g.endpoints(e);
        offsets[local_of.get(u) as usize + 1] += 1;
        offsets[local_of.get(v) as usize + 1] += 1;
    }
    for i in 1..offsets.len() {
        offsets[i] += offsets[i - 1];
    }
    let slots = offsets[nv] as usize;
    let mut adj_nbr = vec![0u32; slots];
    let mut adj_eid = vec![0u32; slots];
    let mut cursor = offsets.clone();
    for &e in edges {
        let (u, v) = g.endpoints(e);
        let (lu, lv) = (local_of.get(u), local_of.get(v));
        let cu = cursor[lu as usize] as usize;
        adj_nbr[cu] = lv;
        adj_eid[cu] = e;
        cursor[lu as usize] += 1;
        let cv = cursor[lv as usize] as usize;
        adj_nbr[cv] = lu;
        adj_eid[cv] = e;
        cursor[lv as usize] += 1;
    }
    sub.global = global;
    sub.offsets = offsets;
    sub.adj_nbr = adj_nbr;
    sub.adj_eid = adj_eid;
    sub.frontier = Vec::new(); // filled once multiplicity is known
    sub.edge_count = edges.len();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    fn square() -> (Graph, EdgePartition) {
        let g = GraphBuilder::new()
            .add_edge(0, 1)
            .add_edge(1, 2)
            .add_edge(2, 3)
            .add_edge(3, 0)
            .build();
        // canonical edge order: (0,1),(0,3),(1,2),(2,3)
        let p = EdgePartition { k: 2, owner: vec![0, 0, 1, 1], rounds: 1 };
        (g, p)
    }

    #[test]
    fn edge_csr_matches_slow_edge_sets() {
        let (g, p) = square();
        let view = PartitionView::build(&g, &p);
        let slow = p.edge_sets();
        for part in 0..p.k {
            assert_eq!(view.edges_of(part), &slow[part][..], "part {part}");
        }
        assert_eq!(view.sizes(), &p.sizes()[..]);
    }

    #[test]
    fn replica_table_is_part_ascending_and_consistent() {
        let (g, p) = square();
        let view = PartitionView::build(&g, &p);
        for v in 0..g.vertex_count() as u32 {
            let reps = view.replicas_of(v);
            assert_eq!(reps.len(), view.multiplicity[v as usize] as usize);
            for w in reps.windows(2) {
                assert!(w[0].0 < w[1].0, "parts not ascending for {v}");
            }
            for &(part, l) in reps {
                assert_eq!(
                    view.subs[part as usize].global[l as usize],
                    v,
                    "replica of {v} points at the wrong local slot"
                );
            }
        }
        // vertices 1 and 3 are frontier
        assert_eq!(view.multiplicity, vec![1, 2, 1, 2]);
        assert_eq!(view.messages(), 4);
    }

    #[test]
    fn subgraphs_match_first_appearance_order() {
        let (g, p) = square();
        let view = PartitionView::build(&g, &p);
        // part 0 owns edges (0,1),(0,3): first-appearance order 0,1,3
        assert_eq!(view.subs[0].global, vec![0, 1, 3]);
        assert_eq!(view.subs[0].edge_count, 2);
        for sub in view.subgraphs() {
            for (l, &gv) in sub.global.iter().enumerate() {
                let expect = gv == 1 || gv == 3;
                assert_eq!(sub.frontier[l], expect, "vertex {gv}");
            }
            let total: usize =
                (0..sub.vertex_count() as u32).map(|v| sub.degree(v)).sum();
            assert_eq!(total, 2 * sub.edge_count);
        }
    }

    #[test]
    fn disconnection_detected_on_local_csr() {
        let (g, _) = square();
        // part 0 owns (0,1)+(2,3), part 1 owns (0,3)+(1,2): both split
        let p = EdgePartition { k: 2, owner: vec![0, 1, 1, 0], rounds: 1 };
        let view = PartitionView::build(&g, &p);
        assert!((view.disconnected_fraction() - 1.0).abs() < 1e-12);
        let p2 = EdgePartition { k: 2, owner: vec![0, 0, 1, 1], rounds: 1 };
        assert_eq!(
            PartitionView::build(&g, &p2).disconnected_fraction(),
            0.0
        );
    }

    #[test]
    fn empty_parts_are_represented_and_skipped() {
        let (g, _) = square();
        let p = EdgePartition { k: 3, owner: vec![0, 0, 1, 1], rounds: 1 };
        let view = PartitionView::build(&g, &p);
        assert_eq!(view.subs[2].vertex_count(), 0);
        assert_eq!(view.subs[2].edge_count, 0);
        assert_eq!(view.edges_of(2), &[] as &[u32]);
        assert_eq!(view.disconnected_fraction(), 0.0);
    }
}
